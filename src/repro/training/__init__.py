from repro.training.train_loop import (HParams, TrainState, Watchdog,
                                       init_state, make_train_step,
                                       train_loop, train_step)
