"""Training step + loop: value_and_grad over the sharded model, AdamW /
factored updates, aux-loss-free router-bias adjustment, watchdog-based
straggler/failure handling, checkpoint/restart.
"""
from __future__ import annotations

import bisect
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (DistCtx, batch_spec, param_pspecs,
                                        param_shardings)
from repro.models import model_zoo as Z
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup

Array = jax.Array


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState


@dataclass(frozen=True)
class HParams:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    max_grad_norm: float = 1.0
    moe_mode: str = "ht"            # "ht" | "ll" | "ref"
    moe_chunks: int = 1
    causal_skip: bool = False
    router_bias_lr: float = 1e-3
    loss_chunk: int = 2048
    seed: int = 0
    unroll: bool = False        # python-loop layers (dry-run cost extraction)
    sp_islands: bool = False    # manual TP+SP shard_map blocks (§Perf)
    remat_policy: str = "full"  # "full" | "dots" (§Perf)


def init_state(cfg: ModelConfig, key: Array, *,
               dist: Optional[DistCtx] = None) -> TrainState:
    if dist is not None:
        def initer(k):
            return Z.init_params(cfg, k)

        params = jax.jit(initer,
                         out_shardings=_state_param_shardings(cfg, dist))(key)
    else:
        params = Z.init_params(cfg, key)
    opt = adamw.init_state(params, factored=(cfg.optimizer == "adafactor"))
    return TrainState(params=params, opt=opt)


def _state_param_shardings(cfg, dist):
    # shapes needed first: use eval_shape to build the sharding tree
    shapes = jax.eval_shape(lambda k: Z.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return param_shardings(cfg, dist, shapes)


def state_shardings(cfg: ModelConfig, dist: DistCtx, state) -> TrainState:
    """NamedSharding pytree for a TrainState (params + mirrored opt state)."""
    pspec = param_shardings(cfg, dist, state.params)
    mu = param_shardings(cfg, dist, state.opt.mu)
    nu = param_shardings(cfg, dist, state.opt.nu)
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(dist.mesh, P())
    return TrainState(params=pspec,
                      opt=adamw.AdamWState(step=scalar, mu=mu, nu=nu))


def _update_router_biases(cfg: ModelConfig, params: dict, loads: dict,
                          lr: float) -> dict:
    """Aux-loss-free balancing: sign-rule bias update per MoE layer."""
    if not cfg.moe.enabled or lr == 0.0:
        return params
    e_real = cfg.moe.n_experts
    blocks = dict(params["blocks"])
    for slot, load in loads.items():           # load: (n_periods, E_pad)
        if slot not in blocks or "moe" not in blocks[slot]:
            continue
        moe_p = dict(blocks[slot]["moe"])
        if "router_b" not in moe_p:
            continue
        e_pad = load.shape[-1]
        target = load.sum(-1, keepdims=True) / e_real
        err = jnp.where(jnp.arange(e_pad)[None] < e_real, target - load, 0.0)
        moe_p["router_b"] = moe_p["router_b"] + lr * jnp.sign(err)
        blocks[slot] = {**blocks[slot], "moe": moe_p}
    return {**params, "blocks": blocks}


def train_step(cfg: ModelConfig, hp: HParams, dist: Optional[DistCtx],
               state: TrainState, batch: dict) -> tuple[TrainState, dict]:
    """One optimizer step.  ``batch``: tokens (B,S), labels (B,S),
    optional prefix (B,P,D)."""

    def lf(params):
        return Z.loss_fn(cfg, params, batch["tokens"], batch["labels"],
                         batch.get("prefix"), dist=dist, moe_mode=hp.moe_mode,
                         moe_chunks=hp.moe_chunks, causal_skip=hp.causal_skip,
                         loss_chunk=hp.loss_chunk, unroll=hp.unroll,
                         sp_islands=hp.sp_islands,
                         remat_policy=hp.remat_policy)

    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state.params)
    lr = cosine_with_warmup(state.opt.step, peak_lr=hp.peak_lr,
                            warmup=hp.warmup, total=hp.total_steps)
    params2, opt2, om = adamw.apply_updates(
        state.params, grads, state.opt, lr=lr, b1=hp.b1, b2=hp.b2,
        weight_decay=hp.weight_decay, factored=(cfg.optimizer == "adafactor"),
        max_grad_norm=hp.max_grad_norm)
    params2 = _update_router_biases(cfg, params2, metrics.pop("loads"),
                                    hp.router_bias_lr)
    out_metrics = {"loss": loss, "lr": lr, **om,
                   **{k: v for k, v in metrics.items()}}
    return TrainState(params2, opt2), out_metrics


def make_train_step(cfg: ModelConfig, hp: HParams,
                    dist: Optional[DistCtx]) -> Callable:
    fn = partial(train_step, cfg, hp, dist)
    if dist is None:
        return jax.jit(fn, donate_argnums=(0,))
    return jax.jit(fn, donate_argnums=(0,))


@dataclass
class WatchdogEvent:
    step: int
    elapsed: float
    kind: str       # "straggler" | "failure"


class Watchdog:
    """Per-step wall-clock watermarking: flags stragglers (steps slower than
    ``straggler_factor`` x the running median) and invokes the failure
    callback on deadline breach (simulated node loss in tests)."""

    def __init__(self, deadline_s: float = 600.0, straggler_factor: float = 2.0):
        self.deadline = deadline_s
        self.factor = straggler_factor
        self.history: list[float] = []   # arrival-order window (<= 100)
        self._sorted: list[float] = []   # same window, kept sorted
        self.events: list[WatchdogEvent] = []

    def observe(self, step: int, elapsed: float) -> Optional[WatchdogEvent]:
        # the sorted window is maintained incrementally (one bisect insert
        # and at most one removal per step) instead of re-sorting the whole
        # history every observation; the upper-median index matches the old
        # sorted(history)[len // 2] exactly
        ev = None
        if elapsed > self.deadline:
            ev = WatchdogEvent(step, elapsed, "failure")
        elif self.history:
            med = self._sorted[len(self._sorted) // 2]
            if elapsed > self.factor * med and len(self.history) >= 5:
                ev = WatchdogEvent(step, elapsed, "straggler")
        self.history.append(elapsed)
        bisect.insort(self._sorted, elapsed)
        if len(self.history) > 100:
            oldest = self.history.pop(0)
            del self._sorted[bisect.bisect_left(self._sorted, oldest)]
        if ev:
            self.events.append(ev)
        return ev


def train_loop(cfg: ModelConfig, hp: HParams, dist, data, *,
               steps: int, state: Optional[TrainState] = None,
               checkpointer=None, ckpt_every: int = 0,
               log_every: int = 10, watchdog: Optional[Watchdog] = None,
               fail_injector: Optional[Callable[[int], bool]] = None,
               log_fn: Callable[[str], None] = print) -> tuple[TrainState, list]:
    """Fault-tolerant loop: on injected/real failure, restore the latest
    checkpoint and continue (restart-from-checkpoint recovery).

    ``data``: either ``fn(step) -> batch`` (preferred — replaying a step
    after checkpoint restore re-reads the SAME batch, making recovery
    deterministic) or an iterator (legacy; replays advance the stream)."""
    if state is None:
        state = init_state(cfg, jax.random.PRNGKey(hp.seed), dist=dist)
    if callable(data) and not hasattr(data, "__next__"):
        get_batch = data
    else:
        it = iter(data)
        get_batch = lambda s: next(it)  # noqa: E731
    step_fn = make_train_step(cfg, hp, dist)
    start = 0
    if checkpointer is not None:
        restored = checkpointer.restore_latest(state)
        if restored is not None:
            state, start = restored
            log_fn(f"[train] restored checkpoint at step {start}")
    history = []
    step = start
    while step < steps:
        t0 = time.perf_counter()
        if fail_injector is not None and fail_injector(step):
            log_fn(f"[train] simulated failure at step {step}; recovering")
            assert checkpointer is not None, "failure without checkpointing"
            restored = checkpointer.restore_latest(state)
            if restored is not None:
                state, step = restored
            step_fn = make_train_step(cfg, hp, dist)  # fresh executable
            continue
        batch = get_batch(step)
        state, metrics = step_fn(state, batch)
        elapsed = time.perf_counter() - t0
        if watchdog is not None:
            ev = watchdog.observe(step, elapsed)
            if ev is not None:
                log_fn(f"[watchdog] {ev.kind} at step {ev.step}: {ev.elapsed:.2f}s")
        if log_every and step % log_every == 0:
            log_fn(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                   f"xent={float(metrics['xent']):.4f} "
                   f"gnorm={float(metrics['grad_norm']):.3f} "
                   f"({elapsed*1e3:.0f} ms)")
        history.append({k: float(v) for k, v in metrics.items()
                        if jnp.ndim(v) == 0})
        step += 1
        if checkpointer is not None and ckpt_every and step % ckpt_every == 0:
            checkpointer.save(state, step)
    return state, history
