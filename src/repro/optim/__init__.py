from repro.optim.adamw import AdamWState, apply_updates, global_norm, init_state
from repro.optim.schedule import cosine_with_warmup

__all__ = ["AdamWState", "apply_updates", "global_norm", "init_state",
           "cosine_with_warmup"]
