"""Optimizers in pure JAX: AdamW and a factored-second-moment variant
(adafactor-style) for very large models (jamba-398B) whose fp32 Adam state
would not fit the single-pod HBM budget (DESIGN.md §4).

State layouts follow the param pytree; sharding of the state follows the
param sharding (plus the fsdp axes — see distributed.sharding).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    mu: dict
    nu: dict        # full second moment (adamw) or factored dict (adafactor)


def _is_factorable(x: Array) -> bool:
    return x.ndim >= 2 and x.shape[-1] >= 128 and x.shape[-2] >= 128


def init_state(params: dict, *, factored: bool = False,
               mu_dtype=jnp.float32) -> AdamWState:
    mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mu_dtype), params)
    if not factored:
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    else:
        def f(p):
            if _is_factorable(p):
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"full": jnp.zeros_like(p, dtype=jnp.float32)}
        nu = jax.tree.map(f, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def apply_updates(params: dict, grads: dict, state: AdamWState, *,
                  lr: float | Array, b1: float = 0.9, b2: float = 0.95,
                  eps: float = 1e-8, weight_decay: float = 0.1,
                  factored: bool = False,
                  max_grad_norm: Optional[float] = 1.0,
                  ) -> tuple[dict, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    if max_grad_norm is not None:
        scale = jnp.minimum(1.0, max_grad_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd_full(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu2 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * g * g
        u = (mu2 / c1) / (jnp.sqrt(nu2 / c2) + eps)
        p2 = p - lr * (u + weight_decay * p)
        return p2, mu2.astype(mu.dtype), nu2

    def upd_fact(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu2 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        if "full" in nu:
            nu2 = {"full": b2 * nu["full"] + (1 - b2) * g * g}
            v = nu2["full"] / c2
        else:
            g2 = g * g
            row = b2 * nu["row"] + (1 - b2) * g2.mean(-1)
            col = b2 * nu["col"] + (1 - b2) * g2.mean(-2)
            nu2 = {"row": row, "col": col}
            rmean = row.mean(-1, keepdims=True)[..., None]
            v = (row[..., None] * col[..., None, :]) / jnp.maximum(rmean, 1e-30)
            v = v / c2
        u = (mu2 / c1) / (jnp.sqrt(v) + eps)
        p2 = p - lr * (u + weight_decay * p)
        return p2, mu2.astype(mu.dtype), nu2

    if factored:
        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_mu = treedef.flatten_up_to(state.mu)
        leaves_nu = treedef.flatten_up_to(state.nu)
        out = [upd_fact(p, g, m, n) for p, g, m, n in
               zip(leaves_p, leaves_g, leaves_mu, leaves_nu)]
        p2 = treedef.unflatten([o[0] for o in out])
        mu2 = treedef.unflatten([o[1] for o in out])
        nu2 = treedef.unflatten([o[2] for o in out])
    else:
        res = jax.tree.map(upd_full, params, grads, state.mu, state.nu)
        p2 = jax.tree.map(lambda t: t[0], res, is_leaf=lambda t: isinstance(t, tuple))
        mu2 = jax.tree.map(lambda t: t[1], res, is_leaf=lambda t: isinstance(t, tuple))
        nu2 = jax.tree.map(lambda t: t[2], res, is_leaf=lambda t: isinstance(t, tuple))
    return p2, AdamWState(step=step, mu=mu2, nu=nu2), {"grad_norm": gnorm}


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
