"""Backend-agnostic EP dispatch planning (DESIGN.md §8).

UCCL-EP separates token-routing *decisions* (compact commands) from transport
*execution* (collectives, CPU proxies issuing RDMA).  This module is the
decision half, shared by every backend: given a routing table it computes

- **slot assignment**: arrival-order rank of each choice within its
  destination group (the receive-buffer slot a real TransferCmd addresses),
- **per-group counts** (the fence/atomic expected-write counts),
- **capacity keep/drop masks** (static-shape overflow policy),
- **per-(token, group) dedup tables** (HT mode: a token crosses each group
  boundary once, carrying its expert list as metadata).

Everything is fully vectorized and dual-dialect: numpy arrays take a
sort-based O(N log N) path (host planning for the simulated-RDMA transport),
jax arrays — including tracers inside ``jit``/``shard_map`` — take a one-hot
cumsum path that XLA fuses well.  Both dialects produce bit-identical plans,
so the jax-collectives path (``repro.core.ep``) and the transport executor
(``repro.core.transport.ep_executor``) can never drift: they *are* the same
routing logic.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import numpy as np

Array = Any  # np.ndarray | jax.Array (incl. tracers)


def _is_np(a: Array) -> bool:
    return isinstance(a, (np.ndarray, np.generic))


def _xp(a: Array):
    """Array-namespace dispatch: numpy for numpy inputs, jnp otherwise."""
    if _is_np(a):
        return np
    import jax.numpy as jnp  # lazy: keep numpy-only consumers jax-free
    return jnp


_ACCEPTS_COUNTS_CACHE: "weakref.WeakKeyDictionary" = None  # lazy init


def _accepts_counts(fn) -> bool:
    """Counts-aware iff the callable takes *args, or its second positional
    parameter is recognizably the counts slot by NAME: ``counts`` (or the
    ``c`` shorthand).  Neither arity nor a None default is enough — a
    legacy fn with an unrelated second parameter (``def fn(tokens,
    scale=1.0)`` or ``def fn(tokens, rng=None)``) must never silently
    receive a counts array as that argument."""
    import inspect
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):      # no introspectable signature
        return True                      # assume the current contract
    if any(p.kind == inspect.Parameter.VAR_POSITIONAL for p in params):
        return True
    pos = [p for p in params
           if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                         inspect.Parameter.POSITIONAL_OR_KEYWORD)]
    return len(pos) >= 2 and pos[1].name in ("counts", "c")


def call_expert_fn(fn, tokens: Array, counts: Array):
    """Invoke an expert_fn with the occupancy-carrying contract
    ``fn(tokens, counts)``; legacy single-argument callables are detected
    by signature (never by catching TypeError, which would mask bugs
    inside a counts-aware fn) and compute over the full buckets.

    Shared by both transports (jax collectives and the numpy substrate) so
    the contract dispatch cannot drift between them.  The per-callable
    verdict is memoized (the substrate invokes expert_fns once per bucket
    launch).
    """
    global _ACCEPTS_COUNTS_CACHE
    if _ACCEPTS_COUNTS_CACHE is None:
        import weakref
        _ACCEPTS_COUNTS_CACHE = weakref.WeakKeyDictionary()
    try:
        accepts = _ACCEPTS_COUNTS_CACHE.get(fn)
        if accepts is None:
            accepts = _ACCEPTS_COUNTS_CACHE[fn] = _accepts_counts(fn)
    except TypeError:                    # not weakref-able / not hashable
        accepts = _accepts_counts(fn)
    return fn(tokens, counts) if accepts else fn(tokens)


def occupancy_mask(counts: Array, n_groups: int, width: int) -> Array:
    """(G, width) bool occupancy mask from per-group occupied counts.

    counts: (G,) occupied-prefix counts — or (G, B) sub-bucket counts where
    B divides ``width`` and each width//B sub-bucket is occupied-prefix
    (the post-a2a receive layout: one capacity bucket per source shard).
    Counts are clipped to the sub-bucket capacity.  Dual-dialect: numpy in,
    numpy out; jax (incl. tracers) in, jnp out — the single source of the
    bucket-layout math for the jnp refs, the numpy substrate, and tests.
    """
    xp = _xp(counts)
    counts = counts.astype(xp.int32) if hasattr(counts, "astype") \
        else xp.asarray(counts, xp.int32)
    B = 1 if counts.ndim == 1 else counts.shape[1]
    cb = width // B
    m = xp.arange(cb)[None, None, :] < xp.minimum(
        counts.reshape(n_groups, B, 1), cb)
    return m.reshape(n_groups, width)


def effective_chunks(T: int, chunks: int) -> int:
    """Largest divisor of T that is <= the requested HT chunk count.

    Shared by both transports so their pipelining degrades identically: the
    seed silently reset any non-dividing chunk request to 1 (no pipelining);
    degrading to the nearest feasible chunking keeps the pipeline, and the
    effective value is surfaced (jax path: ``aux["chunks"]``; substrate:
    ``timeline["n_chunks"]``)."""
    chunks = max(1, min(chunks, T)) if T else 1
    while T % chunks:
        chunks -= 1
    return chunks


def receive_bucket_table(n_buckets: int, base: int, stride: int,
                         extent: Optional[int] = None, gid0: int = 0,
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Receive-bucket registration table: ``(bases, extents, guard_ids)``.

    Bucket ``g`` occupies bytes ``[base + g*stride, base + g*stride +
    extent)`` and owns guard id ``gid0 + g`` — the table the EP executor
    registers with each rank's proxy so the receiver can resolve a write's
    landing offset to its completion-fence guard (DESIGN.md §12).
    ``extent`` defaults to ``stride`` (densely packed buckets).  Guard ids
    double as host counter indices, so the fence descriptor's ``dst_off``
    addresses both with one wide id.  ``gid0`` offsets the ids into a
    per-layer namespace when several layers' tables coexist in one EP
    session (DESIGN.md §16): layer l's buckets own ids
    ``[l*stride_ids, l*stride_ids + n_buckets)`` and never alias another
    layer's fences.
    """
    ext = stride if extent is None else extent
    assert 0 < ext <= stride, (extent, stride)
    gids = gid0 + np.arange(n_buckets, dtype=np.int64)
    bases = base + np.arange(n_buckets, dtype=np.int64) * stride
    extents = np.full(n_buckets, ext, np.int64)
    return bases, extents, gids


# ------------------------------------------------------------ wire layout --
# Quantization block width for low-precision wire payloads (DESIGN.md §14):
# one fp32 absmax scale per WIRE_BLOCK features, packed inline after the
# quantized bytes.  128 matches the lane width of the TPU quantize kernel so
# a scale block never straddles a vector register.
WIRE_BLOCK = 128


class WireLayout(NamedTuple):
    """Byte layout of one token row on the wire for a given ``wire_dtype``.

    ``token_bytes`` is the full per-row wire footprint (quantized payload +
    inline scale blocks); GuardTable extents, fence counts, and every
    receive-bucket stride derive from it, so scale blocks are part of the
    registered range — a write that covers its scales covers its guard.
    """

    token_bytes: int   # full wire bytes per row (q_bytes + scale_bytes)
    q_bytes: int       # quantized payload bytes (D elements)
    n_blocks: int      # scale blocks per row (0 for fp32 passthrough)
    scale_bytes: int   # inline fp32 scale bytes (4 * n_blocks)


def wire_layout(d: int, wire_dtype: str = "fp32") -> WireLayout:
    """Per-row wire layout for a D-feature token under ``wire_dtype``.

    fp32 is the passthrough identity (4 bytes/feature, no scales); fp8/int8
    carry 1 byte/feature plus one fp32 scale per :data:`WIRE_BLOCK` features.
    This is the single source of the payload extent math: the substrate's
    command streams, the codec, and the guard tables all size from here.
    """
    if wire_dtype == "fp32":
        return WireLayout(4 * d, 4 * d, 0, 0)
    if wire_dtype in ("fp8", "int8"):
        nb = -(-d // WIRE_BLOCK)  # ceil
        return WireLayout(d + 4 * nb, d, nb, 4 * nb)
    raise ValueError(f"unknown wire_dtype: {wire_dtype!r}")


# ------------------------------------------------------- slot assignment --
def rank_in_group(group_id: Array, n_groups: int, valid: Array) -> Array:
    """Arrival-order rank of each row within its group (valid rows only).

    group_id: (N,) int32 in [0, n_groups); valid: (N,) bool.
    Returns (N,) int32; rank is meaningless (but in-range) for invalid rows.
    """
    if _is_np(group_id):
        return _rank_in_group_np(group_id, n_groups, valid)
    return _rank_in_group_jnp(group_id, n_groups, valid)


def _rank_in_group_np(group_id: np.ndarray, n_groups: int,
                      valid: np.ndarray) -> np.ndarray:
    n = group_id.size
    gid = np.where(valid, group_id, n_groups).astype(np.int64)
    order = np.argsort(gid, kind="stable")       # arrival order within group
    sg = gid[order]
    is_start = np.empty(n, bool)
    if n:
        is_start[0] = True
        np.not_equal(sg[1:], sg[:-1], out=is_start[1:])
    run = np.cumsum(is_start) - 1
    start = np.flatnonzero(is_start)
    rank_sorted = np.arange(n, dtype=np.int64) - start[run] if n else start
    rank = np.empty(n, np.int32)
    rank[order] = rank_sorted.astype(np.int32)
    return rank


def _rank_in_group_jnp(group_id: Array, n_groups: int, valid: Array) -> Array:
    import jax
    import jax.numpy as jnp
    # O(N * G) one-hot cumsum — N and G are small per shard
    # (T*K <= ~32k, G <= 64), and XLA fuses this into one pass.
    oh = jax.nn.one_hot(jnp.where(valid, group_id, n_groups), n_groups + 1,
                        dtype=jnp.int32)
    ranks = jnp.cumsum(oh, axis=0) - oh
    return jnp.take_along_axis(
        ranks, jnp.where(valid, group_id, n_groups)[:, None], axis=1)[:, 0]


def group_counts(group_id: Array, n_groups: int, valid: Array) -> Array:
    """Number of valid rows per group: (n_groups,) int32."""
    if _is_np(group_id):
        flat = group_id.reshape(-1)[valid.reshape(-1)]
        return np.bincount(flat, minlength=n_groups).astype(np.int32)
    import jax.numpy as jnp
    gid = jnp.where(valid, group_id, n_groups).reshape(-1)
    return jnp.zeros((n_groups + 1,), jnp.int32).at[gid].add(1)[:n_groups]


def flat_slots(group_id: Array, rank: Array, keep: Array, capacity: int,
               n_groups: int) -> Array:
    """Flat receive-slot index ``g * capacity + rank`` for kept entries;
    dropped/invalid entries point at the scratch slot ``n_groups*capacity``."""
    xp = _xp(group_id)
    return xp.where(keep, group_id * capacity + rank, n_groups * capacity)


# -------------------------------------------------------------- full plan --
class DispatchPlan(NamedTuple):
    """Routing decisions for one shard's (T, K) table over ``n_groups``."""

    rank: Array       # (T, K) arrival-order rank per (row, group)
    counts: Array     # (n_groups,) valid choices per group
    valid: Array      # (T, K) bool: group id >= 0
    keep: Array       # (T, K) valid & rank < capacity
    n_dropped: Array  # scalar int: valid choices lost to capacity


def make_plan(group_idx: Array, n_groups: int, capacity: int) -> DispatchPlan:
    """Plan a (T, K) routing table: group ids in [0, n_groups), -1 = pad."""
    valid = group_idx >= 0
    flat = group_idx.reshape(-1)
    fv = valid.reshape(-1)
    rank = rank_in_group(flat, n_groups, fv).reshape(group_idx.shape)
    counts = group_counts(flat, n_groups, fv)
    keep = valid & (rank < capacity)
    n_dropped = (valid & ~keep).sum()
    return DispatchPlan(rank, counts, valid, keep, n_dropped)


class WorldPlan(NamedTuple):
    """Per-rank plans for a whole (R, T, K) world, computed in one pass.

    Slot namespaces are per (source rank, expert): rank r's choices for
    expert e occupy slots [0, counts[r, e]) of the (r, e) receive bucket —
    exactly the paper's sender-side slot metadata.
    """

    rank: Array       # (R, T, K) arrival-order slot per (src, expert)
    counts: Array     # (R, n_groups)
    valid: Array      # (R, T, K)
    keep: Array       # (R, T, K)
    n_dropped: Array  # scalar


def make_world_plan(group_idx: Array, n_groups: int,
                    capacity: int) -> WorldPlan:
    """Plan an (R, T, K) table; groups are independent per source rank."""
    R = group_idx.shape[0]
    valid = group_idx >= 0
    xp = _xp(group_idx)
    # offset group ids per rank so one rank_in_group pass covers all ranks
    r_of = xp.arange(R, dtype=group_idx.dtype).reshape(
        (R,) + (1,) * (group_idx.ndim - 1))
    gid = xp.where(valid, group_idx + r_of * n_groups, -1)
    flat, fv = gid.reshape(-1), valid.reshape(-1)
    rank = rank_in_group(flat, R * n_groups, fv).reshape(group_idx.shape)
    counts = group_counts(flat, R * n_groups, fv).reshape(R, n_groups)
    keep = valid & (rank < capacity)
    n_dropped = (valid & ~keep).sum()
    return WorldPlan(rank, counts, valid, keep, n_dropped)


# ------------------------------------------------- replicated placement ---
class Placement(NamedTuple):
    """Logical->physical expert placement (replicated expert groups).

    One logical expert owns ``n_replicas[e]`` physical slots; slot ``p``
    computes logical expert ``phys_to_logical[p]`` and lives on rank
    ``p // (n_physical // n_ranks)`` — the same slot->rank rule both
    backends already use for experts, so a placement *is* a plan-layer
    object: guard tables, fence counts and ``ret_pos`` all size from the
    physical slot space.  Replica ``j`` of expert ``e`` is
    ``logical_to_phys[e, j]`` (ascending physical id; -1 pads).
    """

    phys_to_logical: np.ndarray   # (E_phys,) int32
    logical_to_phys: np.ndarray   # (E_log, max_replicas) int32, -1 pad
    n_replicas: np.ndarray        # (E_log,) int32, all >= 1

    @property
    def n_physical(self) -> int:
        return int(self.phys_to_logical.shape[0])

    @property
    def n_logical(self) -> int:
        return int(self.n_replicas.shape[0])

    @property
    def is_identity(self) -> bool:
        """True iff this is exactly today's single-placement layout (the
        replicas=1 degenerate case the bit-identity contract pins)."""
        return (self.n_physical == self.n_logical and bool(
            (self.phys_to_logical
             == np.arange(self.n_logical, dtype=np.int32)).all()))

    def key(self) -> tuple[int, ...]:
        """Hashable form (what a frozen EPSpec carries)."""
        return tuple(int(v) for v in self.phys_to_logical)


def placement_from_table(phys_to_logical) -> Placement:
    """Build a full Placement from its (E_phys,) phys->logical table."""
    p2l = np.ascontiguousarray(np.asarray(phys_to_logical).reshape(-1),
                               np.int32)
    assert p2l.size and p2l.min() >= 0
    n_log = int(p2l.max()) + 1
    reps = np.bincount(p2l, minlength=n_log).astype(np.int32)
    assert (reps > 0).all(), "every logical expert needs >= 1 physical slot"
    # replica order within a logical expert = ascending physical id
    j = _rank_in_group_np(p2l, n_log, np.ones(p2l.size, bool))
    l2p = np.full((n_log, int(reps.max())), -1, np.int32)
    l2p[p2l, j] = np.arange(p2l.size, dtype=np.int32)
    return Placement(p2l, l2p, reps)


def identity_placement(n_experts: int) -> Placement:
    return placement_from_table(np.arange(n_experts, dtype=np.int32))


def replicate_uniform(n_logical: int, factor: int) -> Placement:
    """``factor`` replicas per expert, tiled so replica j of expert e sits
    at physical slot ``j * n_logical + e`` — replicas of one expert land on
    distinct ranks whenever experts-per-rank divides ``n_logical``."""
    return placement_from_table(
        np.tile(np.arange(n_logical, dtype=np.int32), factor))


def greedy_placement(loads, n_physical: int, n_ranks: int) -> Placement:
    """Greedy bin-packing placement from observed per-logical-expert loads.

    Two deterministic passes: (1) grant the ``n_physical - E_log`` extra
    replicas one at a time to the expert with the largest per-replica load
    share (ties -> lowest id); (2) pack replica slots onto ranks heaviest
    share first, each onto the least-loaded rank with free slots, preferring
    ranks that do not already host a replica of that expert.  Slot p lands
    on rank ``p // (n_physical // n_ranks)``.
    """
    loads = np.asarray(loads, np.float64).reshape(-1)
    E = loads.shape[0]
    assert n_physical >= E, (n_physical, E)
    assert n_physical % n_ranks == 0, (n_physical, n_ranks)
    eps = n_physical // n_ranks
    if not loads.any():
        loads = np.ones(E, np.float64)
    reps = np.ones(E, np.int64)
    for _ in range(n_physical - E):
        reps[int(np.argmax(loads / reps))] += 1
    items = sorted(((loads[e] / reps[e], e, j)
                    for e in range(E) for j in range(int(reps[e]))),
                   key=lambda it: (-it[0], it[1], it[2]))
    rank_load = np.zeros(n_ranks, np.float64)
    rank_free = np.full(n_ranks, eps, np.int64)
    rank_slots: list[list[int]] = [[] for _ in range(n_ranks)]
    for share, e, _j in items:
        best, best_key = -1, None
        for r in range(n_ranks):
            if not rank_free[r]:
                continue
            k = (e in rank_slots[r], rank_load[r], r)
            if best_key is None or k < best_key:
                best, best_key = r, k
        rank_slots[best].append(e)
        rank_load[best] += share
        rank_free[best] -= 1
    return placement_from_table(np.concatenate(
        [np.asarray(s, np.int32) for s in rank_slots]))


def split_to_physical(placement: Placement, top_idx: Array) -> Array:
    """Deterministic replica split of a logical routing table.

    Each valid choice of expert ``e`` goes to replica ``arrival_rank %
    n_replicas[e]`` — round-robin in arrival order, the same dual-dialect
    :func:`rank_in_group` every plan derives slots from, so numpy and jnp
    produce bit-identical physical tables.  Identity placements return
    ``top_idx`` unchanged (the replicas=1 bit-identity contract: no new ops
    enter the traced graph).  -1 pads pass through.
    """
    if placement.is_identity:
        return top_idx
    xp = _xp(top_idx)
    flat = top_idx.reshape(-1)
    fv = flat >= 0
    rk = rank_in_group(flat, placement.n_logical, fv)
    e_safe = xp.where(fv, flat, 0)
    rep = rk % xp.asarray(placement.n_replicas)[e_safe]
    phys = xp.asarray(placement.logical_to_phys)[e_safe, rep]
    return xp.where(fv, phys, flat).reshape(top_idx.shape).astype(
        top_idx.dtype)


def split_to_physical_world(placement: Placement, top_idx: Array) -> Array:
    """(R, T, K) world-table split: every source rank round-robins its own
    choices independently — identical to stacking per-source
    :func:`split_to_physical`, in one vectorized pass (the offset trick
    :func:`make_world_plan` uses)."""
    if placement.is_identity:
        return top_idx
    xp = _xp(top_idx)
    R, E = top_idx.shape[0], placement.n_logical
    valid = top_idx >= 0
    r_of = xp.arange(R, dtype=top_idx.dtype).reshape(
        (R,) + (1,) * (top_idx.ndim - 1))
    gid = xp.where(valid, top_idx + r_of * E, -1)
    rk = rank_in_group(gid.reshape(-1), R * E,
                       valid.reshape(-1)).reshape(top_idx.shape)
    e_safe = xp.where(valid, top_idx, 0)
    rep = rk % xp.asarray(placement.n_replicas)[e_safe]
    phys = xp.asarray(placement.logical_to_phys)[e_safe, rep]
    return xp.where(valid, phys, top_idx).astype(top_idx.dtype)


# ------------------------------------------------------- load accounting --
def expert_load(top_idx: Array, n_experts: int) -> Array:
    """Per-expert valid routed-choice counts as float32 — the one ``load``
    stat every router/backend/balancer reads (moe.py's three one_hot sums
    and the bias updater all route through here)."""
    flat = top_idx.reshape(-1)
    c = group_counts(flat, n_experts, flat >= 0)
    if _is_np(top_idx):
        return c.astype(np.float32)
    import jax.numpy as jnp
    return c.astype(jnp.float32)


def load_imbalance(counts: Array):
    """max/mean load over the physical slots (1.0 = perfectly balanced;
    1.0 also for an empty table).  Dual-dialect: float for numpy counts,
    jnp scalar for traced ones."""
    if _is_np(counts):
        c = np.asarray(counts, np.float64)
        m = float(c.mean()) if c.size else 0.0
        return float(c.max() / m) if m > 0 else 1.0
    import jax.numpy as jnp
    c = counts.astype(jnp.float32)
    m = c.mean()
    return jnp.where(m > 0, c.max() / jnp.maximum(m, 1e-9), jnp.float32(1.0))


# ------------------------------------------------------------ dedup table --
def dedup_first(group_of: Array, valid: Array) -> Array:
    """First-occurrence mask per (token, group) across the K choices.

    group_of: (T, K) destination group per choice (-1 pad); valid: (T, K).
    Returns (T, K) bool: True iff choice k is the first valid choice of its
    row routed to that group — HT mode sends exactly these entries; the
    remaining (duplicate) choices ride along as metadata.
    """
    xp = _xp(group_of)
    K = group_of.shape[-1]
    same = group_of[:, :, None] == group_of[:, None, :]       # (T, K, K)
    earlier = (xp.arange(K)[None, :, None] > xp.arange(K)[None, None, :])
    return valid & ~xp.any(same & earlier & valid[:, None, :], axis=2)


def dedup_entry_table(group_of: Array, valid: Array, n_groups: int,
                      capacity: int):
    """Dedup'd (token, group) entry table with capacity bucketing.

    Returns ``(first, entry_valid, rank_tg, keep_tg, n_dropped)``:

    - first:       (T, K) first-occurrence mask (see :func:`dedup_first`)
    - entry_valid: (T, G) token has >= 1 choice routed to group g
    - rank_tg:     (T, G) arrival-order rank of the (t, g) entry in group g
    - keep_tg:     (T, G) entry fits under ``capacity``
    - n_dropped:   scalar count of (t, g) entries lost to capacity
    """
    T, K = group_of.shape
    first = dedup_first(group_of, valid)
    if _is_np(group_of):
        entry_valid = np.zeros((T, n_groups), bool)
        rows = np.broadcast_to(np.arange(T)[:, None], (T, K))
        entry_valid[rows[first], group_of[first]] = True
        flat_g = np.where(first, group_of, -1).reshape(-1)
        rank_flat = rank_in_group(flat_g, n_groups, flat_g >= 0).reshape(T, K)
        rank_tg = np.zeros((T, n_groups), np.int32)
        rank_tg[rows[first], group_of[first]] = rank_flat[first]
    else:
        import jax.numpy as jnp
        rows = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K))
        entry_valid = jnp.zeros((T, n_groups), bool).at[
            rows, jnp.where(valid, group_of, 0)].max(first, mode="drop")
        flat_g = jnp.where(first, group_of, -1).reshape(-1)
        rank_flat = rank_in_group(flat_g, n_groups, flat_g >= 0)
        rank_tg = jnp.zeros((T, n_groups), jnp.int32).at[
            rows, jnp.where(first, group_of, 0)].max(
            jnp.where(first, rank_flat.reshape(T, K), 0), mode="drop")
    keep_tg = entry_valid & (rank_tg < capacity)
    n_dropped = (entry_valid & ~keep_tg).sum()
    return first, entry_valid, rank_tg, keep_tg, n_dropped
