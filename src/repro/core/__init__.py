"""UCCL-EP core: routing, dispatch/combine (LL/HT), transport substrate."""
from repro.core.ep import (EPSpec, DispatchResult, dispatch_combine_ht,
                           dispatch_combine_ll, moe_ref)
from repro.core.moe import moe_apply, moe_init, padded_experts_static
from repro.core.routing import RouterOut, RouterParams, route, router_init

__all__ = ["EPSpec", "DispatchResult", "dispatch_combine_ht",
           "dispatch_combine_ll", "moe_ref", "moe_apply", "moe_init",
           "padded_experts_static", "RouterOut", "RouterParams", "route",
           "router_init"]
