"""UCCL-EP core: routing, dispatch planning, dispatch/combine (LL/HT),
pluggable transport backends, transport substrate."""
from repro.core.backend import (EPBackend, available_backends, get_backend,
                                register_backend)
from repro.core.ep import (EPSpec, DispatchResult, dispatch_combine_ht,
                           dispatch_combine_ll, moe_ref)
from repro.core.moe import moe_apply, moe_init, padded_experts_static
from repro.core.plan import (DispatchPlan, WorldPlan, dedup_entry_table,
                             dedup_first, flat_slots, group_counts, make_plan,
                             make_world_plan, rank_in_group)
from repro.core.routing import RouterOut, RouterParams, route, router_init

__all__ = ["EPSpec", "DispatchResult", "dispatch_combine_ht",
           "dispatch_combine_ll", "moe_ref", "moe_apply", "moe_init",
           "padded_experts_static", "RouterOut", "RouterParams", "route",
           "router_init", "EPBackend", "available_backends", "get_backend",
           "register_backend", "DispatchPlan", "WorldPlan",
           "dedup_entry_table", "dedup_first", "flat_slots", "group_counts",
           "make_plan", "make_world_plan", "rank_in_group"]
