"""Single source of truth for the wire contract's bit layouts.

Two packed formats live on the (emulated) wire:

1. The 128-bit **TransferCmd descriptor** (4 x uint32) that rides the
   CPU-GPU FIFO — word 0 carries op/dst_rank/channel/flags, words 1-2 the
   32-bit symmetric-memory offsets, word 3 length+value.

2. The 32-bit **immediate** delivered with an RDMA write/atomic — a
   per-kind layout: seq-carrying kinds are kind(2)|channel(3)|seq(11)|
   value(16); FENCE_ATOMIC is kind(2)|channel(3)|count(21)|unused(6).

Every mask/shift below is derived from a named width so a future field
resize (e.g. widening seq) propagates to the codecs, the receiver
semantics, the srd displacement bound, and the static verifier in
``repro.analysis`` — none of which may re-hardcode a literal.  The lint
pass (``python -m repro.analysis.lint``) whitelists exactly this module
for all-ones bit-mask literals; everything else in ``core/transport``
must import from here.

This module imports nothing from the package (it is the bottom of the
transport dependency graph) so anything — codecs, simulator, analysis —
can import it without cycles.
"""
from __future__ import annotations


def _mask(bits: int) -> int:
    return (1 << bits) - 1


class ProtocolError(ValueError):
    """A wire-contract invariant does not hold.

    Raised (never ``assert``-ed: the contract must survive ``python -O``)
    by the transport hot paths and by ``repro.analysis.verify``'s
    ``verify_or_raise``.  Subclasses ``ValueError`` so callers that guard
    config plumbing generically keep working.
    """


# --------------------------------------------------------------------------
# 128-bit TransferCmd descriptor (4 x uint32)
#
#   w0: op(4) | dst_rank(12) | channel(8) | flags(8)
#   w1: src_off(32)
#   w2: dst_off(32)
#   w3: length(20) | value(12)
# --------------------------------------------------------------------------
OP_BITS = 4
RANK_BITS = 12
CH_BITS = 8
FLAGS_BITS = 8

LEN_BITS = 20
VALUE_BITS = 12
OFF_BITS = 32

OP_SHIFT = 0
RANK_SHIFT = OP_SHIFT + OP_BITS            # 4
CH_SHIFT = RANK_SHIFT + RANK_BITS          # 16
FLAGS_SHIFT = CH_SHIFT + CH_BITS           # 24
LEN_SHIFT = 0
VALUE_SHIFT = LEN_SHIFT + LEN_BITS         # 20

OP_MASK = _mask(OP_BITS)                   # 0xF
RANK_MASK = _mask(RANK_BITS)               # 0xFFF
CH_MASK = _mask(CH_BITS)                   # 0xFF
FLAGS_MASK = _mask(FLAGS_BITS)             # 0xFF
LEN_MASK = _mask(LEN_BITS)                 # 0xFFFFF
VALUE_MASK = _mask(VALUE_BITS)             # 0xFFF
MASK32 = _mask(OFF_BITS)                   # 0xFFFFFFFF

# descriptor flags (w0 bits 24..31)
FLAG_FENCE = 0x1   # atomic uses LL completion-fence semantics (else HT seq)

# --------------------------------------------------------------------------
# 32-bit per-kind immediate
#
#   seq-carrying kinds:  kind(2) | channel(3) | seq(11) | value(16)
#   FENCE_ATOMIC:        kind(2) | channel(3) | count(21) | unused(6)
# --------------------------------------------------------------------------
IMM_KIND_BITS = 2
IMM_CH_BITS = 3
IMM_SEQ_BITS = 11
IMM_VALUE_BITS = 16
IMM_COUNT_BITS = 21

IMM_KIND_SHIFT = 0
IMM_CH_SHIFT = IMM_KIND_SHIFT + IMM_KIND_BITS    # 2
IMM_SEQ_SHIFT = IMM_CH_SHIFT + IMM_CH_BITS       # 5
IMM_VALUE_SHIFT = IMM_SEQ_SHIFT + IMM_SEQ_BITS   # 16
IMM_COUNT_SHIFT = IMM_CH_SHIFT + IMM_CH_BITS     # 5 (count overlays seq+value)

IMM_KIND_MASK = _mask(IMM_KIND_BITS)             # 0x3
IMM_CH_MASK = _mask(IMM_CH_BITS)                 # 0x7
IMM_SEQ_MASK = _mask(IMM_SEQ_BITS)               # 0x7FF
IMM_VALUE_MASK = _mask(IMM_VALUE_BITS)           # 0xFFFF
IMM_COUNT_MASK = _mask(IMM_COUNT_BITS)           # 0x1FFFFF

# Derived protocol constants (the names the rest of the tree imports).
N_CHANNELS_MAX = 1 << IMM_CH_BITS                # 8
SEQ_MOD = 1 << IMM_SEQ_BITS                      # 2048
IMM_VAL_MAX = IMM_VALUE_MASK                     # 65535
FENCE_COUNT_MAX = IMM_COUNT_MASK                 # 2097151

# Receiver-side seq unwrap (semantics._unwrap) recovers the full counter
# from an 11-bit wire seq only while |displacement| stays under a quarter
# wrap; srd reordering plus write coalescing must respect this bound.
SRD_DISPLACEMENT_BOUND = SEQ_MOD // 4            # 512

# Layout sanity — plain raises so they also hold under ``python -O``.
if OP_BITS + RANK_BITS + CH_BITS + FLAGS_BITS != 32:
    raise AssertionError("descriptor word 0 fields must pack to 32 bits")
if LEN_BITS + VALUE_BITS != 32:
    raise AssertionError("descriptor word 3 fields must pack to 32 bits")
if IMM_KIND_BITS + IMM_CH_BITS + IMM_SEQ_BITS + IMM_VALUE_BITS != 32:
    raise AssertionError("seq-carrying immediate fields must pack to 32 bits")
if IMM_KIND_BITS + IMM_CH_BITS + IMM_COUNT_BITS > 32:
    raise AssertionError("fence immediate fields must fit in 32 bits")
