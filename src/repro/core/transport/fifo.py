"""CPU-GPU FIFO channel (paper §3.1), bit-faithful at host level.

A TransferCmd is a 128-bit descriptor (4 x uint32) — one GPU instruction /
MMIO doorbell per command in the real system.  The channel is a bounded
single-producer single-consumer ring: the producer ("GPU thread") writes at
the tail, the consumer ("CPU proxy thread") reads at the head.  The bound
``k_max_inflight`` is the paper's flow-control knob: a full ring
back-pressures the producer, pacing GPU-initiated communication.

The GPU side caches the head value (``_cached_head``) so polling for space
does not cross "PCIe" (here: does not touch the consumer-owned counter)
until the cache goes stale — the paper's tail/head-placement optimisation.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from enum import IntEnum
from typing import NamedTuple, Optional

import numpy as np

from repro.core.transport.wire_format import (CH_MASK, CH_SHIFT, FLAG_FENCE,
                                              FLAGS_MASK, FLAGS_SHIFT,
                                              LEN_MASK, MASK32, OP_BITS,
                                              OP_MASK, RANK_MASK, RANK_SHIFT,
                                              VALUE_MASK, VALUE_SHIFT)

__all__ = ["Op", "FLAG_FENCE", "TransferCmd", "pack_cmds", "CmdColumns",
           "unpack_cmds", "FifoChannel"]


class Op(IntEnum):
    WRITE = 1          # one-sided RDMA write
    ATOMIC = 2         # standalone atomic (emulated via immediate data);
    #                    src_off carries the 32-bit operand (fence count /
    #                    chunk id), dst_off the wide guard/counter id
    DRAIN = 3          # drain CQ up to idx (scheduling hint)
    BARRIER = 4        # reserved opcode (no receiver-side state; the event
    #                    clock quiesce replaced the barrier round-trip)
    WRITE_ATOMIC = 5   # write with piggybacked atomic (completion counter)


@dataclass(frozen=True)
class TransferCmd:
    """Decoded descriptor.  Packs into exactly 128 bits."""

    op: Op
    dst_rank: int       # 12 bits
    channel: int        # 8 bits
    src_off: int        # 32 bits (symmetric-memory offset)
    dst_off: int        # 32 bits
    length: int         # 20 bits (bytes)
    value: int = 0      # 12 bits (free tag; transport semantics ride
    #                     src_off/dst_off — no expert slot on the wire)
    flags: int = 0      # 8 bits (FLAG_FENCE, ...)

    def pack(self) -> np.ndarray:
        w0 = (int(self.op) & OP_MASK) \
            | ((self.dst_rank & RANK_MASK) << RANK_SHIFT) \
            | ((self.channel & CH_MASK) << CH_SHIFT) \
            | ((self.flags & FLAGS_MASK) << FLAGS_SHIFT)
        w3 = (self.length & LEN_MASK) | ((self.value & VALUE_MASK)
                                         << VALUE_SHIFT)
        return np.array([w0, self.src_off & MASK32,
                         self.dst_off & MASK32, w3], dtype=np.uint32)

    @staticmethod
    def unpack(words: np.ndarray) -> "TransferCmd":
        w0, w1, w2, w3 = words.tolist()
        return TransferCmd(op=_OP_TABLE[w0 & OP_MASK],
                           dst_rank=(w0 >> RANK_SHIFT) & RANK_MASK,
                           channel=(w0 >> CH_SHIFT) & CH_MASK,
                           src_off=w1, dst_off=w2,
                           length=w3 & LEN_MASK,
                           value=(w3 >> VALUE_SHIFT) & VALUE_MASK,
                           flags=(w0 >> FLAGS_SHIFT) & FLAGS_MASK)


# tuple dispatch: Op.__call__ through EnumMeta is hot in the consumer loop
_OP_TABLE = tuple(Op(v) if v in Op._value2member_map_ else None
                  for v in range(1 << OP_BITS))


def pack_cmds(op, dst_rank, channel, src_off, dst_off, length, value,
              flags=0) -> np.ndarray:
    """Vectorized descriptor codec: pack N commands into an (N, 4) uint32
    array (the batched TransferCmd stream a GPU kernel would emit in one
    go).  Arguments broadcast against each other; scalars are fine.
    Row i unpacks (via :meth:`TransferCmd.unpack`) to exactly the same
    128-bit descriptor ``TransferCmd(...).pack()`` would produce.
    """
    op, dst_rank, channel, src_off, dst_off, length, value, flags = (
        np.broadcast_arrays(*[np.asarray(a, np.uint64) for a in
                              (op, dst_rank, channel, src_off, dst_off,
                               length, value, flags)]))
    n = op.size
    out = np.empty((n, 4), np.uint32)
    out[:, 0] = ((op.reshape(-1) & OP_MASK)
                 | ((dst_rank.reshape(-1) & RANK_MASK) << RANK_SHIFT)
                 | ((channel.reshape(-1) & CH_MASK) << CH_SHIFT)
                 | ((flags.reshape(-1) & FLAGS_MASK) << FLAGS_SHIFT)
                 ).astype(np.uint32)
    out[:, 1] = (src_off.reshape(-1) & MASK32).astype(np.uint32)
    out[:, 2] = (dst_off.reshape(-1) & MASK32).astype(np.uint32)
    out[:, 3] = ((length.reshape(-1) & LEN_MASK)
                 | ((value.reshape(-1) & VALUE_MASK) << VALUE_SHIFT)
                 ).astype(np.uint32)
    return out


class CmdColumns(NamedTuple):
    """Columnar view of a packed (N, 4) descriptor batch: one int64 array
    per field (the batched consumer's working set — no per-row TransferCmd
    objects on the hot path; :meth:`TransferCmd.unpack` stays the
    scalar/debug codec)."""

    op: np.ndarray
    dst_rank: np.ndarray
    channel: np.ndarray
    src_off: np.ndarray
    dst_off: np.ndarray
    length: np.ndarray
    value: np.ndarray
    flags: np.ndarray


def unpack_cmds(words: np.ndarray) -> CmdColumns:
    """Vectorized inverse of :func:`pack_cmds`: decode an (N, 4) uint32
    descriptor batch into field columns with bit-ops.  Column row i equals
    the fields ``TransferCmd.unpack(words[i])`` would produce."""
    w = words.astype(np.int64)
    w0, w3 = w[:, 0], w[:, 3]
    return CmdColumns(op=w0 & OP_MASK,
                      dst_rank=(w0 >> RANK_SHIFT) & RANK_MASK,
                      channel=(w0 >> CH_SHIFT) & CH_MASK, src_off=w[:, 1],
                      dst_off=w[:, 2], length=w3 & LEN_MASK,
                      value=(w3 >> VALUE_SHIFT) & VALUE_MASK,
                      flags=(w0 >> FLAGS_SHIFT) & FLAGS_MASK)


class FifoChannel:
    """Bounded SPSC ring of 128-bit TransferCmds.

    Counters are monotonically increasing; slot = counter % capacity.
    ``push`` returns a global index usable with ``check_completion``.
    """

    def __init__(self, k_max_inflight: int = 64):
        self.capacity = k_max_inflight
        self.buf = np.zeros((k_max_inflight, 4), dtype=np.uint32)
        self._tail = 0              # producer-owned (next write)
        self._head = 0              # consumer-owned (next read)
        self._cached_head = 0       # producer's stale copy (avoids "PCIe" read)
        self._pcie_reads = 0        # instrumentation: cross-domain reads
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self.closed = False

    # ----------------------------------------------------- producer (GPU) --
    def try_push(self, cmd: TransferCmd) -> Optional[int]:
        """Non-blocking push; None if the ring is full (flow control)."""
        if self._tail - self._cached_head >= self.capacity:
            with self._lock:
                self._cached_head = self._head      # one "PCIe" crossing
                self._pcie_reads += 1
            if self._tail - self._cached_head >= self.capacity:
                return None
        idx = self._tail
        self.buf[idx % self.capacity] = cmd.pack()
        with self._not_empty:
            self._tail = idx + 1
            self._not_empty.notify()
        return idx

    def try_push_batch(self, words: np.ndarray) -> int:
        """Bulk non-blocking push of packed (N, 4) uint32 descriptors.

        Copies as many rows as fit into the ring in one shot (one doorbell
        for the whole batch instead of one per command — the bulk half of
        the paper's Fig. 4 token-vs-bulk distinction).  Returns the number
        of rows consumed (0 if the ring is full).
        """
        n = len(words)
        if n == 0:
            return 0
        free = self.capacity - (self._tail - self._cached_head)
        if free < n:
            with self._lock:
                self._cached_head = self._head      # one "PCIe" crossing
                self._pcie_reads += 1
            free = self.capacity - (self._tail - self._cached_head)
        m = min(free, n)
        if m <= 0:
            return 0
        pos = (self._tail + np.arange(m)) % self.capacity
        self.buf[pos] = words[:m]
        with self._not_empty:
            self._tail += m
            self._not_empty.notify()
        return m

    def _wait_for_space(self, deadline: float) -> None:
        """Block until the ring has space or the absolute ``deadline``
        (time.monotonic seconds) passes.  One deadline covers a whole
        blocking push: a consumer that drains just slowly enough to keep
        waking the producer must NOT keep extending the timeout."""
        with self._not_full:
            remaining = deadline - time.monotonic()
            ok = remaining > 0 and self._not_full.wait_for(
                lambda: self._tail - self._head < self.capacity or self.closed,
                remaining)
            if not ok:
                raise TimeoutError("FIFO full: consumer stalled")
            if self.closed:
                raise RuntimeError("channel closed")
            self._cached_head = self._head

    def push_batch(self, words: np.ndarray, timeout: float = 10.0) -> int:
        """Blocking bulk push: waits for ring space until every row of
        ``words`` is queued, under ONE absolute deadline for the whole
        batch.  Returns the number of rows pushed (== N)."""
        deadline = time.monotonic() + timeout
        done = 0
        while done < len(words):
            done += self.try_push_batch(words[done:])
            if done < len(words):
                self._wait_for_space(deadline)
        return done

    def push(self, cmd: TransferCmd, timeout: float = 10.0) -> int:
        """Blocking push: waits for space (the paper's sender pacing) under
        one absolute deadline — an iterative retry loop, not recursion."""
        deadline = time.monotonic() + timeout
        while True:
            idx = self.try_push(cmd)
            if idx is not None:
                return idx
            self._wait_for_space(deadline)

    def check_completion(self, idx: int) -> bool:
        """Has the command at ``idx`` been popped by the CPU side?"""
        with self._lock:
            return self._head > idx

    def check_completion_batch(self, idxs) -> np.ndarray:
        """Batched :meth:`check_completion`: one locked head read answers
        for the whole index window (the flow-control wait loop polls its
        outstanding window in ONE lock round-trip, not one per index)."""
        with self._lock:
            head = self._head
        return np.asarray(idxs, np.int64) < head

    # ----------------------------------------------------- consumer (CPU) --
    def poll(self) -> Optional[tuple[int, TransferCmd]]:
        """Read (without consuming) the head command.  The row is copied
        while the lock is held: a wrapping producer may overwrite the slot
        the moment the head counter is published as free, so decoding from
        ``self.buf`` after release would race it."""
        with self._lock:
            if self._head >= self._tail:
                return None
            idx = self._head
            row = self.buf[idx % self.capacity].copy()
        return idx, TransferCmd.unpack(row)

    def pop(self) -> Optional[tuple[int, TransferCmd]]:
        with self._not_full:
            if self._head >= self._tail:
                return None
            idx = self._head
            cmd = TransferCmd.unpack(self.buf[idx % self.capacity])
            self._head = idx + 1
            self._not_full.notify()
        return idx, cmd

    def pop_all(self) -> Optional[np.ndarray]:
        """Bulk pop: consume every queued descriptor in one lock round trip
        (the inline-drain fast path).  Returns a packed (N, 4) copy."""
        with self._not_full:
            n = self._tail - self._head
            if n <= 0:
                return None
            # advanced indexing already materializes a fresh array
            words = self.buf[(self._head + np.arange(n)) % self.capacity]
            self._head += n
            self._not_full.notify()
        return words

    def wait_nonempty(self, timeout: float = 0.1) -> bool:
        with self._not_empty:
            return self._not_empty.wait_for(
                lambda: self._head < self._tail or self.closed, timeout)

    def close(self):
        with self._lock:
            self.closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._tail - self._head

    @property
    def pcie_reads(self) -> int:
        return self._pcie_reads
