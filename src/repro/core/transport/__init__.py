from repro.core.transport.codec import (WIRE_DTYPES, WireCodec,
                                        dequantize_blocked, get_codec,
                                        quantize_blocked)
from repro.core.transport.ep_executor import (EPWorld, np_grouped_swiglu,
                                              np_swiglu)
from repro.core.transport.fifo import (FLAG_FENCE, CmdColumns, FifoChannel,
                                       Op, TransferCmd, pack_cmds,
                                       unpack_cmds)
from repro.core.transport.proxy import Proxy, SymmetricMemory
from repro.core.transport.semantics import (ControlBuffer, GuardTable,
                                            ImmKind, pack_imm, unpack_imm)
from repro.core.transport.simulator import Message, NetConfig, Network
from repro.core.transport.wire_format import (FENCE_COUNT_MAX, IMM_VAL_MAX,
                                              N_CHANNELS_MAX, SEQ_MOD,
                                              SRD_DISPLACEMENT_BOUND,
                                              ProtocolError)

__all__ = ["EPWorld", "np_grouped_swiglu", "np_swiglu", "FLAG_FENCE",
           "CmdColumns", "FifoChannel", "Op", "TransferCmd", "pack_cmds",
           "unpack_cmds", "Proxy", "SymmetricMemory", "ControlBuffer",
           "GuardTable", "ImmKind", "pack_imm", "unpack_imm", "Message",
           "NetConfig", "Network", "WIRE_DTYPES", "WireCodec", "get_codec",
           "quantize_blocked", "dequantize_blocked", "ProtocolError",
           "N_CHANNELS_MAX", "SEQ_MOD", "IMM_VAL_MAX", "FENCE_COUNT_MAX",
           "SRD_DISPLACEMENT_BOUND"]
