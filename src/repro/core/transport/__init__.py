from repro.core.transport.ep_executor import EPWorld
from repro.core.transport.fifo import FLAG_FENCE, FifoChannel, Op, TransferCmd
from repro.core.transport.proxy import Proxy, SymmetricMemory
from repro.core.transport.semantics import (ControlBuffer, ImmKind, pack_imm,
                                            unpack_imm)
from repro.core.transport.simulator import Message, NetConfig, Network

__all__ = ["EPWorld", "FLAG_FENCE", "FifoChannel", "Op", "TransferCmd",
           "Proxy", "SymmetricMemory", "ControlBuffer", "ImmKind", "pack_imm",
           "unpack_imm", "Message", "NetConfig", "Network"]
