"""Multithreaded CPU proxy (paper §3.2): consumes TransferCmds from FIFO
channels and executes GPUDirect-RDMA-equivalent operations over the network
model, bridging delivery semantics with the receiver-side control buffer.

One proxy per "GPU" (rank); ``n_threads`` worker threads each own a disjoint
subset of FIFO channels (thread i serves channels i, i+T, ... — no shared
state between threads, as in the paper).  QP selection round-robins across
the thread's QPs unless the command pins a channel (ordering domain).

The consumer is columnar by default (DESIGN.md §13): each drained
``pop_all`` batch is decoded with vectorized bit-ops, contiguous write
runs coalesce into single wire messages carrying immediate vectors, and
the whole batch is issued through ``Network.send_batch`` under one lock.
``columnar=False`` keeps the scalar per-descriptor path alive as the
conformance oracle the fuzz harness holds the batched path to.

Atomics are emulated EFA-style (§4.1): a zero-byte write carrying the value
in immediate data; the receiver proxy updates host-memory counters when the
guard in the ControlBuffer passes.  For ``Op.ATOMIC`` commands the 32-bit
``src_off`` descriptor field (unused by a zero-byte transfer) carries the
atomic operand — fence write-counts and HT chunk ids — and ``dst_off``
addresses the guard/counter by a wide 32-bit id.

Completion-fence guards are keyed by **registered address ranges**
(DESIGN.md §12): at world setup the EP executor registers each rank's
receive-bucket table with its proxy (:meth:`Proxy.register_region` /
:meth:`Proxy.register_table`), and a delivered write is attributed to a
guard by resolving its landing offset against that table — exactly how a
real RDMA write resolves against a registered MR.  The wire immediate
carries no expert slot, so nothing aliases when a rank hosts more than 63
experts; writes into unregistered memory (combine returns) satisfy no
guard by construction.

When a guarded atomic *applies* (its fence passes / its sequence prefix
closes) the receiving proxy fires ``on_ready(src, counter_idx, operand)``:
the readiness event the EP executor uses to launch expert compute for that
bucket while other buckets' writes are still in flight (DESIGN.md §10).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.transport.fifo import (FLAG_FENCE, FifoChannel, Op,
                                       TransferCmd, unpack_cmds)
from repro.core.transport.semantics import (ControlBuffer, GuardTable,
                                            ImmKind, pack_imm, unpack_imm)
from repro.core.transport.simulator import Message, NetConfig, Network
from repro.core.transport.wire_format import (CH_BITS, CH_MASK, IMM_CH_SHIFT,
                                              IMM_COUNT_SHIFT, IMM_SEQ_SHIFT,
                                              IMM_VALUE_SHIFT, FENCE_COUNT_MAX,
                                              IMM_VAL_MAX, N_CHANNELS_MAX,
                                              SEQ_MOD,
                                              SRD_DISPLACEMENT_BOUND,
                                              ProtocolError)


# enum lookup for batch error reporting (matches the scalar path's message)
_OP_OF = {int(o): o for o in Op}


def coalesce_cap(cfg: NetConfig) -> int:
    """Longest write run one wire message may carry under ``cfg``.  Each
    sub-write keeps its own sequence number, so under srd a delayed message
    can be displaced by up to ``(reorder_window + 1) * cap`` *sequences*,
    not arrivals; the cap keeps that product inside the receiver's
    documented ``SEQ_MOD // 4`` displacement bound (semantics.py).  rc
    delivers per-link in order (no displacement) — the cap there is
    payload-assembly sanity.  Module-level (pure in ``cfg``) so the static
    verifier checks the exact cap the proxy will use."""
    if cfg.mode == "srd":
        return max(1, SRD_DISPLACEMENT_BOUND // (cfg.reorder_window + 1))
    return 256


@dataclass
class SymmetricMemory:
    """Per-rank registered region; peers address each other by offset only
    (base addresses exchanged at init; paper §3.2 'symmetric memory')."""

    data: np.ndarray                 # byte-addressable payload region
    counters: np.ndarray             # host-visible atomic counters (int64)

    @staticmethod
    def create(size: int, n_counters: int = 256) -> "SymmetricMemory":
        return SymmetricMemory(data=np.zeros(size, np.uint8),
                               counters=np.zeros(n_counters, np.int64))


class Proxy:
    def __init__(self, rank: int, net: Network, mem: SymmetricMemory,
                 n_threads: int = 4, n_channels: int = 8,
                 k_max_inflight: int = 64, columnar: bool = True,
                 coalesce: bool = True):
        if n_channels > N_CHANNELS_MAX:
            raise ProtocolError(f"n_channels {n_channels} > imm codec max "
                                f"{N_CHANNELS_MAX}")
        self.rank = rank
        self.net = net
        self.mem = mem
        self.n_threads = n_threads
        # columnar=False drains command-by-command through the scalar
        # TransferCmd codec — the conformance oracle the fuzz harness holds
        # the batched path to; coalesce=False keeps the columnar drain but
        # issues one wire message per descriptor (bit-identical schedule to
        # the scalar path)
        self.columnar = columnar
        self.coalesce = coalesce and columnar
        self.channels = [FifoChannel(k_max_inflight) for _ in range(n_channels)]
        # registered receive-bucket table: landing offset -> guard id; one
        # per rank (it describes this rank's symmetric memory), shared by
        # every per-peer ControlBuffer
        self.guards = GuardTable()
        self.ctrl: dict[int, ControlBuffer] = {}       # per source rank
        self.error: Optional[BaseException] = None     # first worker failure
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._seq: dict[tuple[int, int], int] = {}     # (dst, channel) -> seq
        self._lock = threading.Lock()
        self._executing = 0          # commands mid-execution (quiesce check)
        self.stats = {"cmds": 0, "writes": 0, "atomics": 0, "held_max": 0}
        # readiness hook: (src_rank, counter_idx, operand) per applied atomic
        self.on_ready: Optional[Callable[[int, int, int], None]] = None
        net.register(rank, self._on_deliver)

    # ------------------------------------------------------ registration --
    def register_region(self, base: int, extent: int, guard_id: int) -> None:
        """Register one receive bucket: writes landing in
        ``[base, base + extent)`` count toward fence guard ``guard_id``.
        Done once at world setup, before any traffic (the RDMA MR model)."""
        self.guards.register(base, extent, guard_id)

    def register_table(self, bases, extents, guard_ids) -> None:
        """Bulk form of :meth:`register_region`; arguments broadcast."""
        self.guards.register_table(bases, extents, guard_ids)

    # --------------------------------------------------------- GPU side --
    def push(self, ch: int, cmd: TransferCmd, block: bool = True) -> Optional[int]:
        c = self.channels[ch % len(self.channels)]
        return c.push(cmd) if block else c.try_push(cmd)

    def push_batch(self, ch: int, words: np.ndarray,
                   block: bool = True) -> int:
        """Bulk push of packed (N, 4) uint32 descriptors onto one channel.

        block=True waits for ring space (worker threads must be draining);
        block=False pushes what fits and returns the count — the caller
        relieves back-pressure (e.g. via :meth:`drain_inline`) and retries
        with the remainder.
        """
        c = self.channels[ch % len(self.channels)]
        return c.push_batch(words) if block else c.try_push_batch(words)

    # ------------------------------------------------------- CPU threads --
    def start(self):
        for t in range(self.n_threads):
            th = threading.Thread(target=self._worker, args=(t,), daemon=True)
            th.start()
            self._threads.append(th)

    def stop(self):
        self._stop.set()
        for c in self.channels:
            c.close()
        for th in self._threads:
            th.join(timeout=2.0)

    @property
    def busy(self) -> bool:
        """True while any command is queued or mid-execution (used by the
        event-clock quiesce condition in threaded mode).  ``_executing`` is
        read under the proxy lock — worker threads write it there — so the
        quiesce loop never reads a torn/stale snapshot."""
        with self._lock:
            executing = self._executing
        return executing > 0 or any(c.inflight for c in self.channels)

    def poll_error(self) -> Optional[BaseException]:
        """First worker failure, read under the proxy lock (workers publish
        it there); the event-clock pump re-raises it on the main thread."""
        with self._lock:
            return self.error

    def _worker(self, tid: int):
        my = self.channels[tid::self.n_threads]
        while not self._stop.is_set():
            busy = False
            for ch in my:
                # _executing is raised BEFORE the bulk pop so the quiesce
                # condition never sees the batch neither queued nor
                # mid-execution
                with self._lock:
                    self._executing += 1
                words = ch.pop_all()
                if words is None:
                    with self._lock:
                        self._executing -= 1
                    continue
                try:
                    self._execute_words(words)
                except BaseException as e:     # surface instead of hanging:
                    with self._lock:           # the quiesce loop re-raises
                        if self.error is None:
                            self.error = e
                finally:
                    with self._lock:
                        self._executing -= 1
                busy = True
            if not busy:
                time.sleep(1e-5)

    def drain_inline(self):
        """Single-threaded execution of everything queued (deterministic
        mode used by tests/benchmarks without starting worker threads).
        Bulk-pops each channel so the ring's locking is per batch, not per
        command."""
        progress = True
        while progress:
            progress = False
            for ch in self.channels:
                words = ch.pop_all()
                if words is None:
                    continue
                self._execute_words(words)
                progress = True

    def _execute_words(self, words: np.ndarray) -> None:
        """Execute one drained (N, 4) descriptor batch: columnar fast path,
        or row-by-row through the scalar codec (the conformance oracle)."""
        if self.columnar:
            self._execute_batch(words)
        else:
            unpack = TransferCmd.unpack
            for row in words:
                self._execute(unpack(row))

    # ------------------------------------------------------ cmd execution --
    def _next_seq(self, dst: int, channel: int) -> int:
        # only sequence-ordered kinds (writes, seq atomics) consume numbers;
        # fences carry no sequence, so they never hole a channel's prefix.
        # No lock: each (dst, channel) key has exactly one writer — worker
        # threads own disjoint channel subsets, and inline drains are
        # single-threaded.
        k = (dst, channel)
        s = self._seq.get(k, 0)
        self._seq[k] = s + 1
        return s % SEQ_MOD

    def _execute(self, cmd: TransferCmd):
        self.stats["cmds"] += 1
        if cmd.op in (Op.WRITE, Op.WRITE_ATOMIC):
            self.stats["writes"] += 1
            payload = self.mem.data[cmd.src_off:cmd.src_off + cmd.length].copy()
            seq = self._next_seq(cmd.dst_rank, cmd.channel)
            # the immediate carries no guard key: the receiver resolves the
            # landing offset against its registered bucket table instead
            imm = pack_imm(ImmKind.WRITE, cmd.channel, seq, 0)
            self.net.send(Message(self.rank, cmd.dst_rank, qp=cmd.channel,
                                  kind="write", dst_off=cmd.dst_off,
                                  payload=payload, imm=imm))
            if cmd.op == Op.WRITE_ATOMIC:
                self._send_atomic(cmd, fence=True)
        elif cmd.op == Op.ATOMIC:
            self._send_atomic(cmd, fence=bool(cmd.flags & FLAG_FENCE))
        elif cmd.op == Op.DRAIN:
            # delivery is driven by the event clock (Network.step); a DRAIN
            # descriptor is a scheduling hint with nothing left to do here
            pass
        else:
            raise ValueError(f"unhandled op {cmd.op!r}")

    def _send_atomic(self, cmd: TransferCmd, fence: bool):
        self.stats["atomics"] += 1
        operand = cmd.src_off               # 32-bit atomic operand field
        if fence:
            if operand > FENCE_COUNT_MAX:
                raise ProtocolError(f"fence count {operand} > "
                                    f"{FENCE_COUNT_MAX} (21-bit imm field)")
            imm = pack_imm(ImmKind.FENCE_ATOMIC, cmd.channel, 0, operand)
        else:
            if operand > IMM_VAL_MAX:
                raise ProtocolError(f"atomic operand {operand} > "
                                    f"{IMM_VAL_MAX} (16-bit imm field)")
            seq = self._next_seq(cmd.dst_rank, cmd.channel)
            imm = pack_imm(ImmKind.SEQ_ATOMIC, cmd.channel, seq, operand)
        # dst_off addresses the guard/counter by wide id (zero-byte
        # transfers have no landing address to resolve)
        self.net.send(Message(self.rank, cmd.dst_rank, qp=cmd.channel,
                              kind="imm", dst_off=cmd.dst_off, payload=None,
                              imm=imm))

    # ----------------------------------------------- batched cmd execution --
    def _coalesce_cap(self) -> int:
        """See module-level :func:`coalesce_cap` (the cap leaves a 2x
        margin against the true ±SEQ_MOD // 2 unwrap window — cover for
        seq-carrying messages of mixed wire sizes: zero-payload
        SEQ_ATOMICs are denser per wire byte than coalesced data runs)."""
        return coalesce_cap(self.net.cfg)

    def _execute_batch(self, words: np.ndarray) -> None:
        """Columnar consumer fast path: decode a drained (N, 4) descriptor
        batch with vectorized bit-ops, assign per-(dst, channel) sequence
        numbers in bulk, coalesce contiguous write runs into single wire
        messages, and issue the whole batch through ``Network.send_batch``
        under one lock.  Field-for-field equivalent to N scalar
        :meth:`_execute` calls (the fuzz harness holds it to that oracle);
        with coalescing off the message stream is bit-identical."""
        n = len(words)
        if n == 0:
            return
        cols = unpack_cmds(words)
        op, ch, dst = cols.op, cols.channel, cols.dst_rank
        src_off, dst_off, length = cols.src_off, cols.dst_off, cols.length
        is_w = (op == Op.WRITE) | (op == Op.WRITE_ATOMIC)
        is_wa = op == Op.WRITE_ATOMIC
        is_at = op == Op.ATOMIC
        handled = is_w | is_at | (op == Op.DRAIN)
        if not handled.all():
            bad = int(op[~handled][0])
            bad = _OP_OF.get(bad, bad)
            raise ValueError(f"unhandled op {bad!r}")
        self.stats["cmds"] += n
        self.stats["writes"] += int(is_w.sum())
        self.stats["atomics"] += int((is_at | is_wa).sum())
        fenced = (cols.flags & FLAG_FENCE) != 0
        is_fat = is_at & fenced                # LL completion fences
        is_sat = is_at & ~fenced               # HT seq atomics
        sends_imm = is_w | is_at
        if sends_imm.any() and int(ch[sends_imm].max()) >= N_CHANNELS_MAX:
            raise ProtocolError(f"channel {int(ch[sends_imm].max())} >= "
                                f"{N_CHANNELS_MAX}: imm codec carries "
                                "3 channel bits")

        # ---- bulk sequence assignment (order within each (dst, channel)
        # key is the descriptor order, exactly as N _next_seq calls) -------
        seq = np.zeros(n, np.int64)
        m_seq = is_w | is_sat
        if m_seq.any():
            rows = np.flatnonzero(m_seq)
            key = (dst[rows] << CH_BITS) | ch[rows]
            order = np.argsort(key, kind="stable")
            ks = key[order]
            nk = len(ks)
            brk = np.empty(nk, bool)
            brk[0] = True
            np.not_equal(ks[1:], ks[:-1], out=brk[1:])
            starts = np.flatnonzero(brk)
            reps = np.diff(np.append(starts, nk))
            base = np.empty(len(starts), np.int64)
            for j, s in enumerate(starts.tolist()):
                k = (int(ks[s]) >> CH_BITS, int(ks[s]) & CH_MASK)
                base[j] = self._seq.get(k, 0)
                self._seq[k] = int(base[j]) + int(reps[j])
            full = np.repeat(base, reps) + \
                (np.arange(nk) - np.repeat(starts, reps))
            sw = np.empty(nk, np.int64)
            sw[order] = full % SEQ_MOD
            seq[rows] = sw

        # ---- vectorized immediates (same per-kind layout as pack_imm) ----
        imm = np.zeros(n, np.int64)
        imm[is_w] = (ch[is_w] << IMM_CH_SHIFT) \
            | (seq[is_w] << IMM_SEQ_SHIFT)                  # ImmKind.WRITE
        if is_fat.any():
            cnt = src_off[is_fat]              # 32-bit atomic operand field
            if int(cnt.max()) > FENCE_COUNT_MAX:
                raise ProtocolError(f"fence count {int(cnt.max())} > "
                                    f"{FENCE_COUNT_MAX} (21-bit imm field)")
            imm[is_fat] = int(ImmKind.FENCE_ATOMIC) \
                | (ch[is_fat] << IMM_CH_SHIFT) | (cnt << IMM_COUNT_SHIFT)
        if is_sat.any():
            val = src_off[is_sat]
            if int(val.max()) > IMM_VAL_MAX:
                raise ProtocolError(f"atomic operand {int(val.max())} > "
                                    f"{IMM_VAL_MAX} (16-bit imm field)")
            imm[is_sat] = int(ImmKind.SEQ_ATOMIC) \
                | (ch[is_sat] << IMM_CH_SHIFT) \
                | (seq[is_sat] << IMM_SEQ_SHIFT) | (val << IMM_VALUE_SHIFT)

        # ---- coalescing: maximal runs of writes to one (dst, channel)
        # whose landing ranges are contiguous, split at the srd seq-
        # displacement cap ---------------------------------------------------
        if self.coalesce and n > 1:
            cont = np.zeros(n, bool)
            cont[1:] = (is_w[1:] & is_w[:-1] & (dst[1:] == dst[:-1])
                        & (ch[1:] == ch[:-1])
                        & (dst_off[1:] == dst_off[:-1] + length[:-1]))
            run_start = np.cumsum(~cont) - 1        # raw run id per row
            pos = np.arange(n) - \
                np.flatnonzero(~cont)[run_start]    # position within run
            cont &= (pos % self._coalesce_cap()) != 0
            seg_starts = np.flatnonzero(~cont)
            # payload-assembly prefix sums: a run [a, b) has contiguous
            # sources iff spref[b-1] == spref[a], and uniform lengths iff
            # lpref[b-1] == lpref[a] — O(1) per segment in the build loop
            sbrk = np.ones(n, np.int64)
            sbrk[1:] = src_off[1:] != src_off[:-1] + length[:-1]
            spref = np.cumsum(sbrk).tolist()
            lbrk = np.ones(n, np.int64)
            lbrk[1:] = length[1:] != length[:-1]
            lpref = np.cumsum(lbrk).tolist()
        else:
            seg_starts = np.arange(n)
            spref = lpref = None
        seg_ends = np.append(seg_starts[1:], n)

        # ---- build the wire-message batch in descriptor order ------------
        # (columns drop to python lists here: the loop below touches every
        # field once per segment, and list indexing beats np scalar boxing)
        mem = self.mem.data
        rank = self.rank
        wa_rows = set(np.flatnonzero(is_wa).tolist()) if is_wa.any() else ()
        w_l, at_l = is_w.tolist(), is_at.tolist()
        dst_l, ch_l, imm_l = dst.tolist(), ch.tolist(), imm.tolist()
        src_l, off_l, len_l = src_off.tolist(), dst_off.tolist(), \
            length.tolist()
        msgs: list[Message] = []
        for a, b in zip(seg_starts.tolist(), seg_ends.tolist()):
            if w_l[a]:
                if b - a == 1:
                    s = src_l[a]
                    msgs.append(Message(         # positional: hot loop
                        rank, dst_l[a], ch_l[a], "write", off_l[a],
                        mem[s:s + len_l[a]].copy(), imm_l[a]))
                else:
                    # run total bytes = dst span (the run is dst-contiguous
                    # by construction)
                    total = off_l[b - 1] + len_l[b - 1] - off_l[a]
                    if spref[b - 1] == spref[a]:    # contiguous sources
                        payload = mem[src_l[a]:src_l[a] + total].copy()
                    elif lpref[b - 1] == lpref[a]:  # uniform lengths
                        payload = mem[src_off[a:b, None]
                                      + np.arange(len_l[a])].reshape(-1)
                    else:
                        payload = np.concatenate(
                            [mem[src_l[r]:src_l[r] + len_l[r]]
                             for r in range(a, b)])
                    msgs.append(Message(
                        rank, dst_l[a], ch_l[a], "write", off_l[a],
                        payload, None, imm_vec=imm[a:b].astype(np.uint32),
                        sub_off=dst_off[a:b].copy()))
                # piggybacked completion atomics ride behind their writes
                for r in (range(a, b) if wa_rows else ()):
                    if r in wa_rows:
                        opd = src_l[r]
                        if opd > FENCE_COUNT_MAX:
                            raise ProtocolError(
                                f"fence count {opd} > {FENCE_COUNT_MAX} "
                                "(21-bit imm field)")
                        msgs.append(Message(
                            rank, dst_l[r], qp=ch_l[r], kind="imm",
                            dst_off=off_l[r], payload=None,
                            imm=pack_imm(ImmKind.FENCE_ATOMIC, ch_l[r], 0,
                                         opd)))
            elif at_l[a]:
                msgs.append(Message(rank, dst_l[a], qp=ch_l[a], kind="imm",
                                    dst_off=off_l[a], payload=None,
                                    imm=imm_l[a]))
            # DRAIN: scheduling hint, nothing to issue
        self.net.send_batch(msgs)

    # ---------------------------------------------------------- receiver --
    def _ctrl_for(self, src: int) -> ControlBuffer:
        if src not in self.ctrl:
            self.ctrl[src] = ControlBuffer(guards=self.guards)
        return self.ctrl[src]

    def _on_deliver(self, msg: Message):
        cb = self._ctrl_for(msg.src)
        if msg.kind == "write":
            if msg.imm_vec is not None:
                # coalesced run: the landing range is contiguous by
                # construction, so the whole payload is ONE copy; guard
                # resolution and sequence bookkeeping run vectorized over
                # the unrolled immediate vector
                self.mem.data[msg.dst_off:msg.dst_off + msg.payload.size] = \
                    msg.payload
                cb.on_write_batch(msg.imm_vec, msg.sub_off)
                self.stats["held_max"] = max(self.stats["held_max"],
                                             cb.n_held)
                return
            # writes apply immediately under ordered AND unordered
            # transports (one-sided placements at distinct offsets are
            # order-independent); only atomics need receiver-side guards —
            # the landing offset resolves to the guard the write feeds
            def apply(m=msg):
                self.mem.data[m.dst_off:m.dst_off + m.payload.size] = m.payload
            cb.on_write(msg.imm, apply, msg.dst_off)
        else:
            kind, ch, seq, value = unpack_imm(msg.imm)

            def apply(m=msg, v=value):
                idx = m.dst_off % len(self.mem.counters)
                self.mem.counters[idx] += 1
                if self.on_ready is not None:
                    self.on_ready(m.src, idx, v)
            cb.on_atomic(msg.imm, apply, guard=msg.dst_off)
        self.stats["held_max"] = max(self.stats["held_max"], cb.n_held)
