"""Multithreaded CPU proxy (paper §3.2): consumes TransferCmds from FIFO
channels and executes GPUDirect-RDMA-equivalent operations over the network
model, bridging delivery semantics with the receiver-side control buffer.

One proxy per "GPU" (rank); ``n_threads`` worker threads each own a disjoint
subset of FIFO channels (thread i serves channels i, i+T, ... — no shared
state between threads, as in the paper).  QP selection round-robins across
the thread's QPs unless the command pins a channel (ordering domain).

Atomics are emulated EFA-style (§4.1): a zero-byte write carrying the value
in immediate data; the receiver proxy updates host-memory counters when the
guard in the ControlBuffer passes.  For ``Op.ATOMIC`` commands the 32-bit
``src_off`` descriptor field (unused by a zero-byte transfer) carries the
atomic operand — fence write-counts and HT chunk ids — and ``dst_off``
addresses the guard/counter by a wide 32-bit id.

Completion-fence guards are keyed by **registered address ranges**
(DESIGN.md §12): at world setup the EP executor registers each rank's
receive-bucket table with its proxy (:meth:`Proxy.register_region` /
:meth:`Proxy.register_table`), and a delivered write is attributed to a
guard by resolving its landing offset against that table — exactly how a
real RDMA write resolves against a registered MR.  The wire immediate
carries no expert slot, so nothing aliases when a rank hosts more than 63
experts; writes into unregistered memory (combine returns) satisfy no
guard by construction.

When a guarded atomic *applies* (its fence passes / its sequence prefix
closes) the receiving proxy fires ``on_ready(src, counter_idx, operand)``:
the readiness event the EP executor uses to launch expert compute for that
bucket while other buckets' writes are still in flight (DESIGN.md §10).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.transport.fifo import FLAG_FENCE, FifoChannel, Op, TransferCmd
from repro.core.transport.semantics import (FENCE_COUNT_MAX, IMM_VAL_MAX,
                                            N_CHANNELS_MAX, SEQ_MOD,
                                            ControlBuffer, GuardTable,
                                            ImmKind, pack_imm, unpack_imm)
from repro.core.transport.simulator import Message, Network


@dataclass
class SymmetricMemory:
    """Per-rank registered region; peers address each other by offset only
    (base addresses exchanged at init; paper §3.2 'symmetric memory')."""

    data: np.ndarray                 # byte-addressable payload region
    counters: np.ndarray             # host-visible atomic counters (int64)

    @staticmethod
    def create(size: int, n_counters: int = 256) -> "SymmetricMemory":
        return SymmetricMemory(data=np.zeros(size, np.uint8),
                               counters=np.zeros(n_counters, np.int64))


class Proxy:
    def __init__(self, rank: int, net: Network, mem: SymmetricMemory,
                 n_threads: int = 4, n_channels: int = 8,
                 k_max_inflight: int = 64):
        assert n_channels <= N_CHANNELS_MAX, \
            f"imm codec carries {N_CHANNELS_MAX} channels max"
        self.rank = rank
        self.net = net
        self.mem = mem
        self.n_threads = n_threads
        self.channels = [FifoChannel(k_max_inflight) for _ in range(n_channels)]
        # registered receive-bucket table: landing offset -> guard id; one
        # per rank (it describes this rank's symmetric memory), shared by
        # every per-peer ControlBuffer
        self.guards = GuardTable()
        self.ctrl: dict[int, ControlBuffer] = {}       # per source rank
        self.error: Optional[BaseException] = None     # first worker failure
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._seq: dict[tuple[int, int], int] = {}     # (dst, channel) -> seq
        self._lock = threading.Lock()
        self._executing = 0          # commands mid-execution (quiesce check)
        self.stats = {"cmds": 0, "writes": 0, "atomics": 0, "held_max": 0}
        # readiness hook: (src_rank, counter_idx, operand) per applied atomic
        self.on_ready: Optional[Callable[[int, int, int], None]] = None
        net.register(rank, self._on_deliver)

    # ------------------------------------------------------ registration --
    def register_region(self, base: int, extent: int, guard_id: int) -> None:
        """Register one receive bucket: writes landing in
        ``[base, base + extent)`` count toward fence guard ``guard_id``.
        Done once at world setup, before any traffic (the RDMA MR model)."""
        self.guards.register(base, extent, guard_id)

    def register_table(self, bases, extents, guard_ids) -> None:
        """Bulk form of :meth:`register_region`; arguments broadcast."""
        self.guards.register_table(bases, extents, guard_ids)

    # --------------------------------------------------------- GPU side --
    def push(self, ch: int, cmd: TransferCmd, block: bool = True) -> Optional[int]:
        c = self.channels[ch % len(self.channels)]
        return c.push(cmd) if block else c.try_push(cmd)

    def push_batch(self, ch: int, words: np.ndarray,
                   block: bool = True) -> int:
        """Bulk push of packed (N, 4) uint32 descriptors onto one channel.

        block=True waits for ring space (worker threads must be draining);
        block=False pushes what fits and returns the count — the caller
        relieves back-pressure (e.g. via :meth:`drain_inline`) and retries
        with the remainder.
        """
        c = self.channels[ch % len(self.channels)]
        return c.push_batch(words) if block else c.try_push_batch(words)

    # ------------------------------------------------------- CPU threads --
    def start(self):
        for t in range(self.n_threads):
            th = threading.Thread(target=self._worker, args=(t,), daemon=True)
            th.start()
            self._threads.append(th)

    def stop(self):
        self._stop.set()
        for c in self.channels:
            c.close()
        for th in self._threads:
            th.join(timeout=2.0)

    @property
    def busy(self) -> bool:
        """True while any command is queued or mid-execution (used by the
        event-clock quiesce condition in threaded mode)."""
        return self._executing > 0 or any(c.inflight for c in self.channels)

    def _worker(self, tid: int):
        my = self.channels[tid::self.n_threads]
        while not self._stop.is_set():
            busy = False
            for ch in my:
                got = ch.poll()
                if got is None:
                    continue
                idx, cmd = got
                with self._lock:
                    self._executing += 1
                try:
                    self._execute(cmd)
                except BaseException as e:     # surface instead of hanging:
                    if self.error is None:     # the quiesce loop re-raises
                        self.error = e
                finally:
                    ch.pop()
                    with self._lock:
                        self._executing -= 1
                busy = True
            if not busy:
                time.sleep(1e-5)

    def drain_inline(self):
        """Single-threaded execution of everything queued (deterministic
        mode used by tests/benchmarks without starting worker threads).
        Bulk-pops each channel so the ring's locking is per batch, not per
        command."""
        unpack = TransferCmd.unpack
        progress = True
        while progress:
            progress = False
            for ch in self.channels:
                words = ch.pop_all()
                if words is None:
                    continue
                for row in words:
                    self._execute(unpack(row))
                progress = True

    # ------------------------------------------------------ cmd execution --
    def _next_seq(self, dst: int, channel: int) -> int:
        # only sequence-ordered kinds (writes, seq atomics) consume numbers;
        # fences carry no sequence, so they never hole a channel's prefix.
        # No lock: each (dst, channel) key has exactly one writer — worker
        # threads own disjoint channel subsets, and inline drains are
        # single-threaded.
        k = (dst, channel)
        s = self._seq.get(k, 0)
        self._seq[k] = s + 1
        return s % SEQ_MOD

    def _execute(self, cmd: TransferCmd):
        self.stats["cmds"] += 1
        if cmd.op in (Op.WRITE, Op.WRITE_ATOMIC):
            self.stats["writes"] += 1
            payload = self.mem.data[cmd.src_off:cmd.src_off + cmd.length].copy()
            seq = self._next_seq(cmd.dst_rank, cmd.channel)
            # the immediate carries no guard key: the receiver resolves the
            # landing offset against its registered bucket table instead
            imm = pack_imm(ImmKind.WRITE, cmd.channel, seq, 0)
            self.net.send(Message(self.rank, cmd.dst_rank, qp=cmd.channel,
                                  kind="write", dst_off=cmd.dst_off,
                                  payload=payload, imm=imm))
            if cmd.op == Op.WRITE_ATOMIC:
                self._send_atomic(cmd, fence=True)
        elif cmd.op == Op.ATOMIC:
            self._send_atomic(cmd, fence=bool(cmd.flags & FLAG_FENCE))
        elif cmd.op == Op.DRAIN:
            # delivery is driven by the event clock (Network.step); a DRAIN
            # descriptor is a scheduling hint with nothing left to do here
            pass
        else:
            raise ValueError(f"unhandled op {cmd.op!r}")

    def _send_atomic(self, cmd: TransferCmd, fence: bool):
        self.stats["atomics"] += 1
        operand = cmd.src_off               # 32-bit atomic operand field
        if fence:
            assert operand <= FENCE_COUNT_MAX, operand
            imm = pack_imm(ImmKind.FENCE_ATOMIC, cmd.channel, 0, operand)
        else:
            assert operand <= IMM_VAL_MAX, operand
            seq = self._next_seq(cmd.dst_rank, cmd.channel)
            imm = pack_imm(ImmKind.SEQ_ATOMIC, cmd.channel, seq, operand)
        # dst_off addresses the guard/counter by wide id (zero-byte
        # transfers have no landing address to resolve)
        self.net.send(Message(self.rank, cmd.dst_rank, qp=cmd.channel,
                              kind="imm", dst_off=cmd.dst_off, payload=None,
                              imm=imm))

    # ---------------------------------------------------------- receiver --
    def _ctrl_for(self, src: int) -> ControlBuffer:
        if src not in self.ctrl:
            self.ctrl[src] = ControlBuffer(guards=self.guards)
        return self.ctrl[src]

    def _on_deliver(self, msg: Message):
        cb = self._ctrl_for(msg.src)
        if msg.kind == "write":
            # writes apply immediately under ordered AND unordered
            # transports (one-sided placements at distinct offsets are
            # order-independent); only atomics need receiver-side guards —
            # the landing offset resolves to the guard the write feeds
            def apply(m=msg):
                self.mem.data[m.dst_off:m.dst_off + m.payload.size] = m.payload
            cb.on_write(msg.imm, apply, msg.dst_off)
        else:
            kind, ch, seq, value = unpack_imm(msg.imm)

            def apply(m=msg, v=value):
                idx = m.dst_off % len(self.mem.counters)
                self.mem.counters[idx] += 1
                if self.on_ready is not None:
                    self.on_ready(m.src, idx, v)
            cb.on_atomic(msg.imm, apply, guard=msg.dst_off)
        self.stats["held_max"] = max(self.stats["held_max"], cb.n_held)
