"""End-to-end EP dispatch/combine over the transport substrate.

Executes the paper's LL protocol literally: per-token RDMA writes tagged with
immediate data, one completion-fence atomic per (source, expert), expert FFN
at the destination, per-token combine writes back, weighted reduce at the
source — all over the unordered (SRD) or ordered (RC) network model, through
128-bit FIFO channels and CPU proxies.

Tests prove protocol correctness (result == dense oracle under any delivery
order); benchmarks reuse it for paper Figs. 7/15/17.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.transport.fifo import FLAG_FENCE, Op, TransferCmd
from repro.core.transport.proxy import Proxy, SymmetricMemory
from repro.core.transport.simulator import Network, NetConfig

F32 = np.dtype(np.float32)


def np_swiglu(x: np.ndarray, wg, wu, wd) -> np.ndarray:
    g = x @ wg
    u = x @ wu
    return (g / (1 + np.exp(-g)) * u) @ wd


def _to_bytes(a: np.ndarray) -> np.ndarray:
    return np.frombuffer(np.ascontiguousarray(a, F32).tobytes(), np.uint8)


def _from_bytes(b: np.ndarray, shape) -> np.ndarray:
    return np.frombuffer(b.tobytes(), F32).reshape(shape)


@dataclass
class EPWorld:
    n_ranks: int
    n_experts: int
    top_k: int
    d: int
    f: int
    capacity: int
    net_cfg: NetConfig = field(default_factory=NetConfig)
    n_channels: int = 8
    n_threads: int = 4
    use_threads: bool = False

    def __post_init__(self):
        assert self.n_experts % self.n_ranks == 0
        self.eps = self.n_experts // self.n_ranks
        self.tok_bytes = self.d * 4
        self.net = Network(self.net_cfg, self.n_ranks)
        self.proxies: list[Proxy] = []
        self.mems: list[SymmetricMemory] = []

    def run(self, x: np.ndarray, top_idx: np.ndarray, top_w: np.ndarray,
            wg: np.ndarray, wu: np.ndarray, wd: np.ndarray) -> np.ndarray:
        """x: (R, Tl, D); top_idx/top_w: (R, Tl, K); w*: (E, D, F)/(E, F, D)."""
        R, Tl, D = x.shape
        K, C = self.top_k, self.capacity
        tb = self.tok_bytes
        send0 = 0
        recv0 = send0 + Tl * tb
        ret0 = recv0 + R * self.eps * C * tb
        total = ret0 + Tl * K * tb
        mems = [SymmetricMemory.create(total, n_counters=R * self.eps + R)
                for _ in range(R)]
        proxies = [Proxy(r, self.net, mems[r], n_threads=self.n_threads,
                         n_channels=self.n_channels,
                         ordered_transport=(self.net_cfg.mode == "rc"))
                   for r in range(R)]
        self.proxies, self.mems = proxies, mems

        def push(r, ch, cmd):
            # inline mode: back-pressure is relieved by draining the proxy
            # (the paper's kMaxInflight pacing, §3.1) instead of blocking
            if self.use_threads:
                proxies[r].push(ch, cmd)
                return
            while proxies[r].push(ch, cmd, block=False) is None:
                proxies[r].drain_inline()
        self._push = push
        for r in range(R):
            mems[r].data[send0:send0 + Tl * tb] = _to_bytes(x[r])

        # slot assignment: arrival order per (src, expert); the slot map is
        # sender-side state (the metadata a real TransferCmd stream encodes)
        slot_of = np.zeros((R, Tl, K), np.int32)
        counts: dict[tuple[int, int], int] = {}
        for r in range(R):
            for t in range(Tl):
                for k in range(K):
                    e = int(top_idx[r, t, k])
                    c = counts.get((r, e), 0)
                    counts[(r, e)] = c + 1
                    slot_of[r, t, k] = c
        assert max(counts.values()) <= C, "capacity overflow in setup"

        # ------------------------- dispatch ------------------------------
        for r in range(R):
            for t in range(Tl):
                for k in range(K):
                    e = int(top_idx[r, t, k])
                    dst, el = e // self.eps, e % self.eps
                    dst_off = recv0 + ((r * self.eps + el) * C
                                       + int(slot_of[r, t, k])) * tb
                    ch = (t + k) % self.n_channels
                    push(r, ch, TransferCmd(
                        op=Op.WRITE, dst_rank=dst, channel=ch,
                        src_off=send0 + t * tb, dst_off=dst_off,
                        length=tb, value=el))
            for e in range(self.n_experts):
                c = counts.get((r, e), 0)
                if not c:
                    continue
                dst, el = e // self.eps, e % self.eps
                push(r, e % self.n_channels, TransferCmd(
                    op=Op.ATOMIC, dst_rank=dst, channel=e % self.n_channels,
                    src_off=0, dst_off=r * self.eps + el, length=0,
                    value=(el & 0x3F) | (min(c, 63) << 6), flags=FLAG_FENCE))
        self._pump(proxies)
        for r in range(R):          # every fence must have applied
            for e in range(self.n_experts):
                if counts.get((r, e), 0):
                    dst, el = e // self.eps, e % self.eps
                    assert mems[dst].counters[r * self.eps + el] == 1, (r, e)

        # ------------------------- expert compute ------------------------
        outs: dict[tuple[int, int], np.ndarray] = {}
        for dst in range(R):
            buf = _from_bytes(mems[dst].data[recv0:ret0], (R, self.eps, C, D))
            for src in range(R):
                for el in range(self.eps):
                    e = dst * self.eps + el
                    c = counts.get((src, e), 0)
                    if c:
                        outs[(src, e)] = np_swiglu(
                            buf[src, el, :c], wg[e], wu[e], wd[e])

        # ------------------------- combine (write back) ------------------
        inv = {}
        for r in range(R):
            for t in range(Tl):
                for k in range(K):
                    inv[(r, int(top_idx[r, t, k]), int(slot_of[r, t, k]))] = (t, k)
        for dst in range(R):
            for src in range(R):
                for el in range(self.eps):
                    e = dst * self.eps + el
                    c = counts.get((src, e), 0)
                    if not c:
                        continue
                    base = recv0 + ((src * self.eps + el) * C) * tb
                    mems[dst].data[base:base + c * tb] = _to_bytes(outs[(src, e)])
                    for slot in range(c):
                        t, k = inv[(src, e, slot)]
                        ch = (t + k) % self.n_channels
                        push(dst, ch, TransferCmd(
                            op=Op.WRITE, dst_rank=src, channel=ch,
                            src_off=base + slot * tb,
                            dst_off=ret0 + (t * K + k) * tb,
                            length=tb, value=0))
        self._pump(proxies)

        # ------------------------- weighted reduce at source -------------
        out = np.zeros((R, Tl, D), np.float64)
        for r in range(R):
            ret = _from_bytes(mems[r].data[ret0:ret0 + Tl * K * tb], (Tl, K, D))
            out[r] = np.einsum("tkd,tk->td", ret.astype(np.float64),
                               top_w[r].astype(np.float64))
        return out.astype(np.float32)

    def _pump(self, proxies):
        if self.use_threads:
            import time
            for p in proxies:
                if not p._threads:
                    p.start()
            for _ in range(500):
                if all(c.inflight == 0 for p in proxies for c in p.channels):
                    break
                time.sleep(1e-3)
                self.net.flush()
            self.net.flush()
        else:
            for _ in range(4):
                for p in proxies:
                    p.drain_inline()
                self.net.flush()

    @staticmethod
    def oracle(x, top_idx, top_w, wg, wu, wd) -> np.ndarray:
        R, Tl, D = x.shape
        out = np.zeros((R, Tl, D), np.float64)
        for r in range(R):
            for t in range(Tl):
                acc = np.zeros(D, np.float64)
                for k in range(top_idx.shape[2]):
                    e = int(top_idx[r, t, k])
                    acc += float(top_w[r, t, k]) * np_swiglu(
                        x[r, t].astype(np.float32)[None],
                        wg[e], wu[e], wd[e])[0].astype(np.float64)
                out[r, t] = acc
        return out.astype(np.float32)
