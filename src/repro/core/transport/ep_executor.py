"""End-to-end EP dispatch/combine over the transport substrate.

Executes the paper's protocols literally over the event-driven network model
(DESIGN.md §10), through 128-bit FIFO channels and CPU proxies:

- **LL** (:meth:`EPWorld.run`): per-token RDMA writes tagged with immediate
  data, one completion-fence atomic per (source, expert), expert FFN at the
  destination, per-token combine writes back, weighted reduce at the source.
  The run is a *pipelined state machine*: when a (src, expert) fence applies
  at the receiver, the proxy fires a readiness event, and — once every
  source's fence for an expert has landed — that expert's FFN launches and
  its combine writes enter the network while other experts' dispatch writes
  are still in flight (the paper's proxy/compute overlap).

- **HT** (:meth:`EPWorld.run_ht`): chunked dispatch with per-(token, group)
  deduplication and hierarchical reduce.  A token crosses to each
  destination *rank* once per round, its expert list and combine weights
  riding as payload metadata; chunk boundaries are SEQ_ATOMIC markers that
  apply only when the chunk's writes have all applied (per-channel sequence
  order), so each (src, chunk) bucket's partial FFN launches as soon as its
  marker lands.  Exactly one partially reduced vector returns per
  (token, destination rank) — group reduce at the receiver, global reduce at
  the source.

Routing decisions (slot assignment, per-(src, expert) counts, capacity
masks, dedup tables) come from the shared plan layer (:mod:`repro.core.plan`)
— the same plans the jax-collectives path consumes — and are turned into
*batched* TransferCmd streams: packed ``(N, 4)`` uint32 arrays pushed through
the ``Proxy.push_batch`` bulk FIFO path (DESIGN.md §8).

Tests prove protocol correctness (result == dense oracle under any delivery
order); benchmarks reuse it for paper Figs. 4/7/15/17.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional

import numpy as np

from repro.core import plan as planlib
from repro.core.transport.codec import get_codec
from repro.core.transport.fifo import FLAG_FENCE, Op, pack_cmds
from repro.core.transport.proxy import Proxy, SymmetricMemory
from repro.core.transport.semantics import IMM_VAL_MAX
from repro.core.transport.simulator import Network, NetConfig

F32 = np.dtype(np.float32)


class CommandStreams(NamedTuple):
    """Batched TransferCmd streams for one LL EP round, plus routing metadata.

    Each stream is a packed (N, 4) uint32 descriptor array (invalid routing
    entries already dropped) with parallel per-row ``*_pusher`` (the rank
    whose proxy issues the command) and ``*_channel`` arrays.
    ``entry_expert`` is the global expert id per kept entry — the bucket key
    the pipelined executor uses to launch per-expert combine streams."""

    plan: planlib.WorldPlan
    writes: np.ndarray          # dispatch data writes
    write_pusher: np.ndarray
    write_channel: np.ndarray
    fences: np.ndarray          # one completion-fence atomic per (src, e)
    fence_pusher: np.ndarray
    fence_channel: np.ndarray
    combines: np.ndarray        # combine writes back to the source
    combine_pusher: np.ndarray
    combine_channel: np.ndarray
    entry_expert: np.ndarray    # global expert id per kept entry
    guard_table: tuple          # (bases, extents, guard_ids) receive buckets
    ret_pos: np.ndarray         # (R, Tl, K) expert-major return slot per
    #                             choice (0 for invalid entries) — the
    #                             source's final reduce gathers through it


def build_command_streams(top_idx: np.ndarray, n_experts: int, eps: int,
                          capacity: int, tok_bytes: int, n_channels: int,
                          send0: int, recv0: int, ret0: int,
                          wire_bytes: Optional[int] = None,
                          out0: Optional[int] = None,
                          ) -> CommandStreams:
    """Vectorized LL-protocol command generation from a routing table.

    The single source of truth for how plans become TransferCmd streams —
    ``EPWorld.run`` executes exactly these; ``benchmarks/bench_plan.py``
    times this function against the seed's Python loops.

    Fence commands carry their full required write count in the 32-bit
    ``src_off`` operand field (the immediate codec packs 21 bits) and
    address their guard — the (src, expert) receive bucket — by the wide id
    in ``dst_off``.  Receivers attribute dispatch writes to guards by
    resolving each landing offset against the registered bucket table
    (``guard_table``, which :meth:`EPWorld.run` registers with every proxy),
    so no expert slot rides the wire and nothing aliases past 63 experts
    per rank.  Combine writes land in the unregistered return region and
    therefore can never satisfy a dispatch fence.

    ``wire_bytes`` is the per-token *wire* footprint (quantized payload +
    inline scale blocks, ``plan.wire_layout``; defaults to ``tok_bytes`` =
    fp32 passthrough): dispatch writes, receive-bucket strides, and the
    registered guard extents all size from it, so fence counts and guard
    ranges stay exact under compression — the scale blocks live inside the
    registered range.  Combine payloads are always full-precision fp32
    (``tok_bytes``; the fp32-accumulation contract, DESIGN.md §14), sourced
    from the expert-output region at ``out0`` when given (the receive
    buckets hold wire-format rows, which expert outputs must not clobber).
    """
    ti = np.ascontiguousarray(top_idx, np.int64)
    R, Tl, K = ti.shape
    tb = tok_bytes
    wb = tok_bytes if wire_bytes is None else wire_bytes
    wp = planlib.make_world_plan(ti, n_experts, capacity)
    valid = wp.valid.reshape(-1)

    dst = ti // eps                                     # (R, Tl, K)
    el = np.where(wp.valid, ti % eps, 0)
    t_idx = np.arange(Tl, dtype=np.int64)[None, :, None]
    src_off = np.broadcast_to(send0 + t_idx * wb, ti.shape)
    # dispatch writes land in the (src, expert) receive bucket at the plan's
    # arrival-order slot; combine writes come back from that bucket's
    # expert-output block into the source's expert-major return region
    # (``ret_pos`` below)
    bucket = np.arange(R)[:, None, None] * eps + el     # (src, expert) id
    recv_off = recv0 + (bucket * capacity + wp.rank) * wb
    src_rank = np.broadcast_to(np.arange(R)[:, None, None], ti.shape)

    # both write streams ride an expert-keyed channel and are emitted
    # ordered by (destination, landing offset) within each (pusher,
    # channel): one receive bucket's writes form one contiguous ascending
    # run, which is what the proxy's write coalescer turns into single
    # batched RDMA messages.  Sequence semantics don't care: LL writes
    # gate nothing, and seqs are assigned at drain time in stream order.
    ch_w = np.where(wp.valid, ti % n_channels, 0)       # global expert key
    writes = pack_cmds(int(Op.WRITE), dst, ch_w, src_off, recv_off, wb,
                       0)[valid]
    w_pusher = src_rank.reshape(-1)[valid]
    w_channel = ch_w.reshape(-1)[valid]
    wperm = np.lexsort((recv_off.reshape(-1)[valid],
                        dst.reshape(-1)[valid], w_channel, w_pusher))
    writes, w_pusher, w_channel = \
        writes[wperm], w_pusher[wperm], w_channel[wperm]
    # combine writes need no special marking: they land in the return
    # region, which is simply not in the registered bucket table, so they
    # can never count toward a dispatch fence guard (the pipelined executor
    # has combines in flight while other buckets' dispatches still are).
    # The return layout is expert-major per source (one contiguous block
    # per (expert, source), entry order = bucket slot order) rather than
    # (token, choice)-striped: expert e's combine stream back to source r
    # is then one ascending contiguous run the coalescer can merge, and
    # the source's final reduce gathers results back through ``ret_pos``.
    counts64 = np.asarray(wp.counts, np.int64)          # (R, n_experts)
    bstart = np.cumsum(counts64, axis=1) - counts64     # exclusive per-src
    pos = np.where(wp.valid,
                   bstart[np.arange(R)[:, None, None],
                          np.where(wp.valid, ti, 0)] + wp.rank, 0)
    ret_off = ret0 + pos * tb
    comb_src = recv_off if out0 is None \
        else out0 + (bucket * capacity + wp.rank) * tb
    combines = pack_cmds(int(Op.WRITE), src_rank, ch_w, comb_src, ret_off,
                         tb, 0)[valid]
    c_pusher = dst.reshape(-1)[valid]
    c_channel = ch_w.reshape(-1)[valid]
    cperm = np.lexsort((ret_off.reshape(-1)[valid],
                        src_rank.reshape(-1)[valid], c_channel, c_pusher))
    combines, c_pusher, c_channel = \
        combines[cperm], c_pusher[cperm], c_channel[cperm]
    entry_expert = ti.reshape(-1)[valid][cperm]

    # fence for (src r, expert e): guard id == counter id == r*eps + el,
    # the index of the (r, el) receive bucket in the registered table
    r_f, e_f = np.nonzero(wp.counts > 0)
    el_f = e_f % eps
    fences = pack_cmds(int(Op.ATOMIC), e_f // eps, e_f % n_channels,
                       wp.counts[r_f, e_f], r_f * eps + el_f, 0, 0,
                       FLAG_FENCE)

    return CommandStreams(
        plan=wp,
        writes=writes, write_pusher=w_pusher,
        write_channel=w_channel,
        fences=fences, fence_pusher=r_f, fence_channel=e_f % n_channels,
        combines=combines, combine_pusher=c_pusher,
        combine_channel=c_channel,
        entry_expert=entry_expert,
        guard_table=planlib.receive_bucket_table(
            ti.shape[0] * eps, recv0, capacity * wb),
        ret_pos=pos)


def np_swiglu(x: np.ndarray, wg, wu, wd) -> np.ndarray:
    g = x @ wg
    u = x @ wu
    return (g / (1 + np.exp(-g)) * u) @ wd


def np_grouped_swiglu(tokens: np.ndarray, wg, wu, wd,
                      counts=None) -> np.ndarray:
    """Vectorized grouped expert FFN: row block e of ``tokens`` (E, N, D)
    goes through expert e's SwiGLU.  Same contract as the jax path's
    ``expert_fn`` (kernels.ops.grouped_swiglu), in numpy: ``counts`` are
    per-expert — or per-sub-bucket, shape (E, B) — occupied row counts;
    rows beyond occupancy are zero in and out (swiglu(0) == 0)."""
    if counts is not None:
        E, N, _ = tokens.shape
        mask = planlib.occupancy_mask(np.asarray(counts), E, N)
        tokens = np.where(mask[..., None], tokens, 0.0)
    g = np.einsum("end,edf->enf", tokens, wg)
    u = np.einsum("end,edf->enf", tokens, wu)
    return np.einsum("enf,efd->end", g / (1 + np.exp(-g)) * u, wd)


# occupancy-carrying expert_fn contract dispatch (legacy single-argument
# callables compute over the full buckets); shared with the jax path
_call_expert_fn = planlib.call_expert_fn


def _to_bytes(a: np.ndarray) -> np.ndarray:
    return np.frombuffer(np.ascontiguousarray(a, F32).tobytes(), np.uint8)


def _from_bytes(b: np.ndarray, shape) -> np.ndarray:
    return np.frombuffer(b.tobytes(), F32).reshape(shape)


@dataclass
class EPWorld:
    n_ranks: int
    n_experts: int
    top_k: int
    d: int
    f: int = 0                  # expert hidden dim (only for the wg/wu/wd path)
    capacity: int = 0
    net_cfg: NetConfig = field(default_factory=NetConfig)
    n_channels: int = 8
    n_threads: int = 4
    use_threads: bool = False
    # columnar=False drains through the scalar TransferCmd codec (the
    # conformance oracle); coalesce=False keeps the columnar drain but
    # issues one wire message per descriptor
    columnar: bool = True
    coalesce: bool = True
    # wire payload dtype for dispatch: "fp32" (passthrough) | "fp8" | "int8"
    # (block-quantized with inline scales; combines stay fp32 — DESIGN §14)
    wire_dtype: str = "fp32"

    def __post_init__(self):
        assert self.n_experts % self.n_ranks == 0
        # no experts-per-rank ceiling: guards are keyed by registered
        # address ranges, not a 6-bit wire slot (DESIGN.md §12)
        self.eps = self.n_experts // self.n_ranks
        self.tok_bytes = self.d * 4
        self.codec = get_codec(self.wire_dtype)
        self.wire_tok_bytes = self.codec.wire_bytes(self.d)
        self.net = Network(self.net_cfg, self.n_ranks,
                           threadsafe=self.use_threads)
        self.proxies: list[Proxy] = []
        self.mems: list[SymmetricMemory] = []
        self._dirty = False
        self.timeline: dict = {}
        self._ret_deliver: list = [dict() for _ in range(self.n_ranks)]

    # ------------------------------------------------------------ setup ----
    def _make_world(self, total_bytes: int, n_counters: int):
        R = self.n_ranks
        mems = [SymmetricMemory.create(total_bytes, n_counters=n_counters)
                for _ in range(R)]
        proxies = [Proxy(r, self.net, mems[r], n_threads=self.n_threads,
                         n_channels=self.n_channels, columnar=self.columnar,
                         coalesce=self.coalesce)
                   for r in range(R)]
        self.proxies, self.mems = proxies, mems
        return mems, proxies

    def _reset_timeline(self):
        self.timeline = {"compute_start_us": [], "first_compute_us": None,
                         "last_dispatch_write_us": 0.0,
                         "last_delivery_us": 0.0, "overlap_us": 0.0,
                         "wire_dtype": self.wire_dtype,
                         # honest dispatch wire accounting (exact-equality
                         # benchmark rows): payload bytes as serialized,
                         # plus header/sub-write metadata, per the net cfg
                         "dispatch_payload_bytes": 0,
                         "dispatch_wire_bytes": 0,
                         "dispatch_msgs": 0}

    def _note_compute(self, key):
        t = self.net.clock_us
        tl = self.timeline
        tl["compute_start_us"].append((key, t))
        if tl["first_compute_us"] is None:
            tl["first_compute_us"] = t

    def _watch_dispatch(self, lo: int, hi: int,
                        ret_region: Optional[tuple] = None):
        """Record, on the event clock, when each dispatch write (a payload
        write into the receive region [lo, hi)) is delivered — the overlap
        metric compares the last of these against the first compute — and
        accumulate its exact wire-byte footprint (payload, and payload +
        header + per-sub-write metadata), the counters the compression
        benchmarks gate on.

        ``ret_region`` = (ret0, ret_hi, row_bytes): additionally record,
        per destination rank, the delivery time of every combine-return
        sub-write by its return-slot index — the raw material for the
        per-token completion clock (a token is done when the last of its
        choices' return rows has landed; see ``token_completion_us``).
        """
        cfg = self.net.cfg
        ret_t: Optional[list] = None
        if ret_region is not None:
            r0, r1, rb = ret_region
            ret_t = [dict() for _ in range(self.n_ranks)]
            self._ret_deliver = ret_t

        def hook(msg):
            if msg.kind != "write":
                return
            if lo <= msg.dst_off < hi:
                tl = self.timeline
                tl["last_dispatch_write_us"] = max(
                    tl["last_dispatch_write_us"], msg.deliver_t)
                tl["dispatch_payload_bytes"] += msg.size
                tl["dispatch_wire_bytes"] += msg.size + cfg.hdr_bytes \
                    + (msg.n_writes - 1) * cfg.sub_hdr_bytes
                tl["dispatch_msgs"] += 1
            elif ret_t is not None and r0 <= msg.dst_off < r1:
                d = ret_t[msg.dst]
                offs = (msg.sub_off if msg.sub_off is not None
                        else (msg.dst_off,))
                for o in offs:
                    d[(int(o) - r0) // rb] = msg.deliver_t
        self.net.on_deliver_hook = hook

    def _completion_from_returns(self, r: int, n_slots: int) -> np.ndarray:
        """(n_slots,) delivery time per return slot at rank r (0 = never)."""
        slot_t = np.zeros(n_slots)
        d = self._ret_deliver[r]
        if d:
            idx = np.fromiter(d.keys(), np.int64, len(d))
            slot_t[idx] = np.fromiter(d.values(), np.float64, len(d))
        return slot_t

    def _finish_timeline(self):
        tl = self.timeline
        tl["last_delivery_us"] = self.net.clock_us
        if tl["first_compute_us"] is not None:
            tl["overlap_us"] = (tl["last_dispatch_write_us"]
                                - tl["first_compute_us"])
        self.net.on_deliver_hook = None

    # ===================================================== LL protocol =====
    def run(self, x: np.ndarray, top_idx: np.ndarray, top_w: np.ndarray,
            wg: Optional[np.ndarray] = None, wu: Optional[np.ndarray] = None,
            wd: Optional[np.ndarray] = None, *,
            expert_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
            overlap: Optional[bool] = None) -> np.ndarray:
        """x: (R, Tl, D); top_idx/top_w: (R, Tl, K); w*: (E, D, F)/(E, F, D).

        Expert compute is either the built-in grouped SwiGLU over
        ``wg/wu/wd`` or a caller-supplied ``expert_fn`` with the standard
        backend contract: ``(n_experts, N, D) -> (n_experts, N, D)``, row
        block e holding the tokens received by (global) expert e.

        ``overlap`` selects the compute launch policy: True launches each
        expert's FFN the moment its readiness event fires (per-expert
        compute, weighted per-expert weight slices), False waits for all
        fences and issues one grouped call.  Default: True when per-expert
        weights are given, False for a generic grouped ``expert_fn`` (whose
        contract prices a full-width call per bucket).
        """
        R, Tl, D = x.shape
        K, C = self.top_k, self.capacity
        E, eps, tb = self.n_experts, self.eps, self.tok_bytes
        nc = self.n_channels
        if overlap is None:
            overlap = expert_fn is None
        if expert_fn is None:
            assert wg is not None and wu is not None and wd is not None
        # wire-format regions size by the per-token wire footprint wb
        # (quantized payload + inline scales; == tb for fp32 passthrough);
        # expert outputs and combine returns are always fp32 (tb) and live
        # outside the registered receive range
        wb = self.wire_tok_bytes
        send0 = 0
        recv0 = send0 + Tl * wb
        out0 = recv0 + R * eps * C * wb       # expert outputs (fp32)
        ret0 = out0 + R * eps * C * tb
        total = ret0 + Tl * K * tb
        mems, proxies = self._make_world(total, n_counters=R * eps)
        for r in range(R):
            mems[r].data[send0:send0 + Tl * wb] = self.codec.encode(
                np.ascontiguousarray(x[r], np.float32)).reshape(-1)

        # slot assignment + command generation: arrival order per
        # (src, expert) from the shared plan layer, packed as batched
        # TransferCmd streams (the metadata a real command stream encodes)
        cs = build_command_streams(top_idx, E, eps, C, tb, nc,
                                   send0, recv0, ret0,
                                   wire_bytes=wb, out0=out0)
        wp = cs.plan
        assert int(wp.counts.max()) <= C, "capacity overflow in setup"

        # register every rank's receive-bucket table with its proxy (the
        # RDMA MR model): dispatch writes resolve to their bucket's guard on
        # delivery; the expert-output and return regions [out0, total) stay
        # unregistered, so combine writes can never satisfy a dispatch fence
        for p in proxies:
            p.register_table(*cs.guard_table)

        self._reset_timeline()
        self._watch_dispatch(recv0, out0, ret_region=(ret0, total, tb))

        # ---- readiness state machine: expert e is ready once the fence of
        # every contributing source has applied at its destination ----------
        remaining = (np.asarray(wp.counts) > 0).sum(axis=0).astype(np.int64)
        ready: list[int] = []

        def fence_ready(dst, src, counter_idx, operand):
            e = dst * eps + (counter_idx - src * eps)
            remaining[e] -= 1
            if remaining[e] == 0:
                ready.append(e)
        for d in range(R):
            proxies[d].on_ready = \
                lambda src, idx, v, d=d: fence_ready(d, src, idx, v)

        # per-expert combine row index (stable bucketing of the flat stream)
        order = np.argsort(cs.entry_expert, kind="stable")
        starts = np.searchsorted(cs.entry_expert[order], np.arange(E + 1))

        def single_expert(e, toks):
            if expert_fn is None:
                return np_swiglu(toks, wg[e], wu[e], wd[e])
            buf = np.zeros((E, len(toks), D), np.float32)
            buf[e] = toks
            cnts = np.zeros((E,), np.int32)
            cnts[e] = len(toks)
            return np.asarray(_call_expert_fn(expert_fn, buf, cnts))[e]

        def launch(e):
            d, el = divmod(e, eps)
            cnts = np.asarray(wp.counts)[:, e]
            srcs = np.flatnonzero(cnts)
            self._note_compute(("ll", e))
            bases = [recv0 + (int(r) * eps + el) * C * wb for r in srcs]
            toks = self.codec.decode(np.concatenate(
                [mems[d].data[b:b + int(cnts[r]) * wb]
                 for b, r in zip(bases, srcs)]).reshape(-1, wb), D)
            out = np.ascontiguousarray(single_expert(e, toks),
                                       np.float32).view(np.uint8).reshape(-1)
            # write fp32 outputs into the expert-output region (slot-major
            # per source, mirroring the bucket), then stream the combine
            # writes for exactly this bucket
            off = 0
            for r in srcs:
                ob = out0 + (int(r) * eps + el) * C * tb
                n_b = int(cnts[r]) * tb
                mems[d].data[ob:ob + n_b] = out[off:off + n_b]
                off += n_b
            rows = order[starts[e]:starts[e + 1]]
            if len(rows):
                self._push_grouped(cs.combines[rows],
                                   cs.combine_pusher[rows],
                                   cs.combine_channel[rows])

        self._push_grouped(cs.writes, cs.write_pusher, cs.write_channel)
        self._push_grouped(cs.fences, cs.fence_pusher, cs.fence_channel)

        if overlap:
            self._pump_events(proxies, ready, launch)
            assert int(remaining[np.asarray(wp.counts).sum(0) > 0].sum()) == 0
        else:
            self._pump_events(proxies)
            for r, e in zip(*(a.tolist()
                              for a in np.nonzero(np.asarray(wp.counts) > 0))):
                assert mems[e // eps].counters[r * eps + e % eps] == 1, (r, e)
            self._grouped_compute(mems, wp, expert_fn, wg, wu, wd,
                                  recv0, out0)
            self._push_grouped(cs.combines, cs.combine_pusher,
                               cs.combine_channel)
            self._pump_events(proxies)

        self._finish_timeline()

        # -------------------- weighted reduce at source -------------------
        # the return region is expert-major (coalescable combine runs);
        # gather each (token, choice)'s partial back through ret_pos
        out = np.zeros((R, Tl, D), np.float64)
        comp = np.zeros((R, Tl))
        for r in range(R):
            ret = _from_bytes(mems[r].data[ret0:ret0 + Tl * K * tb],
                              (Tl * K, D))
            g = ret[np.asarray(cs.ret_pos[r])]          # (Tl, K, D)
            out[r] = np.einsum("tkd,tk->td", g.astype(np.float64),
                               np.where(wp.valid[r], top_w[r], 0.0)
                               .astype(np.float64))
            # event-clock completion per token: the last of its choices'
            # combine-return deliveries, mapped through the same ret_pos
            # the reduce gathers with (invalid choices contribute nothing)
            slot_t = self._completion_from_returns(r, Tl * K)
            per_choice = np.where(np.asarray(wp.valid[r]),
                                  slot_t[np.asarray(cs.ret_pos[r])], 0.0)
            comp[r] = per_choice.max(axis=1) if K else 0.0
        self.timeline["token_completion_us"] = comp
        return out.astype(np.float32)

    def _grouped_compute(self, mems, wp, expert_fn, wg, wu, wd, recv0, out0):
        """Barrier-mode expert compute: one grouped call over every receive
        bucket (the pre-pipelining behaviour; used for generic expert_fn).
        Wire-format receive rows decode to fp32; outputs land in the fp32
        expert-output region at ``out0``."""
        R, E, eps, C, D = (self.n_ranks, self.n_experts, self.eps,
                           self.capacity, self.d)
        wb, tb = self.wire_tok_bytes, self.tok_bytes
        if expert_fn is None:
            expert_fn = lambda toks: np_grouped_swiglu(toks, wg, wu, wd)  # noqa: E731
        c_max = int(np.asarray(wp.counts).max())
        if not c_max:
            return
        self._note_compute(("ll", "grouped"))
        bufs = [self.codec.decode(
            mems[d].data[recv0:out0].reshape(R * eps * C, wb),
            D).reshape(R, eps, C, D) for d in range(R)]
        toks = np.concatenate([
            b[:, :, :c_max].transpose(1, 0, 2, 3).reshape(
                eps, R * c_max, D) for b in bufs], axis=0)
        # (E, R) occupied counts per (expert, source bucket) — the fence
        # metadata, in the same bucketed layout the jax LL path passes
        cnts = np.minimum(np.asarray(wp.counts), c_max).T.astype(np.int32)
        outs = np.asarray(_call_expert_fn(expert_fn, toks, cnts), np.float32)
        assert outs.shape == (E, R * c_max, D), outs.shape
        for d in range(R):      # fp32 outputs into the expert-output region
            full = np.zeros((R, eps, C, D), np.float32)
            o = outs[d * eps:(d + 1) * eps].reshape(eps, R, c_max, D)
            full[:, :, :c_max] = o.transpose(1, 0, 2, 3)
            mems[d].data[out0:out0 + R * eps * C * tb] = _to_bytes(full)

    # ===================================================== HT protocol =====
    def run_ht(self, x: np.ndarray, top_idx: np.ndarray, top_w: np.ndarray,
               wg: Optional[np.ndarray] = None,
               wu: Optional[np.ndarray] = None,
               wd: Optional[np.ndarray] = None, *,
               expert_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
               n_chunks: int = 1,
               capacity: Optional[int] = None) -> np.ndarray:
        """Chunked + dedup'd + hierarchical dispatch/combine (paper HT mode)
        executed literally on the transport substrate.

        Per source rank, the shared dedup table (plan.dedup_entry_table over
        destination *ranks*) selects one entry per (token, destination); the
        entry's payload is the token vector plus its expert-id/weight
        metadata.  Dispatch is chunked: after each chunk's entry writes, a
        SEQ_ATOMIC chunk marker per destination closes the chunk — it
        applies only once the chunk's writes all applied (per-channel
        sequence order), firing the readiness event that launches the
        destination's partial FFN for that (src, chunk) bucket.  One
        group-reduced vector per entry returns; the source sums per token.
        """
        R, Tl, D = x.shape
        K = self.top_k
        E, eps, tb = self.n_experts, self.eps, self.tok_bytes
        nc = self.n_channels
        C = capacity or Tl                    # entries per (src, dst) bucket
        # mirror the jax HT path: degrade a non-dividing chunk request to
        # the largest divisor of Tl (recorded in the timeline) instead of
        # silently dropping the pipeline to one chunk
        n_chunks = planlib.effective_chunks(Tl, n_chunks)
        # chunk ids ride the 16-bit SEQ_ATOMIC operand field
        assert n_chunks <= IMM_VAL_MAX + 1, \
            f"n_chunks {n_chunks} exceeds the {IMM_VAL_MAX + 1} chunk ids " \
            "the immediate codec can carry"
        chunk_len = Tl // n_chunks
        # dedup-entry payload: wire-format token (quantized + inline scales
        # for fp8/int8; == tb for fp32) + K expert ids + K combine weights
        wb = self.wire_tok_bytes
        ent_b = wb + K * 8
        if expert_fn is None:
            assert wg is not None and wu is not None and wd is not None

        send0 = 0
        recv0 = send0 + R * C * ent_b
        comb0 = recv0 + R * C * ent_b
        ret0 = comb0 + R * C * tb
        total = ret0 + R * C * tb
        mems, proxies = self._make_world(total, n_counters=R * n_chunks)

        self._reset_timeline()
        self.timeline["n_chunks"] = n_chunks
        self._watch_dispatch(recv0, comb0, ret_region=(ret0, total, tb))

        # ---- per-source dedup plans + payload staging --------------------
        valid = top_idx >= 0
        g_of = np.where(valid, top_idx // eps, -1)           # (R, Tl, K)
        el_of = np.where(valid, top_idx % eps, -1)
        plans = []            # (ts, gs, slots, chunk_of) per source
        dropped = 0
        for r in range(R):
            _, entry_valid, rank_tg, keep_tg, n_drop = \
                planlib.dedup_entry_table(g_of[r], valid[r], R, C)
            dropped += int(n_drop)
            ts, gs = np.nonzero(keep_tg)
            slots = rank_tg[ts, gs]
            plans.append((ts, gs, slots, ts // chunk_len))
            # entry metadata: choice k rides iff routed to this destination
            m = g_of[r][ts] == gs[:, None]                    # (n, K)
            eids = np.where(m, el_of[r][ts], -1).astype(np.int32)
            ws = np.where(m, top_w[r][ts], 0.0).astype(np.float32)
            payload = np.zeros((len(ts), ent_b), np.uint8)
            payload[:, :wb] = self.codec.encode(
                np.ascontiguousarray(x[r][ts], np.float32))
            payload[:, wb:wb + K * 4] = np.ascontiguousarray(eids).view(
                np.uint8)
            payload[:, wb + K * 4:] = np.ascontiguousarray(ws).view(np.uint8)
            stage = np.zeros((R * C, ent_b), np.uint8)
            stage[gs * C + slots] = payload
            mems[r].data[send0:recv0] = stage.reshape(-1)
        self.ht_dropped = dropped

        # ---- readiness state machine: (dst, src, chunk) buckets ----------
        ready: list[tuple[int, int, int]] = []

        def marker_ready(dst, src, counter_idx, chunk):
            assert counter_idx == src * n_chunks + chunk
            ready.append((dst, src, chunk))
        for g in range(R):
            proxies[g].on_ready = \
                lambda src, idx, v, g=g: marker_ready(g, src, idx, v)

        def launch(g, r, c):
            ts, gs, slots, chunk_of = plans[r]
            sel = (gs == g) & (chunk_of == c)
            if not sel.any():
                return
            self._note_compute(("ht", g, r, c))
            sl = slots[sel]
            raw = mems[g].data[recv0:comb0].reshape(R * C, ent_b)
            rows = raw[r * C + sl]
            toks = self.codec.decode(np.ascontiguousarray(rows[:, :wb]), D)
            eids = rows[:, wb:wb + K * 4].copy().view(np.int32).reshape(-1, K)
            ws = rows[:, wb + K * 4:].copy().view(np.float32).reshape(-1, K)
            part = self._bucket_partials(g, toks, eids, ws, expert_fn,
                                         wg, wu, wd)
            comb = mems[g].data[comb0:ret0].reshape(R * C, tb)
            comb[r * C + sl] = part.astype(np.float32).view(np.uint8)
            # return writes land in [ret0, total): unregistered memory, so
            # they satisfy no guard (HT needs none — chunk markers are
            # SEQ_ATOMICs ordered behind the chunk's writes per channel)
            writes = pack_cmds(int(Op.WRITE), r, r % nc,
                               comb0 + (r * C + sl) * tb,
                               ret0 + (g * C + sl) * tb, tb, 0)
            self._push_words(g, r % nc, writes)

        # ---- chunked dispatch: writes, then the chunk's markers ----------
        for r in range(R):
            ts, gs, slots, chunk_of = plans[r]
            for c in range(n_chunks):
                sel = chunk_of == c
                if sel.any():
                    writes = pack_cmds(
                        int(Op.WRITE), gs[sel], gs[sel] % nc,
                        send0 + (gs[sel] * C + slots[sel]) * ent_b,
                        recv0 + (r * C + slots[sel]) * ent_b, ent_b, 0)
                    self._push_grouped(writes, np.full(int(sel.sum()), r),
                                       gs[sel] % nc)
                # chunk markers ride the same per-destination channel as the
                # chunk's writes, so their sequence numbers order after them
                markers = pack_cmds(int(Op.ATOMIC), np.arange(R),
                                    np.arange(R) % nc, c,
                                    r * n_chunks + c, 0, 0)
                self._push_grouped(markers, np.full(R, r), np.arange(R) % nc)

        self._pump_events(proxies, ready, lambda b: launch(*b))
        for g in range(R):
            for r in range(R):
                for c in range(n_chunks):
                    assert mems[g].counters[r * n_chunks + c] == 1, (g, r, c)
        self._finish_timeline()

        # ---- global reduce at the source: sum the per-destination partials
        out = np.zeros((R, Tl, D), np.float64)
        comp = np.zeros((R, Tl))
        for r in range(R):
            ts, gs, slots, _ = plans[r]
            ret = _from_bytes(mems[r].data[ret0:total], (R * C, D))
            np.add.at(out[r], ts, ret[gs * C + slots].astype(np.float64))
            # token completion = last return-entry delivery among its
            # (token, destination) entries
            slot_t = self._completion_from_returns(r, R * C)
            np.maximum.at(comp[r], ts, slot_t[gs * C + slots])
        self.timeline["token_completion_us"] = comp
        return out.astype(np.float32)

    def _bucket_partials(self, g: int, toks, eids, ws, expert_fn,
                         wg, wu, wd) -> np.ndarray:
        """Group-level reduce for one (src, chunk) bucket at destination g:
        weighted partial sum over the destination's local experts, one
        vector per entry."""
        n, D = toks.shape
        eps, E = self.eps, self.n_experts
        part = np.zeros((n, D), np.float64)
        if expert_fn is None:
            for el in range(eps):
                i, k = np.nonzero(eids == el)
                if not len(i):
                    continue
                y = np_swiglu(toks[i], wg[g * eps + el], wu[g * eps + el],
                              wd[g * eps + el])
                np.add.at(part, i, ws[i, k][:, None].astype(np.float64)
                          * y.astype(np.float64))
            return part.astype(np.float32)
        # generic grouped contract: bucket the (entry, choice) pairs per
        # local expert and make one full-width expert_fn call
        i_all, k_all = np.nonzero(eids >= 0)
        if not len(i_all):
            return part.astype(np.float32)
        e_glob = g * eps + eids[i_all, k_all]
        pl = planlib.make_plan(e_glob.reshape(-1, 1), E, len(i_all))
        Ce = int(np.asarray(pl.counts).max())
        buf = np.zeros((E, Ce, D), np.float32)
        rank = np.asarray(pl.rank).reshape(-1)
        buf[e_glob, rank] = toks[i_all]
        y = np.asarray(_call_expert_fn(
            expert_fn, buf, np.asarray(pl.counts, np.int32)), np.float32)
        np.add.at(part, i_all,
                  ws[i_all, k_all][:, None].astype(np.float64)
                  * y[e_glob, rank].astype(np.float64))
        return part.astype(np.float32)

    # -------------------------------------------------- bulk push helpers --
    def _push_grouped(self, words: np.ndarray, pusher: np.ndarray,
                      channel: np.ndarray):
        """Route a packed (N, 4) command stream to its per-rank proxies,
        batched per (rank, channel) with original relative order preserved
        inside each channel (the only order the protocol relies on)."""
        pusher = np.asarray(pusher).reshape(-1)
        channel = np.asarray(channel).reshape(-1)
        for r in np.unique(pusher):
            in_r = pusher == r
            w_r, ch_r = words[in_r], channel[in_r]
            for c in np.unique(ch_r):
                self._push_words(int(r), int(c), w_r[ch_r == c])

    def _push_words(self, r: int, ch: int, words: np.ndarray):
        proxies = self.proxies
        self._dirty = True
        if self.use_threads:
            # worker threads drain concurrently; pace on ring space (the
            # paper's kMaxInflight sender flow control, §3.1): when the
            # ring is full, poll the outstanding window's completion in one
            # lock round-trip per spin instead of one check per index
            if not proxies[r]._threads:
                proxies[r].start()
            c = proxies[r].channels[ch % len(proxies[r].channels)]
            deadline = time.monotonic() + 60.0
            done = 0
            while done < len(words):
                done += c.try_push_batch(words[done:])
                if done >= len(words):
                    break
                tail = c._tail              # producer-owned counter
                window = np.arange(max(0, tail - c.capacity), tail)
                # one locked head read answers the whole outstanding
                # window; the ring has space exactly when the OLDEST
                # outstanding slot ([0]) has completed
                while not c.check_completion_batch(window)[0]:
                    if time.monotonic() > deadline:
                        raise TimeoutError("FIFO full: consumer stalled")
                    time.sleep(1e-5)
            return
        done = 0
        while done < len(words):
            done += proxies[r].push_batch(ch, words[done:], block=False)
            if done < len(words):
                # back-pressure: relieve the full ring inline
                proxies[r].drain_inline()

    # ------------------------------------------------- event-driven pump ---
    def _pump_events(self, proxies, ready: Optional[list] = None,
                     launch: Optional[Callable] = None):
        """Drive command execution and network delivery until the world
        quiesces: FIFO rings empty, no command mid-execution, no message in
        flight — the event-clock condition that replaced the seed's fixed
        500-iteration polling loop.  Deliveries append readiness events to
        ``ready``; ``launch`` consumes them between deliveries, so compute
        interleaves with in-flight traffic.  Delivery runs through
        ``Network.deliver_ready``: every event sharing the frontier
        timestamp lands in one lock round-trip."""
        deliver = self.net.deliver_ready
        if self.use_threads:
            for p in proxies:
                if not p._threads:
                    p.start()
            deadline = time.monotonic() + 120.0
            calm = 0
            while True:
                delivered = deliver()
                while ready:
                    launch(ready.pop())
                for p in proxies:  # surface worker failures immediately
                    if p.error is not None:
                        raise RuntimeError(
                            f"proxy {p.rank} worker failed") from p.error
                if delivered:
                    calm = 0
                    continue
                if any(p.busy for p in proxies) or self.net.pending:
                    calm = 0
                    if time.monotonic() > deadline:
                        raise TimeoutError("transport quiesce timed out")
                    time.sleep(2e-5)
                    continue
                calm += 1          # confirm stability across two checks
                if calm >= 2:
                    return
                time.sleep(2e-5)
        while True:
            if self._dirty:
                self._dirty = False
                for p in proxies:
                    p.drain_inline()
            delivered = deliver()
            while ready:
                launch(ready.pop())
            if not delivered and not self._dirty:
                return

    @staticmethod
    def oracle(x, top_idx, top_w, wg, wu, wd) -> np.ndarray:
        R, Tl, D = x.shape
        out = np.zeros((R, Tl, D), np.float64)
        for r in range(R):
            for t in range(Tl):
                acc = np.zeros(D, np.float64)
                for k in range(top_idx.shape[2]):
                    e = int(top_idx[r, t, k])
                    acc += float(top_w[r, t, k]) * np_swiglu(
                        x[r, t].astype(np.float32)[None],
                        wg[e], wu[e], wd[e])[0].astype(np.float64)
                out[r, t] = acc
        return out.astype(np.float32)
