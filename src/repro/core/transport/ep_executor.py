"""End-to-end EP dispatch/combine over the transport substrate.

Executes the paper's protocols literally over the event-driven network model
(DESIGN.md §10), through 128-bit FIFO channels and CPU proxies:

- **LL** (:meth:`EPWorld.run`): per-token RDMA writes tagged with immediate
  data, one completion-fence atomic per (source, expert), expert FFN at the
  destination, per-token combine writes back, weighted reduce at the source.
  The run is a *pipelined state machine*: when a (src, expert) fence applies
  at the receiver, the proxy fires a readiness event, and — once every
  source's fence for an expert has landed — that expert's FFN launches and
  its combine writes enter the network while other experts' dispatch writes
  are still in flight (the paper's proxy/compute overlap).

- **HT** (:meth:`EPWorld.run_ht`): chunked dispatch with per-(token, group)
  deduplication and hierarchical reduce.  A token crosses to each
  destination *rank* once per round, its expert list and combine weights
  riding as payload metadata; chunk boundaries are SEQ_ATOMIC markers that
  apply only when the chunk's writes have all applied (per-channel sequence
  order), so each (src, chunk) bucket's partial FFN launches as soon as its
  marker lands.  Exactly one partially reduced vector returns per
  (token, destination rank) — group reduce at the receiver, global reduce at
  the source.

Routing decisions (slot assignment, per-(src, expert) counts, capacity
masks, dedup tables) come from the shared plan layer (:mod:`repro.core.plan`)
— the same plans the jax-collectives path consumes — and are turned into
*batched* TransferCmd streams: packed ``(N, 4)`` uint32 arrays pushed through
the ``Proxy.push_batch`` bulk FIFO path (DESIGN.md §8).

Tests prove protocol correctness (result == dense oracle under any delivery
order); benchmarks reuse it for paper Figs. 4/7/15/17.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional

import numpy as np

from repro.core import plan as planlib
from repro.core.transport.codec import get_codec
from repro.core.transport.fifo import FLAG_FENCE, Op, pack_cmds
from repro.core.transport.proxy import Proxy, SymmetricMemory
from repro.core.transport.semantics import IMM_VAL_MAX
from repro.core.transport.simulator import Network, NetConfig
from repro.core.transport.wire_format import ProtocolError


def verify_or_raise(*args, **kwargs):
    # Lazy: repro.analysis.verify imports transport leaf modules, which pull
    # in this package's __init__ — a top-level import here would make the
    # cycle analysis → verify → transport → ep_executor → analysis.
    from repro.analysis.verify import verify_or_raise as _vor
    return _vor(*args, **kwargs)

F32 = np.dtype(np.float32)


class CommandStreams(NamedTuple):
    """Batched TransferCmd streams for one LL EP round, plus routing metadata.

    Each stream is a packed (N, 4) uint32 descriptor array (invalid routing
    entries already dropped) with parallel per-row ``*_pusher`` (the rank
    whose proxy issues the command) and ``*_channel`` arrays.
    ``entry_expert`` is the global expert id per kept entry — the bucket key
    the pipelined executor uses to launch per-expert combine streams."""

    plan: planlib.WorldPlan
    writes: np.ndarray          # dispatch data writes
    write_pusher: np.ndarray
    write_channel: np.ndarray
    fences: np.ndarray          # one completion-fence atomic per (src, e)
    fence_pusher: np.ndarray
    fence_channel: np.ndarray
    combines: np.ndarray        # combine writes back to the source
    combine_pusher: np.ndarray
    combine_channel: np.ndarray
    entry_expert: np.ndarray    # global expert id per kept entry
    guard_table: tuple          # (bases, extents, guard_ids) receive buckets
    ret_pos: np.ndarray         # (R, Tl, K) expert-major return slot per
    #                             choice (0 for invalid entries) — the
    #                             source's final reduce gathers through it


class SessSlot(NamedTuple):
    """One layer's namespace inside a persistent EP session (DESIGN §16):
    memory regions (``send0``/``recv0``/``mid0``/``ret0``/``end`` —
    ``mid0`` is the LL expert-output region, or the HT combine region),
    guard/counter id base ``guard0``, and the channel window
    ``[ch0, ch0 + ncl)`` this layer's commands ride."""
    send0: int
    recv0: int
    mid0: int
    ret0: int
    end: int
    guard0: int
    ch0: int
    ncl: int


class LayerPrep(NamedTuple):
    """One prepared layer (or mirror) stream inside a session step."""
    slot: int
    cs: CommandStreams
    tw: Optional[np.ndarray]
    Tl: int
    remaining: Optional[np.ndarray]


def build_command_streams(top_idx: np.ndarray, n_experts: int, eps: int,
                          capacity: int, tok_bytes: int, n_channels: int,
                          send0: int, recv0: int, ret0: int,
                          wire_bytes: Optional[int] = None,
                          out0: Optional[int] = None,
                          ch_base: int = 0,
                          n_ch_eff: Optional[int] = None,
                          guard_base: int = 0,
                          ) -> CommandStreams:
    """Vectorized LL-protocol command generation from a routing table.

    The single source of truth for how plans become TransferCmd streams —
    ``EPWorld.run`` executes exactly these; ``benchmarks/bench_plan.py``
    times this function against the seed's Python loops.

    Fence commands carry their full required write count in the 32-bit
    ``src_off`` operand field (the immediate codec packs 21 bits) and
    address their guard — the (src, expert) receive bucket — by the wide id
    in ``dst_off``.  Receivers attribute dispatch writes to guards by
    resolving each landing offset against the registered bucket table
    (``guard_table``, which :meth:`EPWorld.run` registers with every proxy),
    so no expert slot rides the wire and nothing aliases past 63 experts
    per rank.  Combine writes land in the unregistered return region and
    therefore can never satisfy a dispatch fence.

    ``wire_bytes`` is the per-token *wire* footprint (quantized payload +
    inline scale blocks, ``plan.wire_layout``; defaults to ``tok_bytes`` =
    fp32 passthrough): dispatch writes, receive-bucket strides, and the
    registered guard extents all size from it, so fence counts and guard
    ranges stay exact under compression — the scale blocks live inside the
    registered range.  Combine payloads are always full-precision fp32
    (``tok_bytes``; the fp32-accumulation contract, DESIGN.md §14), sourced
    from the expert-output region at ``out0`` when given (the receive
    buckets hold wire-format rows, which expert outputs must not clobber).

    ``ch_base``/``n_ch_eff``/``guard_base`` carve a per-layer namespace out
    of the channel and guard/counter id spaces for the persistent EP
    session (DESIGN.md §16): this layer's commands ride channels
    ``[ch_base, ch_base + n_ch_eff)`` and its fences address guard ids
    offset by ``guard_base``, so several layers' in-flight streams never
    alias each other's wire seqs or completion fences.  Defaults are the
    whole space (single-layer behaviour, bit-identical to before).
    """
    ti = np.ascontiguousarray(top_idx, np.int64)
    R, Tl, K = ti.shape
    ncl = n_channels if n_ch_eff is None else n_ch_eff
    assert 0 < ncl and ch_base + ncl <= n_channels, (ch_base, ncl)
    tb = tok_bytes
    wb = tok_bytes if wire_bytes is None else wire_bytes
    wp = planlib.make_world_plan(ti, n_experts, capacity)
    valid = wp.valid.reshape(-1)

    dst = ti // eps                                     # (R, Tl, K)
    el = np.where(wp.valid, ti % eps, 0)
    t_idx = np.arange(Tl, dtype=np.int64)[None, :, None]
    src_off = np.broadcast_to(send0 + t_idx * wb, ti.shape)
    # dispatch writes land in the (src, expert) receive bucket at the plan's
    # arrival-order slot; combine writes come back from that bucket's
    # expert-output block into the source's expert-major return region
    # (``ret_pos`` below)
    bucket = np.arange(R)[:, None, None] * eps + el     # (src, expert) id
    recv_off = recv0 + (bucket * capacity + wp.rank) * wb
    src_rank = np.broadcast_to(np.arange(R)[:, None, None], ti.shape)

    # both write streams ride an expert-keyed channel and are emitted
    # ordered by (destination, landing offset) within each (pusher,
    # channel): one receive bucket's writes form one contiguous ascending
    # run, which is what the proxy's write coalescer turns into single
    # batched RDMA messages.  Sequence semantics don't care: LL writes
    # gate nothing, and seqs are assigned at drain time in stream order.
    ch_w = ch_base + np.where(wp.valid, ti % ncl, 0)    # global expert key
    writes = pack_cmds(int(Op.WRITE), dst, ch_w, src_off, recv_off, wb,
                       0)[valid]
    w_pusher = src_rank.reshape(-1)[valid]
    w_channel = ch_w.reshape(-1)[valid]
    wperm = np.lexsort((recv_off.reshape(-1)[valid],
                        dst.reshape(-1)[valid], w_channel, w_pusher))
    writes, w_pusher, w_channel = \
        writes[wperm], w_pusher[wperm], w_channel[wperm]
    # combine writes need no special marking: they land in the return
    # region, which is simply not in the registered bucket table, so they
    # can never count toward a dispatch fence guard (the pipelined executor
    # has combines in flight while other buckets' dispatches still are).
    # The return layout is expert-major per source (one contiguous block
    # per (expert, source), entry order = bucket slot order) rather than
    # (token, choice)-striped: expert e's combine stream back to source r
    # is then one ascending contiguous run the coalescer can merge, and
    # the source's final reduce gathers results back through ``ret_pos``.
    counts64 = np.asarray(wp.counts, np.int64)          # (R, n_experts)
    bstart = np.cumsum(counts64, axis=1) - counts64     # exclusive per-src
    pos = np.where(wp.valid,
                   bstart[np.arange(R)[:, None, None],
                          np.where(wp.valid, ti, 0)] + wp.rank, 0)
    ret_off = ret0 + pos * tb
    comb_src = recv_off if out0 is None \
        else out0 + (bucket * capacity + wp.rank) * tb
    combines = pack_cmds(int(Op.WRITE), src_rank, ch_w, comb_src, ret_off,
                         tb, 0)[valid]
    c_pusher = dst.reshape(-1)[valid]
    c_channel = ch_w.reshape(-1)[valid]
    cperm = np.lexsort((ret_off.reshape(-1)[valid],
                        src_rank.reshape(-1)[valid], c_channel, c_pusher))
    combines, c_pusher, c_channel = \
        combines[cperm], c_pusher[cperm], c_channel[cperm]
    entry_expert = ti.reshape(-1)[valid][cperm]

    # fence for (src r, expert e): guard id == counter id ==
    # guard_base + r*eps + el, the index of the (r, el) receive bucket in
    # the registered table (plus the layer's namespace base)
    r_f, e_f = np.nonzero(wp.counts > 0)
    el_f = e_f % eps
    ch_f = ch_base + e_f % ncl
    fences = pack_cmds(int(Op.ATOMIC), e_f // eps, ch_f,
                       wp.counts[r_f, e_f], guard_base + r_f * eps + el_f,
                       0, 0, FLAG_FENCE)

    return CommandStreams(
        plan=wp,
        writes=writes, write_pusher=w_pusher,
        write_channel=w_channel,
        fences=fences, fence_pusher=r_f, fence_channel=ch_f,
        combines=combines, combine_pusher=c_pusher,
        combine_channel=c_channel,
        entry_expert=entry_expert,
        guard_table=planlib.receive_bucket_table(
            ti.shape[0] * eps, recv0, capacity * wb, gid0=guard_base),
        ret_pos=pos)


def np_swiglu(x: np.ndarray, wg, wu, wd) -> np.ndarray:
    g = x @ wg
    u = x @ wu
    return (g / (1 + np.exp(-g)) * u) @ wd


def np_grouped_swiglu(tokens: np.ndarray, wg, wu, wd,
                      counts=None) -> np.ndarray:
    """Vectorized grouped expert FFN: row block e of ``tokens`` (E, N, D)
    goes through expert e's SwiGLU.  Same contract as the jax path's
    ``expert_fn`` (kernels.ops.grouped_swiglu), in numpy: ``counts`` are
    per-expert — or per-sub-bucket, shape (E, B) — occupied row counts;
    rows beyond occupancy are zero in and out (swiglu(0) == 0)."""
    if counts is not None:
        E, N, _ = tokens.shape
        mask = planlib.occupancy_mask(np.asarray(counts), E, N)
        tokens = np.where(mask[..., None], tokens, 0.0)
    g = np.einsum("end,edf->enf", tokens, wg)
    u = np.einsum("end,edf->enf", tokens, wu)
    return np.einsum("enf,efd->end", g / (1 + np.exp(-g)) * u, wd)


# occupancy-carrying expert_fn contract dispatch (legacy single-argument
# callables compute over the full buckets); shared with the jax path
_call_expert_fn = planlib.call_expert_fn


def _to_bytes(a: np.ndarray) -> np.ndarray:
    return np.frombuffer(np.ascontiguousarray(a, F32).tobytes(), np.uint8)


def _from_bytes(b: np.ndarray, shape) -> np.ndarray:
    return np.frombuffer(b.tobytes(), F32).reshape(shape)


@dataclass
class EPWorld:
    n_ranks: int
    n_experts: int
    top_k: int
    d: int
    f: int = 0                  # expert hidden dim (only for the wg/wu/wd path)
    capacity: int = 0
    net_cfg: NetConfig = field(default_factory=NetConfig)
    n_channels: int = 8
    n_threads: int = 4
    use_threads: bool = False
    # columnar=False drains through the scalar TransferCmd codec (the
    # conformance oracle); coalesce=False keeps the columnar drain but
    # issues one wire message per descriptor
    columnar: bool = True
    coalesce: bool = True
    # wire payload dtype for dispatch: "fp32" (passthrough) | "fp8" | "int8"
    # (block-quantized with inline scales; combines stay fp32 — DESIGN §14)
    wire_dtype: str = "fp32"
    # ---- persistent EP session (DESIGN.md §16) ----------------------------
    # session=True keeps ONE world alive across a model's MoE layers: guard
    # tables, receive buckets, proxies and memory are registered once (at
    # first use) and reused every step via begin_step(); each of n_layers
    # layers owns a private memory slot plus a channel + guard/counter id
    # namespace so concurrent layers never alias seqs or fences.  mirror=True
    # doubles the slots: slot n_layers+l models layer l's backward
    # combine-grad stream (same command shapes, no expert compute).
    session: bool = False
    n_layers: int = 1
    mirror: bool = False

    def __post_init__(self):
        assert self.n_experts % self.n_ranks == 0
        # no experts-per-rank ceiling: guards are keyed by registered
        # address ranges, not a 6-bit wire slot (DESIGN.md §12)
        self.eps = self.n_experts // self.n_ranks
        self.tok_bytes = self.d * 4
        self.codec = get_codec(self.wire_dtype)
        self.wire_tok_bytes = self.codec.wire_bytes(self.d)
        self.net = Network(self.net_cfg, self.n_ranks,
                           threadsafe=self.use_threads)
        self.proxies: list[Proxy] = []
        self.mems: list[SymmetricMemory] = []
        self._dirty = False
        self.timeline: dict = {}
        self._ret_deliver: list = [dict() for _ in range(self.n_ranks)]
        # session state (lazy; _session_layout allocates on first layer run)
        assert not (self.session and self.use_threads), \
            "session mode is inline-only (deterministic event clock)"
        self._slots: Optional[list] = None
        self._sess_mode: Optional[str] = None
        self._sess_geom: Optional[tuple] = None
        self._counter_stride = 0
        self._slot_bytes = 0
        self._slot_ready: dict[int, Callable] = {}   # slot -> fence handler
        self._ready: list[Callable[[], None]] = []   # pending launch thunks
        self._sret: dict[tuple, dict] = {}   # (slot, rank) -> {idx: t}
        self._ret_left: dict[tuple, int] = {}        # outstanding returns
        self._slot_done_cb: dict[int, Callable] = {}  # slot -> fn(rank, now)

    # ------------------------------------------------------------ setup ----
    def _make_world(self, total_bytes: int, n_counters: int):
        R = self.n_ranks
        mems = [SymmetricMemory.create(total_bytes, n_counters=n_counters)
                for _ in range(R)]
        proxies = [Proxy(r, self.net, mems[r], n_threads=self.n_threads,
                         n_channels=self.n_channels, columnar=self.columnar,
                         coalesce=self.coalesce)
                   for r in range(R)]
        self.proxies, self.mems = proxies, mems
        return mems, proxies

    def _reset_timeline(self):
        self.timeline = {"compute_start_us": [], "first_compute_us": None,
                         "last_dispatch_write_us": 0.0,
                         "last_delivery_us": 0.0, "overlap_us": 0.0,
                         "wire_dtype": self.wire_dtype,
                         # honest dispatch wire accounting (exact-equality
                         # benchmark rows): payload bytes as serialized,
                         # plus header/sub-write metadata, per the net cfg
                         "dispatch_payload_bytes": 0,
                         "dispatch_wire_bytes": 0,
                         "dispatch_msgs": 0,
                         # cross-layer batching counters (exact-gated):
                         # quiesce drains and commands pushed this step
                         "drains_per_step": 0,
                         "cmds_per_step": 0}

    def _note_compute(self, key):
        t = self.net.clock_us
        tl = self.timeline
        tl["compute_start_us"].append((key, t))
        if tl["first_compute_us"] is None:
            tl["first_compute_us"] = t

    def _watch_dispatch(self, lo: int, hi: int,
                        ret_region: Optional[tuple] = None):
        """Record, on the event clock, when each dispatch write (a payload
        write into the receive region [lo, hi)) is delivered — the overlap
        metric compares the last of these against the first compute — and
        accumulate its exact wire-byte footprint (payload, and payload +
        header + per-sub-write metadata), the counters the compression
        benchmarks gate on.

        ``ret_region`` = (ret0, ret_hi, row_bytes): additionally record,
        per destination rank, the delivery time of every combine-return
        sub-write by its return-slot index — the raw material for the
        per-token completion clock (a token is done when the last of its
        choices' return rows has landed; see ``token_completion_us``).
        """
        cfg = self.net.cfg
        ret_t: Optional[list] = None
        if ret_region is not None:
            r0, r1, rb = ret_region
            ret_t = [dict() for _ in range(self.n_ranks)]
            self._ret_deliver = ret_t

        def hook(msg):
            if msg.kind != "write":
                return
            if lo <= msg.dst_off < hi:
                tl = self.timeline
                tl["last_dispatch_write_us"] = max(
                    tl["last_dispatch_write_us"], msg.deliver_t)
                tl["dispatch_payload_bytes"] += msg.size
                tl["dispatch_wire_bytes"] += msg.size + cfg.hdr_bytes \
                    + (msg.n_writes - 1) * cfg.sub_hdr_bytes
                tl["dispatch_msgs"] += 1
            elif ret_t is not None and r0 <= msg.dst_off < r1:
                d = ret_t[msg.dst]
                offs = (msg.sub_off if msg.sub_off is not None
                        else (msg.dst_off,))
                for o in offs:
                    d[(int(o) - r0) // rb] = msg.deliver_t
        self.net.on_deliver_hook = hook

    def _completion_from_returns(self, r: int, n_slots: int,
                                 d: Optional[dict] = None) -> np.ndarray:
        """(n_slots,) delivery time per return slot at rank r (0 = never)."""
        slot_t = np.zeros(n_slots)
        if d is None:
            d = self._ret_deliver[r]
        if d:
            idx = np.fromiter(d.keys(), np.int64, len(d))
            slot_t[idx] = np.fromiter(d.values(), np.float64, len(d))
        return slot_t

    def _finish_timeline(self):
        tl = self.timeline
        tl["last_delivery_us"] = self.net.clock_us
        if tl["first_compute_us"] is not None:
            tl["overlap_us"] = (tl["last_dispatch_write_us"]
                                - tl["first_compute_us"])
        self.net.on_deliver_hook = None

    # ================================ persistent EP session (DESIGN §16) ==
    @property
    def n_slots(self) -> int:
        return self.n_layers * (2 if self.mirror else 1)

    def _session_layout(self, mode: str, Tl: int, K: int, C: int,
                        n_chunks: int = 1):
        """Lazily allocate the session world on first layer use: one memory
        slot per layer (two with ``mirror`` — forward + backward stream),
        ONE symmetric memory + proxy set for all of them, every slot's
        receive-bucket guard table registered up front (the once-per-session
        registration the real library amortizes), and a session-wide
        readiness dispatcher + delivery watch installed for the whole
        lifetime.  Geometry is pinned by the first call; later layers and
        steps must match (one plan/stream cache key per EPSpec shape)."""
        if self._slots is not None:
            assert (self._sess_mode == mode
                    and self._sess_geom == (Tl, K, C, n_chunks)), (
                "session geometry pinned at first use: "
                f"{self._sess_mode}/{self._sess_geom} vs "
                f"{mode}/{(Tl, K, C, n_chunks)}")
            return
        assert self.session
        R, eps, tb = self.n_ranks, self.eps, self.tok_bytes
        wb = self.wire_tok_bytes
        n_slots = self.n_slots
        if mode == "ll":
            sizes = (Tl * wb, R * eps * C * wb, R * eps * C * tb,
                     Tl * K * tb)
            stride = R * eps
        else:
            ent_b = wb + K * 8
            sizes = (R * C * ent_b, R * C * ent_b, R * C * tb, R * C * tb)
            stride = R * n_chunks
        slot_bytes = sum(sizes)
        # channel namespace: slots round-robin over disjoint channel groups
        # (adjacent layers always land in different groups, so two layers'
        # in-flight streams never share a wire seq space)
        n_groups = min(n_slots, self.n_channels)
        cpl = self.n_channels // n_groups
        slots = []
        for s in range(n_slots):
            base = s * slot_bytes
            offs = [base]
            for sz in sizes[:-1]:
                offs.append(offs[-1] + sz)
            slots.append(SessSlot(send0=offs[0], recv0=offs[1],
                                  mid0=offs[2], ret0=offs[3],
                                  end=base + slot_bytes,
                                  guard0=s * stride,
                                  ch0=(s % n_groups) * cpl, ncl=cpl))
        # static namespace-disjointness check before registration (§17)
        verify_or_raise(slots=slots, n_channels=self.n_channels,
                        counter_stride=stride)
        self._slots = slots
        self._sess_mode = mode
        self._sess_geom = (Tl, K, C, n_chunks)
        self._counter_stride = stride
        self._slot_bytes = slot_bytes
        mems, proxies = self._make_world(n_slots * slot_bytes,
                                         n_counters=n_slots * stride)
        if mode == "ll":
            # register EVERY slot's receive-bucket table with every proxy
            # exactly once for the session's lifetime (the MR model);
            # begin_step never re-registers — ControlBuffers are recreated
            # per step but share this GuardTable by reference
            for sl in slots:
                tab = planlib.receive_bucket_table(R * eps, sl.recv0,
                                                   C * wb, gid0=sl.guard0)
                for p in proxies:
                    p.register_table(*tab)
        for d in range(R):
            proxies[d].on_ready = \
                lambda src, idx, v, d=d: self._sess_ready(d, src, idx, v)
        self._install_session_watch()
        self.begin_step()

    def begin_step(self):
        """Reset per-step transport state — counters, fence/seq bookkeeping,
        per-step timeline — while KEEPING registered guard tables, receive
        buckets, proxies, memory and the (monotonic) event clock.  The
        session contract: registration happens once, steps only clear."""
        assert self.session, "begin_step is a session-mode API"
        if self._slots is None:
            return                       # first run() initializes + resets
        assert not self.net.pending, "begin_step with traffic in flight"
        for p in self.proxies:
            # per-src receiver bookkeeping (writes_seen, held fences, wire
            # seqs) restarts each step; ControlBuffers are recreated lazily
            # and share the proxy's registered GuardTable by reference
            p.ctrl.clear()
            p._seq.clear()               # sender seqs restart with them
        for m in self.mems:
            m.counters[:] = 0
        self._slot_ready.clear()
        self._ready.clear()
        self._sret.clear()
        self._ret_left.clear()
        self._slot_done_cb.clear()
        self._reset_timeline()

    def _sess_ready(self, dst: int, src: int, idx: int, value: int):
        """Session-wide readiness dispatcher: route a guarded-atomic apply
        to its slot's handler by counter-id namespace."""
        s = idx // self._counter_stride
        h = self._slot_ready.get(s)
        if h is not None:
            h(dst, src, idx - s * self._counter_stride, value)

    def _install_session_watch(self):
        """Session delivery watch: classify every landed write by slot —
        dispatch writes feed the wire-accounting counters, combine returns
        feed per-(slot, rank) completion clocks AND the step pipeline's
        done-callbacks (rank r finished layer l when its last return
        lands)."""
        cfg = self.net.cfg
        sb = self._slot_bytes
        slots = self._slots
        tb = self.tok_bytes

        def hook(msg):
            if msg.kind != "write":
                return
            s = msg.dst_off // sb
            sl = slots[s]
            if sl.recv0 <= msg.dst_off < sl.mid0:
                tl = self.timeline
                tl["last_dispatch_write_us"] = max(
                    tl["last_dispatch_write_us"], msg.deliver_t)
                tl["dispatch_payload_bytes"] += msg.size
                tl["dispatch_wire_bytes"] += msg.size + cfg.hdr_bytes \
                    + (msg.n_writes - 1) * cfg.sub_hdr_bytes
                tl["dispatch_msgs"] += 1
            elif sl.ret0 <= msg.dst_off < sl.end:
                d = self._sret.setdefault((s, msg.dst), {})
                offs = (msg.sub_off if msg.sub_off is not None
                        else (msg.dst_off,))
                for o in offs:
                    d[(int(o) - sl.ret0) // tb] = msg.deliver_t
                key = (s, msg.dst)
                left = self._ret_left.get(key)
                if left is not None and left > 0:
                    left -= len(offs)
                    self._ret_left[key] = left
                    if left == 0:
                        cb = self._slot_done_cb.get(s)
                        if cb is not None:
                            cb(msg.dst, self.net.clock_us)
        self.net.on_deliver_hook = hook

    def _pump_sess(self):
        """Drain the session to quiescence; readiness thunks queued by slot
        handlers run interleaved with delivery (one drain per call)."""
        self._pump_events(self.proxies, self._ready, lambda f: f())

    # ---- shared LL pieces (one code path for isolated and session runs) ---
    def _ll_launch_expert(self, e: int, cs: CommandStreams, wp, recv0: int,
                          out0: int, wg, wu, wd, expert_fn, order, starts,
                          slot: Optional[int] = None):
        """Launch expert e for one LL stream: decode its receive buckets,
        run its FFN, write fp32 outputs, push exactly its combine rows."""
        mems = self.mems
        E, eps, C, D = self.n_experts, self.eps, self.capacity, self.d
        wb, tb = self.wire_tok_bytes, self.tok_bytes
        d, el = divmod(e, eps)
        cnts = np.asarray(wp.counts)[:, e]
        srcs = np.flatnonzero(cnts)
        self._note_compute(("ll", e) if slot is None else ("ll", slot, e))
        bases = [recv0 + (int(r) * eps + el) * C * wb for r in srcs]
        toks = self.codec.decode(np.concatenate(
            [mems[d].data[b:b + int(cnts[r]) * wb]
             for b, r in zip(bases, srcs)]).reshape(-1, wb), D)
        if expert_fn is None:
            out = np_swiglu(toks, wg[e], wu[e], wd[e])
        else:
            buf = np.zeros((E, len(toks), D), np.float32)
            buf[e] = toks
            cnt1 = np.zeros((E,), np.int32)
            cnt1[e] = len(toks)
            out = np.asarray(_call_expert_fn(expert_fn, buf, cnt1))[e]
        out = np.ascontiguousarray(out,
                                   np.float32).view(np.uint8).reshape(-1)
        # write fp32 outputs into the expert-output region (slot-major per
        # source, mirroring the bucket), then stream the combine writes for
        # exactly this bucket
        off = 0
        for r in srcs:
            ob = out0 + (int(r) * eps + el) * C * tb
            n_b = int(cnts[r]) * tb
            mems[d].data[ob:ob + n_b] = out[off:off + n_b]
            off += n_b
        rows = order[starts[e]:starts[e + 1]]
        if len(rows):
            self._push_grouped(cs.combines[rows],
                               cs.combine_pusher[rows],
                               cs.combine_channel[rows])

    def _ll_reduce(self, cs: CommandStreams, wp, top_w, Tl: int, ret0: int,
                   ret_deliver: list) -> tuple[np.ndarray, np.ndarray]:
        """Weighted reduce at each source + per-token completion clock.
        The return region is expert-major (coalescable combine runs);
        gather each (token, choice)'s partial back through ret_pos."""
        R, K, D, tb = self.n_ranks, self.top_k, self.d, self.tok_bytes
        out = np.zeros((R, Tl, D), np.float64)
        comp = np.zeros((R, Tl))
        for r in range(R):
            ret = _from_bytes(self.mems[r].data[ret0:ret0 + Tl * K * tb],
                              (Tl * K, D))
            g = ret[np.asarray(cs.ret_pos[r])]          # (Tl, K, D)
            out[r] = np.einsum("tkd,tk->td", g.astype(np.float64),
                               np.where(wp.valid[r], top_w[r], 0.0)
                               .astype(np.float64))
            # event-clock completion per token: the last of its choices'
            # combine-return deliveries, mapped through the same ret_pos
            # the reduce gathers with (invalid choices contribute nothing)
            slot_t = self._completion_from_returns(r, Tl * K,
                                                   ret_deliver[r])
            per_choice = np.where(np.asarray(wp.valid[r]),
                                  slot_t[np.asarray(cs.ret_pos[r])], 0.0)
            comp[r] = per_choice.max(axis=1) if K else 0.0
        return out.astype(np.float32), comp

    # ---- session layer preparation / push / drivers -----------------------
    def _prepare_ll(self, slot_idx: int, x_l, ti, tw, wg=None, wu=None,
                    wd=None, *, expert_fn=None, launch_compute=True,
                    ) -> LayerPrep:
        """Stage one layer's tokens into its session slot, build its command
        streams in the slot's channel/guard namespace, and register its
        per-expert readiness handler (fences ready -> FFN + combine push;
        with ``launch_compute=False`` — the mirrored backward stream — the
        handler pushes the combine-grad rows without compute)."""
        sl = self._slots[slot_idx]
        R, Tl, K = ti.shape
        C, E, eps = self.capacity, self.n_experts, self.eps
        tb, wb = self.tok_bytes, self.wire_tok_bytes
        assert (Tl, K) == self._sess_geom[:2]
        if x_l is not None:
            for r in range(R):
                self.mems[r].data[sl.send0:sl.send0 + Tl * wb] = \
                    self.codec.encode(np.ascontiguousarray(
                        x_l[r], np.float32)).reshape(-1)
        cs = build_command_streams(ti, E, eps, C, tb, self.n_channels,
                                   sl.send0, sl.recv0, sl.ret0,
                                   wire_bytes=wb, out0=sl.mid0,
                                   ch_base=sl.ch0, n_ch_eff=sl.ncl,
                                   guard_base=sl.guard0)
        # static protocol verification in the slot's namespace (DESIGN §17)
        verify_or_raise(cs, net_cfg=self.net.cfg,
                        n_channels=self.n_channels)
        wp = cs.plan
        assert int(wp.counts.max()) <= C, "capacity overflow in setup"
        order = np.argsort(cs.entry_expert, kind="stable")
        starts = np.searchsorted(cs.entry_expert[order], np.arange(E + 1))
        remaining = (np.asarray(wp.counts) > 0).sum(axis=0).astype(np.int64)

        if launch_compute:
            def launch(e):
                self._ll_launch_expert(e, cs, wp, sl.recv0, sl.mid0,
                                       wg, wu, wd, expert_fn, order, starts,
                                       slot=slot_idx)
        else:
            def launch(e):          # mirrored stream: traffic, no FFN
                rows = order[starts[e]:starts[e + 1]]
                if len(rows):
                    self._push_grouped(cs.combines[rows],
                                       cs.combine_pusher[rows],
                                       cs.combine_channel[rows])

        def on_fence(dst, src, idx_rel, operand):
            e = dst * eps + (idx_rel - src * eps)
            remaining[e] -= 1
            if remaining[e] == 0:
                self._ready.append(lambda e=e: launch(e))

        self._slot_ready[slot_idx] = on_fence
        valid = np.asarray(wp.valid)
        for r in range(R):
            self._ret_left[(slot_idx, r)] = int(valid[r].sum())
        return LayerPrep(slot=slot_idx, cs=cs, tw=tw, Tl=Tl,
                         remaining=remaining)

    def _push_prep(self, prep: LayerPrep, rank: Optional[int] = None):
        """Enqueue a prepared layer's dispatch writes + fences — all ranks,
        or only the rows rank ``rank`` pushes (the per-rank pipeline)."""
        cs = prep.cs
        if rank is None:
            self._push_grouped(cs.writes, cs.write_pusher, cs.write_channel)
            self._push_grouped(cs.fences, cs.fence_pusher, cs.fence_channel)
            ranks = range(self.n_ranks)
        else:
            wm = cs.write_pusher == rank
            self._push_grouped(cs.writes[wm], cs.write_pusher[wm],
                               cs.write_channel[wm])
            fm = cs.fence_pusher == rank
            self._push_grouped(cs.fences[fm], cs.fence_pusher[fm],
                               cs.fence_channel[fm])
            ranks = (rank,)
        for r in ranks:
            # a source with no valid routing entries gets no returns: its
            # layer completes the moment its (empty) dispatch is enqueued
            if self._ret_left.get((prep.slot, r)) == 0:
                self._ret_left[(prep.slot, r)] = -1     # fire exactly once
                cb = self._slot_done_cb.get(prep.slot)
                if cb is not None:
                    cb(r, self.net.clock_us)

    def _run_layer_ll(self, layer: int, x, ti, tw, wg=None, wu=None,
                      wd=None, *, expert_fn=None,
                      overlap: Optional[bool] = None) -> np.ndarray:
        """One LL layer inside the session (sequential mode: push, drain to
        quiescence, reduce) — `run(..., layer=l)` routes here.  Bit-identical
        math to an isolated `run` (same staging/launch/reduce helpers)."""
        if overlap is None:
            overlap = expert_fn is None
        R, Tl, D = x.shape
        self._session_layout("ll", Tl, self.top_k, self.capacity)
        eps = self.eps
        sl = self._slots[layer]
        prep = self._prepare_ll(layer, x, ti, tw, wg, wu, wd,
                                expert_fn=expert_fn)
        wp = prep.cs.plan
        if overlap:
            self._push_prep(prep)
            self._pump_sess()
            assert int(prep.remaining[
                np.asarray(wp.counts).sum(0) > 0].sum()) == 0
        else:
            del self._slot_ready[layer]  # barrier mode: no per-expert launch
            self._push_prep(prep)
            self._pump_sess()
            for r, e in zip(*(a.tolist()
                              for a in np.nonzero(np.asarray(wp.counts) > 0))):
                assert self.mems[e // eps].counters[
                    sl.guard0 + r * eps + e % eps] == 1, (layer, r, e)
            self._grouped_compute(self.mems, wp, expert_fn, wg, wu, wd,
                                  sl.recv0, sl.mid0)
            self._push_grouped(prep.cs.combines, prep.cs.combine_pusher,
                               prep.cs.combine_channel)
            self._pump_sess()
        rd = [self._sret.get((layer, r), {}) for r in range(R)]
        out, comp = self._ll_reduce(prep.cs, wp, tw, Tl, sl.ret0, rd)
        tl = self.timeline
        tl["token_completion_us"] = comp
        tl["last_delivery_us"] = self.net.clock_us
        if tl["first_compute_us"] is not None:
            tl["overlap_us"] = (tl["last_dispatch_write_us"]
                                - tl["first_compute_us"])
        return out

    def run_step_serial(self, xs, tis, tws, wg=None, wu=None, wd=None, *,
                        expert_fn=None, nonmoe_fwd_us: float = 0.0,
                        nonmoe_bwd_us: float = 0.0) -> list:
        """One training step, layer-serialized (the no-overlap baseline,
        same session): each MoE layer's stream is pushed and drained to
        quiescence, THEN the non-MoE compute segment advances the clock with
        the network idle; the backward pass quiesces each mirrored
        combine-grad stream before the next backward segment.  Per-expert
        (PR 2) overlap stays ON inside each layer — the A/B isolates the
        *cross-layer* contribution.  L forward (+ L backward) drains."""
        assert self.session and self._sess_mode in (None, "ll")
        L = self.n_layers
        assert len(xs) == L
        self._session_layout("ll", xs[0].shape[1], self.top_k, self.capacity)
        net = self.net
        t0 = net.clock_us
        outs = []
        for l in range(L):
            outs.append(self._run_layer_ll(l, xs[l], tis[l], tws[l],
                                           wg, wu, wd, expert_fn=expert_fn,
                                           overlap=True))
            if l < L - 1:
                net.advance(nonmoe_fwd_us)
        if self.mirror:
            for l in reversed(range(L)):
                net.advance(nonmoe_bwd_us)   # backward compute of layer l
                mp = self._prepare_ll(L + l, None, tis[l], None,
                                      launch_compute=False)
                self._push_prep(mp)
                self._pump_sess()            # grad traffic fully drained
            net.advance(nonmoe_bwd_us)       # trailing segment (optimizer)
        self.timeline["step_us"] = net.clock_us - t0
        return outs

    def run_step_pipelined(self, xs, tis, tws, wg=None, wu=None, wd=None, *,
                           expert_fn=None, nonmoe_fwd_us: float = 0.0,
                           nonmoe_bwd_us: float = 0.0) -> list:
        """One training step, fully pipelined on the event clock: all L
        layers' command streams are prepared onto the shared columnar path
        up front, rank r enqueues layer l+1's dispatch the moment ITS
        layer-l combine returns have landed plus its non-MoE segment (a
        Timer — no global barrier), and the backward pass fires each
        mirrored combine-grad stream along the per-rank backward compute
        chain, fire-and-forget: grad traffic drains UNDER the remaining
        backward segments and must only complete by step end.  ONE pump
        drains the entire step: ``drains_per_step == 1`` for any L."""
        assert self.session and self._sess_mode in (None, "ll")
        L, R = self.n_layers, self.n_ranks
        assert len(xs) == L
        self._session_layout("ll", xs[0].shape[1], self.top_k, self.capacity)
        net = self.net
        t0 = net.clock_us
        preps = [self._prepare_ll(l, xs[l], tis[l], tws[l], wg, wu, wd,
                                  expert_fn=expert_fn) for l in range(L)]
        mpreps = ([self._prepare_ll(L + l, None, tis[l], None,
                                    launch_compute=False) for l in range(L)]
                  if self.mirror else None)

        def fwd_chain(nxt):
            def cb(rank, now):
                net.call_at(now + nonmoe_fwd_us,
                            lambda: self._push_prep(nxt, rank))
            return cb
        for l in range(L - 1):
            self._slot_done_cb[l] = fwd_chain(preps[l + 1])

        if self.mirror:
            def bwd_cascade(rank, now):
                # per-rank backward compute chain: the whole Timer cascade
                # is scheduled at once — mirror slot l's combine-grad
                # stream launches when the chain REACHES layer l, and its
                # traffic overlaps every later segment
                t = now
                for l in reversed(range(L)):
                    t += nonmoe_bwd_us
                    mp = mpreps[l]
                    net.call_at(t, lambda mp=mp, rank=rank:
                                self._push_prep(mp, rank))
                net.call_at(t + nonmoe_bwd_us, lambda: None)  # trailing seg
            self._slot_done_cb[L - 1] = bwd_cascade

        self._push_prep(preps[0])
        self._pump_sess()
        for prep in preps:
            assert int(prep.remaining[
                np.asarray(prep.cs.plan.counts).sum(0) > 0].sum()) == 0, \
                "pipelined step quiesced with unlaunched experts"
        outs = []
        for l in range(L):
            rd = [self._sret.get((l, r), {}) for r in range(R)]
            out, comp = self._ll_reduce(preps[l].cs, preps[l].cs.plan,
                                        tws[l], preps[l].Tl,
                                        self._slots[l].ret0, rd)
            outs.append(out)
        tl = self.timeline
        tl["token_completion_us"] = comp
        tl["last_delivery_us"] = self.net.clock_us
        tl["step_us"] = net.clock_us - t0
        return outs

    # ===================================================== LL protocol =====
    def run(self, x: np.ndarray, top_idx: np.ndarray, top_w: np.ndarray,
            wg: Optional[np.ndarray] = None, wu: Optional[np.ndarray] = None,
            wd: Optional[np.ndarray] = None, *,
            expert_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
            overlap: Optional[bool] = None, layer: int = 0) -> np.ndarray:
        """x: (R, Tl, D); top_idx/top_w: (R, Tl, K); w*: (E, D, F)/(E, F, D).

        Expert compute is either the built-in grouped SwiGLU over
        ``wg/wu/wd`` or a caller-supplied ``expert_fn`` with the standard
        backend contract: ``(n_experts, N, D) -> (n_experts, N, D)``, row
        block e holding the tokens received by (global) expert e.

        ``overlap`` selects the compute launch policy: True launches each
        expert's FFN the moment its readiness event fires (per-expert
        compute, weighted per-expert weight slices), False waits for all
        fences and issues one grouped call.  Default: True when per-expert
        weights are given, False for a generic grouped ``expert_fn`` (whose
        contract prices a full-width call per bucket).

        In session mode (``session=True``) the call routes to the slot of
        ``layer`` in the persistent world — registration and memory are
        reused, only per-step state resets (see ``begin_step``).
        """
        if expert_fn is None:
            assert wg is not None and wu is not None and wd is not None
        if self.session:
            return self._run_layer_ll(layer, x, top_idx, top_w, wg, wu, wd,
                                      expert_fn=expert_fn, overlap=overlap)
        R, Tl, D = x.shape
        K, C = self.top_k, self.capacity
        E, eps, tb = self.n_experts, self.eps, self.tok_bytes
        nc = self.n_channels
        if overlap is None:
            overlap = expert_fn is None
        # wire-format regions size by the per-token wire footprint wb
        # (quantized payload + inline scales; == tb for fp32 passthrough);
        # expert outputs and combine returns are always fp32 (tb) and live
        # outside the registered receive range
        wb = self.wire_tok_bytes
        send0 = 0
        recv0 = send0 + Tl * wb
        out0 = recv0 + R * eps * C * wb       # expert outputs (fp32)
        ret0 = out0 + R * eps * C * tb
        total = ret0 + Tl * K * tb
        mems, proxies = self._make_world(total, n_counters=R * eps)
        for r in range(R):
            mems[r].data[send0:send0 + Tl * wb] = self.codec.encode(
                np.ascontiguousarray(x[r], np.float32)).reshape(-1)

        # slot assignment + command generation: arrival order per
        # (src, expert) from the shared plan layer, packed as batched
        # TransferCmd streams (the metadata a real command stream encodes)
        cs = build_command_streams(top_idx, E, eps, C, tb, nc,
                                   send0, recv0, ret0,
                                   wire_bytes=wb, out0=out0)
        wp = cs.plan
        assert int(wp.counts.max()) <= C, "capacity overflow in setup"

        # register every rank's receive-bucket table with its proxy (the
        # RDMA MR model): dispatch writes resolve to their bucket's guard on
        # delivery; the expert-output and return regions [out0, total) stay
        # unregistered, so combine writes can never satisfy a dispatch fence
        for p in proxies:
            p.register_table(*cs.guard_table)
        # static protocol verification before any traffic moves (DESIGN §17)
        verify_or_raise(cs, net_cfg=self.net_cfg, n_channels=nc)

        self._reset_timeline()
        self._watch_dispatch(recv0, out0, ret_region=(ret0, total, tb))

        # ---- readiness state machine: expert e is ready once the fence of
        # every contributing source has applied at its destination ----------
        remaining = (np.asarray(wp.counts) > 0).sum(axis=0).astype(np.int64)
        ready: list[int] = []

        def fence_ready(dst, src, counter_idx, operand):
            e = dst * eps + (counter_idx - src * eps)
            remaining[e] -= 1
            if remaining[e] == 0:
                ready.append(e)
        for d in range(R):
            proxies[d].on_ready = \
                lambda src, idx, v, d=d: fence_ready(d, src, idx, v)

        # per-expert combine row index (stable bucketing of the flat stream)
        order = np.argsort(cs.entry_expert, kind="stable")
        starts = np.searchsorted(cs.entry_expert[order], np.arange(E + 1))

        def launch(e):
            self._ll_launch_expert(e, cs, wp, recv0, out0, wg, wu, wd,
                                   expert_fn, order, starts)

        self._push_grouped(cs.writes, cs.write_pusher, cs.write_channel)
        self._push_grouped(cs.fences, cs.fence_pusher, cs.fence_channel)

        if overlap:
            self._pump_events(proxies, ready, launch)
            assert int(remaining[np.asarray(wp.counts).sum(0) > 0].sum()) == 0
        else:
            self._pump_events(proxies)
            for r, e in zip(*(a.tolist()
                              for a in np.nonzero(np.asarray(wp.counts) > 0))):
                assert mems[e // eps].counters[r * eps + e % eps] == 1, (r, e)
            self._grouped_compute(mems, wp, expert_fn, wg, wu, wd,
                                  recv0, out0)
            self._push_grouped(cs.combines, cs.combine_pusher,
                               cs.combine_channel)
            self._pump_events(proxies)

        self._finish_timeline()

        # weighted reduce at source + per-token completion clock
        out, comp = self._ll_reduce(cs, wp, top_w, Tl, ret0,
                                    [self._ret_deliver[r] for r in range(R)])
        self.timeline["token_completion_us"] = comp
        return out

    def _grouped_compute(self, mems, wp, expert_fn, wg, wu, wd, recv0, out0):
        """Barrier-mode expert compute: one grouped call over every receive
        bucket (the pre-pipelining behaviour; used for generic expert_fn).
        Wire-format receive rows decode to fp32; outputs land in the fp32
        expert-output region at ``out0``."""
        R, E, eps, C, D = (self.n_ranks, self.n_experts, self.eps,
                           self.capacity, self.d)
        wb, tb = self.wire_tok_bytes, self.tok_bytes
        if expert_fn is None:
            expert_fn = lambda toks: np_grouped_swiglu(toks, wg, wu, wd)  # noqa: E731
        c_max = int(np.asarray(wp.counts).max())
        if not c_max:
            return
        self._note_compute(("ll", "grouped"))
        bufs = [self.codec.decode(
            mems[d].data[recv0:out0].reshape(R * eps * C, wb),
            D).reshape(R, eps, C, D) for d in range(R)]
        toks = np.concatenate([
            b[:, :, :c_max].transpose(1, 0, 2, 3).reshape(
                eps, R * c_max, D) for b in bufs], axis=0)
        # (E, R) occupied counts per (expert, source bucket) — the fence
        # metadata, in the same bucketed layout the jax LL path passes
        cnts = np.minimum(np.asarray(wp.counts), c_max).T.astype(np.int32)
        outs = np.asarray(_call_expert_fn(expert_fn, toks, cnts), np.float32)
        assert outs.shape == (E, R * c_max, D), outs.shape
        for d in range(R):      # fp32 outputs into the expert-output region
            full = np.zeros((R, eps, C, D), np.float32)
            o = outs[d * eps:(d + 1) * eps].reshape(eps, R, c_max, D)
            full[:, :, :c_max] = o.transpose(1, 0, 2, 3)
            mems[d].data[out0:out0 + R * eps * C * tb] = _to_bytes(full)

    # ===================================================== HT protocol =====
    def run_ht(self, x: np.ndarray, top_idx: np.ndarray, top_w: np.ndarray,
               wg: Optional[np.ndarray] = None,
               wu: Optional[np.ndarray] = None,
               wd: Optional[np.ndarray] = None, *,
               expert_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
               n_chunks: int = 1,
               capacity: Optional[int] = None,
               layer: int = 0) -> np.ndarray:
        """Chunked + dedup'd + hierarchical dispatch/combine (paper HT mode)
        executed literally on the transport substrate.

        Per source rank, the shared dedup table (plan.dedup_entry_table over
        destination *ranks*) selects one entry per (token, destination); the
        entry's payload is the token vector plus its expert-id/weight
        metadata.  Dispatch is chunked: after each chunk's entry writes, a
        SEQ_ATOMIC chunk marker per destination closes the chunk — it
        applies only once the chunk's writes all applied (per-channel
        sequence order), firing the readiness event that launches the
        destination's partial FFN for that (src, chunk) bucket.  One
        group-reduced vector per entry returns; the source sums per token.
        """
        R, Tl, D = x.shape
        K = self.top_k
        E, eps, tb = self.n_experts, self.eps, self.tok_bytes
        nc = self.n_channels
        C = capacity or Tl                    # entries per (src, dst) bucket
        # mirror the jax HT path: degrade a non-dividing chunk request to
        # the largest divisor of Tl (recorded in the timeline) instead of
        # silently dropping the pipeline to one chunk
        n_chunks = planlib.effective_chunks(Tl, n_chunks)
        # chunk ids ride the 16-bit SEQ_ATOMIC operand field; raised (not
        # assert-ed) so the contract holds under ``python -O`` [EPV-003]
        if n_chunks > IMM_VAL_MAX + 1:
            raise ProtocolError(
                f"n_chunks {n_chunks} exceeds the {IMM_VAL_MAX + 1} chunk "
                "ids the immediate codec can carry")
        chunk_len = Tl // n_chunks
        # dedup-entry payload: wire-format token (quantized + inline scales
        # for fp8/int8; == tb for fp32) + K expert ids + K combine weights
        wb = self.wire_tok_bytes
        ent_b = wb + K * 8
        if expert_fn is None:
            assert wg is not None and wu is not None and wd is not None

        if self.session:
            # session slot: offsets, channels, counter ids all namespaced
            # per layer; world + watch + readiness dispatcher are persistent
            self._session_layout("ht", Tl, K, C, n_chunks)
            sl = self._slots[layer]
            send0, recv0, comb0 = sl.send0, sl.recv0, sl.mid0
            ret0, total = sl.ret0, sl.end
            ch0, ncl, g0 = sl.ch0, sl.ncl, sl.guard0
            mems, proxies = self.mems, self.proxies
        else:
            send0 = 0
            recv0 = send0 + R * C * ent_b
            comb0 = recv0 + R * C * ent_b
            ret0 = comb0 + R * C * tb
            total = ret0 + R * C * tb
            ch0, ncl, g0 = 0, nc, 0
            mems, proxies = self._make_world(total, n_counters=R * n_chunks)
            self._reset_timeline()
            self._watch_dispatch(recv0, comb0, ret_region=(ret0, total, tb))
        self.timeline["n_chunks"] = n_chunks

        # ---- per-source dedup plans + payload staging --------------------
        valid = top_idx >= 0
        g_of = np.where(valid, top_idx // eps, -1)           # (R, Tl, K)
        el_of = np.where(valid, top_idx % eps, -1)
        plans = []            # (ts, gs, slots, chunk_of) per source
        dropped = 0
        for r in range(R):
            _, entry_valid, rank_tg, keep_tg, n_drop = \
                planlib.dedup_entry_table(g_of[r], valid[r], R, C)
            dropped += int(n_drop)
            ts, gs = np.nonzero(keep_tg)
            slots = rank_tg[ts, gs]
            plans.append((ts, gs, slots, ts // chunk_len))
            # entry metadata: choice k rides iff routed to this destination
            m = g_of[r][ts] == gs[:, None]                    # (n, K)
            eids = np.where(m, el_of[r][ts], -1).astype(np.int32)
            ws = np.where(m, top_w[r][ts], 0.0).astype(np.float32)
            payload = np.zeros((len(ts), ent_b), np.uint8)
            payload[:, :wb] = self.codec.encode(
                np.ascontiguousarray(x[r][ts], np.float32))
            payload[:, wb:wb + K * 4] = np.ascontiguousarray(eids).view(
                np.uint8)
            payload[:, wb + K * 4:] = np.ascontiguousarray(ws).view(np.uint8)
            stage = np.zeros((R * C, ent_b), np.uint8)
            stage[gs * C + slots] = payload
            mems[r].data[send0:recv0] = stage.reshape(-1)
        self.ht_dropped = dropped

        # ---- readiness state machine: (dst, src, chunk) buckets ----------
        ready: list[tuple[int, int, int]] = []

        def marker_ready(dst, src, counter_idx, chunk):
            assert counter_idx == src * n_chunks + chunk
            ready.append((dst, src, chunk))
        if self.session:
            # the session dispatcher strips the slot's counter namespace and
            # routes here; thunks run off the shared session ready queue
            def on_marker(dst, src, idx_rel, chunk):
                assert idx_rel == src * n_chunks + chunk
                self._ready.append(
                    lambda d=dst, s=src, c=chunk: launch(d, s, c))
            self._slot_ready[layer] = on_marker
        else:
            for g in range(R):
                proxies[g].on_ready = \
                    lambda src, idx, v, g=g: marker_ready(g, src, idx, v)

        def launch(g, r, c):
            ts, gs, slots, chunk_of = plans[r]
            sel = (gs == g) & (chunk_of == c)
            if not sel.any():
                return
            self._note_compute(("ht", g, r, c))
            sl = slots[sel]
            raw = mems[g].data[recv0:comb0].reshape(R * C, ent_b)
            rows = raw[r * C + sl]
            toks = self.codec.decode(np.ascontiguousarray(rows[:, :wb]), D)
            eids = rows[:, wb:wb + K * 4].copy().view(np.int32).reshape(-1, K)
            ws = rows[:, wb + K * 4:].copy().view(np.float32).reshape(-1, K)
            part = self._bucket_partials(g, toks, eids, ws, expert_fn,
                                         wg, wu, wd)
            comb = mems[g].data[comb0:ret0].reshape(R * C, tb)
            comb[r * C + sl] = part.astype(np.float32).view(np.uint8)
            # return writes land in [ret0, total): unregistered memory, so
            # they satisfy no guard (HT needs none — chunk markers are
            # SEQ_ATOMICs ordered behind the chunk's writes per channel)
            writes = pack_cmds(int(Op.WRITE), r, ch0 + r % ncl,
                               comb0 + (r * C + sl) * tb,
                               ret0 + (g * C + sl) * tb, tb, 0)
            self._push_words(g, ch0 + r % ncl, writes)

        # ---- chunked dispatch: writes, then the chunk's markers ----------
        for r in range(R):
            ts, gs, slots, chunk_of = plans[r]
            for c in range(n_chunks):
                sel = chunk_of == c
                if sel.any():
                    writes = pack_cmds(
                        int(Op.WRITE), gs[sel], ch0 + gs[sel] % ncl,
                        send0 + (gs[sel] * C + slots[sel]) * ent_b,
                        recv0 + (r * C + slots[sel]) * ent_b, ent_b, 0)
                    self._push_grouped(writes, np.full(int(sel.sum()), r),
                                       ch0 + gs[sel] % ncl)
                # chunk markers ride the same per-destination channel as the
                # chunk's writes, so their sequence numbers order after them
                markers = pack_cmds(int(Op.ATOMIC), np.arange(R),
                                    ch0 + np.arange(R) % ncl, c,
                                    g0 + r * n_chunks + c, 0, 0)
                self._push_grouped(markers, np.full(R, r),
                                   ch0 + np.arange(R) % ncl)

        if self.session:
            self._pump_sess()
        else:
            self._pump_events(proxies, ready, lambda b: launch(*b))
        for g in range(R):
            for r in range(R):
                for c in range(n_chunks):
                    assert mems[g].counters[g0 + r * n_chunks + c] == 1, \
                        (g, r, c)
        if not self.session:
            self._finish_timeline()

        # ---- global reduce at the source: sum the per-destination partials
        out = np.zeros((R, Tl, D), np.float64)
        comp = np.zeros((R, Tl))
        for r in range(R):
            ts, gs, slots, _ = plans[r]
            ret = _from_bytes(mems[r].data[ret0:total], (R * C, D))
            np.add.at(out[r], ts, ret[gs * C + slots].astype(np.float64))
            # token completion = last return-entry delivery among its
            # (token, destination) entries
            slot_t = self._completion_from_returns(
                r, R * C,
                self._sret.get((layer, r), {}) if self.session else None)
            np.maximum.at(comp[r], ts, slot_t[gs * C + slots])
        self.timeline["token_completion_us"] = comp
        return out.astype(np.float32)

    def _bucket_partials(self, g: int, toks, eids, ws, expert_fn,
                         wg, wu, wd) -> np.ndarray:
        """Group-level reduce for one (src, chunk) bucket at destination g:
        weighted partial sum over the destination's local experts, one
        vector per entry."""
        n, D = toks.shape
        eps, E = self.eps, self.n_experts
        part = np.zeros((n, D), np.float64)
        if expert_fn is None:
            for el in range(eps):
                i, k = np.nonzero(eids == el)
                if not len(i):
                    continue
                y = np_swiglu(toks[i], wg[g * eps + el], wu[g * eps + el],
                              wd[g * eps + el])
                np.add.at(part, i, ws[i, k][:, None].astype(np.float64)
                          * y.astype(np.float64))
            return part.astype(np.float32)
        # generic grouped contract: bucket the (entry, choice) pairs per
        # local expert and make one full-width expert_fn call
        i_all, k_all = np.nonzero(eids >= 0)
        if not len(i_all):
            return part.astype(np.float32)
        e_glob = g * eps + eids[i_all, k_all]
        pl = planlib.make_plan(e_glob.reshape(-1, 1), E, len(i_all))
        Ce = int(np.asarray(pl.counts).max())
        buf = np.zeros((E, Ce, D), np.float32)
        rank = np.asarray(pl.rank).reshape(-1)
        buf[e_glob, rank] = toks[i_all]
        y = np.asarray(_call_expert_fn(
            expert_fn, buf, np.asarray(pl.counts, np.int32)), np.float32)
        np.add.at(part, i_all,
                  ws[i_all, k_all][:, None].astype(np.float64)
                  * y[e_glob, rank].astype(np.float64))
        return part.astype(np.float32)

    # -------------------------------------------------- bulk push helpers --
    def _push_grouped(self, words: np.ndarray, pusher: np.ndarray,
                      channel: np.ndarray):
        """Route a packed (N, 4) command stream to its per-rank proxies,
        batched per (rank, channel) with original relative order preserved
        inside each channel (the only order the protocol relies on)."""
        pusher = np.asarray(pusher).reshape(-1)
        channel = np.asarray(channel).reshape(-1)
        for r in np.unique(pusher):
            in_r = pusher == r
            w_r, ch_r = words[in_r], channel[in_r]
            for c in np.unique(ch_r):
                self._push_words(int(r), int(c), w_r[ch_r == c])

    def _push_words(self, r: int, ch: int, words: np.ndarray):
        proxies = self.proxies
        self._dirty = True
        self.timeline["cmds_per_step"] = \
            self.timeline.get("cmds_per_step", 0) + len(words)
        if self.use_threads:
            # worker threads drain concurrently; pace on ring space (the
            # paper's kMaxInflight sender flow control, §3.1): when the
            # ring is full, poll the outstanding window's completion in one
            # lock round-trip per spin instead of one check per index
            if not proxies[r]._threads:
                proxies[r].start()
            c = proxies[r].channels[ch % len(proxies[r].channels)]
            deadline = time.monotonic() + 60.0
            done = 0
            while done < len(words):
                done += c.try_push_batch(words[done:])
                if done >= len(words):
                    break
                tail = c._tail              # producer-owned counter
                window = np.arange(max(0, tail - c.capacity), tail)
                # one locked head read answers the whole outstanding
                # window; the ring has space exactly when the OLDEST
                # outstanding slot ([0]) has completed
                while not c.check_completion_batch(window)[0]:
                    if time.monotonic() > deadline:
                        raise TimeoutError("FIFO full: consumer stalled")
                    time.sleep(1e-5)
            return
        done = 0
        while done < len(words):
            done += proxies[r].push_batch(ch, words[done:], block=False)
            if done < len(words):
                # back-pressure: relieve the full ring inline
                proxies[r].drain_inline()

    # ------------------------------------------------- event-driven pump ---
    def _pump_events(self, proxies, ready: Optional[list] = None,
                     launch: Optional[Callable] = None):
        """Drive command execution and network delivery until the world
        quiesces: FIFO rings empty, no command mid-execution, no message in
        flight — the event-clock condition that replaced the seed's fixed
        500-iteration polling loop.  Deliveries append readiness events to
        ``ready``; ``launch`` consumes them between deliveries, so compute
        interleaves with in-flight traffic.  Delivery runs through
        ``Network.deliver_ready``: every event sharing the frontier
        timestamp lands in one lock round-trip."""
        # exact-gated batching counter: one increment per quiesce drain —
        # the cross-layer step drivers must show exactly 1 per step
        self.timeline["drains_per_step"] = \
            self.timeline.get("drains_per_step", 0) + 1
        deliver = self.net.deliver_ready
        if self.use_threads:
            for p in proxies:
                if not p._threads:
                    p.start()
            deadline = time.monotonic() + 120.0
            calm = 0
            while True:
                delivered = deliver()
                while ready:
                    launch(ready.pop())
                for p in proxies:  # surface worker failures immediately
                    err = p.poll_error()
                    if err is not None:
                        raise RuntimeError(
                            f"proxy {p.rank} worker failed") from err
                if delivered:
                    calm = 0
                    continue
                if any(p.busy for p in proxies) or self.net.pending:
                    calm = 0
                    if time.monotonic() > deadline:
                        raise TimeoutError("transport quiesce timed out")
                    time.sleep(2e-5)
                    continue
                calm += 1          # confirm stability across two checks
                if calm >= 2:
                    return
                time.sleep(2e-5)
        while True:
            if self._dirty:
                self._dirty = False
                for p in proxies:
                    p.drain_inline()
            delivered = deliver()
            while ready:
                launch(ready.pop())
            if not delivered and not self._dirty:
                return

    @staticmethod
    def oracle(x, top_idx, top_w, wg, wu, wd) -> np.ndarray:
        R, Tl, D = x.shape
        out = np.zeros((R, Tl, D), np.float64)
        for r in range(R):
            for t in range(Tl):
                acc = np.zeros(D, np.float64)
                for k in range(top_idx.shape[2]):
                    e = int(top_idx[r, t, k])
                    acc += float(top_w[r, t, k]) * np_swiglu(
                        x[r, t].astype(np.float32)[None],
                        wg[e], wu[e], wd[e])[0].astype(np.float64)
                out[r, t] = acc
        return out.astype(np.float32)
