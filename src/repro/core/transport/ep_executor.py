"""End-to-end EP dispatch/combine over the transport substrate.

Executes the paper's LL protocol literally: per-token RDMA writes tagged with
immediate data, one completion-fence atomic per (source, expert), expert FFN
at the destination, per-token combine writes back, weighted reduce at the
source — all over the unordered (SRD) or ordered (RC) network model, through
128-bit FIFO channels and CPU proxies.

Routing decisions (slot assignment, per-(src, expert) counts, capacity
masks) come from the shared plan layer (:mod:`repro.core.plan`) — the same
plans the jax-collectives path consumes — and are turned into *batched*
TransferCmd streams: packed ``(N, 4)`` uint32 arrays pushed through the
``Proxy.push_batch`` bulk FIFO path.  No per-command Python objects on the
hot path (DESIGN.md §8).

Tests prove protocol correctness (result == dense oracle under any delivery
order); benchmarks reuse it for paper Figs. 7/15/17.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional

import numpy as np

from repro.core import plan as planlib
from repro.core.transport.fifo import FLAG_FENCE, Op, pack_cmds
from repro.core.transport.proxy import Proxy, SymmetricMemory
from repro.core.transport.simulator import Network, NetConfig

F32 = np.dtype(np.float32)


class CommandStreams(NamedTuple):
    """Batched TransferCmd streams for one EP round, plus routing metadata.

    Each stream is a packed (N, 4) uint32 descriptor array (invalid routing
    entries already dropped) with parallel per-row ``*_pusher`` (the rank
    whose proxy issues the command) and ``*_channel`` arrays."""

    plan: planlib.WorldPlan
    writes: np.ndarray          # dispatch data writes
    write_pusher: np.ndarray
    write_channel: np.ndarray
    fences: np.ndarray          # one completion-fence atomic per (src, e)
    fence_pusher: np.ndarray
    fence_channel: np.ndarray
    combines: np.ndarray        # combine writes back to the source
    combine_pusher: np.ndarray
    combine_channel: np.ndarray


def build_command_streams(top_idx: np.ndarray, n_experts: int, eps: int,
                          capacity: int, tok_bytes: int, n_channels: int,
                          send0: int, recv0: int, ret0: int,
                          ) -> CommandStreams:
    """Vectorized LL-protocol command generation from a routing table.

    The single source of truth for how plans become TransferCmd streams —
    ``EPWorld.run`` executes exactly these; ``benchmarks/bench_plan.py``
    times this function against the seed's Python loops.
    """
    ti = np.ascontiguousarray(top_idx, np.int64)
    R, Tl, K = ti.shape
    tb = tok_bytes
    wp = planlib.make_world_plan(ti, n_experts, capacity)
    valid = wp.valid.reshape(-1)

    dst = ti // eps                                     # (R, Tl, K)
    el = np.where(wp.valid, ti % eps, 0)
    t_idx = np.arange(Tl, dtype=np.int64)[None, :, None]
    k_idx = np.arange(K, dtype=np.int64)[None, None, :]
    ch = np.broadcast_to((t_idx + k_idx) % n_channels, ti.shape)
    src_off = np.broadcast_to(send0 + t_idx * tb, ti.shape)
    # dispatch writes land in the (src, expert) receive bucket at the plan's
    # arrival-order slot; combine writes come straight back from that bucket
    # into the per-(token, choice) return slot
    recv_off = recv0 + ((np.arange(R)[:, None, None] * eps + el) * capacity
                        + wp.rank) * tb
    ret_off = np.broadcast_to(ret0 + (t_idx * K + k_idx) * tb, ti.shape)
    src_rank = np.broadcast_to(np.arange(R)[:, None, None], ti.shape)

    writes = pack_cmds(int(Op.WRITE), dst, ch, src_off, recv_off, tb,
                       el)[valid]
    combines = pack_cmds(int(Op.WRITE), src_rank, ch, recv_off, ret_off, tb,
                         0)[valid]
    ch_flat = ch.reshape(-1)[valid]

    r_f, e_f = np.nonzero(wp.counts > 0)
    el_f = e_f % eps
    fence_val = (el_f & 0x3F) | (np.minimum(wp.counts[r_f, e_f], 63) << 6)
    fences = pack_cmds(int(Op.ATOMIC), e_f // eps, e_f % n_channels, 0,
                       r_f * eps + el_f, 0, fence_val, FLAG_FENCE)

    return CommandStreams(
        plan=wp,
        writes=writes, write_pusher=src_rank.reshape(-1)[valid],
        write_channel=ch_flat,
        fences=fences, fence_pusher=r_f, fence_channel=e_f % n_channels,
        combines=combines, combine_pusher=dst.reshape(-1)[valid],
        combine_channel=ch_flat)


def np_swiglu(x: np.ndarray, wg, wu, wd) -> np.ndarray:
    g = x @ wg
    u = x @ wu
    return (g / (1 + np.exp(-g)) * u) @ wd


def np_grouped_swiglu(tokens: np.ndarray, wg, wu, wd) -> np.ndarray:
    """Vectorized grouped expert FFN: row block e of ``tokens`` (E, N, D)
    goes through expert e's SwiGLU.  Same contract as the jax path's
    ``expert_fn`` (kernels.ops.grouped_swiglu), in numpy."""
    g = np.einsum("end,edf->enf", tokens, wg)
    u = np.einsum("end,edf->enf", tokens, wu)
    return np.einsum("enf,efd->end", g / (1 + np.exp(-g)) * u, wd)


def _to_bytes(a: np.ndarray) -> np.ndarray:
    return np.frombuffer(np.ascontiguousarray(a, F32).tobytes(), np.uint8)


def _from_bytes(b: np.ndarray, shape) -> np.ndarray:
    return np.frombuffer(b.tobytes(), F32).reshape(shape)


@dataclass
class EPWorld:
    n_ranks: int
    n_experts: int
    top_k: int
    d: int
    f: int = 0                  # expert hidden dim (only for the wg/wu/wd path)
    capacity: int = 0
    net_cfg: NetConfig = field(default_factory=NetConfig)
    n_channels: int = 8
    n_threads: int = 4
    use_threads: bool = False

    def __post_init__(self):
        assert self.n_experts % self.n_ranks == 0
        self.eps = self.n_experts // self.n_ranks
        self.tok_bytes = self.d * 4
        self.net = Network(self.net_cfg, self.n_ranks)
        self.proxies: list[Proxy] = []
        self.mems: list[SymmetricMemory] = []

    def run(self, x: np.ndarray, top_idx: np.ndarray, top_w: np.ndarray,
            wg: Optional[np.ndarray] = None, wu: Optional[np.ndarray] = None,
            wd: Optional[np.ndarray] = None, *,
            expert_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
            ) -> np.ndarray:
        """x: (R, Tl, D); top_idx/top_w: (R, Tl, K); w*: (E, D, F)/(E, F, D).

        Expert compute is either the built-in grouped SwiGLU over
        ``wg/wu/wd`` or a caller-supplied ``expert_fn`` with the standard
        backend contract: ``(n_experts, N, D) -> (n_experts, N, D)``, row
        block e holding the tokens received by (global) expert e.
        """
        R, Tl, D = x.shape
        K, C = self.top_k, self.capacity
        E, eps, tb = self.n_experts, self.eps, self.tok_bytes
        nc = self.n_channels
        if expert_fn is None:
            assert wg is not None and wu is not None and wd is not None
            expert_fn = lambda toks: np_grouped_swiglu(toks, wg, wu, wd)  # noqa: E731
        send0 = 0
        recv0 = send0 + Tl * tb
        ret0 = recv0 + R * eps * C * tb
        total = ret0 + Tl * K * tb
        mems = [SymmetricMemory.create(total, n_counters=R * eps + R)
                for _ in range(R)]
        proxies = [Proxy(r, self.net, mems[r], n_threads=self.n_threads,
                         n_channels=nc,
                         ordered_transport=(self.net_cfg.mode == "rc"))
                   for r in range(R)]
        self.proxies, self.mems = proxies, mems
        for r in range(R):
            mems[r].data[send0:send0 + Tl * tb] = _to_bytes(x[r])

        # slot assignment + command generation: arrival order per
        # (src, expert) from the shared plan layer, packed as batched
        # TransferCmd streams (the metadata a real command stream encodes)
        cs = build_command_streams(top_idx, E, eps, C, tb, nc,
                                   send0, recv0, ret0)
        wp = cs.plan
        assert int(wp.counts.max()) <= C, "capacity overflow in setup"

        self._push_grouped(cs.writes, cs.write_pusher, cs.write_channel)
        self._push_grouped(cs.fences, cs.fence_pusher, cs.fence_channel)
        self._pump(proxies)
        for r, e in zip(*(a.tolist() for a in np.nonzero(wp.counts > 0))):
            assert mems[e // eps].counters[r * eps + e % eps] == 1, (r, e)

        # -------------------- expert compute (one grouped call) -----------
        # stack each destination's receive region into a global
        # (E, R*c_max, D) buffer: expert e = dst*eps + el, row block per
        # src.  Only the occupied slot prefix (c_max = fullest bucket) is
        # computed — the rest of each capacity-C bucket is padding.
        c_max = int(wp.counts.max())
        if c_max:
            bufs = [_from_bytes(mems[d].data[recv0:ret0],
                                (R, eps, C, D)).copy()
                    for d in range(R)]
            toks = np.concatenate([
                b[:, :, :c_max].transpose(1, 0, 2, 3).reshape(
                    eps, R * c_max, D) for b in bufs], axis=0)
            outs = expert_fn(toks)
            assert outs.shape == (E, R * c_max, D), outs.shape
            for d in range(R):  # write outputs back over the receive buckets
                o = outs[d * eps:(d + 1) * eps].reshape(eps, R, c_max, D)
                bufs[d][:, :, :c_max] = o.transpose(1, 0, 2, 3)
                mems[d].data[recv0:ret0] = _to_bytes(bufs[d])

        # -------------------- combine (write back) ------------------------
        self._push_grouped(cs.combines, cs.combine_pusher, cs.combine_channel)
        self._pump(proxies)

        # -------------------- weighted reduce at source -------------------
        out = np.zeros((R, Tl, D), np.float64)
        for r in range(R):
            ret = _from_bytes(mems[r].data[ret0:ret0 + Tl * K * tb],
                              (Tl, K, D))
            out[r] = np.einsum("tkd,tk->td", ret.astype(np.float64),
                               np.where(wp.valid[r], top_w[r], 0.0)
                               .astype(np.float64))
        return out.astype(np.float32)

    # -------------------------------------------------- bulk push helpers --
    def _push_grouped(self, words: np.ndarray, pusher: np.ndarray,
                      channel: np.ndarray):
        """Route a packed (N, 4) command stream to its per-rank proxies,
        batched per (rank, channel) with original relative order preserved
        inside each channel (the only order the protocol relies on)."""
        pusher = np.asarray(pusher).reshape(-1)
        channel = np.asarray(channel).reshape(-1)
        for r in np.unique(pusher):
            in_r = pusher == r
            w_r, ch_r = words[in_r], channel[in_r]
            for c in np.unique(ch_r):
                self._push_words(int(r), int(c), w_r[ch_r == c])

    def _push_words(self, r: int, ch: int, words: np.ndarray):
        proxies = self.proxies
        if self.use_threads:
            # worker threads drain concurrently; block on ring space
            # (the paper's kMaxInflight sender pacing, §3.1)
            if not proxies[r]._threads:
                proxies[r].start()
            proxies[r].push_batch(ch, words, block=True)
            return
        done = 0
        while done < len(words):
            done += proxies[r].push_batch(ch, words[done:], block=False)
            if done < len(words):
                # back-pressure: relieve the full ring inline
                proxies[r].drain_inline()

    def _pump(self, proxies):
        if self.use_threads:
            for p in proxies:
                if not p._threads:
                    p.start()
            for _ in range(500):
                if all(c.inflight == 0 for p in proxies for c in p.channels):
                    break
                time.sleep(1e-3)
                self.net.flush()
            self.net.flush()
        else:
            for _ in range(4):
                for p in proxies:
                    p.drain_inline()
                self.net.flush()

    @staticmethod
    def oracle(x, top_idx, top_w, wg, wu, wd) -> np.ndarray:
        R, Tl, D = x.shape
        out = np.zeros((R, Tl, D), np.float64)
        for r in range(R):
            for t in range(Tl):
                acc = np.zeros(D, np.float64)
                for k in range(top_idx.shape[2]):
                    e = int(top_idx[r, t, k])
                    acc += float(top_w[r, t, k]) * np_swiglu(
                        x[r, t].astype(np.float32)[None],
                        wg[e], wu[e], wd[e])[0].astype(np.float64)
                out[r, t] = acc
        return out.astype(np.float32)
