"""Delivery-semantics bridging (paper §3.3, §4.1): immediate-data codec,
registered guard ranges, and the receiver-side control buffer.

Heterogeneous NICs differ in ordering: ConnectX RC delivers in order, AWS
EFA SRD is reliable-but-unordered, and EFA lacks hardware atomics.  The
receiver CPU proxy therefore (a) tags every message with a 32-bit immediate,
(b) applies *writes* immediately, and (c) holds *atomics* in a control
buffer until their guard is satisfied:

- LL completion fence: an atomic guarding receive bucket ``g`` with required
  count ``X`` applies only once >= X writes have landed *inside bucket g's
  registered address range* (any order).
- HT partial ordering: an atomic with sequence ``s`` on channel ``c``
  applies only after all messages with smaller sequence on ``c`` applied —
  ordering is per-channel, never global.

Guard state is keyed by **registered address ranges**, not by wire-carried
slots: at world setup each rank registers its receive-bucket table
(base offset, extent, guard id) with its proxy — mirroring how real RDMA
resolves a landing address against a registered MR — and the receiver
resolves each write's ``dst_off`` to a guard id on delivery
(:class:`GuardTable`).  Writes outside any registered range (combine return
regions, HT entry buckets) satisfy no fence, which is why no reserved
"unfenced" wire slot exists anymore; and because guard ids are 32-bit
memory-table indices rather than a 6-bit immediate field, there is no limit
of 64 experts per rank (the seed aliased expert ``e`` onto guard ``e % 64``
past that).

The 32-bit immediate layout is per-kind (DESIGN.md §12).  Sequence-carrying
kinds (WRITE, SEQ_ATOMIC, BARRIER) pack

    kind(2) | channel(3) | seq(11) | value(16)

while FENCE_ATOMIC — which does not participate in sequence ordering and
therefore needs no seq field — trades it for a wide count:

    kind(2) | channel(3) | count(21) | unused(6)

so LL fence guards cover receive buckets of up to 2M tokens.  The fence's
guard id rides the descriptor's 32-bit ``dst_off`` field (a zero-byte
transfer has no landing address to resolve), and the SEQ_ATOMIC operand
(HT chunk id) rides the 16-bit value field.  Wire sequences are modulo
``SEQ_MOD``; the receiver unwraps them against the highest sequence seen per
channel, which is safe while delivery displacement stays below
``SEQ_MOD // 4`` *sequences* (half the true unwrap window, margin for
mixed wire sizes).  Two senders together keep it there: the network model
bounds its reorder window below 512 arrivals, and the proxy's write
coalescer caps run length so ``(reorder_window + 1) * cap`` sequences
stay inside the same bound (``Proxy._coalesce_cap``).
"""
from __future__ import annotations

import heapq
from bisect import bisect_left, bisect_right
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Callable, Optional

import numpy as np

from repro.core.transport.wire_format import (FENCE_COUNT_MAX, IMM_CH_MASK,
                                              IMM_CH_SHIFT, IMM_COUNT_MASK,
                                              IMM_COUNT_SHIFT, IMM_KIND_MASK,
                                              IMM_SEQ_MASK, IMM_SEQ_SHIFT,
                                              IMM_VAL_MAX, IMM_VALUE_SHIFT,
                                              N_CHANNELS_MAX, SEQ_MOD,
                                              ProtocolError)

__all__ = ["ImmKind", "N_CHANNELS_MAX", "SEQ_MOD", "IMM_VAL_MAX",
           "FENCE_COUNT_MAX", "ProtocolError", "pack_imm", "unpack_imm",
           "GuardTable", "ControlBuffer"]


class ImmKind(IntEnum):
    WRITE = 0          # data write notification
    FENCE_ATOMIC = 1   # LL: apply after `count` writes landed in the guarded
    #                    address range (guard id rides the descriptor dst_off)
    SEQ_ATOMIC = 2     # HT: apply in per-channel sequence order
    BARRIER = 3        # reserved (applies immediately)


# Field widths/masks/shifts and the derived protocol constants
# (N_CHANNELS_MAX, SEQ_MOD, IMM_VAL_MAX, FENCE_COUNT_MAX) live in
# ``wire_format`` — the single source of truth — and are re-exported here
# for existing import sites.


def pack_imm(kind: ImmKind, channel: int, seq: int, value: int) -> int:
    """32-bit immediate; layout is per-kind (see module docstring).  For
    FENCE_ATOMIC, ``seq`` must be 0 (fences carry no sequence number) and
    ``value`` is the required write count (up to :data:`FENCE_COUNT_MAX`);
    the guard id travels in the descriptor, not the immediate.

    Out-of-range fields raise :class:`ProtocolError` (never ``assert`` —
    truncating a field silently corrupts the wire under ``python -O``)."""
    if not 0 <= channel < N_CHANNELS_MAX:
        raise ProtocolError(f"imm channel {channel} not in "
                            f"[0, {N_CHANNELS_MAX})")
    if kind == ImmKind.FENCE_ATOMIC:
        if seq != 0 or not 0 <= value <= FENCE_COUNT_MAX:
            raise ProtocolError(f"fence imm seq={seq} count={value}: seq "
                                f"must be 0 and count <= {FENCE_COUNT_MAX}")
        return int(kind) | (channel << IMM_CH_SHIFT) \
            | (value << IMM_COUNT_SHIFT)
    if not 0 <= seq < SEQ_MOD or not 0 <= value <= IMM_VAL_MAX:
        raise ProtocolError(f"imm seq={seq} value={value}: need seq < "
                            f"{SEQ_MOD} and value <= {IMM_VAL_MAX}")
    return int(kind) | (channel << IMM_CH_SHIFT) | (seq << IMM_SEQ_SHIFT) \
        | (value << IMM_VALUE_SHIFT)


_IMM_KINDS = (ImmKind.WRITE, ImmKind.FENCE_ATOMIC, ImmKind.SEQ_ATOMIC,
              ImmKind.BARRIER)   # tuple dispatch: Enum.__call__ is hot


def unpack_imm(imm: int) -> tuple[ImmKind, int, int, int]:
    kind = _IMM_KINDS[imm & IMM_KIND_MASK]
    if kind is ImmKind.FENCE_ATOMIC:
        return (kind, (imm >> IMM_CH_SHIFT) & IMM_CH_MASK, 0,
                (imm >> IMM_COUNT_SHIFT) & IMM_COUNT_MASK)
    return (kind, (imm >> IMM_CH_SHIFT) & IMM_CH_MASK,
            (imm >> IMM_SEQ_SHIFT) & IMM_SEQ_MASK, imm >> IMM_VALUE_SHIFT)


class GuardTable:
    """Registered receive-bucket table for one rank's symmetric memory.

    Mirrors how a real NIC resolves a landing address against registered
    memory regions: each entry is a non-overlapping ``[base, base + extent)``
    byte range owning a wide integer ``guard_id``.  :meth:`resolve` maps a
    delivered write's destination offset to the guard of the bucket it fell
    in, or ``None`` for unregistered memory (e.g. combine return regions) —
    such writes apply but can never satisfy a completion fence.
    """

    __slots__ = ("_bases", "_ends", "_gids", "_np")

    def __init__(self):
        self._bases: list[int] = []
        self._ends: list[int] = []
        self._gids: list[int] = []
        self._np = None              # cached array form for resolve_batch

    def __len__(self) -> int:
        return len(self._bases)

    def register(self, base: int, extent: int, guard_id: int) -> None:
        """Register one bucket.  Ranges must not overlap (a landing address
        must resolve to exactly one guard, as with real MRs)."""
        base, extent = int(base), int(extent)
        if extent <= 0:
            raise ProtocolError(f"guard range extent must be > 0, got "
                                f"{extent}")
        i = bisect_left(self._bases, base)
        if not ((i == 0 or self._ends[i - 1] <= base) and
                (i == len(self._bases) or base + extent <= self._bases[i])):
            raise ProtocolError(f"guard range [{base}, {base + extent}) "
                                "overlaps a registered one")
        self._bases.insert(i, base)
        self._ends.insert(i, base + extent)
        self._gids.insert(i, int(guard_id))
        self._np = None

    def register_table(self, bases, extents, guard_ids) -> None:
        """Bulk registration of a bucket table; arguments broadcast."""
        bases, extents, guard_ids = np.broadcast_arrays(
            np.asarray(bases), np.asarray(extents), np.asarray(guard_ids))
        for b, x, g in zip(bases.reshape(-1).tolist(),
                           extents.reshape(-1).tolist(),
                           guard_ids.reshape(-1).tolist()):
            self.register(b, x, g)

    def resolve(self, off: int) -> Optional[int]:
        """Guard id of the registered range containing ``off``, else None."""
        i = bisect_right(self._bases, off) - 1
        if i >= 0 and off < self._ends[i]:
            return self._gids[i]
        return None

    def resolve_batch(self, offs) -> np.ndarray:
        """Vectorized :meth:`resolve`: (N,) offsets -> (N,) int64 guard ids,
        -1 where the offset lands in unregistered memory.  One searchsorted
        over the (cached) sorted range table for the whole batch."""
        offs = np.asarray(offs, np.int64)
        if not self._bases:
            return np.full(offs.shape, -1, np.int64)
        if self._np is None:
            self._np = (np.asarray(self._bases, np.int64),
                        np.asarray(self._ends, np.int64),
                        np.asarray(self._gids, np.int64))
        bases, ends, gids = self._np
        i = np.searchsorted(bases, offs, side="right") - 1
        j = np.maximum(i, 0)
        ok = (i >= 0) & (offs < ends[j])
        return np.where(ok, gids[j], -1)


def _noop() -> None:
    """Stand-in apply for batch unrolling: a coalesced run's payload is
    landed by the receiver in one contiguous copy before the semantics
    bookkeeping runs, so the per-write apply has nothing left to do."""


@dataclass(order=True)
class _Held:
    seq: int
    imm: int = field(compare=False)
    apply: Callable[[], None] = field(compare=False)


class ControlBuffer:
    """Receiver-side guard state for one peer connection.

    ``writes_seen[guard_id]`` counts landed writes per registered receive
    bucket (LL fence) — writes are attributed to guards by resolving their
    landing offset against the shared :class:`GuardTable`, never by a
    wire-carried slot; ``next_seq[channel]`` tracks the next expected
    (unwrapped) sequence (HT order).  Held seq atomics live in per-channel
    min-heaps keyed by sequence; held fences live in per-guard lists.
    """

    def __init__(self, guards: Optional[GuardTable] = None,
                 n_channels: int = N_CHANNELS_MAX):
        self.guards = guards
        self.writes_seen: dict[int, int] = {}
        self.next_seq = [0] * n_channels
        self._hi_seq = [0] * n_channels        # unwrap anchor per channel
        # per-channel min-heaps of [start, end) arrived-sequence intervals:
        # a coalesced run buffers as ONE interval, not n entries, so the
        # heap stays O(messages) rather than O(sequences)
        self._arrived: dict[int, list[tuple[int, int]]] = {}
        self.held_seq: dict[int, list[_Held]] = {}
        # guard id -> [(required count, imm, apply)]
        self.held_fence: dict[int, list[tuple[int, int, Callable]]] = {}
        self.applied_log: list[int] = []     # imm values, in application order
        self._held = 0                       # incremental count (hot path)
        self.held_peak = 0

    # ------------------------------------------------------------ events --
    def on_write(self, imm: int, apply: Callable[[], None],
                 dst_off: int = 0) -> None:
        """A data write landed at ``dst_off`` (RDMA writes apply
        immediately); the landing offset resolves to the guard it feeds."""
        kind, ch, seq, value = unpack_imm(imm)
        assert kind == ImmKind.WRITE
        apply()
        gid = self.guards.resolve(dst_off) if self.guards is not None else None
        if gid is not None:
            self.writes_seen[gid] = self.writes_seen.get(gid, 0) + 1
        self._bump_seq(ch, self._unwrap(ch, seq))
        self.applied_log.append(imm)
        if self._held:          # guard the (common) nothing-held fast path
            if gid is not None:
                self._drain_fences(gid)
            self._drain(ch)

    def on_write_batch(self, imms: np.ndarray, dst_offs: np.ndarray) -> None:
        """Batched :meth:`on_write` for a coalesced delivery.  The payload
        is already in place (the caller lands a coalesced run with ONE
        contiguous copy); this attributes every sub-write to its guard with
        one ``searchsorted`` over the registered range table and advances
        the channel's sequence prefix in bulk.

        The vectorized path requires that no held guarded atomic can fire
        mid-run — a held fence on one of the run's own guards, or a held
        seq atomic on the run's channel.  Those cases (out-of-order srd
        stragglers racing their guard) unroll through the scalar
        :meth:`on_write`, which stays the semantics oracle (identical
        apply ordering); held atomics on unrelated guards/channels can't
        observe the run and don't force the fallback."""
        imms = np.asarray(imms, np.uint32)
        n = len(imms)
        if n == 0:
            return
        ch = (int(imms[0]) >> IMM_CH_SHIFT) & IMM_CH_MASK
        dst_offs = np.asarray(dst_offs)
        # guard attribution: a proxy-coalesced run lands in one ascending
        # contiguous interval, so when its offsets are monotone and the
        # first and last resolve to the same bucket, the whole run is
        # inside it (registered ranges are intervals) — two bisect probes
        # plus one comparison, no searchsorted.  Anything else (the API
        # accepts arbitrary offset batches) takes the vectorized resolve.
        uniq = cnt = None
        if self.guards is not None:
            g0 = self.guards.resolve(int(dst_offs[0]))
            if g0 is not None and \
                    self.guards.resolve(int(dst_offs[-1])) == g0 and \
                    bool((dst_offs[1:] >= dst_offs[:-1]).all()):
                uniq, cnt = [g0], [n]
            else:
                gids = self.guards.resolve_batch(dst_offs)
                reg = gids[gids >= 0]
                if len(reg):
                    u, c = np.unique(reg, return_counts=True)
                    uniq, cnt = u.tolist(), c.tolist()
        hf = self.held_fence
        if self.held_seq.get(ch) or (
                hf and uniq is not None and any(g in hf for g in uniq)):
            for i in range(n):                 # scalar oracle path
                self.on_write(int(imms[i]), _noop, int(dst_offs[i]))
            return
        if uniq is not None:
            seen = self.writes_seen
            for g, c in zip(uniq, cnt):
                seen[g] = seen.get(g, 0) + c
        # the sender assigns a coalesced run consecutive sequences
        # [full0, full0 + n), so the prefix state advances in bulk
        full0 = self._unwrap(ch, (int(imms[0]) >> IMM_SEQ_SHIFT)
                             & IMM_SEQ_MASK)
        if full0 + n - 1 > self._hi_seq[ch]:
            self._hi_seq[ch] = full0 + n - 1
        if full0 == self.next_seq[ch]:
            # in-order run: extends the contiguous prefix at once (no held
            # seq atomic on this channel — checked above — so closing more
            # of the prefix releases nothing)
            self.next_seq[ch] = full0 + n
            h = self._arrived.get(ch)
            while h and h[0][0] == self.next_seq[ch]:
                self.next_seq[ch] = heapq.heappop(h)[1]
        else:
            # out-of-order srd straggler-side run: buffer the whole run as
            # ONE [start, end) interval (nothing can pop yet — the prefix
            # below full0 is still open)
            self._bump_seq(ch, full0, full0 + n)
        self.applied_log.extend(imms.tolist())

    def on_atomic(self, imm: int, apply: Callable[[], None],
                  guard: Optional[int] = None) -> None:
        """An atomic-as-immediate landed.  For FENCE_ATOMIC, ``guard`` is
        the wide guard id the descriptor's ``dst_off`` addressed."""
        kind, ch, seq, value = unpack_imm(imm)
        if kind is ImmKind.FENCE_ATOMIC:
            if self.writes_seen.get(guard, 0) >= value:
                apply()
                self.applied_log.append(imm)
            else:
                self.held_fence.setdefault(guard, []).append(
                    (value, imm, apply))
                self._held += 1
                if self._held > self.held_peak:
                    self.held_peak = self._held
        elif kind is ImmKind.SEQ_ATOMIC:
            full = self._unwrap(ch, seq)
            if self.next_seq[ch] >= full:
                apply()
                self.applied_log.append(imm)
                self._bump_seq(ch, full)
                self._drain(ch)
            else:
                heapq.heappush(self.held_seq.setdefault(ch, []),
                               _Held(full, imm, apply))
                self._held += 1
                if self._held > self.held_peak:
                    self.held_peak = self._held
        else:
            apply()
            self.applied_log.append(imm)

    # ----------------------------------------------------------- helpers --
    def _unwrap(self, ch: int, wire_seq: int) -> int:
        """Reconstruct the full sequence from its SEQ_MOD-wrapped wire form,
        nearest to the highest sequence seen on this channel.  Correct while
        delivery displacement < SEQ_MOD // 4 arrivals (network guarantee)."""
        hi = self._hi_seq[ch]
        diff = ((wire_seq - hi + SEQ_MOD // 2) % SEQ_MOD) - SEQ_MOD // 2
        full = hi + diff
        assert full >= 0, (ch, wire_seq, hi)
        if full > hi:
            self._hi_seq[ch] = full
        return full

    def _bump_seq(self, ch: int, seq: int, end: Optional[int] = None) -> None:
        # sequences are assigned consecutively per channel by the sender;
        # next_seq advances over the contiguous prefix of *applied* seqs
        # (writes may land out of order and apply immediately, so arrivals
        # are buffered — as [start, end) intervals — until the prefix
        # closes).
        heapq.heappush(self._arrived.setdefault(ch, []),
                       (seq, seq + 1 if end is None else end))
        h = self._arrived[ch]
        while h and h[0][0] == self.next_seq[ch]:
            self.next_seq[ch] = heapq.heappop(h)[1]

    def _drain(self, ch: int) -> None:
        heap = self.held_seq.get(ch)
        while heap and heap[0].seq <= self.next_seq[ch]:
            h = heapq.heappop(heap)
            h.apply()
            self._held -= 1
            self.applied_log.append(h.imm)
            self._bump_seq(ch, h.seq)

    def _drain_fences(self, gid: int) -> None:
        held = self.held_fence.get(gid)
        if not held:
            return
        seen = self.writes_seen.get(gid, 0)
        still = []
        for value, imm, apply in held:
            if seen >= value:
                apply()
                self._held -= 1
                self.applied_log.append(imm)
            else:
                still.append((value, imm, apply))
        if still:
            self.held_fence[gid] = still
        else:
            del self.held_fence[gid]

    @property
    def n_held(self) -> int:
        return self._held
