"""Delivery-semantics bridging (paper §3.3, §4.1): immediate-data codec and
the receiver-side control buffer.

Heterogeneous NICs differ in ordering: ConnectX RC delivers in order, AWS
EFA SRD is reliable-but-unordered, and EFA lacks hardware atomics.  The
receiver CPU proxy therefore (a) tags every message with a 32-bit immediate
carrying (kind, channel, seq, value), (b) applies *writes* immediately, and
(c) holds *atomics* in a control buffer until their guard is satisfied:

- LL completion fence: an atomic covering expert ``e`` with required count
  ``X`` applies only once >= X writes for ``e`` have landed (any order).
- HT partial ordering: an atomic with sequence ``s`` on channel ``c``
  applies only after all messages with smaller sequence on ``c`` applied —
  ordering is per-channel, never global.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Callable, Optional


class ImmKind(IntEnum):
    WRITE = 0          # data write notification
    FENCE_ATOMIC = 1   # LL: apply after `value` writes for expert `slot`
    SEQ_ATOMIC = 2     # HT: apply in per-channel sequence order
    BARRIER = 3


def pack_imm(kind: ImmKind, channel: int, seq: int, slot: int, value: int) -> int:
    """32-bit immediate: kind(2) | channel(6) | seq(12) | slot(6) | value(6)."""
    assert 0 <= channel < 64 and 0 <= seq < 4096 and 0 <= slot < 64 \
        and 0 <= value < 64, (channel, seq, slot, value)
    return (int(kind) & 0x3) | (channel << 2) | (seq << 8) | (slot << 20) | \
        (value << 26)


def unpack_imm(imm: int) -> tuple[ImmKind, int, int, int, int]:
    return (ImmKind(imm & 0x3), (imm >> 2) & 0x3F, (imm >> 8) & 0xFFF,
            (imm >> 20) & 0x3F, (imm >> 26) & 0x3F)


@dataclass(order=True)
class _Held:
    seq: int
    imm: int = field(compare=False)
    apply: Callable[[], None] = field(compare=False)


class ControlBuffer:
    """Receiver-side guard state for one peer connection.

    ``writes_seen[slot]`` counts landed writes per expert slot (LL fence);
    ``applied_seq[channel]`` tracks the next expected sequence (HT order).
    Held atomics live in per-channel min-heaps keyed by sequence.
    """

    def __init__(self, n_slots: int = 64, n_channels: int = 64):
        self.writes_seen = [0] * n_slots
        self.next_seq = [0] * n_channels
        self._arrived: dict[int, list[int]] = {}   # per-channel seq min-heaps
        self.held_seq: dict[int, list[_Held]] = {}
        self.held_fence: list[tuple[int, int, int, Callable]] = []
        self.applied_log: list[int] = []     # imm values, in application order
        self.held_peak = 0

    # ------------------------------------------------------------ events --
    def on_write(self, imm: int, apply: Callable[[], None]) -> None:
        """A data write landed (RDMA writes apply immediately)."""
        kind, ch, seq, slot, value = unpack_imm(imm)
        assert kind == ImmKind.WRITE
        apply()
        self.writes_seen[slot] += 1
        self._bump_seq(ch, seq)
        self.applied_log.append(imm)
        self._drain(ch)
        self._drain_fences()

    def on_atomic(self, imm: int, apply: Callable[[], None]) -> None:
        kind, ch, seq, slot, value = unpack_imm(imm)
        if kind == ImmKind.FENCE_ATOMIC:
            if self.writes_seen[slot] >= value:
                apply()
                self.applied_log.append(imm)
            else:
                self.held_fence.append((slot, value, imm, apply))
                self.held_peak = max(self.held_peak,
                                     len(self.held_fence) + self._n_held_seq())
        elif kind == ImmKind.SEQ_ATOMIC:
            if self.next_seq[ch] >= seq:
                apply()
                self.applied_log.append(imm)
                self._bump_seq(ch, seq)
                self._drain(ch)
            else:
                heapq.heappush(self.held_seq.setdefault(ch, []),
                               _Held(seq, imm, apply))
                self.held_peak = max(self.held_peak,
                                     len(self.held_fence) + self._n_held_seq())
        else:
            apply()
            self.applied_log.append(imm)

    # ----------------------------------------------------------- helpers --
    def _bump_seq(self, ch: int, seq: int) -> None:
        # sequences are assigned consecutively per channel by the sender;
        # next_seq advances over the contiguous prefix of *applied* seqs
        # (writes may land out of order and apply immediately, so arrivals
        # are buffered in a heap until the prefix closes).
        heapq.heappush(self._arrived.setdefault(ch, []), seq)
        h = self._arrived[ch]
        while h and h[0] == self.next_seq[ch]:
            heapq.heappop(h)
            self.next_seq[ch] += 1

    def _drain(self, ch: int) -> None:
        heap = self.held_seq.get(ch)
        while heap and heap[0].seq <= self.next_seq[ch]:
            h = heapq.heappop(heap)
            h.apply()
            self.applied_log.append(h.imm)
            self._bump_seq(ch, h.seq)
        self._drain_fences()

    def _drain_fences(self) -> None:
        still = []
        for slot, value, imm, apply in self.held_fence:
            if self.writes_seen[slot] >= value:
                apply()
                self.applied_log.append(imm)
            else:
                still.append((slot, value, imm, apply))
        self.held_fence = still

    def _n_held_seq(self) -> int:
        return sum(len(v) for v in self.held_seq.values())

    @property
    def n_held(self) -> int:
        return len(self.held_fence) + self._n_held_seq()
