"""Delivery-semantics bridging (paper §3.3, §4.1): immediate-data codec and
the receiver-side control buffer.

Heterogeneous NICs differ in ordering: ConnectX RC delivers in order, AWS
EFA SRD is reliable-but-unordered, and EFA lacks hardware atomics.  The
receiver CPU proxy therefore (a) tags every message with a 32-bit immediate,
(b) applies *writes* immediately, and (c) holds *atomics* in a control
buffer until their guard is satisfied:

- LL completion fence: an atomic covering expert ``e`` with required count
  ``X`` applies only once >= X writes for ``e`` have landed (any order).
- HT partial ordering: an atomic with sequence ``s`` on channel ``c``
  applies only after all messages with smaller sequence on ``c`` applied —
  ordering is per-channel, never global.

The 32-bit immediate layout is per-kind (DESIGN.md §10).  Sequence-carrying
kinds (WRITE, SEQ_ATOMIC, BARRIER) pack

    kind(2) | channel(3) | seq(11) | slot(6) | value(10)

while FENCE_ATOMIC — which does not participate in sequence ordering and
therefore needs no seq field — trades it for a wide count:

    kind(2) | channel(3) | slot(6) | count(21)

so LL fence guards cover receive buckets of up to 2M tokens (the seed
truncated counts to 6 bits, silently corrupting any bucket > 63).  Wire
sequences are modulo ``SEQ_MOD``; the receiver unwraps them against the
highest sequence seen per channel, which is safe while delivery displacement
stays below ``SEQ_MOD // 4`` arrivals (the network model bounds its reorder
window accordingly).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Callable, Optional


class ImmKind(IntEnum):
    WRITE = 0          # data write notification
    FENCE_ATOMIC = 1   # LL: apply after `value` writes for expert `slot`
    SEQ_ATOMIC = 2     # HT: apply in per-channel sequence order
    BARRIER = 3        # reserved (applies immediately)


N_CHANNELS_MAX = 8           # channel field: 3 bits
SEQ_MOD = 2048               # seq field: 11 bits (wire sequences wrap)
IMM_VAL_MAX = 1023           # value field: 10 bits (seq-carrying kinds)
FENCE_COUNT_MAX = (1 << 21) - 1   # fence count field: 21 bits
# slot 63 is reserved for writes that must never satisfy a fence guard
# (combine writes share the per-peer ControlBuffer with dispatch writes;
# without a reserved slot an early combine write would inflate
# writes_seen[el] and let expert el's completion fence pass before all of
# its dispatch writes landed)
UNFENCED_SLOT = 63


def pack_imm(kind: ImmKind, channel: int, seq: int, slot: int, value: int) -> int:
    """32-bit immediate; layout is per-kind (see module docstring).  For
    FENCE_ATOMIC, ``seq`` must be 0 (fences carry no sequence number) and
    ``value`` is the required write count (up to :data:`FENCE_COUNT_MAX`)."""
    assert 0 <= channel < N_CHANNELS_MAX and 0 <= slot < 64, (channel, slot)
    if kind == ImmKind.FENCE_ATOMIC:
        assert seq == 0 and 0 <= value <= FENCE_COUNT_MAX, (seq, value)
        return int(kind) | (channel << 2) | (slot << 5) | (value << 11)
    assert 0 <= seq < SEQ_MOD and 0 <= value <= IMM_VAL_MAX, (seq, value)
    return (int(kind) | (channel << 2) | (seq << 5) | (slot << 16)
            | (value << 22))


_IMM_KINDS = (ImmKind.WRITE, ImmKind.FENCE_ATOMIC, ImmKind.SEQ_ATOMIC,
              ImmKind.BARRIER)   # tuple dispatch: Enum.__call__ is hot


def unpack_imm(imm: int) -> tuple[ImmKind, int, int, int, int]:
    kind = _IMM_KINDS[imm & 0x3]
    if kind is ImmKind.FENCE_ATOMIC:
        return (kind, (imm >> 2) & 0x7, 0, (imm >> 5) & 0x3F, imm >> 11)
    return (kind, (imm >> 2) & 0x7, (imm >> 5) & 0x7FF, (imm >> 16) & 0x3F,
            imm >> 22)


@dataclass(order=True)
class _Held:
    seq: int
    imm: int = field(compare=False)
    apply: Callable[[], None] = field(compare=False)


class ControlBuffer:
    """Receiver-side guard state for one peer connection.

    ``writes_seen[slot]`` counts landed writes per expert slot (LL fence);
    ``next_seq[channel]`` tracks the next expected (unwrapped) sequence (HT
    order).  Held atomics live in per-channel min-heaps keyed by sequence.
    """

    def __init__(self, n_slots: int = 64, n_channels: int = N_CHANNELS_MAX):
        self.writes_seen = [0] * n_slots
        self.next_seq = [0] * n_channels
        self._hi_seq = [0] * n_channels        # unwrap anchor per channel
        self._arrived: dict[int, list[int]] = {}   # per-channel seq min-heaps
        self.held_seq: dict[int, list[_Held]] = {}
        self.held_fence: list[tuple[int, int, int, Callable]] = []
        self.applied_log: list[int] = []     # imm values, in application order
        self._held = 0                       # incremental count (hot path)
        self.held_peak = 0

    # ------------------------------------------------------------ events --
    def on_write(self, imm: int, apply: Callable[[], None]) -> None:
        """A data write landed (RDMA writes apply immediately)."""
        kind, ch, seq, slot, value = unpack_imm(imm)
        assert kind == ImmKind.WRITE
        apply()
        self.writes_seen[slot] += 1
        self._bump_seq(ch, self._unwrap(ch, seq))
        self.applied_log.append(imm)
        if self._held:          # guard the (common) nothing-held fast path
            self._drain(ch)
            self._drain_fences()

    def on_atomic(self, imm: int, apply: Callable[[], None]) -> None:
        kind, ch, seq, slot, value = unpack_imm(imm)
        if kind == ImmKind.FENCE_ATOMIC:
            if self.writes_seen[slot] >= value:
                apply()
                self.applied_log.append(imm)
            else:
                self.held_fence.append((slot, value, imm, apply))
                self._held += 1
                if self._held > self.held_peak:
                    self.held_peak = self._held
        elif kind == ImmKind.SEQ_ATOMIC:
            full = self._unwrap(ch, seq)
            if self.next_seq[ch] >= full:
                apply()
                self.applied_log.append(imm)
                self._bump_seq(ch, full)
                self._drain(ch)
            else:
                heapq.heappush(self.held_seq.setdefault(ch, []),
                               _Held(full, imm, apply))
                self._held += 1
                if self._held > self.held_peak:
                    self.held_peak = self._held
        else:
            apply()
            self.applied_log.append(imm)

    # ----------------------------------------------------------- helpers --
    def _unwrap(self, ch: int, wire_seq: int) -> int:
        """Reconstruct the full sequence from its SEQ_MOD-wrapped wire form,
        nearest to the highest sequence seen on this channel.  Correct while
        delivery displacement < SEQ_MOD // 4 arrivals (network guarantee)."""
        hi = self._hi_seq[ch]
        diff = ((wire_seq - hi + SEQ_MOD // 2) % SEQ_MOD) - SEQ_MOD // 2
        full = hi + diff
        assert full >= 0, (ch, wire_seq, hi)
        if full > hi:
            self._hi_seq[ch] = full
        return full

    def _bump_seq(self, ch: int, seq: int) -> None:
        # sequences are assigned consecutively per channel by the sender;
        # next_seq advances over the contiguous prefix of *applied* seqs
        # (writes may land out of order and apply immediately, so arrivals
        # are buffered in a heap until the prefix closes).
        heapq.heappush(self._arrived.setdefault(ch, []), seq)
        h = self._arrived[ch]
        while h and h[0] == self.next_seq[ch]:
            heapq.heappop(h)
            self.next_seq[ch] += 1

    def _drain(self, ch: int) -> None:
        heap = self.held_seq.get(ch)
        while heap and heap[0].seq <= self.next_seq[ch]:
            h = heapq.heappop(heap)
            h.apply()
            self._held -= 1
            self.applied_log.append(h.imm)
            self._bump_seq(ch, h.seq)
        self._drain_fences()

    def _drain_fences(self) -> None:
        if not self.held_fence:
            return
        still = []
        for slot, value, imm, apply in self.held_fence:
            if self.writes_seen[slot] >= value:
                apply()
                self._held -= 1
                self.applied_log.append(imm)
            else:
                still.append((slot, value, imm, apply))
        self.held_fence = still

    @property
    def n_held(self) -> int:
        return self._held
