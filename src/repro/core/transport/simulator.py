"""Network model: event-driven time, reliable transports, configurable order.

- "rc":  reliable, per-QP in-order delivery (ConnectX RC).
- "srd": reliable, UNORDERED delivery (AWS EFA SRD): any in-flight message
  may be delivered next (bounded by a reorder window for realism).

The network is a heap-ordered event queue (DESIGN.md §10): ``send`` computes
an arrival timestamp and schedules the message; ``step``/``run_until``
deliver events in timestamp order, advancing ``clock_us``.  Consumers (the
EP executor) interleave delivery with work — expert FFNs launch for a
receive bucket the moment its completion fence applies, while other buckets'
writes are still in flight.

Latency accounting (honest units, replacing the seed's ad-hoc
``base_latency_us * 0.01`` per-message fudge):

- each (src, dst) link serialises: a message starts transmitting when the
  link frees, takes ``(size + hdr_bytes + (n_writes - 1) * sub_hdr_bytes) /
  bw_bytes_per_us`` on the wire (``hdr_bytes`` models per-message
  header/immediate overhead, so zero-byte atomics still occupy a wire slot;
  each *additional* sub-write a coalesced message carries charges
  ``sub_hdr_bytes`` for its ``imm_vec``/``sub_off`` entry — coalescing
  amortizes the message header, not the per-write metadata),
- propagation adds ``base_latency_us`` once per message (NOT accumulated
  across messages — links are parallel),
- srd adds a seeded jitter of up to ``reorder_window`` own-size wire slots,
  so a message can be overtaken by at most ~``reorder_window`` later
  messages of its size class (the same bounded-displacement semantics the
  seed's shuffle had, now in the time domain).

Delivery is deterministic under a seed.
"""
from __future__ import annotations

import heapq
import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.transport.wire_format import (SRD_DISPLACEMENT_BOUND,
                                              ProtocolError)


@dataclass(slots=True)
class Message:
    src: int
    dst: int
    qp: int
    kind: str            # "write" | "imm" (atomic-as-immediate)
    dst_off: int
    payload: Optional[np.ndarray]
    imm: Optional[int]
    inject_t: float = 0.0
    deliver_t: float = 0.0
    size: int = 0
    # coalesced RDMA write (proxy write coalescing): one wire message
    # carrying N contiguous sub-writes.  ``imm_vec`` holds each sub-write's
    # 32-bit immediate (srd ordering emulation still sees one immediate per
    # fenced write), ``sub_off`` each sub-write's landing offset — the
    # receiver unrolls both against its guard table in one vectorized pass.
    imm_vec: Optional[np.ndarray] = None
    sub_off: Optional[np.ndarray] = None

    @property
    def n_writes(self) -> int:
        """Sub-writes this wire message carries (1 unless coalesced)."""
        return 1 if self.imm_vec is None else len(self.imm_vec)


class Timer:
    """A scheduled callback on the event clock (no wire footprint).

    Timers share the delivery heap with messages: ``step``/``deliver_ready``
    pop them in timestamp order, advance ``clock_us``, and invoke ``fn`` —
    with no receiver dispatch, no byte accounting, and no delivery hook.
    The EP step pipeline uses them to model serial *compute* segments
    (non-MoE forward/backward time) between communication events: a timer
    models "this rank's compute finishes at t", and its callback enqueues
    the next layer's commands — comm scheduled earlier keeps draining on
    the same clock underneath (comm/compute overlap, DESIGN.md §16)."""

    __slots__ = ("fn", "deliver_t")

    def __init__(self, fn: Callable[[], None], deliver_t: float = 0.0):
        self.fn = fn
        self.deliver_t = deliver_t


@dataclass
class NetConfig:
    mode: str = "srd"            # "rc" | "srd"
    reorder_window: int = 64     # srd: max messages a later one can overtake
    base_latency_us: float = 5.0
    bw_bytes_per_us: float = 25_000.0   # ~200 Gbit/s
    hdr_bytes: int = 64          # per-message wire overhead (header + imm)
    # per-sub-write metadata a coalesced message carries for each sub-write
    # beyond the first: its 4B immediate + 8B landing offset + 4B length.
    # The first sub-write's metadata rides in hdr_bytes (same as an
    # uncoalesced write), so coalescing N writes costs
    # hdr_bytes + (N-1)*sub_hdr_bytes, never less than one write's header.
    sub_hdr_bytes: int = 16
    seed: int = 0


class Network:
    """Central message switch with an event-driven clock.

    ``send`` schedules delivery at ``inject_t + serialization + latency``
    (+ bounded srd jitter); ``step`` delivers the earliest scheduled message
    to its registered receiver; ``run_until``/``flush`` drain in timestamp
    order.  Thread-safe: proxies may ``send`` from worker threads while one
    pump thread steps.
    """

    def __init__(self, cfg: NetConfig, n_ranks: int, threadsafe: bool = True):
        # seq unwrap at the receiver (semantics.ControlBuffer) tolerates
        # displacement < SEQ_MOD // 4 arrivals; the bound is derived from
        # the wire seq width, and raised (not assert-ed) so a mis-sized
        # window can't slip through under ``python -O``
        if cfg.reorder_window >= SRD_DISPLACEMENT_BOUND:
            raise ProtocolError(
                f"reorder_window {cfg.reorder_window} >= SEQ_MOD // 4 = "
                f"{SRD_DISPLACEMENT_BOUND}: receiver seq unwrap would be "
                "ambiguous")
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.n_ranks = n_ranks
        self.receivers: dict[int, Callable[[Message], None]] = {}
        self._heap: list[tuple[float, int, Message]] = []
        self._order = 0                       # FIFO tiebreak for equal times
        self._link_free: dict[tuple[int, int], float] = {}
        # lock elision for the (deterministic) single-threaded executor:
        # every send/step pays two lock ops otherwise
        self._lock = threading.Lock() if threadsafe else None
        self._srd = cfg.mode == "srd"
        self._jit = np.empty(0, np.int64)     # batched reorder-jitter draws
        self._jit_pos = 0                     # cursor into the draw buffer
        self.delivered = 0
        self.bytes_moved = 0
        self.hdr_bytes_moved = 0      # header + per-sub-write metadata bytes
        self.coalesced_msgs = 0       # delivered messages carrying >1 write
        self.coalesced_writes = 0     # sub-writes delivered inside those
        self.clock_us = 0.0
        self.on_deliver_hook: Optional[Callable[[Message], None]] = None

    def register(self, rank: int, on_deliver: Callable[[Message], None]):
        self.receivers[rank] = on_deliver

    # ------------------------------------------------------------- sending --
    def _jitter_batch(self, n: int) -> np.ndarray:
        """Next ``n`` seeded reorder draws, in draw order (a cursor into a
        replenished buffer — scalar and batched sends consume the identical
        stream, so a non-coalescing batched sender schedules bit-identically
        to a scalar one)."""
        end = self._jit_pos + n
        if end > len(self._jit):
            fresh = self.rng.integers(0, self.cfg.reorder_window + 1,
                                      size=max(4096, n))
            self._jit = np.concatenate([self._jit[self._jit_pos:], fresh])
            self._jit_pos, end = 0, n
        out = self._jit[self._jit_pos:end]
        self._jit_pos = end
        return out

    def _jitter(self) -> int:
        return int(self._jitter_batch(1)[0])

    def _schedule(self, msg: Message):
        msg.size = 0 if msg.payload is None else msg.payload.nbytes
        cfg = self.cfg
        meta = cfg.hdr_bytes + (msg.n_writes - 1) * cfg.sub_hdr_bytes
        tx = (msg.size + meta) / cfg.bw_bytes_per_us
        link = (msg.src, msg.dst)
        msg.inject_t = self.clock_us
        free = self._link_free.get(link, 0.0)
        start = free if free > msg.inject_t else msg.inject_t
        self._link_free[link] = start + tx
        arrival = start + tx + cfg.base_latency_us
        if self._srd:
            # jitter in units of this message's own wire slot: a message
            # can be overtaken by at most ~reorder_window later ones
            arrival += self._jitter() * tx
        msg.deliver_t = arrival
        self._order += 1
        heapq.heappush(self._heap, (arrival, self._order, msg))

    def send(self, msg: Message):
        if self._lock is None:
            self._schedule(msg)
        else:
            with self._lock:
                self._schedule(msg)

    def _schedule_batch(self, msgs: list) -> None:
        """Vectorized :meth:`_schedule` for a whole batch under one lock:
        per-link serialization via a grouped cumulative sum, one batched
        jitter draw, and a bulk heap extension (heapify beats N pushes once
        the batch stops being small relative to the heap)."""
        cfg = self.cfg
        n = len(msgs)
        if n < 8:          # vectorization overhead beats tiny batches
            for m in msgs:
                self._schedule(m)
            return
        clock = self.clock_us
        nr = self.n_ranks
        sz = [0] * n
        ky = [0] * n
        nw = [0] * n
        for i, m in enumerate(msgs):
            if m.payload is not None:
                sz[i] = m.payload.nbytes
            m.size = sz[i]
            m.inject_t = clock
            ky[i] = m.src * nr + m.dst
            nw[i] = m.n_writes
        sizes = np.asarray(sz, np.int64)
        key = np.asarray(ky, np.int64)
        meta = cfg.hdr_bytes + (np.asarray(nw, np.int64) - 1) \
            * cfg.sub_hdr_bytes
        tx = (sizes + meta) / cfg.bw_bytes_per_us
        order = np.argsort(key, kind="stable")
        ko, txo = key[order], tx[order]
        brk = np.empty(n, bool)
        brk[0] = True
        np.not_equal(ko[1:], ko[:-1], out=brk[1:])
        starts = np.flatnonzero(brk)
        reps = np.diff(np.append(starts, n))
        # per-link serialization: message i on a link starts when the
        # previous one finishes.  cumsum seeded with the link-free base is
        # the exact scalar recurrence (np.add.accumulate is sequential), so
        # batched scheduling is bit-identical to N _schedule calls.
        finish = np.empty(n)
        for j, s in enumerate(starts.tolist()):
            cnt = int(reps[j])
            m = msgs[int(order[s])]
            free = self._link_free.get((m.src, m.dst), 0.0)
            seg = txo[s:s + cnt].copy()
            seg[0] += free if free > self.clock_us else self.clock_us
            fin = np.cumsum(seg)
            finish[s:s + cnt] = fin
            self._link_free[(m.src, m.dst)] = float(fin[-1])
        arrival = np.empty(n)
        arrival[order] = finish + cfg.base_latency_us
        if self._srd:
            arrival += self._jitter_batch(n) * tx
        arr = arrival.tolist()          # one C conversion, not n boxings
        seq0 = self._order
        entries = [(arr[i], seq0 + 1 + i, m) for i, m in enumerate(msgs)]
        for i, m in enumerate(msgs):
            m.deliver_t = arr[i]
        self._order = seq0 + n
        heap = self._heap
        if n >= max(64, len(heap) // 4):
            heap.extend(entries)
            heapq.heapify(heap)
        else:
            for e in entries:
                heapq.heappush(heap, e)

    # ------------------------------------------------------------- timers --
    def call_at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to run when the event clock reaches ``t`` (>= now).
        Fires in timestamp order interleaved with message deliveries."""
        tm = Timer(fn, max(float(t), self.clock_us))
        lock = self._lock
        if lock is not None:
            lock.acquire()
        try:
            self._order += 1
            heapq.heappush(self._heap, (tm.deliver_t, self._order, tm))
        finally:
            if lock is not None:
                lock.release()

    def advance(self, dt: float) -> None:
        """Advance the clock by ``dt`` us of serial host/compute time (the
        *un*-overlapped baseline: nothing is delivered meanwhile — in-flight
        messages keep their timestamps and deliver on the next pump)."""
        assert dt >= 0.0
        lock = self._lock
        if lock is not None:
            lock.acquire()
        try:
            self.clock_us += dt
        finally:
            if lock is not None:
                lock.release()

    def send_batch(self, msgs: list) -> None:
        """Schedule a whole batch of messages in one lock round-trip (the
        proxy's batched-RDMA issue path)."""
        if not msgs:
            return
        if self._lock is None:
            self._schedule_batch(msgs)
        else:
            with self._lock:
                self._schedule_batch(msgs)

    # ------------------------------------------------------------ delivery --
    @property
    def pending(self) -> int:
        # worker threads send() (heap push) concurrently in threadsafe mode;
        # readers must take the same lock (len() alone is atomic in CPython,
        # but the quiesce loop pairs this with next_event_t and must not see
        # a heap mid-mutation)
        if self._lock is None:
            return len(self._heap)
        with self._lock:
            return len(self._heap)

    def next_event_t(self) -> Optional[float]:
        if self._lock is None:
            return self._heap[0][0] if self._heap else None
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def step(self) -> bool:
        """Deliver the earliest in-flight message (advances the clock).
        Returns False when nothing is in flight."""
        lock = self._lock
        if lock is not None:
            lock.acquire()
        try:
            heap = self._heap
            if not heap:
                return False
            t, _, m = heapq.heappop(heap)
            if t > self.clock_us:
                self.clock_us = t
            if isinstance(m, Message):
                self._account(m)
        finally:
            if lock is not None:
                lock.release()
        # deliver OUTSIDE the lock: receivers may trigger further sends
        if isinstance(m, Timer):
            m.fn()
            return True
        self.receivers[m.dst](m)
        if self.on_deliver_hook is not None:
            self.on_deliver_hook(m)
        return True

    def _account(self, m: Message) -> None:
        # caller holds the lock (threadsafe mode)
        cfg = self.cfg
        self.bytes_moved += m.size
        self.hdr_bytes_moved += cfg.hdr_bytes \
            + (m.n_writes - 1) * cfg.sub_hdr_bytes
        self.delivered += 1
        if m.imm_vec is not None and len(m.imm_vec) > 1:
            self.coalesced_msgs += 1
            self.coalesced_writes += len(m.imm_vec)

    @property
    def wire_bytes_moved(self) -> int:
        """Total bytes the serialization model charged: payload + headers +
        per-sub-write metadata — the honest on-the-wire figure."""
        return self.bytes_moved + self.hdr_bytes_moved

    def deliver_ready(self) -> int:
        """Deliver every event sharing the frontier timestamp in ONE lock
        round-trip (the batched half of :meth:`step`).  Returns the number
        of messages delivered (0 when nothing is in flight)."""
        lock = self._lock
        if lock is not None:
            lock.acquire()
        try:
            heap = self._heap
            if not heap:
                return 0
            t0 = heap[0][0]
            batch = []
            while heap and heap[0][0] == t0:
                batch.append(heapq.heappop(heap)[2])
            if t0 > self.clock_us:
                self.clock_us = t0
            for m in batch:
                if isinstance(m, Message):
                    self._account(m)
        finally:
            if lock is not None:
                lock.release()
        hook = self.on_deliver_hook
        for m in batch:         # deliver OUTSIDE the lock (receivers send)
            if isinstance(m, Timer):
                m.fn()
                continue
            self.receivers[m.dst](m)
            if hook is not None:
                hook(m)
        return len(batch)

    def run_until(self, t: float) -> int:
        """Deliver every message scheduled at or before ``t``."""
        n = 0
        while True:
            nxt = self.next_event_t()
            if nxt is None or nxt > t:
                return n
            self.step()
            n += 1

    def flush(self, steps: Optional[int] = None) -> int:
        """Deliver everything currently in flight (and anything scheduled by
        the deliveries themselves), in timestamp order.  ``steps`` bounds the
        number of deliveries (None = drain completely); returns how many
        messages were delivered."""
        n = 0
        while (steps is None or n < steps) and self.step():
            n += 1
        return n
