"""Network model: reliable transports with configurable ordering.

- "rc":  reliable, per-QP in-order delivery (ConnectX RC).
- "srd": reliable, UNORDERED delivery (AWS EFA SRD): any in-flight message
  may be delivered next (bounded by a reorder window for realism).

Delivery is deterministic under a seed.  Latency/bandwidth accounting gives
the benchmarks a cost model (paper Fig. 7/15 reproductions).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class Message:
    src: int
    dst: int
    qp: int
    kind: str            # "write" | "imm" (atomic-as-immediate) | "barrier"
    dst_off: int
    payload: Optional[np.ndarray]
    imm: Optional[int]
    inject_t: float = 0.0
    size: int = 0


@dataclass
class NetConfig:
    mode: str = "srd"            # "rc" | "srd"
    reorder_window: int = 64     # srd: max messages a later one can overtake
    base_latency_us: float = 5.0
    bw_bytes_per_us: float = 25_000.0   # ~200 Gbit/s
    seed: int = 0


class Network:
    """Central message switch.  ``flush`` delivers everything currently in
    flight to the registered receivers, in transport order."""

    def __init__(self, cfg: NetConfig, n_ranks: int):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.n_ranks = n_ranks
        self.queues: dict[tuple[int, int], list[Message]] = {}
        self.receivers: dict[int, Callable[[Message], None]] = {}
        self.delivered = 0
        self.bytes_moved = 0
        self.clock_us = 0.0

    def register(self, rank: int, on_deliver: Callable[[Message], None]):
        self.receivers[rank] = on_deliver

    def send(self, msg: Message):
        msg.size = 0 if msg.payload is None else msg.payload.nbytes
        msg.inject_t = self.clock_us
        self.queues.setdefault((msg.src, msg.dst), []).append(msg)

    def flush(self, steps: Optional[int] = None):
        """Deliver in-flight messages.  rc: FIFO per (src,dst,qp); srd:
        seeded shuffle within the reorder window."""
        for key in sorted(self.queues):
            q = self.queues[key]
            if not q:
                continue
            if self.cfg.mode == "rc":
                order = list(range(len(q)))
            else:
                order = self._srd_order(len(q))
            for i in order:
                m = q[i]
                self.clock_us += self.cfg.base_latency_us * 0.01 + \
                    m.size / self.cfg.bw_bytes_per_us
                self.bytes_moved += m.size
                self.delivered += 1
                self.receivers[m.dst](m)
            q.clear()

    def _srd_order(self, n: int) -> list[int]:
        w = self.cfg.reorder_window
        order = list(range(n))
        # bounded random displacement: swap each element with one up to w away
        for i in range(n - 1, 0, -1):
            j = int(self.rng.integers(max(0, i - w), i + 1))
            order[i], order[j] = order[j], order[i]
        return order
