"""Network model: event-driven time, reliable transports, configurable order.

- "rc":  reliable, per-QP in-order delivery (ConnectX RC).
- "srd": reliable, UNORDERED delivery (AWS EFA SRD): any in-flight message
  may be delivered next (bounded by a reorder window for realism).

The network is a heap-ordered event queue (DESIGN.md §10): ``send`` computes
an arrival timestamp and schedules the message; ``step``/``run_until``
deliver events in timestamp order, advancing ``clock_us``.  Consumers (the
EP executor) interleave delivery with work — expert FFNs launch for a
receive bucket the moment its completion fence applies, while other buckets'
writes are still in flight.

Latency accounting (honest units, replacing the seed's ad-hoc
``base_latency_us * 0.01`` per-message fudge):

- each (src, dst) link serialises: a message starts transmitting when the
  link frees, takes ``(size + hdr_bytes) / bw_bytes_per_us`` on the wire
  (``hdr_bytes`` models per-message header/immediate overhead, so zero-byte
  atomics still occupy a wire slot),
- propagation adds ``base_latency_us`` once per message (NOT accumulated
  across messages — links are parallel),
- srd adds a seeded jitter of up to ``reorder_window`` own-size wire slots,
  so a message can be overtaken by at most ~``reorder_window`` later
  messages of its size class (the same bounded-displacement semantics the
  seed's shuffle had, now in the time domain).

Delivery is deterministic under a seed.
"""
from __future__ import annotations

import heapq
import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class Message:
    src: int
    dst: int
    qp: int
    kind: str            # "write" | "imm" (atomic-as-immediate)
    dst_off: int
    payload: Optional[np.ndarray]
    imm: Optional[int]
    inject_t: float = 0.0
    deliver_t: float = 0.0
    size: int = 0


@dataclass
class NetConfig:
    mode: str = "srd"            # "rc" | "srd"
    reorder_window: int = 64     # srd: max messages a later one can overtake
    base_latency_us: float = 5.0
    bw_bytes_per_us: float = 25_000.0   # ~200 Gbit/s
    hdr_bytes: int = 64          # per-message wire overhead (header + imm)
    seed: int = 0


class Network:
    """Central message switch with an event-driven clock.

    ``send`` schedules delivery at ``inject_t + serialization + latency``
    (+ bounded srd jitter); ``step`` delivers the earliest scheduled message
    to its registered receiver; ``run_until``/``flush`` drain in timestamp
    order.  Thread-safe: proxies may ``send`` from worker threads while one
    pump thread steps.
    """

    def __init__(self, cfg: NetConfig, n_ranks: int, threadsafe: bool = True):
        # seq unwrap at the receiver (semantics.ControlBuffer) tolerates
        # displacement < SEQ_MOD // 4 = 512 arrivals
        assert cfg.reorder_window < 512, "reorder_window must be < 512"
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.n_ranks = n_ranks
        self.receivers: dict[int, Callable[[Message], None]] = {}
        self._heap: list[tuple[float, int, Message]] = []
        self._order = 0                       # FIFO tiebreak for equal times
        self._link_free: dict[tuple[int, int], float] = {}
        # lock elision for the (deterministic) single-threaded executor:
        # every send/step pays two lock ops otherwise
        self._lock = threading.Lock() if threadsafe else None
        self._srd = cfg.mode == "srd"
        self._jit: list[int] = []             # batched reorder-jitter draws
        self.delivered = 0
        self.bytes_moved = 0
        self.clock_us = 0.0
        self.on_deliver_hook: Optional[Callable[[Message], None]] = None

    def register(self, rank: int, on_deliver: Callable[[Message], None]):
        self.receivers[rank] = on_deliver

    # ------------------------------------------------------------- sending --
    def _jitter(self) -> int:
        if not self._jit:
            self._jit = self.rng.integers(
                0, self.cfg.reorder_window + 1, size=4096).tolist()
        return self._jit.pop()

    def _schedule(self, msg: Message):
        msg.size = 0 if msg.payload is None else msg.payload.nbytes
        cfg = self.cfg
        tx = (msg.size + cfg.hdr_bytes) / cfg.bw_bytes_per_us
        link = (msg.src, msg.dst)
        msg.inject_t = self.clock_us
        free = self._link_free.get(link, 0.0)
        start = free if free > msg.inject_t else msg.inject_t
        self._link_free[link] = start + tx
        arrival = start + tx + cfg.base_latency_us
        if self._srd:
            # jitter in units of this message's own wire slot: a message
            # can be overtaken by at most ~reorder_window later ones
            arrival += self._jitter() * tx
        msg.deliver_t = arrival
        self._order += 1
        heapq.heappush(self._heap, (arrival, self._order, msg))

    def send(self, msg: Message):
        if self._lock is None:
            self._schedule(msg)
        else:
            with self._lock:
                self._schedule(msg)

    # ------------------------------------------------------------ delivery --
    @property
    def pending(self) -> int:
        # worker threads send() (heap push) concurrently in threadsafe mode;
        # readers must take the same lock (len() alone is atomic in CPython,
        # but the quiesce loop pairs this with next_event_t and must not see
        # a heap mid-mutation)
        if self._lock is None:
            return len(self._heap)
        with self._lock:
            return len(self._heap)

    def next_event_t(self) -> Optional[float]:
        if self._lock is None:
            return self._heap[0][0] if self._heap else None
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def step(self) -> bool:
        """Deliver the earliest in-flight message (advances the clock).
        Returns False when nothing is in flight."""
        lock = self._lock
        if lock is not None:
            lock.acquire()
        try:
            heap = self._heap
            if not heap:
                return False
            t, _, m = heapq.heappop(heap)
            if t > self.clock_us:
                self.clock_us = t
            self.bytes_moved += m.size
            self.delivered += 1
        finally:
            if lock is not None:
                lock.release()
        # deliver OUTSIDE the lock: receivers may trigger further sends
        self.receivers[m.dst](m)
        if self.on_deliver_hook is not None:
            self.on_deliver_hook(m)
        return True

    def run_until(self, t: float) -> int:
        """Deliver every message scheduled at or before ``t``."""
        n = 0
        while True:
            nxt = self.next_event_t()
            if nxt is None or nxt > t:
                return n
            self.step()
            n += 1

    def flush(self, steps: Optional[int] = None) -> int:
        """Deliver everything currently in flight (and anything scheduled by
        the deliveries themselves), in timestamp order.  ``steps`` bounds the
        number of deliveries (None = drain completely); returns how many
        messages were delivered."""
        n = 0
        while (steps is None or n < steps) and self.step():
            n += 1
        return n
