"""Wire payload codec: fp8-e4m3 / int8 block quantization (DESIGN.md §14).

The transport dispatches token payloads in a *wire dtype* negotiated via
``EPSpec.wire_dtype`` / ``MoEConfig.wire_dtype``.  One row on the wire is

    [ D quantized bytes | n_blocks fp32 scales ]        (fp8 / int8)
    [ D * 4 fp32 bytes ]                                (fp32 passthrough)

with one symmetric absmax scale per :data:`repro.core.plan.WIRE_BLOCK`
features, packed inline after the payload so a single RDMA write carries
everything needed to decode — GuardTable extents and fence counts size from
:func:`repro.core.plan.wire_layout` and therefore cover the scale blocks.

This module is the repo's single quantization implementation: the
dual-dialect :func:`quantize_blocked` / :func:`dequantize_blocked` back the
numpy substrate codecs here, the jnp kernel refs in
``repro.kernels.quantize_pack``, and the int8 gradient-compression ring in
``repro.distributed.compression``.  Decode always accumulates in fp32.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.core.plan import WIRE_BLOCK, WireLayout, _is_np, wire_layout

Array = Any

FP8_MAX = 448.0      # float8_e4m3fn finite max (no inf encoding)
INT8_MAX = 127.0

# scale = absmax * (1/qmax) as an f32 multiply, NOT absmax / qmax: XLA
# strength-reduces division by a constant to a reciprocal multiply, so a
# true divide in the numpy dialect would drift from the kernels by 1 ULP.
# Both dialects multiply by the same pre-rounded f32 reciprocal.
_QINV = {"fp8": np.float32(1.0) / np.float32(FP8_MAX),
         "int8": np.float32(1.0) / np.float32(INT8_MAX)}


def _f8_dtype(xp):
    if xp is np:
        import ml_dtypes  # ships with jax; numpy has no native fp8
        return ml_dtypes.float8_e4m3fn
    import jax.numpy as jnp
    return jnp.float8_e4m3fn


def _np_f8():
    import ml_dtypes
    return ml_dtypes.float8_e4m3fn


def quantize_blocked(x: Array, wire_dtype: str = "int8",
                     block: int = WIRE_BLOCK) -> tuple[Array, Array]:
    """Symmetric per-block quantization over the last axis.

    x: (..., D) fp32 → ``(q, scales)`` with q (..., D) in the wire dtype
    (int8 or float8_e4m3fn) and scales (..., nb) fp32, nb = ceil(D/block).
    The raw scale (including an exact 0 for all-zero blocks) is stored; the
    divide guards with 1.0 so zero blocks quantize to exact zeros.  Values
    are clipped to the representable range before the cast so fp division
    rounding can never push a max-magnitude element into NaN territory.
    Dual-dialect: numpy in → numpy out, jax in → jnp out, bit-identical.
    """
    is_np = _is_np(x)
    if is_np:
        xp = np
    else:
        import jax.numpy as jnp
        xp = jnp
    x = x.astype(xp.float32)
    d = x.shape[-1]
    nb = -(-d // block)
    pad = nb * block - d
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        xb = xp.pad(x, widths)
    else:
        xb = x
    xb = xb.reshape(x.shape[:-1] + (nb, block))
    if wire_dtype not in _QINV:
        raise ValueError(f"unknown wire_dtype: {wire_dtype!r}")
    qmax = FP8_MAX if wire_dtype == "fp8" else INT8_MAX
    scale = xp.max(xp.abs(xb), axis=-1) * _QINV[wire_dtype]
    s = xp.where(scale == 0, xp.float32(1.0), scale)
    y = xp.clip(xb / s[..., None], -qmax, qmax)
    if wire_dtype == "fp8":
        # wire rounding contract: f32 -> f16 -> f8e4m3 (both RTNE).  XLA's
        # CPU lowering of the f32->f8 convert double-rounds through f16;
        # ml_dtypes casts directly and disagrees on ~0.3% of values.  Making
        # the intermediate explicit in BOTH dialects pins bit-identical
        # refs/kernels on every backend instead of chasing lowering details.
        q = y.astype(xp.float16).astype(_f8_dtype(xp))
    elif wire_dtype == "int8":
        q = xp.clip(xp.round(y), -127, 127).astype(xp.int8)
    else:
        raise ValueError(f"unknown wire_dtype: {wire_dtype!r}")
    q = q.reshape(x.shape[:-1] + (nb * block,))[..., :d]
    return q, scale.astype(xp.float32)


def dequantize_blocked(q: Array, scales: Array,
                       block: int = WIRE_BLOCK) -> Array:
    """Inverse of :func:`quantize_blocked`: (..., D) wire dtype + (..., nb)
    fp32 scales → (..., D) fp32.  Accumulation downstream is fp32 by
    contract (DESIGN.md §14) — this never returns a low-precision dtype."""
    is_np = _is_np(q) or isinstance(q, np.ndarray)
    if is_np:
        xp = np
    else:
        import jax.numpy as jnp
        xp = jnp
    d = q.shape[-1]
    nb = scales.shape[-1]
    qf = q.astype(xp.float32)
    pad = nb * block - d
    if pad:
        widths = [(0, 0)] * (q.ndim - 1) + [(0, pad)]
        qf = xp.pad(qf, widths)
    qf = qf.reshape(q.shape[:-1] + (nb, block))
    out = qf * scales[..., None].astype(xp.float32)
    return out.reshape(q.shape[:-1] + (nb * block,))[..., :d]


# ------------------------------------------------------- substrate codecs --
class WireCodec:
    """Row codec for the numpy transport substrate: fp32 rows <-> wire
    bytes.  ``encode`` packs (N, D) fp32 into (N, wire_bytes(D)) uint8 in
    the inline-scale layout; ``decode`` is its fp32 inverse."""

    name = "fp32"

    def layout(self, d: int) -> WireLayout:
        return wire_layout(d, self.name)

    def wire_bytes(self, d: int) -> int:
        return self.layout(d).token_bytes

    def encode(self, x: np.ndarray) -> np.ndarray:
        x = np.ascontiguousarray(x, np.float32)
        return x.view(np.uint8).reshape(x.shape[0], -1)

    def decode(self, buf: np.ndarray, d: int) -> np.ndarray:
        buf = np.ascontiguousarray(buf, np.uint8)
        return buf.view(np.float32).reshape(buf.shape[0], d).copy()


class _QuantCodec(WireCodec):
    def encode(self, x: np.ndarray) -> np.ndarray:
        x = np.ascontiguousarray(x, np.float32)
        n, d = x.shape
        lo = self.layout(d)
        q, scales = quantize_blocked(x, self.name)
        out = np.empty((n, lo.token_bytes), np.uint8)
        out[:, :lo.q_bytes] = q.view(np.uint8)
        out[:, lo.q_bytes:] = np.ascontiguousarray(
            scales, np.float32).view(np.uint8).reshape(n, lo.scale_bytes)
        return out

    def decode(self, buf: np.ndarray, d: int) -> np.ndarray:
        lo = self.layout(d)
        buf = np.asarray(buf, np.uint8)
        q = buf[:, :lo.q_bytes].view(self._qdtype())
        scales = np.ascontiguousarray(buf[:, lo.q_bytes:]).view(
            np.float32).reshape(buf.shape[0], lo.n_blocks)
        return dequantize_blocked(q, scales)

    def _qdtype(self):
        raise NotImplementedError


class Fp8Codec(_QuantCodec):
    name = "fp8"

    def _qdtype(self):
        return _np_f8()


class Int8Codec(_QuantCodec):
    name = "int8"

    def _qdtype(self):
        return np.int8


_CODECS = {"fp32": WireCodec(), "fp8": Fp8Codec(), "int8": Int8Codec()}
WIRE_DTYPES = tuple(_CODECS)


def get_codec(name: str) -> WireCodec:
    try:
        return _CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown wire_dtype: {name!r} (have {WIRE_DTYPES})") from None
