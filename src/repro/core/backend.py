"""Pluggable EP transport backends behind one dispatch/combine seam.

UCCL-EP's portability claim (paper §1) is that the *same* EP protocol runs
over heterogeneous transports.  This module is that seam for the repo: every
backend consumes the shared dispatch plans (:mod:`repro.core.plan`) and
implements

    ``dispatch_combine(spec, x, top_idx, top_w, expert_fn) -> DispatchResult``

where ``expert_fn`` has the standard grouped contract — it maps a stacked
row-block buffer ``(n_expert_blocks, N, D)`` to outputs of the same shape,
applying expert block i to rows i (for ``jax_collectives`` the blocks are
the calling shard's local experts; for host backends they are all
``spec.n_experts`` global experts).

Registered backends:

- ``jax_collectives``: the XLA path — capacity-bucketed ``all_to_all`` over
  the EP mesh axes, LL or HT per ``spec.mode``.  Runs inside ``shard_map``.
- ``simulated_rdma``: the transport-substrate path — numpy host execution
  over FIFO channels, CPU proxies and the ordered/unordered network model
  (:class:`repro.core.transport.ep_executor.EPWorld`).  Bit-level protocol
  reference; also the cross-check oracle for routing equivalence tests.

Future PRs add backends (ragged a2a, cross-DC hybrid, ...) by registering a
new name here; routing logic never needs re-touching (DESIGN.md §8).
"""
from __future__ import annotations

from typing import Callable, Dict, Protocol, runtime_checkable

import numpy as np

from repro.core import plan as planlib


@runtime_checkable
class EPBackend(Protocol):
    """One EP transport implementation behind the dispatch/combine seam."""

    name: str
    # True: runs on traced jax arrays inside the EP shard_map island.
    # False: host backend (concrete numpy arrays, outside jit) — the moe
    # layer routes these generically, no per-name special cases.
    jit_compatible: bool

    def dispatch_combine(self, spec, x, top_idx, top_w, expert_fn):
        """x: (T, D); top_idx/top_w: (T, K) -> DispatchResult."""
        ...


_REGISTRY: Dict[str, Callable[..., EPBackend]] = {}


def register_backend(name: str):
    """Class/factory decorator: ``@register_backend("my_transport")``."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_backend(name: str, **kwargs) -> EPBackend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown EP backend {name!r}; "
                       f"available: {available_backends()}")
    return _REGISTRY[name](**kwargs)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


# ===================================================== jax collectives ====
@register_backend("jax_collectives")
class JaxCollectivesBackend:
    """The shard_map path: one-shot LL or chunked/dedup'd HT dispatch over
    ``jax.lax`` collectives, selected by ``spec.mode``.  Must be called
    inside the EP ``shard_map`` island (it sees per-shard arrays)."""

    name = "jax_collectives"
    jit_compatible = True

    def dispatch_combine(self, spec, x, top_idx, top_w, expert_fn):
        from repro.core.ep import dispatch_combine_ht, dispatch_combine_ll
        fn = dispatch_combine_ll if spec.mode == "ll" else dispatch_combine_ht
        return fn(spec, x, top_idx, top_w, expert_fn)


# ===================================================== simulated RDMA =====
@register_backend("simulated_rdma")
class SimulatedRDMABackend:
    """Host-side reference backend over the transport substrate.

    Simulates ``spec.degree`` ranks in-process: tokens are split row-major
    across ranks, dispatched as batched TransferCmd streams through FIFO
    channels + CPU proxies over the (ordered RC / unordered SRD) network
    model, and combined with per-token weighted reduce at the source.

    Capacity is lossless (``T_local * K`` slots per (src, expert) bucket),
    so with a jax spec whose capacity factor avoids drops the two backends
    must agree exactly on the same routing table.  ``expert_fn`` must cover
    all ``spec.n_physical`` global expert slots: ``(E, N, D) -> (E, N, D)``
    (== ``spec.n_experts`` without a replicated placement; with one, row
    block p holds physical slot p = logical ``phys_to_logical[p]``).

    Replicated placements translate the logical routing table to physical
    slots per source rank (``plan.split_to_physical_world`` — the same
    deterministic round-robin split the jax path applies per shard) before
    command-stream generation, so guard tables, fence counts and
    ``ret_pos`` all size from the replicated layout with no executor
    changes.
    """

    name = "simulated_rdma"
    jit_compatible = False

    def __init__(self, net_cfg=None, n_channels: int = 8,
                 use_threads: bool = False, n_threads: int = 4,
                 columnar: bool = True, coalesce: bool = True,
                 session_layers: int = 0, session_mirror: bool = False):
        from repro.core.transport.simulator import NetConfig
        self.net_cfg = net_cfg or NetConfig(mode="srd", seed=0)
        self.n_channels = n_channels
        # threaded proxies exercise the concurrent FIFO/quiesce path (the
        # semantics conformance fuzz drives both); inline is deterministic
        self.use_threads = use_threads
        self.n_threads = n_threads
        # columnar=False runs the scalar TransferCmd drain (the conformance
        # oracle); coalesce=False disables RDMA write coalescing only
        self.columnar = columnar
        self.coalesce = coalesce
        # session_layers > 0: persistent EP session (DESIGN §16) — ONE
        # EPWorld per spec shape kept across dispatch_combine calls, guard
        # tables/buckets/proxies registered once; call l mod session_layers
        # routes to layer slot l, and the wrap to slot 0 begins a new step
        self.session_layers = session_layers
        self.session_mirror = session_mirror
        self._sessions: dict = {}
        self._layer_cursor = 0
        self.last_world = None      # exposed for stats/introspection

    def begin_step(self):
        """Realign the layer cursor (the next dispatch_combine is layer 0
        of a fresh step).  Safe to call with no session configured."""
        self._layer_cursor = 0

    def dispatch_combine(self, spec, x, top_idx, top_w, expert_fn):
        from repro.core.ep import DispatchResult
        from repro.core.transport.ep_executor import EPWorld

        x = np.asarray(x, np.float32)
        top_idx = np.asarray(top_idx)
        top_w = np.asarray(top_w, np.float32)
        T, D = x.shape
        K = top_idx.shape[1]
        R = spec.degree
        assert T % R == 0, f"token count {T} not divisible by EP degree {R}"
        Tl = T // R

        def global_expert_fn(toks, counts=None):
            out = planlib.call_expert_fn(expert_fn, toks, counts)
            return np.asarray(out, np.float32)

        # replicated placement: translate logical->physical per source rank
        # (numpy dialect of the same deterministic split the jax path runs)
        pl_obj = None
        p_tab = getattr(spec, "placement", None)
        if p_tab is not None:
            pl_obj = planlib.placement_from_table(np.asarray(p_tab, np.int32))
            if pl_obj.is_identity:
                pl_obj = None
        E_phys = len(p_tab) if p_tab is not None else spec.n_experts

        wire_dtype = getattr(spec, "wire_dtype", "fp32")
        layer = 0
        if self.session_layers > 0:
            # persistent session: one world per spec shape, reused across
            # layers and steps; the cursor assigns layer slots in call
            # order (the model calls its MoE layers in a fixed sequence)
            skey = (spec.mode, R, E_phys, K, D, Tl, spec.chunks, wire_dtype)
            world = self._sessions.get(skey)
            if world is None:
                world = EPWorld(n_ranks=R, n_experts=E_phys, top_k=K, d=D,
                                capacity=Tl * K, net_cfg=self.net_cfg,
                                n_channels=self.n_channels,
                                columnar=self.columnar,
                                coalesce=self.coalesce,
                                wire_dtype=wire_dtype, session=True,
                                n_layers=self.session_layers,
                                mirror=self.session_mirror)
                self._sessions[skey] = world
            layer = self._layer_cursor % self.session_layers
            self._layer_cursor += 1
            if layer == 0:
                world.begin_step()
        else:
            world = EPWorld(n_ranks=R, n_experts=E_phys, top_k=K, d=D,
                            capacity=Tl * K, net_cfg=self.net_cfg,
                            n_channels=self.n_channels,
                            use_threads=self.use_threads,
                            n_threads=self.n_threads,
                            columnar=self.columnar, coalesce=self.coalesce,
                            wire_dtype=wire_dtype)
        xs = x.reshape(R, Tl, D)
        tis = top_idx.reshape(R, Tl, K)
        tws = top_w.reshape(R, Tl, K)
        if pl_obj is not None:
            tis = planlib.split_to_physical_world(pl_obj, tis)
        if spec.mode == "ht":
            # HT: chunked dedup'd dispatch + hierarchical reduce, executed
            # literally on the substrate; capacity Tl per (src, dst) bucket
            # is lossless (a token crosses each rank boundary at most once)
            out = world.run_ht(xs, tis, tws, expert_fn=global_expert_fn,
                               n_chunks=spec.chunks, capacity=Tl,
                               layer=layer)
        else:
            out = world.run(xs, tis, tws, expert_fn=global_expert_fn,
                            layer=layer)
        self.last_world = world
        flat = np.asarray(tis).reshape(-1)
        load_phys = planlib.group_counts(flat, E_phys, flat >= 0)
        return DispatchResult(
            out.reshape(T, D),
            {"dropped": np.float32(0.0), "load_phys": load_phys,
             "imbalance": np.float32(planlib.load_imbalance(load_phys))})
