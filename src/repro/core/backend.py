"""Pluggable EP transport backends behind one dispatch/combine seam.

UCCL-EP's portability claim (paper §1) is that the *same* EP protocol runs
over heterogeneous transports.  This module is that seam for the repo: every
backend consumes the shared dispatch plans (:mod:`repro.core.plan`) and
implements

    ``dispatch_combine(spec, x, top_idx, top_w, expert_fn) -> DispatchResult``

where ``expert_fn`` has the standard grouped contract — it maps a stacked
row-block buffer ``(n_expert_blocks, N, D)`` to outputs of the same shape,
applying expert block i to rows i (for ``jax_collectives`` the blocks are
the calling shard's local experts; for host backends they are all
``spec.n_experts`` global experts).

Registered backends:

- ``jax_collectives``: the XLA path — capacity-bucketed ``all_to_all`` over
  the EP mesh axes, LL or HT per ``spec.mode``.  Runs inside ``shard_map``.
- ``simulated_rdma``: the transport-substrate path — numpy host execution
  over FIFO channels, CPU proxies and the ordered/unordered network model
  (:class:`repro.core.transport.ep_executor.EPWorld`).  Bit-level protocol
  reference; also the cross-check oracle for routing equivalence tests.

Future PRs add backends (ragged a2a, cross-DC hybrid, ...) by registering a
new name here; routing logic never needs re-touching (DESIGN.md §8).
"""
from __future__ import annotations

from typing import Callable, Dict, Protocol, runtime_checkable

import numpy as np

from repro.core import plan as planlib


@runtime_checkable
class EPBackend(Protocol):
    """One EP transport implementation behind the dispatch/combine seam."""

    name: str
    # True: runs on traced jax arrays inside the EP shard_map island.
    # False: host backend (concrete numpy arrays, outside jit) — the moe
    # layer routes these generically, no per-name special cases.
    jit_compatible: bool

    def dispatch_combine(self, spec, x, top_idx, top_w, expert_fn):
        """x: (T, D); top_idx/top_w: (T, K) -> DispatchResult."""
        ...


_REGISTRY: Dict[str, Callable[..., EPBackend]] = {}


def register_backend(name: str):
    """Class/factory decorator: ``@register_backend("my_transport")``."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_backend(name: str, **kwargs) -> EPBackend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown EP backend {name!r}; "
                       f"available: {available_backends()}")
    return _REGISTRY[name](**kwargs)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


# ===================================================== jax collectives ====
@register_backend("jax_collectives")
class JaxCollectivesBackend:
    """The shard_map path: one-shot LL or chunked/dedup'd HT dispatch over
    ``jax.lax`` collectives, selected by ``spec.mode``.  Must be called
    inside the EP ``shard_map`` island (it sees per-shard arrays)."""

    name = "jax_collectives"
    jit_compatible = True

    def dispatch_combine(self, spec, x, top_idx, top_w, expert_fn):
        from repro.core.ep import dispatch_combine_ht, dispatch_combine_ll
        fn = dispatch_combine_ll if spec.mode == "ll" else dispatch_combine_ht
        return fn(spec, x, top_idx, top_w, expert_fn)


# ===================================================== simulated RDMA =====
@register_backend("simulated_rdma")
class SimulatedRDMABackend:
    """Host-side reference backend over the transport substrate.

    Simulates ``spec.degree`` ranks in-process: tokens are split row-major
    across ranks, dispatched as batched TransferCmd streams through FIFO
    channels + CPU proxies over the (ordered RC / unordered SRD) network
    model, and combined with per-token weighted reduce at the source.

    Capacity is lossless (``T_local * K`` slots per (src, expert) bucket),
    so with a jax spec whose capacity factor avoids drops the two backends
    must agree exactly on the same routing table.  ``expert_fn`` must cover
    all ``spec.n_physical`` global expert slots: ``(E, N, D) -> (E, N, D)``
    (== ``spec.n_experts`` without a replicated placement; with one, row
    block p holds physical slot p = logical ``phys_to_logical[p]``).

    Replicated placements translate the logical routing table to physical
    slots per source rank (``plan.split_to_physical_world`` — the same
    deterministic round-robin split the jax path applies per shard) before
    command-stream generation, so guard tables, fence counts and
    ``ret_pos`` all size from the replicated layout with no executor
    changes.
    """

    name = "simulated_rdma"
    jit_compatible = False

    def __init__(self, net_cfg=None, n_channels: int = 8,
                 use_threads: bool = False, n_threads: int = 4,
                 columnar: bool = True, coalesce: bool = True,
                 session_layers: int = 0, session_mirror: bool = False):
        from repro.core.transport.simulator import NetConfig
        self.net_cfg = net_cfg or NetConfig(mode="srd", seed=0)
        self.n_channels = n_channels
        # threaded proxies exercise the concurrent FIFO/quiesce path (the
        # semantics conformance fuzz drives both); inline is deterministic
        self.use_threads = use_threads
        self.n_threads = n_threads
        # columnar=False runs the scalar TransferCmd drain (the conformance
        # oracle); coalesce=False disables RDMA write coalescing only
        self.columnar = columnar
        self.coalesce = coalesce
        # session_layers > 0: persistent EP session (DESIGN §16) — ONE
        # EPWorld per spec shape kept across dispatch_combine calls, guard
        # tables/buckets/proxies registered once; call l mod session_layers
        # routes to layer slot l, and the wrap to slot 0 begins a new step
        self.session_layers = session_layers
        self.session_mirror = session_mirror
        self._sessions: dict = {}
        self._layer_cursor = 0
        self.last_world = None      # exposed for stats/introspection

    def begin_step(self):
        """Realign the layer cursor (the next dispatch_combine is layer 0
        of a fresh step).  Safe to call with no session configured."""
        self._layer_cursor = 0

    def dispatch_combine(self, spec, x, top_idx, top_w, expert_fn):
        from repro.core.ep import DispatchResult
        from repro.core.transport.ep_executor import EPWorld

        x = np.asarray(x, np.float32)
        top_idx = np.asarray(top_idx)
        top_w = np.asarray(top_w, np.float32)
        T, D = x.shape
        K = top_idx.shape[1]
        R = spec.degree
        assert T % R == 0, f"token count {T} not divisible by EP degree {R}"
        Tl = T // R

        def global_expert_fn(toks, counts=None):
            out = planlib.call_expert_fn(expert_fn, toks, counts)
            return np.asarray(out, np.float32)

        # replicated placement: translate logical->physical per source rank
        # (numpy dialect of the same deterministic split the jax path runs)
        pl_obj = None
        p_tab = getattr(spec, "placement", None)
        if p_tab is not None:
            pl_obj = planlib.placement_from_table(np.asarray(p_tab, np.int32))
            if pl_obj.is_identity:
                pl_obj = None
        E_phys = len(p_tab) if p_tab is not None else spec.n_experts

        wire_dtype = getattr(spec, "wire_dtype", "fp32")
        layer = 0
        if self.session_layers > 0:
            # persistent session: one world per spec shape, reused across
            # layers and steps; the cursor assigns layer slots in call
            # order (the model calls its MoE layers in a fixed sequence)
            skey = (spec.mode, R, E_phys, K, D, Tl, spec.chunks, wire_dtype)
            world = self._sessions.get(skey)
            if world is None:
                world = EPWorld(n_ranks=R, n_experts=E_phys, top_k=K, d=D,
                                capacity=Tl * K, net_cfg=self.net_cfg,
                                n_channels=self.n_channels,
                                columnar=self.columnar,
                                coalesce=self.coalesce,
                                wire_dtype=wire_dtype, session=True,
                                n_layers=self.session_layers,
                                mirror=self.session_mirror)
                self._sessions[skey] = world
            layer = self._layer_cursor % self.session_layers
            self._layer_cursor += 1
            if layer == 0:
                world.begin_step()
        else:
            world = EPWorld(n_ranks=R, n_experts=E_phys, top_k=K, d=D,
                            capacity=Tl * K, net_cfg=self.net_cfg,
                            n_channels=self.n_channels,
                            use_threads=self.use_threads,
                            n_threads=self.n_threads,
                            columnar=self.columnar, coalesce=self.coalesce,
                            wire_dtype=wire_dtype)
        xs = x.reshape(R, Tl, D)
        tis = top_idx.reshape(R, Tl, K)
        tws = top_w.reshape(R, Tl, K)
        if pl_obj is not None:
            tis = planlib.split_to_physical_world(pl_obj, tis)
        if spec.mode == "ht":
            # HT: chunked dedup'd dispatch + hierarchical reduce, executed
            # literally on the substrate; capacity Tl per (src, dst) bucket
            # is lossless (a token crosses each rank boundary at most once)
            out = world.run_ht(xs, tis, tws, expert_fn=global_expert_fn,
                               n_chunks=spec.chunks, capacity=Tl,
                               layer=layer)
        else:
            out = world.run(xs, tis, tws, expert_fn=global_expert_fn,
                            layer=layer)
        self.last_world = world
        flat = np.asarray(tis).reshape(-1)
        load_phys = planlib.group_counts(flat, E_phys, flat >= 0)
        return DispatchResult(
            out.reshape(T, D),
            {"dropped": np.float32(0.0), "load_phys": load_phys,
             "imbalance": np.float32(planlib.load_imbalance(load_phys))})

    # per-step counters aggregated by dispatch_step (exact-gated rows)
    _STEP_COUNTERS = ("drains_per_step", "cmds_per_step",
                      "dispatch_payload_bytes", "dispatch_wire_bytes",
                      "dispatch_msgs")
    # ibv_reg_mr page-pin cost, us per 4 KiB page — the per-call memory
    # registration a persistent session pays once instead of every call
    _PIN_US_PER_PAGE = 0.3

    def _rendezvous_us(self, R: int, ctrl_bytes: int) -> float:
        """Event-clock cost of the control-plane rendezvous a NON-session
        dispatch must run before payload flies: every receiver advertises
        its bucket layout (base addr + rkey + capacity per local expert,
        ``ctrl_bytes``) to every sender, then an ack barrier confirms all
        sides saw it.  Simulated with real control messages on a scratch
        :class:`Network` under the backend's own ``NetConfig`` (same
        latency/bandwidth/jitter model as the payload path), so the number
        scales with fabric parameters instead of being a magic constant.
        Persistent sessions run this ONCE at open (DESIGN §16/§18)."""
        key = (R, ctrl_bytes)
        cache = getattr(self, "_rdv_cache", None)
        if cache is None:
            cache = self._rdv_cache = {}
        v = cache.get(key)
        if v is not None:
            return v
        from repro.core.transport.simulator import Message, Network
        net = Network(self.net_cfg, R, threadsafe=False)
        for r in range(R):
            net.register(r, lambda m: None)
        for phase_bytes in (ctrl_bytes, 8):      # advertise, then ack
            net.send_batch([
                Message(src=r, dst=s, qp=0, kind="write", dst_off=0,
                        payload=np.zeros(phase_bytes, np.uint8), imm=None)
                for r in range(R) for s in range(R) if s != r])
            while net.pending:
                net.deliver_ready()
        cache[key] = net.clock_us
        return net.clock_us

    def _setup_us(self, world) -> float:
        """Per-call session-open cost for ``world``'s geometry: pin+register
        the receive buckets and return region (page-granular, all ranks in
        parallel), then the advertisement rendezvous."""
        reg_bytes = (world.n_experts * world.capacity * world.tok_bytes
                     + world.capacity * world.top_k * world.d * 4)
        reg_us = -(-reg_bytes // 4096) * self._PIN_US_PER_PAGE
        ctrl = 64 + (world.n_experts // world.n_ranks) * 24
        return reg_us + self._rendezvous_us(world.n_ranks, ctrl)

    def dispatch_step(self, spec, xs, tis, tws, wg, wu, wd, *,
                      nonmoe_fwd_us: float = 0.0, mode: str = "pipelined"):
        """One full model step for a serving microbatch: ``L`` MoE layers
        worth of dispatch+combine on the event clock, with a non-MoE
        (attention/norm) compute segment of ``nonmoe_fwd_us`` ahead of each
        layer.  ``xs/tis/tws`` are length-``session_layers`` lists of
        ``(T, D)`` / ``(T, K)`` arrays (``top_idx < 0`` rows are padding and
        move no traffic); ``wg/wu/wd`` are the shared per-expert FFN weights.

        ``mode`` selects the step driver — the serving A/B switch:

        - ``"pipelined"`` — persistent session, all layers' command streams
          prepared up front, rank-local cross-layer overlap, ONE quiesce
          drain per step (``EPWorld.run_step_pipelined``);
        - ``"serial"`` — same persistent session, layer-serialized drains
          (isolates the cross-layer contribution);
        - ``"per_layer"`` — the naive comparator: a FRESH non-session world
          per layer (registration, guard tables and buckets rebuilt each
          call), clocks summed across layers.  Per-expert overlap stays ON
          inside every layer in all three modes.

        Returns ``(outs, elapsed_us, stats)``: per-layer ``(T, D)`` outputs,
        the step's event-clock span (including the L non-MoE segments), and
        the aggregated per-step transport counters.
        """
        from repro.core.transport.ep_executor import EPWorld

        assert spec.mode == "ll", "serving decode dispatch is LL-mode"
        assert getattr(spec, "placement", None) is None, \
            "dispatch_step takes pre-translated physical routing tables"
        assert mode in ("pipelined", "serial", "per_layer"), mode
        L = len(xs)
        assert len(tis) == L and len(tws) == L and L > 0
        x0 = np.asarray(xs[0], np.float32)
        T, D = x0.shape
        R = spec.degree
        assert T % R == 0, f"token count {T} not divisible by EP degree {R}"
        Tl = T // R
        K = np.asarray(tis[0]).shape[1]
        E_phys = spec.n_experts
        wire_dtype = getattr(spec, "wire_dtype", "fp32")
        xs_r = [np.asarray(x, np.float32).reshape(R, Tl, D) for x in xs]
        tis_r = [np.asarray(t).reshape(R, Tl, K) for t in tis]
        tws_r = [np.asarray(w, np.float32).reshape(R, Tl, K) for w in tws]

        if mode == "per_layer":
            outs, elapsed = [], 0.0
            stats = dict.fromkeys(self._STEP_COUNTERS, 0)
            for l in range(L):
                world = EPWorld(n_ranks=R, n_experts=E_phys, top_k=K, d=D,
                                capacity=Tl * K, net_cfg=self.net_cfg,
                                n_channels=self.n_channels,
                                columnar=self.columnar,
                                coalesce=self.coalesce,
                                wire_dtype=wire_dtype)
                t0 = world.net.clock_us
                world.net.advance(nonmoe_fwd_us)
                # non-persistent dispatch: registration + rendezvous per
                # call (what the session amortizes to once at open)
                world.net.advance(self._setup_us(world))
                out = world.run(xs_r[l], tis_r[l], tws_r[l], wg, wu, wd)
                elapsed += world.net.clock_us - t0
                for k in self._STEP_COUNTERS:
                    stats[k] += int(world.timeline.get(k, 0))
                outs.append(out.reshape(T, D))
                self.last_world = world
            return outs, elapsed, stats

        assert self.session_layers == L, \
            f"backend session_layers={self.session_layers} != {L} layers"
        skey = (spec.mode, R, E_phys, K, D, Tl, spec.chunks, wire_dtype)
        world = self._sessions.get(skey)
        opened = world is None
        if opened:
            world = EPWorld(n_ranks=R, n_experts=E_phys, top_k=K, d=D,
                            capacity=Tl * K, net_cfg=self.net_cfg,
                            n_channels=self.n_channels,
                            columnar=self.columnar, coalesce=self.coalesce,
                            wire_dtype=wire_dtype, session=True,
                            n_layers=L, mirror=self.session_mirror)
            self._sessions[skey] = world
        world.begin_step()
        t0 = world.net.clock_us
        if opened:
            # session open: registration + rendezvous ONCE, charged to the
            # first step (the naive path re-pays it every layer, every step)
            world.net.advance(self._setup_us(world))
        world.net.advance(nonmoe_fwd_us)     # leading non-MoE segment
        runner = (world.run_step_pipelined if mode == "pipelined"
                  else world.run_step_serial)
        outs = runner(xs_r, tis_r, tws_r, wg, wu, wd,
                      nonmoe_fwd_us=nonmoe_fwd_us)
        elapsed = world.net.clock_us - t0
        assert not world.net.pending, "step ended with traffic in flight"
        stats = {k: int(world.timeline.get(k, 0))
                 for k in self._STEP_COUNTERS}
        self.last_world = world
        self._layer_cursor = 0
        return [o.reshape(T, D) for o in outs], elapsed, stats
