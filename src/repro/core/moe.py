"""MoE FFN layer: router + UCCL-EP dispatch/combine + grouped expert SwiGLU
(+ optional always-on shared experts which bypass dispatch, qwen2-moe style).

The expert-parallel path runs inside one ``shard_map`` island over the full
mesh; without a mesh (CPU smoke tests) it falls back to the dense oracle.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import repro.compat  # noqa: F401  jax version shims (jax.shard_map)
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, _round_up
from repro.core import plan as planlib
from repro.core.backend import get_backend
from repro.core.ep import EPSpec, moe_ref
from repro.core.routing import RouterParams, route, router_init
from repro.distributed.sharding import DistCtx
from repro.kernels import ops as kops
from repro.models.layers import MLPParams, mlp_init, swiglu

Array = jax.Array


def padded_experts_static(cfg: ModelConfig) -> int:
    """Mesh-independent padded expert count (divisible by EP16 and, when the
    model has >=32 experts, by EP32) so checkpoints are mesh-portable."""
    e = cfg.moe.n_experts
    return _round_up(e, 32) if e >= 32 else _round_up(e, 16)


def moe_init(cfg: ModelConfig, key: Array) -> dict:
    m = cfg.moe
    e_pad = padded_experts_static(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, f = cfg.d_model, m.d_expert
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    r = router_init(d, e_pad, k1, m.router_aux_free_bias)
    out = {
        "router_w": r.w,
        "w_gate": jax.random.normal(k2, (e_pad, d, f), jnp.float32) * s,
        "w_up": jax.random.normal(k3, (e_pad, d, f), jnp.float32) * s,
        "w_down": jax.random.normal(k4, (e_pad, f, d), jnp.float32) * so,
    }
    if r.bias is not None:
        out["router_b"] = r.bias
    if m.d_shared:
        out["shared"] = dict(mlp_init(d, m.d_shared, k5)._asdict())
    return out


def _expert_fn(wg, wu, wd):
    """Occupancy-carrying expert_fn: ``fn(tokens, counts)`` applies the
    grouped SwiGLU skipping rows beyond each bucket's occupied count, and
    ``fn.fused`` is the fully fused gather->FFN->scatter hot path the HT
    local compute uses (no (E, C, D) buffer materialization).

    EP dispatch buffers pad with exact zeros (scratch-row gathers), and
    swiglu(0) == 0 — ``zero_padded=True`` lets the jnp "ref" path skip the
    (pure-overhead) occupancy mask while the kernel paths use counts to
    skip the padding's MXU flops (the whole point of the contract)."""
    def fn(tokens, counts=None):  # (E_local, C, D)
        return kops.grouped_swiglu(tokens, wg, wu, wd, counts,
                                   zero_padded=True)

    def fused(x_ext, src_of_slot, w_slot, counts=None):
        return kops.gather_swiglu_scatter(x_ext, src_of_slot, w_slot,
                                          wg, wu, wd, counts,
                                          zero_padded=True)
    fn.fused = fused
    return fn


def make_ep_spec(cfg: ModelConfig, dist: DistCtx, *, mode: str,
                 chunks: int = 1, dtype=jnp.bfloat16) -> EPSpec:
    sizes = tuple(dist.mesh.shape[a] for a in dist.ep_axes)
    cf = (cfg.moe.ll_capacity_factor if mode == "ll"
          else cfg.moe.capacity_factor)
    return EPSpec(axes=tuple(dist.ep_axes), sizes=sizes,
                  n_experts=padded_experts_static(cfg), top_k=cfg.moe.top_k,
                  capacity_factor=cf, chunks=chunks, dtype=dtype,
                  mode=("ll" if mode == "ll" else "ht"),
                  wire_dtype=getattr(cfg.moe, "wire_dtype", "fp32"))


def moe_apply(cfg: ModelConfig, dist: Optional[DistCtx], p: dict, x: Array,
              *, mode: str = "ht", chunks: int = 1,
              backend=None) -> tuple[Array, dict]:
    """x: (B, S, D) -> (y, aux).  mode: "ht" | "ll" | "ref".

    ``backend`` (default ``cfg.moe.ep_backend``) selects the EP transport
    from the :mod:`repro.core.backend` registry — a registered name, or an
    :class:`~repro.core.backend.EPBackend` *instance* (the persistent-
    session path: a model passes ONE backend object to all its MoE layers
    so guard tables/buckets/proxies register once per step, DESIGN §16).
    ``simulated_rdma`` is a host-side reference path (numpy over the
    transport substrate) — valid outside ``jit`` only, for protocol
    cross-checks and debugging.
    """
    B, S, D = x.shape
    mcfg = cfg.moe
    e_pad = p["w_gate"].shape[0]
    rparams = RouterParams(w=p["router_w"], bias=p.get("router_b"))
    # fail loud on unknown names (get_backend raises), never fall back
    be = backend if backend is not None else mcfg.ep_backend
    ep_be = get_backend(be) if isinstance(be, str) else be

    if not ep_be.jit_compatible and mode != "ref":
        y, aux = _moe_host_sim(cfg, dist, rparams, p, x, mode, ep_be)
    elif dist is None or not dist.ep_axes or mode == "ref":
        t = x.reshape(-1, D)
        rout = route(mcfg, rparams, t, mcfg.n_experts)
        y = moe_ref(t, rout.top_idx, rout.top_w, p["w_gate"], p["w_up"],
                    p["w_down"])
        load = planlib.expert_load(rout.top_idx, e_pad)
        # imbalance over the REAL experts only: padded slots never receive
        # tokens and would dilute the mean (4 real in 16 padded -> 4x)
        aux = {"aux_loss": rout.aux_loss, "dropped": jnp.float32(0.0),
               "load": load,
               "imbalance": planlib.load_imbalance(load[:mcfg.n_experts])}
        y = y.reshape(B, S, D)
    else:
        y, aux = _moe_dist(cfg, dist, rparams, p, x, mode, chunks, ep_be)

    if mcfg.d_shared and "shared" in p:
        sh = MLPParams(**{k: p["shared"][k] for k in ("w_gate", "w_up", "w_down")})
        y = y + swiglu(sh, x)
    return y, aux


def _moe_host_sim(cfg: ModelConfig, dist: Optional[DistCtx],
                  rparams: RouterParams, p: dict, x: Array,
                  mode: str, ep_be) -> tuple[Array, dict]:
    """Host-backend path: run the MoE layer's dispatch/combine on concrete
    numpy arrays (e.g. the simulated-RDMA substrate; outside jit only)."""
    import numpy as np

    from repro.core.transport.ep_executor import np_grouped_swiglu

    B, S, D = x.shape
    mcfg = cfg.moe
    t = x.reshape(-1, D)
    rout = route(mcfg, rparams, t, mcfg.n_experts)
    e_pad = p["w_gate"].shape[0]
    if dist is not None and dist.ep_axes:
        spec = make_ep_spec(cfg, dist, mode=mode, dtype=x.dtype)
    else:
        degree = max(d for d in (1, 2, 4) if (B * S) % d == 0
                     and e_pad % d == 0)
        spec = EPSpec(axes=("sim",), sizes=(degree,), n_experts=e_pad,
                      top_k=mcfg.top_k, mode=mode,
                      wire_dtype=getattr(mcfg, "wire_dtype", "fp32"))
    wg, wu, wd = (np.asarray(p[k], np.float32)
                  for k in ("w_gate", "w_up", "w_down"))
    res = ep_be.dispatch_combine(
        spec, np.asarray(t, np.float32), np.asarray(rout.top_idx),
        np.asarray(rout.top_w, np.float32),
        lambda toks, counts=None: np_grouped_swiglu(toks, wg, wu, wd,
                                                    counts=counts))
    load = planlib.expert_load(rout.top_idx, e_pad)
    # with a replicated placement the backend's *physical*-slot stat is the
    # truth; without one, report over the real (unpadded) logical experts
    if getattr(spec, "placement", None) is not None:
        imb = jnp.float32(res.aux["imbalance"])
    else:
        imb = planlib.load_imbalance(load[:mcfg.n_experts])
    aux = {"aux_loss": rout.aux_loss,
           "dropped": jnp.float32(res.aux["dropped"]),
           "load": load, "imbalance": imb}
    return jnp.asarray(res.out, x.dtype).reshape(B, S, D), aux


def _moe_dist(cfg: ModelConfig, dist: DistCtx, rparams: RouterParams, p: dict,
              x: Array, mode: str, chunks: int, ep_backend) -> tuple[Array,
                                                                     dict]:
    mesh = dist.mesh
    all_axes = tuple(mesh.axis_names)
    mcfg = cfg.moe
    spec = make_ep_spec(cfg, dist, mode=mode, chunks=chunks, dtype=x.dtype)
    eps = spec.experts_per_shard
    nshards = math.prod(mesh.shape[a] for a in all_axes)

    from repro.distributed.sharding import effective_batch_axes
    Bg, Sg, _ = x.shape
    bd = effective_batch_axes(dist, Bg)
    sq = (dist.seq_axis if (Sg > 1 and dist.seq_axis
                            and Sg % mesh.shape[dist.seq_axis] == 0) else None)
    ep_spec_p = tuple(dist.ep_axes) if len(dist.ep_axes) > 1 else dist.ep_axes[0]
    x_spec = P(bd, sq, None)

    def island(x_l, rw, rb, wg, wu, wd):
        Bl, Sl, D = x_l.shape
        t = x_l.reshape(-1, D)
        rout = route(mcfg, RouterParams(rw, rb), t, mcfg.n_experts)
        fn = _expert_fn(wg, wu, wd)
        res = ep_backend.dispatch_combine(spec, t, rout.top_idx, rout.top_w,
                                          fn)
        y = res.out.reshape(Bl, Sl, D)
        denom = jnp.float32(nshards)
        # global load via the shared helper (one definition for all three
        # moe branches); imbalance is max/mean physical-slot load — with
        # the identity placement the logical counts ARE the physical ones
        load_g = jax.lax.psum(
            planlib.expert_load(rout.top_idx, spec.n_experts), all_axes)
        aux = {
            "aux_loss": jax.lax.psum(rout.aux_loss, all_axes) / denom,
            "dropped": jax.lax.psum(res.aux["dropped"], all_axes) / denom,
            "occupancy": jax.lax.psum(
                jnp.float32(res.aux.get("occupancy", 0.0)), all_axes) / denom,
            "load": load_g,
            "imbalance": planlib.load_imbalance(load_g[:mcfg.n_experts]),
        }
        return y, aux

    rb = rparams.bias
    if rb is None:
        rb = jnp.zeros((spec.n_experts,), jnp.float32)
    out_specs = (x_spec, {"aux_loss": P(), "dropped": P(), "occupancy": P(),
                          "load": P(), "imbalance": P()})
    y, aux = jax.shard_map(
        island, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None),
                  P(ep_spec_p, None, None), P(ep_spec_p, None, None),
                  P(ep_spec_p, None, None)),
        out_specs=out_specs, check_vma=False,
    )(x, rparams.w, rb, p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
