"""MoE FFN layer: router + UCCL-EP dispatch/combine + grouped expert SwiGLU
(+ optional always-on shared experts which bypass dispatch, qwen2-moe style).

The expert-parallel path runs inside one ``shard_map`` island over the full
mesh; without a mesh (CPU smoke tests) it falls back to the dense oracle.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, _round_up
from repro.core import ep as ep_mod
from repro.core.ep import EPSpec, dispatch_combine_ht, dispatch_combine_ll, moe_ref
from repro.core.routing import RouterParams, route, router_init
from repro.distributed.sharding import DistCtx
from repro.kernels import ops as kops
from repro.models.layers import MLPParams, mlp_init, swiglu

Array = jax.Array


def padded_experts_static(cfg: ModelConfig) -> int:
    """Mesh-independent padded expert count (divisible by EP16 and, when the
    model has >=32 experts, by EP32) so checkpoints are mesh-portable."""
    e = cfg.moe.n_experts
    return _round_up(e, 32) if e >= 32 else _round_up(e, 16)


def moe_init(cfg: ModelConfig, key: Array) -> dict:
    m = cfg.moe
    e_pad = padded_experts_static(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, f = cfg.d_model, m.d_expert
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    r = router_init(d, e_pad, k1, m.router_aux_free_bias)
    out = {
        "router_w": r.w,
        "w_gate": jax.random.normal(k2, (e_pad, d, f), jnp.float32) * s,
        "w_up": jax.random.normal(k3, (e_pad, d, f), jnp.float32) * s,
        "w_down": jax.random.normal(k4, (e_pad, f, d), jnp.float32) * so,
    }
    if r.bias is not None:
        out["router_b"] = r.bias
    if m.d_shared:
        out["shared"] = dict(mlp_init(d, m.d_shared, k5)._asdict())
    return out


def _expert_fn(wg, wu, wd):
    def fn(tokens):  # (E_local, C, D)
        return kops.grouped_swiglu(tokens, wg, wu, wd)
    return fn


def make_ep_spec(cfg: ModelConfig, dist: DistCtx, *, mode: str,
                 chunks: int = 1, dtype=jnp.bfloat16) -> EPSpec:
    sizes = tuple(dist.mesh.shape[a] for a in dist.ep_axes)
    cf = (cfg.moe.ll_capacity_factor if mode == "ll"
          else cfg.moe.capacity_factor)
    return EPSpec(axes=tuple(dist.ep_axes), sizes=sizes,
                  n_experts=padded_experts_static(cfg), top_k=cfg.moe.top_k,
                  capacity_factor=cf, chunks=chunks, dtype=dtype)


def moe_apply(cfg: ModelConfig, dist: Optional[DistCtx], p: dict, x: Array,
              *, mode: str = "ht", chunks: int = 1) -> tuple[Array, dict]:
    """x: (B, S, D) -> (y, aux).  mode: "ht" | "ll" | "ref"."""
    B, S, D = x.shape
    mcfg = cfg.moe
    e_pad = p["w_gate"].shape[0]
    rparams = RouterParams(w=p["router_w"], bias=p.get("router_b"))

    if dist is None or not dist.ep_axes or mode == "ref":
        t = x.reshape(-1, D)
        rout = route(mcfg, rparams, t, mcfg.n_experts)
        y = moe_ref(t, rout.top_idx, rout.top_w, p["w_gate"], p["w_up"],
                    p["w_down"])
        aux = {"aux_loss": rout.aux_loss, "dropped": jnp.float32(0.0),
               "load": jax.nn.one_hot(rout.top_idx, e_pad).sum((0, 1))}
        y = y.reshape(B, S, D)
    else:
        y, aux = _moe_dist(cfg, dist, rparams, p, x, mode, chunks)

    if mcfg.d_shared and "shared" in p:
        sh = MLPParams(**{k: p["shared"][k] for k in ("w_gate", "w_up", "w_down")})
        y = y + swiglu(sh, x)
    return y, aux


def _moe_dist(cfg: ModelConfig, dist: DistCtx, rparams: RouterParams, p: dict,
              x: Array, mode: str, chunks: int) -> tuple[Array, dict]:
    mesh = dist.mesh
    all_axes = tuple(mesh.axis_names)
    mcfg = cfg.moe
    spec = make_ep_spec(cfg, dist, mode=mode, chunks=chunks, dtype=x.dtype)
    eps = spec.experts_per_shard
    nshards = math.prod(mesh.shape[a] for a in all_axes)

    from repro.distributed.sharding import effective_batch_axes
    Bg, Sg, _ = x.shape
    bd = effective_batch_axes(dist, Bg)
    sq = (dist.seq_axis if (Sg > 1 and dist.seq_axis
                            and Sg % mesh.shape[dist.seq_axis] == 0) else None)
    ep_spec_p = tuple(dist.ep_axes) if len(dist.ep_axes) > 1 else dist.ep_axes[0]
    x_spec = P(bd, sq, None)

    def island(x_l, rw, rb, wg, wu, wd):
        Bl, Sl, D = x_l.shape
        t = x_l.reshape(-1, D)
        rout = route(mcfg, RouterParams(rw, rb), t, mcfg.n_experts)
        fn = _expert_fn(wg, wu, wd)
        if mode == "ll":
            res = dispatch_combine_ll(spec, t, rout.top_idx, rout.top_w, fn)
        else:
            res = dispatch_combine_ht(spec, t, rout.top_idx, rout.top_w, fn)
        y = res.out.reshape(Bl, Sl, D)
        denom = jnp.float32(nshards)
        aux = {
            "aux_loss": jax.lax.psum(rout.aux_loss, all_axes) / denom,
            "dropped": jax.lax.psum(res.aux["dropped"], all_axes) / denom,
            "load": jax.lax.psum(
                jax.nn.one_hot(rout.top_idx, spec.n_experts).sum((0, 1)),
                all_axes),
        }
        return y, aux

    rb = rparams.bias
    if rb is None:
        rb = jnp.zeros((spec.n_experts,), jnp.float32)
    out_specs = (x_spec, {"aux_loss": P(), "dropped": P(), "load": P()})
    y, aux = jax.shard_map(
        island, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None),
                  P(ep_spec_p, None, None), P(ep_spec_p, None, None),
                  P(ep_spec_p, None, None)),
        out_specs=out_specs, check_vma=False,
    )(x, rparams.w, rb, p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
