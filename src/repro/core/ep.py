"""UCCL-EP expert-parallel dispatch/combine, adapted natively to TPU meshes.

Two modes, mirroring the paper (§3.3):

- **LL (low latency)**: one-shot capacity-bucketed ``all_to_all`` per choice
  (token, expert).  No synchronisation between transfers; used for decode.

- **HT (high throughput)**: chunked dispatch with **token deduplication** and
  **hierarchical reduce**.  A token routed to multiple experts inside the same
  destination *group* (a pod on the 2-level mesh, a shard on the 1-level mesh)
  crosses that group boundary exactly once, carrying its expert list as
  metadata (the paper's TransferCmd payload); expert outputs are partially
  reduced inside the group and exactly one combined vector returns per
  (token, group) — the paper's intra-node reduce + single inter-node return.

All functions below run INSIDE ``shard_map`` — they see per-shard arrays and
use ``jax.lax`` collectives over the EP mesh axes.  ``repro.core.moe`` wraps
them; pure-jnp oracles live in :func:`moe_ref` for tests.

Routing *decisions* (slot assignment, counts, capacity masks, dedup tables)
come from the shared plan layer in :mod:`repro.core.plan`; this module only
implements their *execution* over jax collectives (payload packing, a2a,
grouped FFN, combine).  The simulated-RDMA transport executor consumes the
same plans, so the two backends cannot drift (DESIGN.md §8).

Shapes are static (XLA): capacity-bucketed buffers with overflow *drops*,
which are counted and returned (the paper's incast/congestion concern maps to
capacity pressure here; see DESIGN.md §6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import plan as planlib

Array = jax.Array

NEG = jnp.int32(-1)


@dataclass(frozen=True)
class EPSpec:
    """Static description of the expert-parallel layout."""

    axes: tuple[str, ...]        # mesh axes carrying experts, outer->inner
    sizes: tuple[int, ...]       # sizes of those axes
    n_experts: int               # padded expert count
    top_k: int
    capacity_factor: float = 2.0
    chunks: int = 1              # HT pipeline chunks
    dtype: jnp.dtype = jnp.bfloat16
    mode: str = "ht"             # "ll" (decode) | "ht" (train/prefill)
    # dispatch-payload wire dtype: "fp32" (passthrough: tokens cross in
    # ``dtype``) | "fp8" | "int8" (block-quantized, inline per-128-feature
    # fp32 scales; dequantized to fp32 at the receiver — DESIGN.md §14).
    # Compression applies to dispatch only; combine returns and all
    # accumulation stay full precision.
    wire_dtype: str = "fp32"
    # replicated expert placement: phys->logical slot table as a hashable
    # tuple (``Placement.key()``), length = physical slot count.  None (or
    # the identity table) keeps today's single-placement layout bit-for-bit;
    # otherwise routing splits each logical expert's tokens across its
    # replicas deterministically (plan.split_to_physical) and every
    # downstream structure — a2a buckets, guard tables, fence counts,
    # ret_pos — sizes from ``n_physical``.  ``n_experts`` stays the LOGICAL
    # (router-space) count.
    placement: Optional[tuple[int, ...]] = None

    @property
    def degree(self) -> int:
        return math.prod(self.sizes)

    @property
    def experts_per_shard(self) -> int:
        assert self.n_experts % self.degree == 0
        return self.n_experts // self.degree

    @property
    def n_physical(self) -> int:
        """Physical expert-slot count (== n_experts without replication)."""
        return len(self.placement) if self.placement is not None \
            else self.n_experts

    @property
    def physical_per_shard(self) -> int:
        assert self.n_physical % self.degree == 0
        return self.n_physical // self.degree

    def placement_obj(self) -> Optional[planlib.Placement]:
        """Materialized Placement, or None for the identity layout (the
        replicas=1 contract: identity tables take the exact legacy path)."""
        if self.placement is None:
            return None
        pl = planlib.placement_from_table(
            np.asarray(self.placement, np.int32))
        return None if pl.is_identity else pl

    @property
    def two_level(self) -> bool:
        return len(self.axes) == 2

    def flat_axis(self):
        return self.axes if len(self.axes) > 1 else self.axes[0]


class DispatchResult(NamedTuple):
    out: Array          # (T, D) combined expert outputs
    aux: dict           # {"dropped": scalar fraction, "occupancy": ..., ...}


# occupancy-carrying expert_fn contract dispatch: ``fn(tokens, counts)``
# where counts are the per-expert (or per-bucket, shape (E_local, B))
# occupied row counts; legacy single-argument callables still work
_call_expert_fn = planlib.call_expert_fn


def _cap(n: float, cf: float, hard_max: int, multiple: int = 8) -> int:
    c = int(math.ceil(n * cf / multiple)) * multiple
    # floor of 32 slots: tiny per-shard token counts (decode, smoke tests)
    # have large load fluctuations relative to the mean; 32 rows cost ~nothing
    floor = min(hard_max, 32)
    return max(floor, min(c, hard_max))


# ================================================= wire-dtype dispatch ====
def _wire_qdtype(wire_dtype: str):
    return jnp.float8_e4m3fn if wire_dtype == "fp8" else jnp.int8


def _quantized_a2a(spec: EPSpec, x_ext_f32: Array, src_of_slot: Array,
                   counts: Optional[Array], axis, P: int) -> Array:
    """Dispatch payloads cross the wire block-quantized (DESIGN.md §14).

    Fused gather->quantize (kernels.gather_quantize) from the fp32 source,
    a2a of the quantized bytes plus the inline per-block fp32 scales, then
    dequantize-on-receive back to fp32.  Empty slots gather the scratch zero
    row and decode to exact zeros, preserving the ``zero_padded`` contract.
    fp8 payloads cross bitcast to uint8: the *wire* carries raw bytes, and
    narrow-float collectives aren't portable across backends.
    """
    from repro.kernels import ops as kops
    n = src_of_slot.shape[0]
    D = x_ext_f32.shape[1]
    q, sc = kops.gather_quantize(x_ext_f32, src_of_slot, counts,
                                 wire_dtype=spec.wire_dtype)
    nb = sc.shape[1]
    per = n // P
    qb = lax.bitcast_convert_type(q, jnp.uint8).reshape(P, per, D)
    qr = lax.all_to_all(qb, axis, split_axis=0, concat_axis=0, tiled=True)
    sr = lax.all_to_all(sc.reshape(P, per, nb), axis, split_axis=0,
                        concat_axis=0, tiled=True)
    qw = lax.bitcast_convert_type(qr.reshape(n, D),
                                  _wire_qdtype(spec.wire_dtype))
    return kops.dequantize_tokens(qw, sr.reshape(n, nb))      # (n, D) fp32


def _wire_dispatch_a2a(spec: EPSpec, x: Array, plan: "_GroupPlan", axis,
                       G: int, C: int) -> Array:
    """Token-payload a2a for one dedup'd group dispatch, in the wire dtype.

    fp32 passthrough sends ``plan.send_x`` as-is (tokens cross in
    ``spec.dtype``); compressed modes re-gather from the fp32 source via
    ``plan.src_of_slot`` so the quantize fuses with the packing gather.
    Metadata (expert ids, combine weights) always crosses uncompressed.
    """
    if spec.wire_dtype == "fp32":
        return lax.all_to_all(plan.send_x, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    D = x.shape[1]
    xf = jnp.concatenate([x.astype(jnp.float32),
                          jnp.zeros((1, D), jnp.float32)], axis=0)
    rows = _quantized_a2a(spec, xf, plan.src_of_slot, None, axis, G)
    return rows.astype(spec.dtype).reshape(G, C, D)


# =========================================================== LL mode ======
def dispatch_combine_ll(spec: EPSpec, x: Array, top_idx: Array, top_w: Array,
                        expert_fn: Callable[[Array], Array],
                        capacity: Optional[int] = None) -> DispatchResult:
    """One-shot per-choice dispatch -> grouped expert FFN -> combine.

    x: (T, D); top_idx/top_w: (T, K).  expert_fn maps (E_local, C_in, D) ->
    (E_local, C_in, D) applying local expert i to row block i — under a
    replicated ``spec.placement`` the row blocks are PHYSICAL slots (the
    caller gathers weights through ``phys_to_logical``).
    """
    T, D = x.shape
    K = spec.top_k
    pl_obj = spec.placement_obj()
    if pl_obj is not None:
        top_idx = planlib.split_to_physical(pl_obj, top_idx)
    E, P, eps = spec.n_physical, spec.degree, spec.physical_per_shard
    # hard_max is T*K, not T: routing tables may send a token to the same
    # expert more than once (e.g. random tables in tests)
    C = capacity or _cap(T * K / E, spec.capacity_factor, hard_max=T * K)

    pl = planlib.make_plan(top_idx, E, C)
    flat_e = top_idx.reshape(-1)                       # (T*K,)
    valid, rank = pl.valid.reshape(-1), pl.rank.reshape(-1)
    keep = pl.keep.reshape(-1)
    slot = planlib.flat_slots(flat_e, rank, keep, C, E)  # overflow -> scratch

    # index-indirection packing (scatter ids, gather payloads; §Perf O2)
    rows = jnp.arange(T * K, dtype=jnp.int32) // K
    src_of_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        rows, mode="drop")[:-1]
    # a2a over the (flattened) EP axes: expert e lives on flat shard e // eps.
    if spec.wire_dtype == "fp32":
        x_ext = jnp.concatenate([x.astype(spec.dtype),
                                 jnp.zeros((1, D), spec.dtype)], axis=0)
        send = x_ext[src_of_slot].reshape(P, eps * C, D)
        recv = lax.all_to_all(send, spec.flat_axis(), split_axis=0,
                              concat_axis=0, tiled=True)     # (P, eps*C, D)
    else:
        # compressed wire: quantize from the full-precision source (not the
        # already-narrowed spec.dtype), dequantize to fp32 at the receiver
        xf_ext = jnp.concatenate([x.astype(jnp.float32),
                                  jnp.zeros((1, D), jnp.float32)], axis=0)
        deq = _quantized_a2a(spec, xf_ext, src_of_slot,
                             jnp.minimum(pl.counts, C), spec.flat_axis(), P)
        recv = deq.astype(spec.dtype).reshape(P, eps * C, D)
    recv = recv.reshape(P, eps, C, D).transpose(1, 0, 2, 3).reshape(eps, P * C, D)

    # occupancy exchange: each source's per-(dest expert) occupied counts —
    # the same metadata the paper's completion fences carry — so the expert
    # kernel can skip the capacity padding (§Perf: occupancy-aware compute).
    # recv bucket layout is (local expert, source bucket): counts (eps, P).
    cnt_send = jnp.minimum(pl.counts, C).reshape(P, eps)
    cnt_recv = lax.all_to_all(cnt_send, spec.flat_axis(), split_axis=0,
                              concat_axis=0, tiled=True)       # (P, eps)
    out_e = _call_expert_fn(expert_fn, recv, cnt_recv.T)  # (eps, P*C, D)

    back = out_e.reshape(eps, P, C, D).transpose(1, 0, 2, 3).reshape(P, eps * C, D)
    back = lax.all_to_all(back, spec.flat_axis(), split_axis=0, concat_axis=0,
                          tiled=True)
    back = back.reshape(E * C, D)

    # combine: weighted fp32 segment-sum over the T*K kept choices — no
    # (T, K, D) fp32 materialization + einsum, and no touching the (mostly
    # padded) E*C slot space: each choice gathers its slot's row and
    # scatter-adds into its token (dropped choices add 0 via the scratch row)
    w_flat = jnp.where(keep, top_w.reshape(-1).astype(jnp.float32), 0.0)
    contrib = back[jnp.where(keep, flat_e * C + rank, 0)].astype(
        jnp.float32) * w_flat[:, None]
    out = jnp.zeros((T + 1, D), jnp.float32).at[
        jnp.where(keep, rows, T)].add(contrib)[:-1]
    dropped = pl.n_dropped / jnp.maximum(valid.sum(), 1)
    occupancy = jnp.minimum(pl.counts, C).sum() / (E * C)
    # global per-physical-slot load + imbalance (max/mean): the one stat the
    # online re-placer and the benchmarks both read (DESIGN.md §15)
    load_phys = lax.psum(pl.counts, spec.flat_axis())
    return DispatchResult(out.astype(x.dtype),
                          {"dropped": dropped, "occupancy": occupancy,
                           "load_phys": load_phys,
                           "imbalance": planlib.load_imbalance(load_phys)})


# =========================================================== HT mode ======
class _GroupPlan(NamedTuple):
    """Source-side bookkeeping of one dedup'd group dispatch."""

    send_x: Array       # (G, C, D) token payloads
    send_eid: Array     # (G, C, K) expert ids local to the dest group (-1 pad)
    send_w: Array       # (G, C, K) combine weights
    src_of_slot: Array  # (G*C,) source token row per slot (T for empty) —
                        # drives both payload packing and the combine scatter
    dropped: Array      # scalar count


def _dedup_group_dispatch(x: Array, eid: Array, w: Array, group_of: Array,
                          n_groups: int, C: int, dtype) -> _GroupPlan:
    """Deduplicate choices per (token, group); bucket entries by group.

    x: (T, D); eid: (T, K) expert ids *within the group's namespace* (-1 pad);
    w: (T, K); group_of: (T, K) destination group per choice (-1 for pad).
    """
    T, K = eid.shape
    D = x.shape[1]
    valid = eid >= 0
    # dedup + (token, group) entry table from the shared plan layer
    first, entry_valid, rank_tg, keep_tg, dropped = planlib.dedup_entry_table(
        group_of, valid, n_groups, C)
    # pack entries by index-indirection: scatter row ids, gather payloads
    # once per (t, g) — no (T, G, D) value materialisation (§Perf O2)
    slot_tg = planlib.flat_slots(jnp.arange(n_groups)[None], rank_tg, keep_tg,
                                 C, n_groups)
    src_rows = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                                (T, n_groups))
    src_of_slot = jnp.full((n_groups * C + 1,), T, jnp.int32).at[slot_tg].set(
        src_rows, mode="drop")[:-1]
    x_ext = jnp.concatenate([x.astype(dtype), jnp.zeros((1, D), dtype)],
                            axis=0)
    send_x = x_ext[src_of_slot].reshape(n_groups, C, D)
    # metadata: k-th choice rides on its (t,g) entry
    slot_choice = jnp.where(valid, jnp.take_along_axis(
        slot_tg, jnp.where(valid, group_of, 0), axis=1), n_groups * C)
    kpos = jnp.broadcast_to(jnp.arange(K)[None], (T, K))
    send_eid = jnp.full((n_groups * C + 1, K), NEG, jnp.int32).at[
        slot_choice, kpos].set(jnp.where(valid, eid, NEG), mode="drop")[:-1]
    send_w = jnp.zeros((n_groups * C + 1, K), jnp.float32).at[
        slot_choice, kpos].set(jnp.where(valid, w.astype(jnp.float32), 0.0),
                               mode="drop")[:-1]
    return _GroupPlan(send_x, send_eid.reshape(n_groups, C, K),
                      send_w.reshape(n_groups, C, K), src_of_slot, dropped)


def _expert_apply(spec: EPSpec, x_in: Array, eid: Array, w: Array,
                  expert_fn: Callable[[Array], Array], cf: float,
                  n_tokens_hint: int):
    """Final-level compute: entries (N, D) each with <=K local expert ids.

    Buckets (entry, choice) pairs per local expert, applies the grouped FFN,
    and returns the *weighted partial sum per entry* (the intra-node reduce).

    Capacity is sized from the REAL expected load (``n_tokens_hint`` source
    tokens x K choices, balanced across experts) — not from the padded recv
    row count N, which is mostly capacity padding; invalid rows (eid = -1)
    consume no slots.

    HBM-traffic note (§Perf O2): packing is *index-indirection* — row ids
    are scattered (4-byte ints), payloads move through ONE gather into the
    (eps, Ce, D) buffer, and the combine is a weighted scatter-add of the
    expert outputs.  This avoids materialising (N·K, D) value scatters and
    the padded (N, K, D) fp32 gather of the naive formulation (~8x traffic).
    """
    N, D = x_in.shape
    K = eid.shape[1]
    eps = spec.physical_per_shard
    Ce = _cap(n_tokens_hint * K / eps, cf, hard_max=N * K)
    pl = planlib.make_plan(eid, eps, Ce)
    flat_e = eid.reshape(-1)
    valid, rank, keep = (pl.valid.reshape(-1), pl.rank.reshape(-1),
                         pl.keep.reshape(-1))
    slot = planlib.flat_slots(flat_e, rank, keep, Ce, eps)
    rows = jnp.arange(N * K, dtype=jnp.int32) // K          # choice -> entry
    # index scatter (ints) + payload gather
    ent_of_slot = jnp.full((eps * Ce + 1,), N, jnp.int32).at[slot].set(
        rows, mode="drop")[:-1]
    x_ext = jnp.concatenate([x_in.astype(spec.dtype),
                             jnp.zeros((1, D), spec.dtype)], axis=0)
    w_of_slot = jnp.zeros((eps * Ce + 1,), jnp.float32).at[slot].set(
        w.reshape(-1).astype(jnp.float32), mode="drop")[:-1]
    counts = jnp.minimum(pl.counts, Ce)       # occupied prefix per expert
    occupancy = counts.sum() / (eps * Ce)
    fused = getattr(expert_fn, "fused", None)
    if fused is not None:
        # fully fused gather -> expert SwiGLU -> weighted fp32 scatter-add:
        # neither the (eps, Ce, D) gather buffer nor the expert-output
        # intermediate is materialized (kernels.gather_swiglu_scatter)
        part = fused(x_ext, ent_of_slot, w_of_slot, counts)
    else:
        buf = x_ext[ent_of_slot]
        out_e = _call_expert_fn(expert_fn, buf.reshape(eps, Ce, D),
                                counts).reshape(eps * Ce, D)
        # weighted scatter-add back per entry (intra-node reduce)
        part = jnp.zeros((N + 1, D), jnp.float32).at[
            jnp.where(w_of_slot != 0, ent_of_slot, N)].add(
            out_e.astype(jnp.float32) * w_of_slot[:, None], mode="drop")[:-1]
    return part, (valid & ~keep).sum(), occupancy


def _combine_scatter(plan: _GroupPlan, ret: Array, T: int) -> Array:
    """ret: (G, C, D) returned partials; scatter-add entries back per token.

    Empty slots carry zero partials and point at the scratch row T, so one
    unmasked fp32 scatter-add replaces the old (T, G, D) gather + where +
    sum materialization (§Perf: scatter-based combine)."""
    G, C, D = ret.shape
    out = jnp.zeros((T + 1, D), jnp.float32).at[plan.src_of_slot].add(
        ret.reshape(G * C, D).astype(jnp.float32))
    return out[:-1]


def dispatch_combine_ht(spec: EPSpec, x: Array, top_idx: Array, top_w: Array,
                        expert_fn: Callable[[Array], Array]) -> DispatchResult:
    """Chunked + dedup'd + hierarchical dispatch/combine (paper HT mode)."""
    T, D = x.shape
    pl_obj = spec.placement_obj()
    if pl_obj is not None:
        # one replica split for the whole table (not per chunk), matching
        # the substrate's per-source round-robin semantics
        top_idx = planlib.split_to_physical(pl_obj, top_idx)
    n_chunks = planlib.effective_chunks(T, spec.chunks)
    Tc = T // n_chunks
    outs, drops, total = [], jnp.int32(0), jnp.int32(0)
    occs = []
    for c in range(n_chunks):
        sl = slice(c * Tc, (c + 1) * Tc)
        o, d, occ = _ht_one_chunk(spec, x[sl], top_idx[sl], top_w[sl],
                                  expert_fn)
        outs.append(o)
        occs.append(occ)
        drops += d
        total += Tc * spec.top_k
    out = jnp.concatenate(outs, axis=0) if n_chunks > 1 else outs[0]
    load_phys = lax.psum(
        planlib.group_counts(top_idx.reshape(-1), spec.n_physical,
                             (top_idx >= 0).reshape(-1)), spec.flat_axis())
    return DispatchResult(out.astype(x.dtype),
                          {"dropped": drops / jnp.maximum(total, 1),
                           "occupancy": sum(occs) / n_chunks,
                           "chunks": n_chunks,
                           "load_phys": load_phys,
                           "imbalance": planlib.load_imbalance(load_phys)})


def _ht_one_chunk(spec: EPSpec, x: Array, top_idx: Array, top_w: Array,
                  expert_fn) -> tuple[Array, Array, Array]:
    # top_idx is already PHYSICAL here (dispatch_combine_ht splits replicas
    # once up front); all bucketing below runs in the physical slot space
    T, D = x.shape
    K = spec.top_k
    E, eps = spec.n_physical, spec.physical_per_shard
    cf = spec.capacity_factor
    valid = top_idx >= 0

    if not spec.two_level:
        # one-level: groups are the EP shards themselves (dedup at shard level)
        P = spec.degree
        group_of = jnp.where(valid, top_idx // eps, -1)
        eid_local = jnp.where(valid, top_idx % eps, NEG)
        frac = 1.0 - (1.0 - 1.0 / P) ** K
        C = _cap(T * frac, cf, hard_max=T)
        plan = _dedup_group_dispatch(x, eid_local, top_w, group_of, P, C,
                                     spec.dtype)
        rx = _wire_dispatch_a2a(spec, x, plan, spec.axes[0], P, C)
        re = lax.all_to_all(plan.send_eid, spec.axes[0], 0, 0, tiled=True)
        rw = lax.all_to_all(plan.send_w, spec.axes[0], 0, 0, tiled=True)
        part, d2, occ = _expert_apply(spec, rx.reshape(P * C, D),
                                      re.reshape(P * C, K),
                                      rw.reshape(P * C, K),
                                      expert_fn, cf, n_tokens_hint=T)
        ret = lax.all_to_all(part.reshape(P, C, D).astype(spec.dtype),
                             spec.axes[0], 0, 0, tiled=True)
        out = _combine_scatter(plan, ret.astype(jnp.float32), T)
        return out, plan.dropped + d2, occ

    # ---- two-level: outer = pod (RDMA domain), inner = model (ICI domain) --
    ax_o, ax_i = spec.axes
    Po, Pi = spec.sizes
    e_per_pod = E // Po
    pod_of = jnp.where(valid, top_idx // e_per_pod, -1)
    eid_in_pod = jnp.where(valid, top_idx % e_per_pod, NEG)
    frac_o = 1.0 - (1.0 - 1.0 / Po) ** K
    C1 = _cap(T * frac_o, cf, hard_max=T)
    plan1 = _dedup_group_dispatch(x, eid_in_pod, top_w, pod_of, Po, C1,
                                  spec.dtype)
    # inter-pod a2a (same-rail: inner index unchanged), tokens cross once
    rx = _wire_dispatch_a2a(spec, x, plan1, ax_o, Po, C1)       # (Po, C1, D)
    re = lax.all_to_all(plan1.send_eid, ax_o, 0, 0, tiled=True)
    rw = lax.all_to_all(plan1.send_w, ax_o, 0, 0, tiled=True)
    N2 = Po * C1
    x2 = rx.reshape(N2, D)
    e2 = re.reshape(N2, K)                 # expert ids within my pod
    w2 = rw.reshape(N2, K)
    # intra-pod forwarding: group by inner shard (NVLink-domain distribution)
    v2 = e2 >= 0
    grp2 = jnp.where(v2, e2 // eps, -1)
    eid2 = jnp.where(v2, e2 % eps, NEG)
    frac_i = 1.0 - (1.0 - 1.0 / Pi) ** K
    C2 = _cap(N2 * frac_i, cf, hard_max=N2)
    plan2 = _dedup_group_dispatch(x2, eid2, w2, grp2, Pi, C2, spec.dtype)
    rx2 = _wire_dispatch_a2a(spec, x2, plan2, ax_i, Pi, C2)
    re2 = lax.all_to_all(plan2.send_eid, ax_i, 0, 0, tiled=True)
    rw2 = lax.all_to_all(plan2.send_w, ax_i, 0, 0, tiled=True)
    part, d3, occ = _expert_apply(spec, rx2.reshape(Pi * C2, D),
                                  re2.reshape(Pi * C2, K),
                                  rw2.reshape(Pi * C2, K),
                                  expert_fn, cf, n_tokens_hint=T)
    # hierarchical combine A: return partials intra-pod, reduce per (t, pod)
    ret2 = lax.all_to_all(part.reshape(Pi, C2, D).astype(spec.dtype),
                          ax_i, 0, 0, tiled=True)
    red2 = _combine_scatter(plan2, ret2.astype(jnp.float32), N2)  # (N2, D)
    # hierarchical combine B: ONE vector per (token, pod) crosses pods back
    ret1 = lax.all_to_all(red2.reshape(Po, C1, D).astype(spec.dtype),
                          ax_o, 0, 0, tiled=True)
    out = _combine_scatter(plan1, ret1.astype(jnp.float32), T)
    return out, plan1.dropped + plan2.dropped + d3, occ


# ====================================================== reference oracle ==
def moe_ref(x: Array, top_idx: Array, top_w: Array, w_gate: Array, w_up: Array,
            w_down: Array) -> Array:
    """Dense per-token MoE oracle: no parallelism, no capacity drops.

    x: (T, D); top_idx/top_w: (T, K); w_*: (E, D, F) / (E, F, D).
    """
    E = w_gate.shape[0]
    oh = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)        # (T, K, E)
    w_e = jnp.einsum("tke,tk->te", oh, top_w.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    g = jnp.einsum("td,edf->tef", xf, w_gate.astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xf, w_up.astype(jnp.float32))
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, w_down.astype(jnp.float32))
    return jnp.einsum("ted,te->td", y, w_e).astype(x.dtype)
