"""MoE gating: top-k softmax router with aux-loss-free bias + load-balance loss.

The router output (per-token expert ids + weights) is what the paper calls the
"token-routing decision computed at runtime in GPUs" — everything downstream
(dispatch/combine) consumes RouterOut.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import plan as planlib

Array = jax.Array


class RouterParams(NamedTuple):
    w: Array                  # (d_model, E_padded)
    bias: Optional[Array]     # (E_padded,) aux-loss-free balancing bias (non-grad)


class RouterOut(NamedTuple):
    top_idx: Array     # (T, K) int32 expert ids (into padded expert space)
    top_w: Array       # (T, K) combine weights (normalised probs)
    probs: Array       # (T, E) full router probabilities (for aux loss)
    aux_loss: Array    # scalar Switch-style load-balance loss


def router_init(d_model: int, n_experts_padded: int, key: Array,
                aux_free_bias: bool) -> RouterParams:
    w = jax.random.normal(key, (d_model, n_experts_padded), jnp.float32)
    w = w / math.sqrt(d_model)
    b = jnp.zeros((n_experts_padded,), jnp.float32) if aux_free_bias else None
    return RouterParams(w=w, bias=b)


def route(moe: MoEConfig, p: RouterParams, x: Array, n_experts_real: int) -> RouterOut:
    """x: (T, d_model). Experts >= n_experts_real are padding and masked out."""
    T, _ = x.shape
    e_pad = p.w.shape[1]
    logits = (x.astype(jnp.float32) @ p.w).astype(jnp.float32)     # (T, E)
    if e_pad > n_experts_real:
        pad_mask = jnp.arange(e_pad) >= n_experts_real
        logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    # aux-loss-free balancing: bias shifts *selection* only; combine weights
    # still come from the unbiased probabilities (DeepSeek-V3 style).
    sel = logits if p.bias is None else logits + jax.lax.stop_gradient(p.bias)
    _, top_idx = jax.lax.top_k(sel, moe.top_k)
    top_idx = top_idx.astype(jnp.int32)
    top_p = jnp.take_along_axis(probs, top_idx, axis=-1)
    top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * sum_e f_e * P_e
    onehot = jax.nn.one_hot(top_idx, e_pad, dtype=jnp.float32).sum(1)  # (T, E)
    f = onehot.mean(0)
    pbar = probs.mean(0)
    aux = n_experts_real * jnp.sum(f * pbar) * moe.aux_loss_weight
    return RouterOut(top_idx=top_idx, top_w=top_w.astype(x.dtype),
                     probs=probs, aux_loss=aux)


def update_aux_free_bias(p: RouterParams, out: RouterOut, n_experts_real: int,
                         lr: float = 1e-3) -> RouterParams:
    """Post-step bias update: push load toward uniform (sign rule, DeepSeek)."""
    if p.bias is None:
        return p
    e_pad = p.bias.shape[0]
    load = planlib.expert_load(out.top_idx, e_pad)
    target = load.sum() / n_experts_real
    err = jnp.where(jnp.arange(e_pad) < n_experts_real, target - load, 0.0)
    return p._replace(bias=p.bias + lr * jnp.sign(err))
