"""Repo-specific lint rules over ``src/repro`` (ISSUE 9, DESIGN.md §17).

Run as ``python -m repro.analysis.lint [paths...]`` (default ``src/repro``
relative to the current directory); exits 1 if any finding.  Rules:

- **LNT-BITMASK** — no magic all-ones bit-mask literals (``0xF``,
  ``0x7FF``, ...) in ``core/transport`` outside ``wire_format.py``: every
  field width/mask/shift has exactly one home, so a field resize can't
  leave a stale literal behind.
- **LNT-SCALE-DIV** — no float division by a constant-like divisor inside
  quantization-scale code (codec / quantize_pack / compression): PR 6
  showed XLA constant-folds ``x / QMAX`` differently from the runtime
  (1-ULP drift between traced and eager paths); scale math must multiply
  by a precomputed reciprocal.  Module-level constants (the reciprocal
  itself) are exempt.
- **LNT-ASSERT-PROTO** — no bare ``assert`` referencing protocol-width
  constants (SEQ_MOD, IMM_VAL_MAX, FENCE_COUNT_MAX, N_CHANNELS_MAX, ...)
  in ``core/transport``: those checks vanish under ``python -O`` and must
  be explicit :class:`ProtocolError` raises (or verifier rules).
- **LNT-PL-WHEN** — Pallas kernels (``*_kernel`` functions in
  ``kernels/``) taking an occupancy/count ref must gate their work with
  ``pl.when``: unconditionally touching rows past occupancy is exactly
  the padding-garbage class PR 3's occupancy-aware kernels exist to avoid.
"""
from __future__ import annotations

import ast
import io
import os
import sys
import tokenize
from dataclasses import dataclass

PROTOCOL_NAMES = frozenset({
    "SEQ_MOD", "IMM_VAL_MAX", "FENCE_COUNT_MAX", "N_CHANNELS_MAX",
    "SRD_DISPLACEMENT_BOUND", "IMM_KIND_BITS", "IMM_CH_BITS",
    "IMM_SEQ_BITS", "IMM_VALUE_BITS", "IMM_COUNT_BITS",
})

# modules holding quantization-scale math (matched on basename)
_QUANT_BASENAMES = frozenset({"codec.py", "quantize_pack.py",
                              "compression.py"})

# smallest all-ones literal worth flagging (0x1/0x3/0x7 are ubiquitous
# small-flag idioms; field masks start at 4 bits)
_MIN_MASK = 0xF


@dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _posix(path: str) -> str:
    return path.replace(os.sep, "/")


def _in_transport(path: str) -> bool:
    p = _posix(path)
    return "core/transport" in p and os.path.basename(p) != "wire_format.py"


def _in_kernels(path: str) -> bool:
    return "kernels" in _posix(path).split("/")


def _is_quant_module(path: str) -> bool:
    return os.path.basename(path) in _QUANT_BASENAMES


# ------------------------------------------------------------------------
# LNT-BITMASK (token level: the AST constant-folds literal forms away)
# ------------------------------------------------------------------------
def _check_bitmask(src: str, path: str) -> list[LintFinding]:
    if not _in_transport(path):
        return []
    out = []
    for tok in tokenize.generate_tokens(io.StringIO(src).readline):
        if tok.type != tokenize.NUMBER:
            continue
        s = tok.string.lower().replace("_", "")
        if not (s.startswith("0x") or s.startswith("0b")):
            continue
        try:
            v = int(s, 0)
        except ValueError:
            continue
        if v >= _MIN_MASK and (v & (v + 1)) == 0:
            out.append(LintFinding(
                path, tok.start[0], "LNT-BITMASK",
                f"magic bit-mask literal {tok.string}: import the named "
                "mask from core/transport/wire_format.py"))
    return out


# ------------------------------------------------------------------------
# LNT-SCALE-DIV
# ------------------------------------------------------------------------
def _constant_like(node: ast.expr) -> bool:
    """Divisors that XLA can constant-fold differently from eager numpy:
    numeric literals, ALL_CAPS module constants, and casts/calls wrapping
    those (``np.float32(FP8_MAX)``)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return True
    if isinstance(node, ast.Name) and node.id.isupper():
        return True
    if isinstance(node, ast.Attribute) and node.attr.isupper():
        return True
    if isinstance(node, ast.Call):
        return any(_constant_like(a) for a in node.args)
    return False


def _check_scale_div(tree: ast.AST, path: str) -> list[LintFinding]:
    if not _is_quant_module(path):
        return []
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue        # module-level reciprocals (_QINV) are the fix
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) \
                    and isinstance(node.op, ast.Div) \
                    and _constant_like(node.right):
                out.append(LintFinding(
                    path, node.lineno, "LNT-SCALE-DIV",
                    "float division by a constant in quantization-scale "
                    "math: precompute the reciprocal at module level and "
                    "multiply (XLA constant-folds x / C with different "
                    "rounding than eager numpy — the PR 6 1-ULP drift "
                    "class)"))
    return out


# ------------------------------------------------------------------------
# LNT-ASSERT-PROTO
# ------------------------------------------------------------------------
def _check_assert_proto(tree: ast.AST, path: str) -> list[LintFinding]:
    if not _in_transport(path):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assert):
            continue
        names = {n.id for n in ast.walk(node.test)
                 if isinstance(n, ast.Name)}
        hit = names & PROTOCOL_NAMES
        if hit:
            out.append(LintFinding(
                path, node.lineno, "LNT-ASSERT-PROTO",
                f"bare assert references protocol constant(s) "
                f"{sorted(hit)}: asserts vanish under python -O — raise "
                "ProtocolError (wire_format) or verify via "
                "repro.analysis.verify"))
    return out


# ------------------------------------------------------------------------
# LNT-PL-WHEN
# ------------------------------------------------------------------------
def _takes_occupancy(fn: ast.FunctionDef) -> bool:
    args = [a.arg for a in
            fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs]
    return any(a.split("_")[0] in ("cnt", "counts", "occ", "occupancy")
               for a in args)


def _uses_pl_when(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr == "when" \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "pl":
            return True
    return False


def _check_pl_when(tree: ast.AST, path: str) -> list[LintFinding]:
    if not _in_kernels(path):
        return []
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef) \
                or not fn.name.endswith("_kernel"):
            continue
        if _takes_occupancy(fn) and not _uses_pl_when(fn):
            out.append(LintFinding(
                path, fn.lineno, "LNT-PL-WHEN",
                f"Pallas kernel {fn.name} takes an occupancy/count ref but "
                "never guards with pl.when: rows past occupancy hold "
                "padding garbage"))
    return out


# ------------------------------------------------------------------------
# driver
# ------------------------------------------------------------------------
def lint_source(src: str, path: str) -> list[LintFinding]:
    """Lint one file's source under its (relative) ``path`` — the path
    decides which rules apply.  Unparseable files produce a single
    finding rather than a crash."""
    findings = list(_check_bitmask(src, path))
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return findings + [LintFinding(path, e.lineno or 0, "LNT-PARSE",
                                       f"syntax error: {e.msg}")]
    findings += _check_scale_div(tree, path)
    findings += _check_assert_proto(tree, path)
    findings += _check_pl_when(tree, path)
    return findings


def lint_paths(paths: list[str]) -> list[LintFinding]:
    findings: list[LintFinding] = []
    for root in paths:
        if os.path.isfile(root):
            files = [root]
        else:
            files = sorted(
                os.path.join(dp, f)
                for dp, _, fs in os.walk(root) for f in fs
                if f.endswith(".py"))
        for fp in files:
            with open(fp, encoding="utf-8") as fh:
                findings += lint_source(fh.read(), fp)
    findings.sort(key=lambda f: (f.path, f.line))
    return findings


def main(argv: list[str]) -> int:
    paths = argv or ["src/repro"]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    n_files = sum(1 for _ in {f.path for f in findings})
    if findings:
        print(f"lint: {len(findings)} finding(s) in {n_files} file(s)")
        return 1
    print(f"lint: clean ({', '.join(paths)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
