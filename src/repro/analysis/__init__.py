"""Static analysis for the EP transport (ISSUE 9, DESIGN.md §17).

Three tools, none of which execute transport code:

- :mod:`repro.analysis.verify` — the protocol verifier: proves the wire
  contract's invariant catalog (:mod:`repro.analysis.invariants`) over
  command streams / guard tables / net configs before any traffic moves.
- :mod:`repro.analysis.racecheck` — an Eraser-style lockset race detector
  that instruments ``FifoChannel``/``Network``/``Proxy`` in threaded runs.
- :mod:`repro.analysis.lint` — repo-specific AST/token lint rules
  (``python -m repro.analysis.lint src/repro``).

This package may import ``core.transport`` leaf modules (wire_format,
fifo, simulator, proxy) but never ``ep_executor`` — the executor imports
the verifier, and the verifier duck-types its ``CommandStreams``.
"""
from repro.analysis.invariants import CATALOG, Finding, Rule
from repro.analysis.verify import verify, verify_or_raise

__all__ = ["CATALOG", "Finding", "Rule", "verify", "verify_or_raise"]
