"""The EP wire-contract invariant catalog (DESIGN.md §17).

Each :class:`Rule` states one invariant of the transport protocol that can
be proven *statically* — from command streams, guard tables, session
layouts, and network configs, before any traffic moves.  The catalog is
the shared vocabulary between the verifier (:mod:`repro.analysis.verify`),
its findings, the fuzz harness's seeded mutants, and the DESIGN.md table;
rule ids are stable and never reused.

Three of these rules reconstruct bugs this repo actually shipped and later
fixed (PRs 4, 5, 6) — the catalog exists so the *next* such bug is caught
at plan time, not by a flaky threaded repro.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple


class Rule(NamedTuple):
    id: str
    title: str
    statement: str
    caught: str          # which shipped PR's bug this rule would have caught


@dataclass(frozen=True)
class Finding:
    """One invariant violation: the rule it breaks, a human-readable
    message, and the offending descriptor/config (``where`` is free-form
    structured context — row index, guard id, offsets...)."""

    rule: str
    message: str
    severity: str = "error"
    where: tuple = field(default_factory=tuple)

    def __str__(self) -> str:
        loc = f" @ {self.where}" if self.where else ""
        return f"[{self.rule}] {self.message}{loc}"


_RULES = [
    Rule("EPV-001", "imm-channel-width",
         "Every immediate-carrying command's channel fits the 3-bit imm "
         "channel field (< N_CHANNELS_MAX).",
         "generic width guard (descriptor carries 8 channel bits, the imm "
         "codec only 3 — a wide channel would silently alias mod 8)"),
    Rule("EPV-002", "fence-count-width",
         "Every completion fence's required write count fits the 21-bit "
         "imm count field (<= FENCE_COUNT_MAX).",
         "PR 2/4: the seed's 6-bit count field truncated buckets past 63 "
         "writes"),
    Rule("EPV-003", "seq-operand-width",
         "Every SEQ_ATOMIC operand (HT chunk id) fits the 16-bit imm "
         "value field (<= IMM_VAL_MAX).",
         "generic width guard for the HT chunk-id pipeline (PR 8)"),
    Rule("EPV-004", "guard-no-overlap",
         "Registered guard ranges are pairwise non-overlapping: a landing "
         "offset resolves to at most one guard (the MR model).",
         "PR 6: guard extents sized from payload bytes excluded the inline "
         "codec scale blocks — the verifier sees the gap/overlap directly"),
    Rule("EPV-005", "guard-id-unique",
         "Registered guard ids are unique: two buckets sharing an id merge "
         "their write counts and fences fire early.",
         "PR 4: the seed keyed guards by a 6-bit wire slot, aliasing "
         "expert e onto guard e % 64 past 63 experts/rank"),
    Rule("EPV-006", "guard-covers-write",
         "Every dispatch write's landing range [dst_off, dst_off+len) that "
         "touches a registered guard range is fully contained in ONE range "
         "(no straddling, no partial coverage of inline scales).",
         "PR 6: fp8/int8 wire tokens carry inline scale blocks; a guard "
         "extent sized from payload-only bytes left each token's tail "
         "outside its bucket"),
    Rule("EPV-007", "fence-count-exact",
         "Each completion fence's required count equals the number of "
         "dispatch writes (same pusher, same destination) resolving to its "
         "guard id; every fence addresses a registered guard.",
         "PR 4: aliased guards double-counted writes, firing fences before "
         "their bucket had fully landed"),
    Rule("EPV-008", "srd-displacement-bound",
         "coalesce_cap * (reorder_window + 1) <= SEQ_MOD // 4, and "
         "reorder_window < SEQ_MOD // 4: receiver seq unwrap stays "
         "unambiguous under srd reordering.",
         "PR 5: write coalescing multiplied per-message displacement by "
         "the run length, silently exceeding the unwrap window"),
    Rule("EPV-009", "session-namespace-disjoint",
         "Session slots' memory regions and guard/counter windows are "
         "pairwise disjoint, and adjacent slots' channel windows are "
         "disjoint (two in-flight layers never share a wire seq space).",
         "guards the PR 8 session layout (per-layer namespacing) against "
         "future geometry changes"),
    Rule("EPV-010", "descriptor-op-known",
         "Every descriptor's op field decodes to a known opcode.",
         "generic decode guard (an unknown op is dropped or misexecuted "
         "depending on consumer path)"),
    Rule("EPV-012", "combine-unguarded",
         "No combine write's landing range intersects a registered guard "
         "range: combine returns must never satisfy a dispatch fence.",
         "PR 4: the return region overlapping a receive bucket would let "
         "in-flight combines count toward another bucket's fence"),
    # dynamic-analysis and lint rule ids share the catalog so findings from
    # all three analysis parts speak one vocabulary
    Rule("RACE-LOCKSET", "eraser-lockset",
         "Every concurrency-relevant transport field (FifoChannel "
         "counters, Network clock/accounting, Proxy execution state) is "
         "consistently protected by at least one common lock once shared — "
         "modulo the SPSC ring's intentional producer-owned lockless "
         "reads.",
         "guards the PR 7 threaded-proxy path (racecheck.py, validated by "
         "seeded lock-removal mutants)"),
    Rule("LNT-BITMASK", "no-magic-bitmask",
         "No magic all-ones bit-mask literal in core/transport outside "
         "wire_format.py — every width/mask/shift has one home.",
         "a field resize that misses one stale hand-written mask is the "
         "PR 2/4 width-bug class"),
    Rule("LNT-SCALE-DIV", "no-scale-division",
         "No float division by a constant-like divisor in quantization-"
         "scale math: multiply by a precomputed reciprocal.",
         "PR 6: XLA constant-folds x / QMAX with different rounding than "
         "eager numpy (1-ULP scale drift between traced and eager paths)"),
    Rule("LNT-ASSERT-PROTO", "no-bare-protocol-assert",
         "No bare assert referencing protocol-width constants in "
         "core/transport: python -O removes asserts.",
         "generic hardening — protocol checks must raise ProtocolError"),
    Rule("LNT-PL-WHEN", "kernel-occupancy-guarded",
         "Pallas kernels taking an occupancy/count ref must gate work "
         "with pl.when.",
         "PR 3: rows past bucket occupancy hold padding garbage"),
]

CATALOG: dict[str, Rule] = {r.id: r for r in _RULES}
