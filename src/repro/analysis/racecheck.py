"""Eraser-style lockset race detection for the threaded transport.

:class:`RaceChecker` instruments ``FifoChannel`` / ``Network`` / ``Proxy``
instances created while it is installed: their concurrency-relevant scalar
fields are tracked per attribute access, their locks are replaced with
recording wrappers, and every access is fed through the classic Eraser
state machine (Savage et al. 1997) with two refinements the transport's
intentional lock-free patterns require:

- **exclusive phase**: a variable touched by only one thread so far is
  never refined (initialization happens before sharing);
- **sole-writer reads**: a read by the *only* thread that has ever written
  the variable is exempt (the SPSC ring's producer reads its own ``_tail``
  and ``_cached_head`` locklessly by design — the consumer never writes
  them, so those reads race nothing).

A variable whose candidate lockset empties while it has at least one
writer and at least two accessing threads is reported as a candidate race.
Instrumentation is attribute-level: in-place mutation of tracked
containers (``buf[i] = ...``, ``stats["k"] += 1``) is invisible — only
rebinding writes are seen.  That is the right granularity for the
transport's contract (counters and flags are rebound; arrays are owned by
one side per slot), and it is what keeps the shipped threaded path at
zero findings while a seeded lock-removal mutant is flagged.

Usage::

    with RaceChecker() as rc:
        w = EPWorld(..., use_threads=True)
        w.run(...)
    assert rc.findings() == []

The context manager monkeypatches the three constructors on entry and
restores them on exit; objects created outside the window are untouched.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.analysis.invariants import Finding
from repro.core.transport import fifo as _fifo
from repro.core.transport import proxy as _proxy
from repro.core.transport import simulator as _sim

# concurrency-relevant *rebound* scalar fields per class (containers that
# are mutated in place — buf, stats, _seq, ctrl, heaps — are attribute-
# stable and deliberately not trackable at this granularity)
TRACKED_FIELDS = {
    "FifoChannel": frozenset({"_head", "_tail", "_cached_head",
                              "_pcie_reads", "closed"}),
    "Network": frozenset({"clock_us", "_order", "delivered", "bytes_moved",
                          "hdr_bytes_moved", "coalesced_msgs",
                          "coalesced_writes", "_jit", "_jit_pos"}),
    "Proxy": frozenset({"_executing", "error"}),
}


class TrackedLock:
    """A ``threading.Lock`` stand-in that records acquire/release with the
    checker.  Duck-types everything ``threading.Condition`` needs
    (``acquire``/``release``/``_is_owned``), so conditions built on it
    keep working — and their internal waiter juggling is recorded too."""

    __slots__ = ("_lk", "_ck", "name")

    def __init__(self, checker: "RaceChecker", name: str):
        self._lk = threading.Lock()
        self._ck = checker
        self.name = name

    def acquire(self, blocking: bool = True, timeout: float = -1):
        ok = self._lk.acquire(blocking, timeout)
        if ok:
            self._ck._push_lock(self.name)
        return ok

    def release(self):
        self._ck._pop_lock(self.name)
        self._lk.release()

    __enter__ = acquire

    def __exit__(self, *exc):
        self.release()

    def locked(self):
        return self._lk.locked()

    def _is_owned(self):
        # Condition's ownership probe for non-RLocks: try-acquire without
        # recording (same fallback CPython uses when the primitive lacks
        # _is_owned)
        if self._lk.acquire(False):
            self._lk.release()
            return False
        return True


class _VarState:
    """Eraser state for one (object, field) variable."""

    __slots__ = ("owner", "shared", "writers", "threads", "lockset",
                 "reported", "last")

    def __init__(self, owner: int):
        self.owner = owner          # first-accessing thread (exclusive phase)
        self.shared = False
        self.writers: set[int] = set()
        self.threads: set[int] = {owner}
        self.lockset: Optional[frozenset] = None   # None = ⊤ (not yet shared)
        self.reported = False
        self.last = ""


class RaceChecker:
    """Install with ``with RaceChecker() as rc:`` (or ``install()`` /
    ``uninstall()``); read candidate races via :meth:`findings`."""

    def __init__(self):
        self._guard = threading.Lock()        # leaf lock for checker state
        self._tl = threading.local()
        self._vars: dict[tuple[int, str], _VarState] = {}
        self._labels: dict[int, str] = {}
        self._findings: list[Finding] = []
        self._orig: list[tuple] = []
        self._subclass_cache: dict[type, type] = {}
        self._counter = 0
        self._active = False

    # ------------------------------------------------------ lock tracking --
    def _held(self) -> tuple:
        return tuple(getattr(self._tl, "held", ()))

    def _push_lock(self, name: str) -> None:
        held = getattr(self._tl, "held", None)
        if held is None:
            held = []
            self._tl.held = held
        held.append(name)

    def _pop_lock(self, name: str) -> None:
        held = getattr(self._tl, "held", None)
        if held and name in held:
            held.reverse()
            held.remove(name)
            held.reverse()

    # -------------------------------------------------------- state machine --
    def record_access(self, var: tuple[int, str], thread: int,
                      held: frozenset, write: bool,
                      where: str = "") -> None:
        """Feed one access through the Eraser state machine.  Public so the
        unit tests can drive synthetic traces deterministically."""
        with self._guard:
            st = self._vars.get(var)
            if st is None:
                st = _VarState(thread)
                self._vars[var] = st
            st.threads.add(thread)
            if write:
                st.writers.add(thread)
            if not st.shared:
                if thread == st.owner:
                    return              # exclusive phase: no refinement
                st.shared = True        # second thread arrives: lockset = ⊤
            # reads race nothing until a write exists (refining from a
            # pre-first-write lockless read would poison the lockset), and
            # the sole writer's own reads are exempt (the SPSC ring's
            # producer-owned counters are read locklessly by design)
            if not write and (not st.writers or st.writers == {thread}):
                return
            st.lockset = held if st.lockset is None \
                else st.lockset & held
            st.last = where
            if (not st.lockset and st.writers and len(st.threads) >= 2
                    and not st.reported):
                st.reported = True
                obj_id, field = var
                label = self._labels.get(obj_id, f"obj{obj_id}")
                kind = "write" if write else "read"
                self._findings.append(Finding(
                    "RACE-LOCKSET",
                    f"{label}.{field}: candidate race — lockset empty after "
                    f"unsynchronized {kind} ({len(st.threads)} threads, "
                    f"{len(st.writers)} writer(s))",
                    where=(label, field)))

    def _record(self, obj_id: int, field: str, write: bool) -> None:
        if not self._active:
            return
        self.record_access((obj_id, field), threading.get_ident(),
                           frozenset(self._held()), write)

    def findings(self) -> list[Finding]:
        with self._guard:
            return list(self._findings)

    # ------------------------------------------------------ instrumentation --
    def _instrumented_class(self, cls: type) -> type:
        sub = self._subclass_cache.get(cls)
        if sub is not None:
            return sub
        tracked = TRACKED_FIELDS[cls.__name__]
        checker = self

        class Instrumented(cls):
            def __getattribute__(self, name):
                if name in tracked:
                    checker._record(id(self), name, write=False)
                return object.__getattribute__(self, name)

            def __setattr__(self, name, value):
                if name in tracked:
                    checker._record(id(self), name, write=True)
                object.__setattr__(self, name, value)

        Instrumented.__name__ = cls.__name__ + "·traced"
        self._subclass_cache[cls] = Instrumented
        return Instrumented

    def instrument(self, obj, label: Optional[str] = None,
                   strip_locks: bool = False) -> None:
        """Attach tracking to one FifoChannel/Network/Proxy instance:
        replace its lock(s) with :class:`TrackedLock`s (rebuilding any
        Conditions on them) and swap in the field-recording subclass.

        ``strip_locks=True`` installs *non-recording* plain locks instead —
        the seeded lock-removal mutant: the code still synchronizes (no
        real corruption in the test process) but the checker can no longer
        see the lock, exactly as if the ``with self._lock:`` were deleted.
        """
        cls = type(obj)
        base = cls.__name__.split("·")[0]
        self._counter += 1
        if label is None:
            label = f"{base}#{self._counter}"
        self._labels[id(obj)] = label

        def mklock(name):
            return threading.Lock() if strip_locks \
                else TrackedLock(self, f"{label}.{name}")

        if base == "FifoChannel":
            lk = mklock("_lock")
            obj._lock = lk
            obj._not_full = threading.Condition(lk)
            obj._not_empty = threading.Condition(lk)
        elif base == "Network":
            if obj._lock is not None:
                obj._lock = mklock("_lock")
        elif base == "Proxy":
            obj._lock = mklock("_lock")
        else:
            raise TypeError(f"cannot instrument {cls.__name__}")
        # swap the class last: the lock surgery above must not be recorded
        obj.__class__ = self._instrumented_class(
            cls if "·" not in cls.__name__ else cls.__mro__[1])

    # ------------------------------------------------- constructor patching --
    def install(self) -> "RaceChecker":
        """Monkeypatch the three constructors so every instance created
        while installed is instrumented (FIFO channels created inside
        Proxy.__init__ included — the FifoChannel patch sees them)."""
        checker = self

        def wrap(cls):
            orig = cls.__init__

            def __init__(self_, *a, **k):
                orig(self_, *a, **k)
                checker.instrument(self_)

            self._orig.append((cls, orig))
            cls.__init__ = __init__

        wrap(_fifo.FifoChannel)
        wrap(_sim.Network)
        wrap(_proxy.Proxy)
        self._active = True
        return self

    def uninstall(self) -> None:
        self._active = False
        for cls, orig in self._orig:
            cls.__init__ = orig
        self._orig.clear()

    __enter__ = install

    def __exit__(self, *exc) -> None:
        self.uninstall()
