"""Static protocol verifier: prove the invariant catalog over command
streams, guard tables, session layouts, and network configs — without
executing anything (DESIGN.md §17).

The verifier consumes the same packed ``(N, 4)`` descriptor batches the
proxy drains and the same ``(bases, extents, guard_ids)`` tables the world
registers, decodes them with the shared codecs, and checks every rule in
:mod:`repro.analysis.invariants` with vectorized passes.  ``EPWorld``
calls :func:`verify_or_raise` at stream-build time (every run, both
session and one-shot), and the fuzz harness calls :func:`verify` directly
— both on the clean generator output (zero findings expected) and on
seeded invariant-breaking mutants (the specific rule id expected).

``CommandStreams`` is duck-typed (any object with ``writes`` /
``write_pusher`` / ``fences`` / ``fence_pusher`` / ``combines`` /
``guard_table`` attributes) so this module never imports ``ep_executor``
— the executor imports *us*.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.analysis.invariants import Finding
from repro.core.transport.fifo import FLAG_FENCE, Op, unpack_cmds
from repro.core.transport.wire_format import (FENCE_COUNT_MAX, IMM_VAL_MAX,
                                              N_CHANNELS_MAX,
                                              SRD_DISPLACEMENT_BOUND,
                                              ProtocolError)

# opcodes the proxy consumer actually executes (BARRIER is a reserved
# opcode with no consumer path — a stream carrying it is malformed)
_EXECUTABLE_OPS = frozenset((int(Op.WRITE), int(Op.ATOMIC), int(Op.DRAIN),
                             int(Op.WRITE_ATOMIC)))


# ------------------------------------------------------------------------
# stream-level width/op checks (EPV-001/002/003/010)
# ------------------------------------------------------------------------
def verify_stream(words: np.ndarray, *, n_channels: Optional[int] = None,
                  label: str = "stream") -> list[Finding]:
    """Check one packed (N, 4) descriptor batch: known opcodes and every
    immediate field within its wire width."""
    findings: list[Finding] = []
    words = np.asarray(words)
    if words.size == 0:
        return findings
    cols = unpack_cmds(words.reshape(-1, 4))
    op, ch, src_off, flags = cols.op, cols.channel, cols.src_off, cols.flags

    known = np.isin(op, list(_EXECUTABLE_OPS))
    for r in np.flatnonzero(~known)[:8].tolist():
        findings.append(Finding(
            "EPV-010", f"{label}[{r}]: op {int(op[r])} has no consumer path",
            where=(label, r, int(op[r]))))

    is_w = (op == Op.WRITE) | (op == Op.WRITE_ATOMIC)
    is_at = op == Op.ATOMIC
    sends_imm = is_w | is_at
    ch_max = N_CHANNELS_MAX if n_channels is None \
        else min(n_channels, N_CHANNELS_MAX)
    bad_ch = sends_imm & (ch >= ch_max)
    for r in np.flatnonzero(bad_ch)[:8].tolist():
        findings.append(Finding(
            "EPV-001", f"{label}[{r}]: channel {int(ch[r])} >= {ch_max} "
            "(3-bit imm channel field)", where=(label, r, int(ch[r]))))

    # fences (standalone fenced atomics and piggybacked WRITE_ATOMICs)
    # carry their required write count in the 32-bit src_off operand; the
    # imm codec packs only 21 of those bits
    is_fence = ((op == Op.ATOMIC) & ((flags & FLAG_FENCE) != 0)) \
        | (op == Op.WRITE_ATOMIC)
    bad_cnt = is_fence & (src_off > FENCE_COUNT_MAX)
    for r in np.flatnonzero(bad_cnt)[:8].tolist():
        findings.append(Finding(
            "EPV-002", f"{label}[{r}]: fence count {int(src_off[r])} > "
            f"{FENCE_COUNT_MAX} (21-bit imm count field)",
            where=(label, r, int(src_off[r]))))

    is_sat = is_at & ((flags & FLAG_FENCE) == 0)
    bad_val = is_sat & (src_off > IMM_VAL_MAX)
    for r in np.flatnonzero(bad_val)[:8].tolist():
        findings.append(Finding(
            "EPV-003", f"{label}[{r}]: atomic operand {int(src_off[r])} > "
            f"{IMM_VAL_MAX} (16-bit imm value field)",
            where=(label, r, int(src_off[r]))))
    return findings


# ------------------------------------------------------------------------
# guard-table checks (EPV-004/005)
# ------------------------------------------------------------------------
def _table_arrays(guard_table):
    bases, extents, gids = guard_table
    bases = np.asarray(bases, np.int64).reshape(-1)
    extents = np.asarray(extents, np.int64)
    gids = np.asarray(gids, np.int64)
    extents = np.broadcast_to(extents, bases.shape).reshape(-1)
    gids = np.broadcast_to(gids, bases.shape).reshape(-1)
    return bases, extents, gids


def verify_guard_table(guard_table) -> list[Finding]:
    """Ranges non-overlapping with positive extents (EPV-004), guard ids
    unique (EPV-005) — tolerates malformed tables (unlike
    ``GuardTable.register``, which raises) so mutants are *reported*."""
    findings: list[Finding] = []
    bases, extents, gids = _table_arrays(guard_table)
    if bases.size == 0:
        return findings
    for r in np.flatnonzero(extents <= 0)[:8].tolist():
        findings.append(Finding(
            "EPV-004", f"guard range [{int(bases[r])}, ...) has non-positive "
            f"extent {int(extents[r])}", where=(int(bases[r]),)))
    order = np.argsort(bases, kind="stable")
    b, e = bases[order], bases[order] + np.maximum(extents[order], 0)
    olap = np.flatnonzero(e[:-1] > b[1:])
    for r in olap[:8].tolist():
        findings.append(Finding(
            "EPV-004", f"guard range [{int(b[r])}, {int(e[r])}) overlaps "
            f"[{int(b[r + 1])}, {int(e[r + 1])})",
            where=(int(b[r]), int(b[r + 1]))))
    uniq, cnt = np.unique(gids, return_counts=True)
    for g in uniq[cnt > 1][:8].tolist():
        findings.append(Finding(
            "EPV-005", f"guard id {int(g)} registered "
            f"{int(cnt[uniq == g][0])} times: buckets sharing an id merge "
            "their write counts and fences fire early", where=(int(g),)))
    return findings


def _resolve(offs: np.ndarray, bases, ends, gids) -> np.ndarray:
    """Vectorized landing-offset -> guard-id resolution over a *sorted*
    table; -1 for unregistered memory.  Local (not GuardTable.resolve_batch)
    so malformed tables can still be analyzed."""
    i = np.searchsorted(bases, offs, side="right") - 1
    j = np.maximum(i, 0)
    ok = (i >= 0) & (offs < ends[j])
    return np.where(ok, gids[j], -1)


# ------------------------------------------------------------------------
# cross-stream checks (EPV-006/007/012)
# ------------------------------------------------------------------------
def verify_command_streams(cs, *, net_cfg=None,
                           n_channels: Optional[int] = None,
                           label: str = "cs") -> list[Finding]:
    """Full static check of one LL round's ``CommandStreams``: per-stream
    widths, guard-table shape, guard coverage of every dispatch write,
    exact fence counts, and combine-unguarded — plus the net-config
    displacement bound when ``net_cfg`` is given."""
    findings = []
    findings += verify_stream(cs.writes, n_channels=n_channels,
                              label=f"{label}.writes")
    findings += verify_stream(cs.fences, n_channels=n_channels,
                              label=f"{label}.fences")
    findings += verify_stream(cs.combines, n_channels=n_channels,
                              label=f"{label}.combines")
    findings += verify_guard_table(cs.guard_table)
    if net_cfg is not None:
        findings += verify_net_config(net_cfg)

    bases, extents, gids = _table_arrays(cs.guard_table)
    order = np.argsort(bases, kind="stable")
    sb, se, sg = bases[order], (bases + extents)[order], gids[order]

    # EPV-006: every dispatch write range fully inside one guard range, or
    # fully outside all of them (straddling in corrupts fence counting)
    w = np.asarray(cs.writes).reshape(-1, 4)
    if w.size and sb.size:
        wc = unpack_cmds(w)
        lo, hi = wc.dst_off, wc.dst_off + wc.length
        i = np.searchsorted(sb, lo, side="right") - 1
        j = np.maximum(i, 0)
        inside = (i >= 0) & (lo < se[j])
        contained = inside & (hi <= se[j])
        # rows starting outside any range must not run into the next one
        nxt = np.searchsorted(sb, lo, side="left")
        creeps = ~inside & (nxt < len(sb)) & (hi > sb[np.minimum(nxt,
                                                                 len(sb) - 1)])
        bad = (inside & ~contained) | creeps
        for r in np.flatnonzero(bad)[:8].tolist():
            findings.append(Finding(
                "EPV-006", f"{label}.writes[{r}]: landing range "
                f"[{int(lo[r])}, {int(hi[r])}) is not fully contained in "
                "one registered guard range (inline scales outside the "
                "bucket?)", where=(label, r, int(lo[r]), int(hi[r]))))
        # per-(pusher, dst, gid) write totals for EPV-007, vectorized:
        # pusher/dst are 12-bit ranks and gid is a 32-bit wide id, so the
        # triple packs into one int64 key for a single np.unique pass
        wgid = np.where(contained, sg[j], -1)
        pusher = np.asarray(cs.write_pusher, np.int64).reshape(-1)
        keep = wgid >= 0
        if keep.any():
            key = ((pusher[keep] * 4096 + wc.dst_rank[keep]) << 32) \
                | wgid[keep]
            wuk, wucnt = np.unique(key, return_counts=True)
        else:
            wuk = wucnt = np.zeros(0, np.int64)
    else:
        wuk = wucnt = np.zeros(0, np.int64)

    # EPV-007: each fence's required count == matching write total
    # (vectorized: one sorted-key lookup for the whole fence stream)
    f = np.asarray(cs.fences).reshape(-1, 4)
    if f.size:
        fc = unpack_cmds(f)
        fpush = np.asarray(cs.fence_pusher, np.int64).reshape(-1)
        proper = (fc.op == int(Op.ATOMIC)) & ((fc.flags & FLAG_FENCE) != 0)
        is_reg = np.isin(fc.dst_off, gids) if gids.size else \
            np.zeros(len(f), bool)
        for r in np.flatnonzero(proper & ~is_reg)[:8].tolist():
            findings.append(Finding(
                "EPV-007", f"{label}.fences[{r}]: fence addresses "
                f"unregistered guard id {int(fc.dst_off[r])}",
                where=(label, r, int(fc.dst_off[r]))))
        rows = np.flatnonzero(proper & is_reg)
        if rows.size:
            fkey = ((fpush[rows] * 4096 + fc.dst_rank[rows]) << 32) \
                | fc.dst_off[rows]
            if wuk.size:
                idx = np.clip(np.searchsorted(wuk, fkey), 0, len(wuk) - 1)
                have = np.where(wuk[idx] == fkey, wucnt[idx], 0)
            else:
                have = np.zeros(len(rows), np.int64)
            need = fc.src_off[rows]
            for k in np.flatnonzero(have != need)[:8].tolist():
                r = int(rows[k])
                findings.append(Finding(
                    "EPV-007", f"{label}.fences[{r}]: fence on guard "
                    f"{int(fc.dst_off[r])} requires {int(need[k])} writes "
                    f"but {int(have[k])} resolve to it (pusher "
                    f"{int(fpush[r])} -> rank {int(fc.dst_rank[r])})",
                    where=(label, r, int(fc.dst_off[r]))))

    # EPV-012: combine writes must land entirely in unregistered memory
    c = np.asarray(cs.combines).reshape(-1, 4)
    if c.size and len(sb):
        cc = unpack_cmds(c)
        lo, hi = cc.dst_off, cc.dst_off + cc.length
        i = np.searchsorted(sb, lo, side="right") - 1
        j = np.maximum(i, 0)
        inside = (i >= 0) & (lo < se[j])
        nxt = np.searchsorted(sb, lo, side="left")
        creeps = (nxt < len(sb)) & (hi > sb[np.minimum(nxt, len(sb) - 1)])
        bad = inside | (~inside & creeps)
        for r in np.flatnonzero(bad)[:8].tolist():
            findings.append(Finding(
                "EPV-012", f"{label}.combines[{r}]: combine landing range "
                f"[{int(lo[r])}, {int(hi[r])}) intersects a registered "
                "guard range — combines must never satisfy a dispatch "
                "fence", where=(label, r, int(lo[r]))))
    return findings


# ------------------------------------------------------------------------
# net-config check (EPV-008)
# ------------------------------------------------------------------------
def verify_net_config(net_cfg) -> list[Finding]:
    """srd seq-displacement bound: the receiver unwraps 11-bit wire seqs
    only while displacement < SEQ_MOD // 4 sequences; the reorder window
    and the proxy's coalescing cap must jointly respect it."""
    findings: list[Finding] = []
    if getattr(net_cfg, "mode", "rc") != "srd":
        return findings
    rw = int(net_cfg.reorder_window)
    if rw >= SRD_DISPLACEMENT_BOUND:
        findings.append(Finding(
            "EPV-008", f"reorder_window {rw} >= SEQ_MOD // 4 = "
            f"{SRD_DISPLACEMENT_BOUND}: seq unwrap ambiguous",
            where=(rw,)))
    from repro.core.transport.proxy import coalesce_cap
    cap = coalesce_cap(net_cfg)
    if cap * (rw + 1) > SRD_DISPLACEMENT_BOUND:
        findings.append(Finding(
            "EPV-008", f"coalesce cap {cap} x (reorder_window {rw} + 1) = "
            f"{cap * (rw + 1)} > SEQ_MOD // 4 = {SRD_DISPLACEMENT_BOUND}: "
            "coalesced-run displacement exceeds the unwrap window",
            where=(cap, rw)))
    return findings


# ------------------------------------------------------------------------
# session-layout check (EPV-009)
# ------------------------------------------------------------------------
def verify_session_slots(slots, *, n_channels: int,
                         counter_stride: int) -> list[Finding]:
    """Per-layer session namespaces: memory regions and guard/counter
    windows pairwise disjoint; adjacent slots' channel windows disjoint
    (the round-robin grouping guarantees exactly that much)."""
    findings: list[Finding] = []
    n = len(slots)
    for s, sl in enumerate(slots):
        if sl.ch0 + sl.ncl > n_channels:
            findings.append(Finding(
                "EPV-009", f"slot {s}: channel window [{sl.ch0}, "
                f"{sl.ch0 + sl.ncl}) exceeds n_channels {n_channels}",
                where=(s,)))
    for a in range(n):
        for b in range(a + 1, n):
            sa, sb_ = slots[a], slots[b]
            if sa.send0 < sb_.end and sb_.send0 < sa.end:
                findings.append(Finding(
                    "EPV-009", f"slots {a}/{b}: memory regions "
                    f"[{sa.send0}, {sa.end}) and [{sb_.send0}, {sb_.end}) "
                    "overlap", where=(a, b)))
            ga = (sa.guard0, sa.guard0 + counter_stride)
            gb = (sb_.guard0, sb_.guard0 + counter_stride)
            if ga[0] < gb[1] and gb[0] < ga[1]:
                findings.append(Finding(
                    "EPV-009", f"slots {a}/{b}: guard/counter windows "
                    f"[{ga[0]}, {ga[1]}) and [{gb[0]}, {gb[1]}) overlap",
                    where=(a, b)))
            if b == a + 1 and n > 1:
                ca = (sa.ch0, sa.ch0 + sa.ncl)
                cb = (sb_.ch0, sb_.ch0 + sb_.ncl)
                if ca[0] < cb[1] and cb[0] < ca[1]:
                    findings.append(Finding(
                        "EPV-009", f"adjacent slots {a}/{b} share channel "
                        f"windows [{ca[0]}, {ca[1]}) and [{cb[0]}, "
                        f"{cb[1]}): their in-flight streams would share a "
                        "wire seq space", where=(a, b)))
    return findings


# ------------------------------------------------------------------------
# omnibus entry points
# ------------------------------------------------------------------------
def verify(cs=None, *, net_cfg=None, guard_table=None, slots=None,
           n_channels: Optional[int] = None,
           counter_stride: Optional[int] = None,
           label: str = "cs") -> list[Finding]:
    """Run every applicable check for the pieces given; returns the
    (possibly empty) list of findings."""
    findings: list[Finding] = []
    if cs is not None:
        findings += verify_command_streams(cs, net_cfg=net_cfg,
                                           n_channels=n_channels,
                                           label=label)
    elif net_cfg is not None:
        findings += verify_net_config(net_cfg)
    if guard_table is not None:
        findings += verify_guard_table(guard_table)
    if slots is not None:
        findings += verify_session_slots(
            slots, n_channels=n_channels or N_CHANNELS_MAX,
            counter_stride=counter_stride or 0)
    return findings


def verify_or_raise(cs=None, **kw) -> None:
    """Raise :class:`ProtocolError` listing every finding (rule ids first)
    if any invariant fails; no-op otherwise.  ``EPWorld`` calls this at
    stream-build time, the fuzz harness on every generated stream."""
    findings = verify(cs, **kw)
    if findings:
        shown = "\n  ".join(str(f) for f in findings[:8])
        more = f"\n  ... and {len(findings) - 8} more" \
            if len(findings) > 8 else ""
        raise ProtocolError(
            f"protocol verification failed ({len(findings)} finding(s)):"
            f"\n  {shown}{more}")
