"""Shared transformer layer primitives: RMSNorm, RoPE, GQA attention, SwiGLU.

All functions are pure (params passed explicitly) and jit/scan friendly.
Attention is implemented flash-style (blocked over q and kv with a running
softmax) so that 32k-sequence prefill lowers without materialising S x S
score matrices; the Pallas TPU kernel in ``repro.kernels.flash_attention``
shares the same oracle (``repro.kernels.ref``).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------- RMSNorm --
def rmsnorm_init(d: int) -> Array:
    return jnp.ones((d,), jnp.float32)


def rmsnorm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps) * scale).astype(dt)


# ------------------------------------------------------------------- RoPE --
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D) ; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- Attention --
class AttnParams(NamedTuple):
    wq: Array          # (d_model, H, Dh)
    wk: Array          # (d_model, Hkv, Dh)
    wv: Array          # (d_model, Hkv, Dh)
    wo: Array          # (H, Dh, d_model)
    bq: Optional[Array]
    bk: Optional[Array]
    bv: Optional[Array]
    q_norm: Optional[Array]   # (Dh,) qk-norm scales
    k_norm: Optional[Array]


def attn_init(cfg: ModelConfig, key: Array) -> AttnParams:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(h * hd)
    mk = lambda k, shape, sc: (jax.random.normal(k, shape, jnp.float32) * sc)
    return AttnParams(
        wq=mk(k1, (d, h, hd), s), wk=mk(k2, (d, hkv, hd), s),
        wv=mk(k3, (d, hkv, hd), s), wo=mk(k4, (h, hd, d), so),
        bq=jnp.zeros((h, hd), jnp.float32) if cfg.qkv_bias else None,
        bk=jnp.zeros((hkv, hd), jnp.float32) if cfg.qkv_bias else None,
        bv=jnp.zeros((hkv, hd), jnp.float32) if cfg.qkv_bias else None,
        q_norm=rmsnorm_init(hd) if cfg.qk_norm else None,
        k_norm=rmsnorm_init(hd) if cfg.qk_norm else None,
    )


def _qkv(cfg: ModelConfig, p: AttnParams, x: Array, positions: Array):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq.astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk.astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv.astype(dt))
    if p.bq is not None:
        q = q + p.bq.astype(dt)
        k = k + p.bk.astype(dt)
        v = v + p.bv.astype(dt)
    if p.q_norm is not None:
        q = rmsnorm(q, p.q_norm, cfg.norm_eps)
        k = rmsnorm(k, p.k_norm, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention_blocked(q: Array, k: Array, v: Array, *, causal: bool = True,
                            q_block: int = 512, kv_block: int = 512,
                            causal_skip: bool = False) -> Array:
    """Blocked causal attention, O(block^2) live memory.

    q: (B, Sq, H, Dh); k/v: (B, Skv, Hkv, Dh) with H % Hkv == 0.
    ``causal_skip``: hierarchical causal decomposition that avoids computing
    fully-masked blocks (beyond-paper perf path; see EXPERIMENTS.md §Perf).
    """
    if causal and causal_skip and q.shape[1] == k.shape[1]:
        return _causal_hierarchical(q, k, v, q_block)
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    nq = -(-Sq // q_block)
    nk = -(-Skv // kv_block)
    qb = q.reshape(B, nq, q_block, H, Dh)

    def q_step(_, qi_idx):
        i, qi = qi_idx                                 # qi: (B, qb, H, Dh)
        o = jnp.zeros((B, q_block, H, Dh), jnp.float32)
        m = jnp.full((B, q_block, H), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, q_block, H), jnp.float32)

        def kv_step(carry, j):
            o, m, l = carry
            kj = lax.dynamic_slice_in_dim(k, j * kv_block, kv_block, axis=1)
            vj = lax.dynamic_slice_in_dim(v, j * kv_block, kv_block, axis=1)
            kj = jnp.repeat(kj, rep, axis=2)
            vj = jnp.repeat(vj, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bqhk", qi, kj).astype(jnp.float32) * scale
            if causal:
                qpos = i * q_block + jnp.arange(q_block)
                kpos = j * kv_block + jnp.arange(kv_block)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
            mj = jnp.maximum(m, s.max(axis=-1))
            mj_safe = jnp.where(jnp.isneginf(mj), 0.0, mj)
            pj = jnp.exp(s - mj_safe[..., None])
            corr = jnp.exp(m - mj_safe)
            l2 = l * corr + pj.sum(axis=-1)
            o2 = o * corr[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", pj.astype(vj.dtype), vj).astype(jnp.float32)
            return (o2, mj, l2), None

        (o, m, l), _ = lax.scan(kv_step, (o, m, l), jnp.arange(nk))
        l = jnp.where(l == 0.0, 1.0, l)
        return None, (o / l[..., None]).astype(q.dtype)

    _, ob = lax.scan(q_step, None, (jnp.arange(nq), qb.swapaxes(0, 1)))
    return ob.swapaxes(0, 1).reshape(B, Sq, H, Dh)


class _POut(NamedTuple):
    o: Array   # (B, S, H, Dh) fp32, un-normalised numerator
    m: Array   # (B, S, H) running max
    l: Array   # (B, S, H) running denom


def _partial_attn(q, k, v, mask, scale) -> _POut:
    rep = q.shape[2] // k.shape[2]
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bqhk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    m = s.max(axis=-1)
    # fully-masked rows (e.g. a cache shard entirely beyond `pos`) have
    # m = -inf; exp(s - m) would be NaN — use a zero-safe max instead.
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bqhk,bkhd->bqhd", p.astype(v.dtype), v).astype(jnp.float32)
    return _POut(o, m, l)


def merge_partials(parts: list[_POut]) -> Array:
    """LSE-merge partial attention results (flash-decoding combine)."""
    m = parts[0].m
    for p in parts[1:]:
        m = jnp.maximum(m, p.m)
    o = jnp.zeros_like(parts[0].o)
    l = jnp.zeros_like(parts[0].l)
    for p in parts:
        c = jnp.exp(jnp.where(jnp.isneginf(p.m), -jnp.inf, p.m - m))
        o = o + p.o * c[..., None]
        l = l + p.l * c
    l = jnp.where(l == 0.0, 1.0, l)
    return o / l[..., None]


def _causal_hierarchical(q, k, v, block: int) -> Array:
    """Exact causal attention without fully-masked-block waste.

    Level 0: block-diagonal causal blocks (masked).  Level k>=1: at stride
    2^k * block, the upper half of each pair attends the lower half with NO
    mask (dense matmuls, MXU-friendly).  FLOPs ~ S^2/2 instead of S^2.
    """
    B, S, H, Dh = q.shape
    scale = 1.0 / math.sqrt(Dh)
    nb = S // block
    assert nb & (nb - 1) == 0, "hierarchical causal needs power-of-two blocks"
    qb = q.reshape(B, nb, block, H, Dh)
    kb = k.reshape(B, nb, block, k.shape[2], Dh)
    vb = v.reshape(B, nb, block, v.shape[2], Dh)

    # diagonal (causal-masked) blocks, batched over nb
    pos = jnp.arange(block)
    dmask = (pos[:, None] >= pos[None, :])[None, :, None, :]
    diag = jax.vmap(lambda qi, ki, vi: _partial_attn(qi, ki, vi, dmask, scale),
                    in_axes=(1, 1, 1), out_axes=1)(qb, kb, vb)
    parts_per_block: list[list[_POut]] = [[_POut(diag.o[:, i], diag.m[:, i], diag.l[:, i])]
                                          for i in range(nb)]
    # off-diagonal levels: q half 2 attends kv half 1, unmasked
    level = 1
    while (1 << level) <= nb:
        span = 1 << level
        for start in range(0, nb, span):
            lo = slice(start, start + span // 2)
            hi = slice(start + span // 2, start + span)
            kk = kb[:, lo].reshape(B, -1, k.shape[2], Dh)
            vv = vb[:, lo].reshape(B, -1, v.shape[2], Dh)
            qq = qb[:, hi].reshape(B, -1, H, Dh)
            part = _partial_attn(qq, kk, vv, None, scale)
            half = span // 2
            for bi in range(half):
                sl = slice(bi * block, (bi + 1) * block)
                parts_per_block[start + half + bi].append(
                    _POut(part.o[:, sl], part.m[:, sl], part.l[:, sl]))
        level += 1
    outs = [merge_partials(ps).astype(q.dtype) for ps in parts_per_block]
    return jnp.concatenate(outs, axis=1).reshape(B, S, H, Dh)


def attention(cfg: ModelConfig, p: AttnParams, x: Array, positions: Array,
              *, causal_skip: bool = False) -> Array:
    """Training/prefill self-attention: (B, S, d_model) -> (B, S, d_model)."""
    q, k, v = _qkv(cfg, p, x, positions)
    S = x.shape[1]
    blk = min(512, S)
    o = flash_attention_blocked(q, k, v, causal=True, q_block=blk, kv_block=blk,
                                causal_skip=causal_skip)
    return jnp.einsum("bshk,hkd->bsd", o, p.wo.astype(x.dtype))


# -------------------------------------------------------- Decode attention --
class KVCache(NamedTuple):
    k: Array   # (B, S_max, Hkv, Dh)
    v: Array


def decode_qkv(cfg: ModelConfig, p: AttnParams, x: Array, pos: Array):
    """x: (B, 1, d) new token; pos: scalar current position."""
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    return _qkv(cfg, p, x, positions)


def decode_attention_local(q, cache_k, cache_v, pos, *, start: int = 0) -> _POut:
    """Partial decode attention over a (possibly sharded) cache slice.

    q: (B, 1, H, Dh); cache_*: (B, S_local, Hkv, Dh); valid positions are
    global indices [0, pos]; this shard covers [start, start + S_local).
    Returns un-normalised partials for LSE merge across shards.
    """
    S_local = cache_k.shape[1]
    kpos = start + jnp.arange(S_local)
    mask = (kpos <= pos)[None, None, None, :]
    scale = 1.0 / math.sqrt(q.shape[-1])
    return _partial_attn(q, cache_k, cache_v, mask, scale)


# ----------------------------------------------------------------- SwiGLU --
class MLPParams(NamedTuple):
    w_gate: Array   # (d, ff)
    w_up: Array     # (d, ff)
    w_down: Array   # (ff, d)


def mlp_init(d: int, ff: int, key: Array) -> MLPParams:
    k1, k2, k3 = jax.random.split(key, 3)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    return MLPParams(
        w_gate=jax.random.normal(k1, (d, ff), jnp.float32) * s,
        w_up=jax.random.normal(k2, (d, ff), jnp.float32) * s,
        w_down=jax.random.normal(k3, (ff, d), jnp.float32) * so,
    )


def swiglu(p: MLPParams, x: Array) -> Array:
    dt = x.dtype
    g = x @ p.w_gate.astype(dt)
    u = x @ p.w_up.astype(dt)
    return (jax.nn.silu(g) * u) @ p.w_down.astype(dt)
