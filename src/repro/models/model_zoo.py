"""Model zoo: init/forward/loss/decode for every assigned architecture.

Layers repeat with a static ``period`` (1 for uniform stacks, 8 for jamba);
parameters for each slot in the period are stacked over ``n_periods`` and the
forward pass is a single ``lax.scan`` over periods (small HLO, fast 512-way
SPMD compiles).  VLM/audio frontends are stubs: precomputed prefix embeddings
arrive via ``input_specs`` and are prepended to the embedded token stream.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import repro.compat  # noqa: F401  jax version shims (jax.shard_map)
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.moe import padded_experts_static
from repro.distributed.sharding import DistCtx, scan_period
from repro.models import blocks as B
from repro.models.layers import rmsnorm, rmsnorm_init

Array = jax.Array


# ------------------------------------------------------------------ init --
def init_params(cfg: ModelConfig, key: Array) -> dict:
    period, n_periods = scan_period(cfg)
    keys = jax.random.split(key, period + 2)
    vp = cfg.padded_vocab()
    d = cfg.d_model
    params: dict = {
        "embed": jax.random.normal(keys[-1], (vp, d), jnp.float32) * 0.02,
        "final_ln": rmsnorm_init(d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[-2], (d, vp), jnp.float32) / math.sqrt(d)

    def stack_slot(s: int):
        ks = jax.random.split(keys[s], n_periods)
        ps = [B.block_init(cfg, s, ks[i]) for i in range(n_periods)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    params["blocks"] = {f"slot{s}": stack_slot(s) for s in range(period)}
    return params


def cast_params(params: dict, dtype) -> dict:
    """Cast float params to compute dtype (norm scales stay fp32)."""
    def f(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if x.dtype == jnp.float32 and not any(
                t in name for t in ("ln", "norm", "A_log", "dt_b", "router",
                                    "D", "conv_b")):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map_with_path(f, params)


# --------------------------------------------------------------- forward --
def forward(cfg: ModelConfig, params: dict, tokens: Array,
            prefix_embeds: Optional[Array] = None, *,
            dist: Optional[DistCtx] = None, moe_mode: str = "ht",
            moe_chunks: int = 1, causal_skip: bool = False,
            unroll: bool = False, sp_islands: bool = False,
            remat_policy: str = "full",
            moe_backend=None) -> tuple[Array, dict]:
    """tokens (B, S_txt) [+ prefix (B, S_pre, D)] -> hidden (B, S, D), aux.

    ``moe_backend``: name or EPBackend instance shared by every MoE layer
    (the persistent-session path registers transport state once per step;
    host-backend instances require ``unroll=True`` outside jit)."""
    period, n_periods = scan_period(cfg)
    x = B.vocab_embed(dist, params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if dist is not None:
        x = dist.constraint(x, dist.batch_axes, dist.seq_axis, None)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (x.shape[0], S))

    def period_body(x, slot_params):
        aux_l = {}
        aux_loss = jnp.float32(0.0)
        dropped = jnp.float32(0.0)
        for s in range(period):
            x, aux = B.block_apply(cfg, dist, slot_params[f"slot{s}"], x,
                                   positions, moe_mode=moe_mode,
                                   moe_chunks=moe_chunks,
                                   causal_skip=causal_skip,
                                   sp_islands=sp_islands,
                                   moe_backend=moe_backend)
            aux_loss = aux_loss + aux.get("aux_loss", jnp.float32(0.0))
            dropped = dropped + aux.get("dropped", jnp.float32(0.0))
            if "load" in aux:
                aux_l[f"slot{s}"] = aux["load"]
        return x, {"aux_loss": aux_loss, "dropped": dropped, "loads": aux_l}

    body = period_body
    if cfg.remat:
        policy = {"full": None,
                  "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                  }[remat_policy]
        body = jax.checkpoint(period_body, prevent_cse=False, policy=policy)
    if unroll:
        # python-loop over periods (used by the dry-run cost extrapolation:
        # XLA cost_analysis counts a while body once, so truncated models
        # are compiled scan-free and extrapolated; see launch/dryrun.py)
        auxes = []
        for i in range(n_periods):
            slot_i = jax.tree.map(lambda a: a[i], params["blocks"])
            x, a = body(x, slot_i)
            auxes.append(a)
        aux_s = jax.tree.map(lambda *xs: jnp.stack(xs), *auxes)
    else:
        x, aux_s = lax.scan(body, x, params["blocks"])
    aux = {"aux_loss": aux_s["aux_loss"].sum(),
           "dropped": aux_s["dropped"].mean() if cfg.moe.enabled else jnp.float32(0.0),
           "loads": aux_s["loads"]}  # per slot: (n_periods, E) expert loads
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return x, aux


def lm_head_weight(cfg: ModelConfig, params: dict) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def loss_fn(cfg: ModelConfig, params: dict, tokens: Array, labels: Array,
            prefix_embeds: Optional[Array] = None, *,
            dist: Optional[DistCtx] = None, moe_mode: str = "ht",
            moe_chunks: int = 1, causal_skip: bool = False,
            loss_chunk: int = 2048, unroll: bool = False,
            sp_islands: bool = False,
            remat_policy: str = "full",
            moe_backend=None) -> tuple[Array, dict]:
    """Next-token cross entropy with a vocab-parallel, seq-chunked head."""
    dtype = jnp.dtype(cfg.dtype)
    x, aux = forward(cfg, cast_params(params, dtype), tokens, prefix_embeds,
                     dist=dist, moe_mode=moe_mode, moe_chunks=moe_chunks,
                     causal_skip=causal_skip, unroll=unroll,
                     sp_islands=sp_islands, remat_policy=remat_policy,
                     moe_backend=moe_backend)
    head = lm_head_weight(cfg, params).astype(dtype)
    if prefix_embeds is not None:  # prefix positions carry no label
        x = x[:, prefix_embeds.shape[1]:]
    total, count = _chunked_xent(cfg, dist, x, head, labels, loss_chunk)
    loss = total / jnp.maximum(count, 1.0) + aux["aux_loss"]
    metrics = {"xent": total / jnp.maximum(count, 1.0),
               "aux_loss": aux["aux_loss"], "dropped": aux["dropped"],
               "loads": jax.lax.stop_gradient(aux["loads"])}
    return loss, metrics


def _chunked_xent(cfg: ModelConfig, dist: Optional[DistCtx], x: Array,
                  head: Array, labels: Array, chunk: int):
    Bsz, S, D = x.shape
    V = head.shape[1]
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    total = jnp.float32(0.0)
    count = jnp.float32(0.0)
    for c in range(n_chunks):
        sl = slice(c * chunk, min((c + 1) * chunk, S))
        xc, yc = x[:, sl], labels[:, sl]
        if dist is not None and dist.model_axis:
            t, n = _xent_island(dist, xc, head, yc, cfg.vocab_size)
        else:
            logits = (xc @ head).astype(jnp.float32)
            logits = jnp.where(jnp.arange(V)[None, None] < cfg.vocab_size,
                               logits, -jnp.inf)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
            ok = (yc >= 0).astype(jnp.float32)
            t = ((lse - gold) * ok).sum()
            n = ok.sum()
        total += t
        count += n
    return total, count


def _xent_island(dist: DistCtx, xc: Array, head: Array, yc: Array,
                 vocab_real: int):
    """Vocab-parallel cross entropy: head (D, V/model) local per shard."""
    mesh, m, bd = dist.mesh, dist.model_axis, dist.batch_axes
    V_local = head.shape[1] // mesh.shape[m]

    def island(x_l, h_l, y_l):
        start = lax.axis_index(m) * V_local
        logits = (x_l @ h_l).astype(jnp.float32)          # (B_l, Sc, V_l)
        vmask = (start + jnp.arange(V_local)) < vocab_real
        logits = jnp.where(vmask[None, None], logits, -jnp.inf)
        # stability max is gradient-free (lse grad == softmax either way);
        # pmax has no JVP rule, so it must see a symbolic-zero tangent:
        # stop_gradient goes INSIDE the pmax.
        mx = lax.pmax(lax.stop_gradient(logits.max(-1)), m)
        se = lax.psum(jnp.exp(logits - mx[..., None]).sum(-1), m)
        lse = mx + jnp.log(se)
        idx = y_l - start
        ok_v = (idx >= 0) & (idx < V_local)
        gold_l = jnp.take_along_axis(logits, jnp.clip(idx, 0, V_local - 1)[..., None],
                                     axis=-1)[..., 0]
        gold = lax.psum(jnp.where(ok_v, gold_l, 0.0), m)
        ok = (y_l >= 0).astype(jnp.float32)
        t = lax.psum(((lse - gold) * ok).sum(), (m,) + tuple(bd))
        n = lax.psum(ok.sum(), (m,) + tuple(bd))
        return t, n

    return jax.shard_map(island, mesh=mesh,
                         in_specs=(P(bd, None, None), P(None, m), P(bd, None)),
                         out_specs=(P(), P()), check_vma=False)(xc, head, yc)


# ---------------------------------------------------------------- decode --
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    period, n_periods = scan_period(cfg)

    def stack_slot(s: int):
        c = B.block_init_cache(cfg, s, batch, max_len, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape).copy(),
            c)

    return {f"slot{s}": stack_slot(s) for s in range(period)}


def prefill(cfg: ModelConfig, params: dict, cache: dict, tokens: Array,
            *, dist: Optional[DistCtx] = None, moe_mode: str = "ht",
            unroll: bool = False) -> tuple[Array, dict]:
    """Batched prompt prefill: ONE forward pass over tokens (B, S) that
    fills ``cache[:, :S]`` for every attention layer and returns the
    last-position logits (B, V_pad) — the single-pass replacement for S
    ``decode_step`` calls.  Local-cache path (no model-axis sharding) and
    attention-only stacks; mamba archs keep the per-token loop."""
    assert dist is None or dist.model_axis is None, \
        "batched prefill is the local-cache path; sharded caches decode"
    assert not cfg.mamba.enabled, "mamba prefill goes through decode_step"
    period, n_periods = scan_period(cfg)
    dtype = jnp.dtype(cfg.dtype)
    cparams = cast_params(params, dtype)
    x = B.vocab_embed(dist, cparams["embed"], tokens)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (x.shape[0], S))

    def period_body(x, scanned):
        slot_params, slot_cache = scanned
        new_cache = {}
        for s in range(period):
            x, c2, _ = B.block_prefill(cfg, dist, slot_params[f"slot{s}"], x,
                                       slot_cache[f"slot{s}"], positions,
                                       moe_mode=moe_mode)
            new_cache[f"slot{s}"] = c2
        return x, new_cache

    if unroll:
        caches = []
        for i in range(n_periods):
            sl = jax.tree.map(lambda a: a[i], (cparams["blocks"], cache))
            x, c2 = period_body(x, sl)
            caches.append(c2)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, new_cache = lax.scan(period_body, x, (cparams["blocks"], cache))
    x = rmsnorm(x, cparams["final_ln"], cfg.norm_eps)
    head = lm_head_weight(cfg, cparams)
    logits = (x[:, -1] @ head).astype(jnp.float32)
    if dist is not None:
        logits = dist.constraint(logits, dist.batch_axes, dist.model_axis)
    return logits, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: Array,
                pos, *, dist: Optional[DistCtx] = None,
                moe_mode: str = "ll", unroll: bool = False) -> tuple[Array, dict]:
    """One decode step: tokens (B, 1) at position ``pos`` (same for batch).

    Returns (logits (B, V_pad), new_cache).
    """
    period, n_periods = scan_period(cfg)
    dtype = jnp.dtype(cfg.dtype)
    cparams = cast_params(params, dtype)
    x = B.vocab_embed(dist, cparams["embed"], tokens)
    if dist is not None:
        from repro.distributed.sharding import effective_batch_axes
        x = dist.constraint(x, effective_batch_axes(dist, x.shape[0]),
                            None, None)

    def period_body(x, scanned):
        slot_params, slot_cache = scanned
        new_cache = {}
        for s in range(period):
            x, c2, _ = B.block_decode(cfg, dist, slot_params[f"slot{s}"], x,
                                      slot_cache[f"slot{s}"], pos,
                                      moe_mode=moe_mode)
            new_cache[f"slot{s}"] = c2
        return x, new_cache

    if unroll:
        caches = []
        for i in range(n_periods):
            sl = jax.tree.map(lambda a: a[i], (cparams["blocks"], cache))
            x, c2 = period_body(x, sl)
            caches.append(c2)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, new_cache = lax.scan(period_body, x, (cparams["blocks"], cache))
    x = rmsnorm(x, cparams["final_ln"], cfg.norm_eps)
    head = lm_head_weight(cfg, cparams)
    logits = (x[:, 0] @ head).astype(jnp.float32)
    if dist is not None:
        logits = dist.constraint(logits, dist.batch_axes, dist.model_axis)
    return logits, new_cache
