"""Transformer/Mamba blocks with mesh-aware sharding constraints, plus the
distributed decode-attention and vocab-parallel embedding islands.

Layout contract (DESIGN.md §4): the residual stream between blocks is
``P(batch_axes, "model", None)`` — batch over data axes, sequence over the
model axis (sequence parallelism).  Attention/MLP gather the sequence and
reduce-scatter it back (Megatron-style SP); the MoE island consumes tokens
in-place (EP needs no gather); Mamba gathers the sequence and keeps d_inner
on "model".
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional  # noqa: F401

import repro.compat  # noqa: F401  jax version shims (jax.shard_map)
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.moe import moe_apply, moe_init
from repro.distributed.sharding import DistCtx
from repro.models import mamba as mamba_mod
from repro.models.layers import (AttnParams, KVCache, MLPParams, _qkv,
                                 apply_rope, attention, attn_init,
                                 decode_attention_local, decode_qkv,
                                 flash_attention_blocked, mlp_init, rmsnorm,
                                 rmsnorm_init, swiglu)

Array = jax.Array


def _c(dist: Optional[DistCtx], x: Array, *spec):
    return dist.constraint(x, *spec) if dist is not None else x


def block_init(cfg: ModelConfig, layer_slot: int, key: Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model), "ln2": rmsnorm_init(cfg.d_model)}
    if cfg.is_attn_layer(layer_slot):
        ap = attn_init(cfg, k1)
        p["attn"] = {k: v for k, v in ap._asdict().items() if v is not None}
    elif cfg.mamba.enabled:
        p["mamba"] = mamba_mod.mamba_init(cfg, k1)
    if cfg.is_moe_layer(layer_slot):
        p["moe"] = moe_init(cfg, k2)
    elif cfg.d_ff:
        p["mlp"] = dict(mlp_init(cfg.d_model, cfg.d_ff, k3)._asdict())
    return p


def _attn_params(cfg: ModelConfig, d: dict) -> AttnParams:
    return AttnParams(wq=d["wq"], wk=d["wk"], wv=d["wv"], wo=d["wo"],
                      bq=d.get("bq"), bk=d.get("bk"), bv=d.get("bv"),
                      q_norm=d.get("q_norm"), k_norm=d.get("k_norm"))


def block_apply(cfg: ModelConfig, dist: Optional[DistCtx], p: dict, x: Array,
                positions: Array, *, moe_mode: str = "ht",
                moe_chunks: int = 1, causal_skip: bool = False,
                sp_islands: bool = False,
                moe_backend=None) -> tuple[Array, dict]:
    """x: (B, S, D) residual (sharded P(bd, model, None)) -> (x', aux).

    ``sp_islands``: route attention/MLP through explicit shard_map islands
    (manual Megatron TP+SP: all-gather(seq) fwd / reduce-scatter bwd) instead
    of GSPMD constraint transitions — see EXPERIMENTS.md §Perf.

    ``moe_backend``: a backend name or :class:`EPBackend` instance handed to
    :func:`moe_apply` — a model passes one instance to ALL its blocks for
    the persistent-session path (registration once per step, DESIGN §16).
    """
    aux = {}
    bd = dist.batch_axes if dist else None
    use_islands = sp_islands and _islands_ok(cfg, dist, x)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if "attn" in p:
        if use_islands:
            h = _attention_island(cfg, dist, p["attn"], h, positions,
                                  causal_skip=causal_skip)
        else:
            h = _c(dist, h, bd, None, None)          # gather seq (SP)
            h = attention(cfg, _attn_params(cfg, p["attn"]), h, positions,
                          causal_skip=causal_skip)
            h = _c(dist, h, bd, dist.seq_axis if dist else None, None)
    elif "mamba" in p:
        h = _c(dist, h, bd, None, None)
        h = mamba_mod.mamba_apply(cfg, p["mamba"], h)
        h = _c(dist, h, bd, dist.seq_axis if dist else None, None)
    x = x + h

    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        h, aux = moe_apply(cfg, dist, p["moe"], h, mode=moe_mode,
                           chunks=moe_chunks, backend=moe_backend)
    elif "mlp" in p:
        if use_islands:
            h = _mlp_island(cfg, dist, p["mlp"], h)
        else:
            h = _c(dist, h, bd, None, None)
            h = swiglu(MLPParams(**{k: p["mlp"][k]
                                    for k in ("w_gate", "w_up", "w_down")}), h)
            h = _c(dist, h, bd, dist.seq_axis if dist else None, None)
    else:
        h = jnp.zeros_like(h)
    return x + h, aux


def _islands_ok(cfg: ModelConfig, dist: Optional[DistCtx], x: Array) -> bool:
    if dist is None or dist.model_axis is None:
        return False
    msz = dist.mesh.shape[dist.model_axis]
    import math as _m
    bsz = _m.prod(dist.mesh.shape[a] for a in dist.batch_axes)
    return (x.shape[1] % msz == 0 and x.shape[0] % bsz == 0
            and (cfg.attention_free or cfg.n_heads % msz == 0)
            and (not cfg.d_ff or cfg.d_ff % msz == 0))


def _attention_island(cfg: ModelConfig, dist: DistCtx, pa: dict, x: Array,
                      positions: Array, *, causal_skip: bool) -> Array:
    """Manual TP+SP attention: all-gather(seq) -> local-head attention ->
    reduce-scatter(seq).  Autodiff through shard_map transposes the
    collectives minimally (gather^T = psum_scatter)."""
    mesh, m, bd = dist.mesh, dist.model_axis, dist.batch_axes
    msz = mesh.shape[m]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    H_l = H // msz
    kv_sharded = Hkv % msz == 0
    rep = H // Hkv

    def island(x_l, pos_l, wq, wk, wv, wo, bq, bk, bv, qn, kn):
        xg = lax.all_gather(x_l, m, axis=1, tiled=True)   # (B_l, S, D)
        pos = lax.all_gather(pos_l, m, axis=1, tiled=True)
        dt = xg.dtype
        midx = lax.axis_index(m)
        q = jnp.einsum("bsd,dhk->bshk", xg, wq.astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", xg, wk.astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", xg, wv.astype(dt))
        if bq is not None:
            q = q + bq.astype(dt)
            k = k + bk.astype(dt)
            v = v + bv.astype(dt)
        if qn is not None:
            q = rmsnorm(q, qn, cfg.norm_eps)
            k = rmsnorm(k, kn, cfg.norm_eps)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        if not kv_sharded:
            # local q heads [midx*H_l, (midx+1)*H_l) need kv heads
            # [midx*H_l//rep, ...): gather the aligned slice dynamically
            kv_per_shard = max(1, H_l // rep)
            start = (midx * H_l) // rep
            k = lax.dynamic_slice_in_dim(k, start, kv_per_shard, axis=2)
            v = lax.dynamic_slice_in_dim(v, start, kv_per_shard, axis=2)
            rep_l = H_l // kv_per_shard
        else:
            rep_l = rep
        S = xg.shape[1]
        blk = min(512, S)
        o = flash_attention_blocked(q, k, v, causal=True, q_block=blk,
                                    kv_block=blk, causal_skip=causal_skip)
        y = jnp.einsum("bshk,hkd->bsd", o, wo.astype(dt))  # partial over m
        return lax.psum_scatter(y, m, scatter_dimension=1, tiled=True)

    qspec = P(None, m, None)
    kvspec = P(None, m, None) if kv_sharded else P(None, None, None)
    bspec_q = P(m, None)
    bspec_kv = P(m, None) if kv_sharded else P(None, None)
    args = [x, positions, pa["wq"], pa["wk"], pa["wv"], pa["wo"],
            pa.get("bq"), pa.get("bk"), pa.get("bv"),
            pa.get("q_norm"), pa.get("k_norm")]
    in_specs = [P(bd, m, None), P(bd, m), qspec, kvspec, kvspec,
                P(m, None, None), bspec_q, bspec_kv, bspec_kv,
                P(None), P(None)]
    # drop None args (optional biases/norms) — shard_map needs real arrays
    keep = [i for i, a in enumerate(args) if a is not None]
    none_mask = [a is None for a in args]

    def wrapper(*present):
        full = []
        it = iter(present)
        for is_none in none_mask:
            full.append(None if is_none else next(it))
        return island(*full)

    return jax.shard_map(wrapper, mesh=mesh,
                         in_specs=tuple(in_specs[i] for i in keep),
                         out_specs=P(bd, m, None),
                         check_vma=False)(*[args[i] for i in keep])


def _mlp_island(cfg: ModelConfig, dist: DistCtx, pm: dict, x: Array) -> Array:
    """Manual TP+SP SwiGLU MLP island."""
    mesh, m, bd = dist.mesh, dist.model_axis, dist.batch_axes

    def island(x_l, wg, wu, wd):
        xg = lax.all_gather(x_l, m, axis=1, tiled=True)   # (B_l, S, D)
        dt = xg.dtype
        h = jax.nn.silu(xg @ wg.astype(dt)) * (xg @ wu.astype(dt))
        y = h @ wd.astype(dt)                             # partial over m
        return lax.psum_scatter(y, m, scatter_dimension=1, tiled=True)

    return jax.shard_map(
        island, mesh=mesh,
        in_specs=(P(bd, m, None), P(None, m), P(None, m), P(m, None)),
        out_specs=P(bd, m, None), check_vma=False)(
        x, pm["w_gate"], pm["w_up"], pm["w_down"])


# ------------------------------------------------------------ decode path --
class BlockCache(NamedTuple):
    """Per-layer decode state: exactly one of (kv, mamba) is meaningful."""
    k: Array
    v: Array
    conv: Array
    ssm: Array


def block_init_cache(cfg: ModelConfig, layer_slot: int, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> BlockCache:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    if cfg.is_attn_layer(layer_slot):
        z = jnp.zeros((batch, max_len, hkv, hd), dtype)
        return BlockCache(k=z, v=z, conv=jnp.zeros((batch, 1, 1), dtype),
                          ssm=jnp.zeros((batch, 1, 1), jnp.float32))
    mc = mamba_mod.mamba_init_cache(cfg, batch, dtype)
    return BlockCache(k=jnp.zeros((batch, 1, 1, 1), dtype),
                      v=jnp.zeros((batch, 1, 1, 1), dtype),
                      conv=mc.conv, ssm=mc.ssm)


def _decode_attn_dist(dist: DistCtx, q, k_new, v_new, cache: BlockCache,
                      pos) -> tuple[Array, BlockCache]:
    """Split-sequence (flash-decoding) attention over the sharded KV cache.

    Global shapes: q (B,1,H,hd); k_new/v_new (B,1,Hkv,hd); cache.k/v
    (B, S_max, Hkv, hd) sharded P(bd_eff, seq_axes, None, None), where
    seq_axes = model axis plus any batch axes idled by a tiny decode batch
    (long_500k shards its 512k cache over every axis; DESIGN.md §4).
    """
    from repro.distributed.sharding import (cache_seq_axes,
                                            effective_batch_axes)
    mesh = dist.mesh
    Bg = q.shape[0]
    bd = effective_batch_axes(dist, Bg)
    seq_axes = cache_seq_axes(dist, Bg)
    n_seq_shards = math.prod(mesh.shape[a] for a in seq_axes)
    S_max = cache.k.shape[1]
    S_local = S_max // n_seq_shards

    def island(q_l, kn, vn, kc, vc, pos):
        idx = jnp.int32(0)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + lax.axis_index(a)
        start = idx * S_local
        loc = jnp.clip(pos - start, 0, S_local - 1)
        in_rng = (pos >= start) & (pos < start + S_local)
        kc2 = lax.dynamic_update_slice_in_dim(kc, kn.astype(kc.dtype), loc, 1)
        vc2 = lax.dynamic_update_slice_in_dim(vc, vn.astype(vc.dtype), loc, 1)
        kc = jnp.where(in_rng, kc2, kc)
        vc = jnp.where(in_rng, vc2, vc)
        part = decode_attention_local(q_l, kc, vc, pos, start=start)
        mx = lax.pmax(part.m, seq_axes)
        c = jnp.exp(jnp.where(jnp.isneginf(part.m), -jnp.inf, part.m - mx))
        o = lax.psum(part.o * c[..., None], seq_axes)
        l = lax.psum(part.l * c, seq_axes)
        o = o / jnp.maximum(l, 1e-9)[..., None]
        return o.astype(q_l.dtype), kc, vc

    sq = seq_axes if len(seq_axes) > 1 else (seq_axes[0] if seq_axes else None)
    o, k2, v2 = jax.shard_map(
        island, mesh=mesh,
        in_specs=(P(bd, None, None, None), P(bd, None, None, None),
                  P(bd, None, None, None), P(bd, sq, None, None),
                  P(bd, sq, None, None), P()),
        out_specs=(P(bd, None, None, None), P(bd, sq, None, None),
                   P(bd, sq, None, None)),
        check_vma=False)(q, k_new, v_new, cache.k, cache.v,
                         jnp.asarray(pos, jnp.int32))
    return o, cache._replace(k=k2, v=v2)


def block_decode(cfg: ModelConfig, dist: Optional[DistCtx], p: dict,
                 x: Array, cache: BlockCache, pos,
                 *, moe_mode: str = "ll") -> tuple[Array, BlockCache, dict]:
    """One-token decode: x (B, 1, D)."""
    aux = {}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if "attn" in p:
        ap = _attn_params(cfg, p["attn"])
        q, k_new, v_new = decode_qkv(cfg, ap, h, pos)
        if dist is not None and dist.model_axis:
            o, cache = _decode_attn_dist(dist, q, k_new, v_new, cache, pos)
        else:
            kc = lax.dynamic_update_slice_in_dim(
                cache.k, k_new.astype(cache.k.dtype), pos, 1)
            vc = lax.dynamic_update_slice_in_dim(
                cache.v, v_new.astype(cache.v.dtype), pos, 1)
            cache = cache._replace(k=kc, v=vc)
            part = decode_attention_local(q, kc, vc, pos)
            l = jnp.where(part.l == 0, 1.0, part.l)
            o = (part.o / l[..., None]).astype(h.dtype)
        h = jnp.einsum("bshk,hkd->bsd", o, ap.wo.astype(h.dtype))
    elif "mamba" in p:
        mc = mamba_mod.MambaCache(conv=cache.conv, ssm=cache.ssm)
        h, mc = mamba_mod.mamba_decode_step(cfg, p["mamba"], h, mc)
        cache = cache._replace(conv=mc.conv, ssm=mc.ssm)
    x = x + h

    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        h, aux = moe_apply(cfg, dist, p["moe"], h, mode=moe_mode)
    elif "mlp" in p:
        h = swiglu(MLPParams(**{k: p["mlp"][k]
                                for k in ("w_gate", "w_up", "w_down")}), h)
    else:
        h = jnp.zeros_like(h)
    return x + h, cache, aux


def block_prefill(cfg: ModelConfig, dist: Optional[DistCtx], p: dict,
                  x: Array, cache: BlockCache, positions: Array,
                  *, moe_mode: str = "ht",
                  moe_chunks: int = 1) -> tuple[Array, BlockCache, dict]:
    """Batched prompt prefill: x (B, S, D) -> (x', cache', aux).

    Causal attention over the whole prompt while the projected k/v land in
    ``cache[:, :S]`` in ONE ``dynamic_update_slice`` — the batched
    replacement for S ``block_decode`` calls (the serving launcher's old
    placeholder).  Local-cache path only: a model-axis mesh shards the
    cache over chips (``_decode_attn_dist``), where prefill stays with the
    distributed decode loop.
    """
    aux = {}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if "attn" in p:
        ap = _attn_params(cfg, p["attn"])
        q, k_new, v_new = _qkv(cfg, ap, h, positions)
        S = x.shape[1]
        blk = min(512, S)
        o = flash_attention_blocked(q, k_new, v_new, causal=True,
                                    q_block=blk, kv_block=blk)
        kc = lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), 0, 1)
        vc = lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), 0, 1)
        cache = cache._replace(k=kc, v=vc)
        h = jnp.einsum("bshk,hkd->bsd", o, ap.wo.astype(h.dtype))
    elif "mamba" in p:
        raise NotImplementedError(
            "batched prefill needs the post-prompt recurrent state; mamba "
            "layers prefill through the per-token decode loop")
    x = x + h

    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        h, aux = moe_apply(cfg, dist, p["moe"], h, mode=moe_mode,
                           chunks=moe_chunks)
    elif "mlp" in p:
        h = swiglu(MLPParams(**{k: p["mlp"][k]
                                for k in ("w_gate", "w_up", "w_down")}), h)
    else:
        h = jnp.zeros_like(h)
    return x + h, cache, aux


# ---------------------------------------------- vocab-parallel embedding --
def vocab_embed(dist: Optional[DistCtx], embed: Array, tokens: Array) -> Array:
    """tokens (B, S) -> (B, S, D); embed (V_pad, D) sharded P("model", None)."""
    if dist is None or dist.model_axis is None:
        return jnp.take(embed, tokens, axis=0)
    from repro.distributed.sharding import effective_batch_axes
    mesh, m = dist.mesh, dist.model_axis
    Bg, S = tokens.shape
    bd = effective_batch_axes(dist, Bg)
    sq = m if (S > 1 and S % mesh.shape[m] == 0) else None
    V_local = embed.shape[0] // mesh.shape[m]

    def island(emb_l, tok_l):
        # tokens are seq-sharded over the same axis as the vocab slices:
        # gather the (tiny, int) token ids, look up against the local vocab
        # slice, then reduce-scatter the partial embeddings back to the
        # seq-sharded layout (Megatron vocab-parallel embedding).
        if sq is not None:
            tok_all = lax.all_gather(tok_l, m, axis=1, tiled=True)  # (B_l, S)
        else:
            tok_all = tok_l
        start = lax.axis_index(m) * V_local
        idx = tok_all - start
        ok = (idx >= 0) & (idx < V_local)
        got = jnp.take(emb_l, jnp.clip(idx, 0, V_local - 1), axis=0)
        got = jnp.where(ok[..., None], got, 0)
        if sq is not None:
            return lax.psum_scatter(got, m, scatter_dimension=1, tiled=True)
        return lax.psum(got, m)

    return jax.shard_map(island, mesh=mesh,
                         in_specs=(P(m, None), P(bd, sq)),
                         out_specs=P(bd, sq, None),
                         check_vma=False)(embed, tokens)
