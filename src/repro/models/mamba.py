"""Mamba-1 block (falcon-mamba / jamba mamba layers): init, train apply,
single-step decode apply with carried (conv, ssm) state.

Sharding (DESIGN.md §4): d_inner over "model"; batch over data axes; the
sequence stays local to a shard for the scan (Mamba parallelises over batch
and channels, not time).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops

Array = jax.Array


def mamba_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    d = cfg.d_model
    di = cfg.mamba.expand * d
    dtr = cfg.mamba.dt_rank or -(-d // 16)
    return d, di, dtr, cfg.mamba.d_state


def mamba_init(cfg: ModelConfig, key: Array) -> dict:
    d, di, dtr, n = mamba_dims(cfg)
    dc = cfg.mamba.d_conv
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.normal(ks[0], (d, di), jnp.float32) * s,
        "z_proj": jax.random.normal(ks[1], (d, di), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[2], (dc, di), jnp.float32) * (1.0 / math.sqrt(dc)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(ks[3], (di, dtr + 2 * n), jnp.float32) * (1.0 / math.sqrt(di)),
        "dt_w": jax.random.normal(ks[4], (dtr, di), jnp.float32) * (1.0 / math.sqrt(dtr)),
        "dt_b": jnp.log(jnp.expm1(  # softplus-inverse of ~[1e-3, 1e-1] inits
            jnp.exp(jax.random.uniform(ks[5], (di,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, n)).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(key, (di, d), jnp.float32) * (1.0 / math.sqrt(di)),
    }


def _ssm_inputs(cfg: ModelConfig, p: dict, xc: Array):
    """xc: post-conv activations (B, S, Di) -> (dt, A, Bs, Cs)."""
    _, di, dtr, n = mamba_dims(cfg)
    dt = xc.dtype
    proj = xc @ p["x_proj"].astype(dt)                      # (B,S,R+2N)
    dt_raw, Bs, Cs = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dts = jax.nn.softplus(dt_raw.astype(jnp.float32) @ p["dt_w"] + p["dt_b"])
    A = -jnp.exp(p["A_log"])                                # (Di, N)
    return dts.astype(jnp.float32), A, Bs.astype(jnp.float32), Cs.astype(jnp.float32)


def mamba_apply(cfg: ModelConfig, p: dict, x: Array) -> Array:
    """Training/prefill: x (B, S, d_model) -> (B, S, d_model)."""
    dt = x.dtype
    xi = x @ p["in_proj"].astype(dt)                        # (B,S,Di)
    z = x @ p["z_proj"].astype(dt)
    # causal depthwise conv over seq
    dc = cfg.mamba.d_conv
    xpad = jnp.pad(xi, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + xi.shape[1]] * p["conv_w"][i].astype(dt)
             for i in range(dc)) + p["conv_b"].astype(dt)
    xc = jax.nn.silu(xc)
    dts, A, Bs, Cs = _ssm_inputs(cfg, p, xc)
    y = kops.mamba_scan(xc.astype(jnp.float32), dts, A, Bs, Cs, p["D"])
    y = y.astype(dt) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt)


class MambaCache(NamedTuple):
    conv: Array   # (B, d_conv-1, Di) trailing conv inputs
    ssm: Array    # (B, Di, N) recurrent state (fp32)


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> MambaCache:
    _, di, _, n = mamba_dims(cfg)
    return MambaCache(conv=jnp.zeros((batch, cfg.mamba.d_conv - 1, di), dtype),
                      ssm=jnp.zeros((batch, di, n), jnp.float32))


def mamba_decode_step(cfg: ModelConfig, p: dict, x: Array,
                      cache: MambaCache) -> tuple[Array, MambaCache]:
    """x: (B, 1, d_model) one token -> (y (B,1,d), new cache)."""
    dt = x.dtype
    xi = (x[:, 0] @ p["in_proj"].astype(dt))                # (B, Di)
    z = x[:, 0] @ p["z_proj"].astype(dt)
    hist = jnp.concatenate([cache.conv, xi[:, None]], axis=1)  # (B, dc, Di)
    xc = jnp.einsum("bcd,cd->bd", hist, p["conv_w"].astype(dt)) + p["conv_b"].astype(dt)
    xc = jax.nn.silu(xc)
    dts, A, Bs, Cs = _ssm_inputs(cfg, p, xc[:, None])
    dts, Bs, Cs = dts[:, 0], Bs[:, 0], Cs[:, 0]             # (B,Di)/(B,N)
    dA = jnp.exp(dts[..., None] * A[None])                  # (B,Di,N)
    dBx = dts[..., None] * Bs[:, None, :] * xc.astype(jnp.float32)[..., None]
    h = dA * cache.ssm + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cs) + xc.astype(jnp.float32) * p["D"]
    y = y.astype(dt) * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(dt))[:, None]
    return out, MambaCache(conv=hist[:, 1:], ssm=h)
