"""jax version-portability shims (DESIGN.md §9).

The repo is written against the modern jax API surface:

- ``jax.shard_map(..., check_vma=...)``
- ``jax.make_mesh(shape, names, axis_types=...)``
- ``jax.sharding.AxisType``
- ``jax.set_mesh(mesh)``
- ``jax.lax.axis_size(name)``

On older jax releases (e.g. 0.4.x) these are missing or spelled differently
(``jax.experimental.shard_map.shard_map(check_rep=...)``, no ``axis_types``
kwarg, ``with mesh:`` instead of ``set_mesh``).  Importing this module
installs equivalents onto the jax namespace so call sites stay written
against the modern API; on new jax every patch is skipped.  Modules that use
any of the APIs above import this first; tests do it once in conftest.
"""
from __future__ import annotations

import enum
import inspect

import jax
import jax.sharding as _jsh

# --------------------------------------------------------------- AxisType --
if not hasattr(_jsh, "AxisType"):
    class _AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _jsh.AxisType = _AxisType

AxisType = _jsh.AxisType

# --------------------------------------------------------------- make_mesh --
_orig_make_mesh = getattr(jax, "make_mesh", None)
if (_orig_make_mesh is None
        or "axis_types" not in inspect.signature(_orig_make_mesh).parameters):
    def _make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # old jax: every axis behaves as Auto under shard_map
        if _orig_make_mesh is not None:
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)
        import math

        import numpy as np
        devs = list(devices) if devices is not None else jax.devices()
        n = math.prod(axis_shapes)
        return _jsh.Mesh(np.asarray(devs[:n]).reshape(axis_shapes),
                         axis_names)

    jax.make_mesh = _make_mesh

make_mesh = jax.make_mesh

# --------------------------------------------------------------- shard_map --
if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                          **kw):
        # check_vma (varying-manual-axes check) maps onto the old
        # replication-rule check; both default-on, both safe to disable.
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=bool(check_vma),
                          **kw)

    jax.shard_map = _compat_shard_map

shard_map = jax.shard_map

# --------------------------------------------------------------- axis_size --
from jax import lax as _lax

if not hasattr(_lax, "axis_size"):
    def _axis_size(axis_name):
        # classic idiom: psum of a literal 1 constant-folds to the (static)
        # named-axis size at trace time
        return _lax.psum(1, axis_name)

    _lax.axis_size = _axis_size

# ---------------------------------------------------------------- set_mesh --
if not hasattr(jax, "set_mesh"):
    def _set_mesh(mesh):
        # old jax: Mesh is itself the thread-local-mesh context manager
        return mesh

    jax.set_mesh = _set_mesh

set_mesh = jax.set_mesh
