from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    ShapeCell,
    all_configs,
    cells_for,
    get_config,
    reduced_config,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "MambaConfig", "ModelConfig", "MoEConfig",
    "ShapeCell", "all_configs", "cells_for", "get_config", "reduced_config",
]
