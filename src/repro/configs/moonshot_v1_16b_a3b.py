"""moonshot-v1-16b-a3b: kimi/moonlight MoE. [hf:moonshotai/Moonlight-16B-A3B; hf]

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
Primary paper-representative config: EP dispatch/combine on every layer.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="moonshot_v1_16b_a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,  # all-MoE FFN
    vocab_size=163_840,
    rope_theta=5e4,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, moe_every=1),
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
)
