"""musicgen-large: decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192 vocab=2048.  Audio: the
EnCodec frontend is a STUB — input_specs() provides precomputed frame
embeddings (conditioning prefix); the decoder operates on codec-token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen_large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=1e4,
    frontend_prefix=64,
    source="[arXiv:2306.05284; hf]",
)
