"""qwen2-moe-a2.7b: Qwen1.5-MoE-A2.7B. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4,
plus 4 shared experts (fused into one d_shared=4*1408 SwiGLU that bypasses EP).
60 routed experts are padded to 64 for EP16 divisibility (router masks pads).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen2_moe_a2_7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4,
                  d_expert=1408, d_shared=4 * 1408, moe_every=1),
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
)
