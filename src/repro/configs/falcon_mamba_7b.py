"""falcon-mamba-7b: attention-free Mamba-1. [arXiv:2410.05355; unverified]

64L d_model=4096, ssm_state=16, vocab=65024.  Sub-quadratic: runs long_500k.
"""
from repro.configs.base import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon_mamba_7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    subquadratic=True,
    source="[arXiv:2410.05355; unverified]",
)
