"""Config system for repro: model/arch configs, input shapes, run configs.

Every assigned architecture gets a module in ``repro.configs`` exporting
``CONFIG: ModelConfig``.  Shapes are the four assigned LM shape cells.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for one model."""

    n_experts: int = 0                 # routed experts
    top_k: int = 0
    n_shared_experts: int = 0          # always-on shared experts (qwen2-moe style)
    d_expert: int = 0                  # per-expert FFN hidden dim
    d_shared: int = 0                  # fused shared-expert hidden dim
    moe_every: int = 1                 # MoE layer every Nth layer (1 = all)
    capacity_factor: float = 2.0       # train/prefill capacity factor
    ll_capacity_factor: float = 4.0    # decode (LL) capacity factor
    router_aux_free_bias: bool = True  # DeepSeek aux-loss-free balancing bias
    aux_loss_weight: float = 1e-2      # Switch-style load-balance loss weight
    # EP transport backend (repro.core.backend registry): "jax_collectives"
    # (XLA a2a path) | "simulated_rdma" (host transport-substrate reference)
    ep_backend: str = "jax_collectives"
    # dispatch payload wire dtype: "fp32" | "fp8" | "int8" (block-quantized
    # with inline per-128-feature scales; combines stay fp32 — DESIGN.md §14)
    wire_dtype: str = "fp32"

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 SSM settings."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)

    @property
    def enabled(self) -> bool:
        return self.d_state > 0


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture.  Field names follow the assignment table."""

    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // n_heads
    d_ff: int = 0                     # dense FFN hidden (0 for pure-MoE / ssm)
    vocab_size: int = 32000
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: MoEConfig = field(default_factory=MoEConfig)
    mamba: MambaConfig = field(default_factory=lambda: MambaConfig(d_state=0))
    # hybrid (jamba): one attention layer per `attn_every` layers; rest mamba.
    attn_every: int = 0               # 0 = all layers attention (or none if n_heads==0)
    attn_offset: int = 0              # index within the period that is attention
    # modality frontend stub: number of prefix embedding positions fed by
    # input_specs() ("vlm": patch embeddings, "audio": frame embeddings).
    frontend_prefix: int = 0
    source: str = ""                  # provenance note ([hf:...] / [arXiv:...])
    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    optimizer: str = "adamw"          # adamw | adafactor (factored 2nd moment)
    remat: bool = True
    # sub-quadratic attention available? (pure full-attention archs -> False)
    subquadratic: bool = False

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    def padded_vocab(self, multiple: int = 256) -> int:
        return _round_up(self.vocab_size, multiple)

    def padded_experts(self, ep_degree: int) -> int:
        """Routed experts padded up so EP sharding divides evenly."""
        if not self.moe.enabled:
            return 0
        return _round_up(self.moe.n_experts, ep_degree)

    def is_attn_layer(self, layer_idx: int) -> bool:
        if self.attention_free:
            return False
        if self.attn_every <= 1:
            return True
        return layer_idx % self.attn_every == self.attn_offset

    def is_moe_layer(self, layer_idx: int) -> bool:
        if not self.moe.enabled:
            return False
        return layer_idx % self.moe.moe_every == (self.moe.moe_every - 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for sanity tests
        and MODEL_FLOPS in the roofline (6*N*D dense / 6*N_active*D MoE)."""
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            n += self._block_params(i)
        return n

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: top_k + shared experts only)."""
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            n += self._block_params(i, active_only=True)
        return n

    def _block_params(self, i: int, active_only: bool = False) -> int:
        d = self.d_model
        n = 0
        if self.is_attn_layer(i):
            hd = self.head_dim_
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            n += q + kv + o
            if self.qkv_bias:
                n += (self.n_heads + 2 * self.n_kv_heads) * hd
        elif self.mamba.enabled:
            di = self.mamba.expand * d
            dtr = self.mamba.dt_rank or -(-d // 16)
            n += d * di * 2            # in_proj (x and z)
            n += di * self.mamba.d_conv  # depthwise conv
            n += di * (dtr + 2 * self.mamba.d_state)  # x_proj
            n += dtr * di + di         # dt_proj
            n += di * self.mamba.d_state + di  # A_log, D
            n += di * d                # out_proj
        if self.is_moe_layer(i):
            e = self.moe.top_k if active_only else self.moe.n_experts
            n += e * 3 * d * self.moe.d_expert
            if self.moe.d_shared:
                n += 3 * d * self.moe.d_shared
            n += d * self.moe.n_experts  # router
        elif self.d_ff:
            n += 3 * d * self.d_ff     # SwiGLU
        n += 2 * d                     # 2 RMSNorms
        return n


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS: Sequence[str] = (
    "moonshot_v1_16b_a3b",
    "qwen2_moe_a2_7b",
    "qwen3_1_7b",
    "phi3_medium_14b",
    "qwen2_72b",
    "qwen3_4b",
    "internvl2_26b",
    "musicgen_large",
    "falcon_mamba_7b",
    "jamba_1_5_large_398b",
)

# canonical external ids (--arch accepts either form)
_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced_config(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
                   n_experts: int = 8, vocab: int = 512) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    heads = 0 if cfg.attention_free else 4
    kv = 0 if cfg.attention_free else (2 if cfg.n_kv_heads < cfg.n_heads else 4)
    moe = cfg.moe
    if moe.enabled:
        moe = dataclasses.replace(
            moe, n_experts=n_experts, top_k=min(moe.top_k, 2),
            d_expert=d_model, d_shared=d_model if moe.d_shared else 0)
    mamba = cfg.mamba
    # jamba interleave period shrinks to 2 so a 2-layer smoke covers both kinds
    attn_every = min(cfg.attn_every, 2) if cfg.attn_every else 0
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=d_model, n_heads=heads, n_kv_heads=kv,
        head_dim=d_model // heads if heads else 0,
        d_ff=d_model * 2 if cfg.d_ff else 0, vocab_size=vocab, moe=moe,
        mamba=mamba, attn_every=attn_every, attn_offset=0,
        frontend_prefix=min(cfg.frontend_prefix, 4))


def cells_for(cfg: ModelConfig) -> list[str]:
    """Shape cells this arch runs (long_500k only for sub-quadratic archs)."""
    out = []
    for name, cell in SHAPES.items():
        if name == "long_500k" and not cfg.subquadratic:
            continue  # skip: pure full-attention arch (DESIGN.md §5)
        out.append(name)
    return out
