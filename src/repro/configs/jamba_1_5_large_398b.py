"""jamba-1.5-large-398b: Mamba+attention 1:7 interleave, MoE. [arXiv:2403.19887; hf]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2 every
2nd layer; one attention layer per 8 (offset 4), rest Mamba.  Sub-quadratic
(Mamba layers O(1)/step; sparse attention layers use split-sequence decode):
runs long_500k.  Uses factored 2nd-moment optimizer so the 398B training state
fits the 256-chip pod (DESIGN.md §4).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="jamba_1_5_large_398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24_576, moe_every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    attn_offset=4,
    optimizer="adafactor",
    subquadratic=True,
    source="[arXiv:2403.19887; hf]",
)
