"""internvl2-26b: InternViT + InternLM2 backbone. [arXiv:2404.16821; hf]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 (padded to 92672 for
16-way vocab TP).  VLM: the InternViT frontend is a STUB — input_specs()
provides 256 precomputed patch embeddings per sample at d_model, prepended to
the text token stream (assignment rule: backbone only).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2_26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_553,
    rope_theta=1e6,
    frontend_prefix=256,
    source="[arXiv:2404.16821; hf]",
)
