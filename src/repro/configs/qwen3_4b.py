"""qwen3-4b: dense, qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936. head_dim=128
(qwen3 uses a fixed 128 head_dim decoupled from d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3_4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1e6,
    source="[hf:Qwen/Qwen3-8B; hf]",
)
