"""Fault-tolerant checkpointing: atomic (write-temp + fsync + rename) npz
checkpoints of the full TrainState, with retention, resume, and corruption
fallback — a node can die mid-write and the previous checkpoint stays valid.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", None) or getattr(k, "name", None)
                or getattr(k, "idx", None) or k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(k, "key", None) or getattr(k, "name", None)
                or getattr(k, "idx", None) or k) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    """Directory layout: <dir>/step_000123/state.npz + MANIFEST.json.
    The manifest is written last; a checkpoint without a valid manifest is
    treated as garbage (crash mid-write) and ignored/cleaned."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------- save --
    def save(self, state, step: int, extra: Optional[dict] = None) -> Path:
        final = self.dir / f"step_{step:09d}"
        tmp = Path(tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=self.dir))
        try:
            flat = _flatten_with_paths(state)
            with open(tmp / "state.npz", "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
            manifest = {"step": step, "time": time.time(),
                        "n_leaves": len(flat), "extra": extra or {}}
            with open(tmp / "MANIFEST.json", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._retain()
        return final

    def _retain(self):
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
        # clean stale temp dirs from crashed writers
        for p in self.dir.glob(".tmp_ckpt_*"):
            shutil.rmtree(p, ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def list_steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if self._valid(p):
                out.append(int(p.name.split("_")[1]))
        return out

    def _valid(self, p: Path) -> bool:
        mf = p / "MANIFEST.json"
        if not mf.exists() or not (p / "state.npz").exists():
            return False
        try:
            json.load(open(mf))
            return True
        except Exception:
            return False

    def restore(self, template, step: int):
        p = self.dir / f"step_{step:09d}"
        with np.load(p / "state.npz") as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_like(template, flat)

    def restore_latest(self, template) -> Optional[tuple[Any, int]]:
        """Returns (state, step) from the newest VALID checkpoint, walking
        backwards past corrupted ones."""
        for step in reversed(self.list_steps()):
            try:
                return self.restore(template, step), step
            except Exception:
                continue
        return None
