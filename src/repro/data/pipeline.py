"""Deterministic synthetic data pipeline: shard-aware, resumable, seeded.

Generates structured token streams (a noisy modular-arithmetic language) so
training loss demonstrably decreases — unlike uniform noise — while needing
no external corpus.  Every batch is a pure function of (seed, step), which is
what makes checkpoint-resume exactly reproducible and elastic re-sharding
trivially consistent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    prefix_len: int = 0       # vlm/audio stub prefix embeddings
    d_model: int = 0


def synth_batch(dc: DataConfig, step: int) -> dict:
    """Pure function of (seed, step) -> batch dict."""
    rng = np.random.default_rng(np.random.SeedSequence([dc.seed, step]))
    B, S, V = dc.batch, dc.seq_len, dc.vocab_size
    # structured stream: x_{t+1} = (a * x_t + b) mod Veff, with noise
    veff = max(2, min(V, 4096))
    a = rng.integers(2, 8, size=(B, 1))
    b = rng.integers(0, veff, size=(B, 1))
    x0 = rng.integers(0, veff, size=(B, 1))
    toks = np.empty((B, S + 1), np.int64)
    toks[:, 0:1] = x0
    for t in range(S):
        nxt = (a[:, 0] * toks[:, t] + b[:, 0]) % veff
        noise = rng.random(B) < 0.05
        nxt = np.where(noise, rng.integers(0, veff, size=B), nxt)
        toks[:, t + 1] = nxt
    batch = {"tokens": toks[:, :-1].astype(np.int32),
             "labels": toks[:, 1:].astype(np.int32)}
    if dc.prefix_len:
        batch["prefix"] = rng.standard_normal(
            (B, dc.prefix_len, dc.d_model)).astype(np.float32)
    return batch


def data_iterator(dc: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield synth_batch(dc, step)
        step += 1


def make_data_config(cfg: ModelConfig, cell: ShapeCell, *,
                     batch: Optional[int] = None,
                     seq: Optional[int] = None, seed: int = 0) -> DataConfig:
    B = batch or cell.global_batch
    S = seq or cell.seq_len
    pre = cfg.frontend_prefix
    return DataConfig(vocab_size=cfg.vocab_size, batch=B, seq_len=S - pre,
                      seed=seed, prefix_len=pre, d_model=cfg.d_model)
