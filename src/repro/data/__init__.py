from repro.data.pipeline import DataConfig, data_iterator, make_data_config, synth_batch
