"""Grouped expert matmul / fused grouped SwiGLU Pallas TPU kernels.

This is the compute hot-spot of EP: after dispatch, each EP shard applies its
local experts to capacity-bucketed token blocks — a batch of per-expert
matmuls (MegaBlocks-style, but with static capacity buckets, which is the
TPU-native formulation: MXU wants dense 128-aligned tiles, not CSR).

The fused SwiGLU kernel streams over the expert hidden dim F in blocks,
keeping gate/up activations in VMEM only (no HBM intermediate):

  for f-block:  acc += silu(x @ Wg[:, f]) * (x @ Wu[:, f]) @ Wd[f, :]

VMEM working set per grid step: x (bm x D) + Wg/Wu (D x bf) + Wd (bf x D)
+ acc (bm x D) — all 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_matmul_pallas(x: jax.Array, w: jax.Array, *, bm: int = 128,
                          bn: int = 128, bk: int = 512,
                          interpret: bool = False) -> jax.Array:
    """x: (G, M, K) @ w: (G, K, N) -> (G, M, N)."""
    G, M, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    nm, nn, nk = pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk)
    return pl.pallas_call(
        functools.partial(_gm_kernel, nk=nk),
        grid=(G, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g) * u).astype(x.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def grouped_swiglu_pallas(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                          w_down: jax.Array, *, bm: int = 128, bf: int = 256,
                          interpret: bool = False) -> jax.Array:
    """Fused grouped expert SwiGLU.  x: (E, C, D); w_*: (E, D, F)/(E, F, D)."""
    E, C, D = x.shape
    F = w_gate.shape[2]
    bm, bf = min(bm, C), min(bf, F)
    nm, nf = pl.cdiv(C, bm), pl.cdiv(F, bf)
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, nf=nf),
        grid=(E, nm, nf),
        in_specs=[
            pl.BlockSpec((1, bm, D), lambda e, i, f: (e, i, 0)),
            pl.BlockSpec((1, D, bf), lambda e, i, f: (e, 0, f)),
            pl.BlockSpec((1, D, bf), lambda e, i, f: (e, 0, f)),
            pl.BlockSpec((1, bf, D), lambda e, i, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, D), lambda e, i, f: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, D), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
