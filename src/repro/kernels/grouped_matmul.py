"""Grouped expert matmul / fused grouped SwiGLU Pallas TPU kernels,
occupancy-aware (MegaBlocks-style, adapted to static TPU capacity buckets).

This is the compute hot-spot of EP: after dispatch, each EP shard applies its
local experts to capacity-bucketed token blocks — a batch of per-expert
matmuls with static capacity buckets (the TPU-native formulation: MXU wants
dense 128-aligned tiles, not CSR).  At ``capacity_factor=2.0`` roughly half
of every bucket is zero padding, so all kernels here take optional
scalar-prefetched per-expert **occupied counts** (computed by
``core/plan.py``) and skip row-blocks beyond each expert's occupancy with a
``pl.when`` guard on the row grid dimension: padding rows cost zero MXU
flops, and out rows beyond occupancy are written as exact zeros (bit-equal
to the masked jnp refs in ``repro.kernels.ref``).

Counts may be bucketed: a ``(E, B)`` counts array describes ``B`` sub-buckets
per expert (each ``N // B`` rows, occupied-prefix each) — the layout the LL
receive buffer has after the all-to-all, where each source shard contributes
its own capacity-``C`` bucket.  The kernel then runs over ``E*B`` groups and
indexes the weights with ``g // B``.

Three entry points:

- ``grouped_matmul_pallas(x, w, counts=None)``  — blocked GEMM per group.
- ``grouped_swiglu_pallas(x, wg, wu, wd, counts=None)`` — fused expert FFN,
  streaming the hidden dim F in blocks (gate/up activations live in VMEM
  only).  VMEM working set per grid step: x (bm x D) + Wg/Wu (D x bf) +
  Wd (bf x D) + acc (bm x D), all 128-aligned for the MXU.
- ``gather_swiglu_scatter_pallas(x_ext, src, w_slot, wg, wu, wd, counts)``
  — the fully fused post-dispatch hot path: gathers token rows in-kernel
  from the extended token table via the scalar-prefetched ``src_of_slot``
  indirection, applies the expert SwiGLU, and scatter-adds the weighted
  fp32 outputs straight into the per-token accumulator.  No ``(E, C, D)``
  send buffer and no ``(E*C, D)`` expert-output intermediate ever touch HBM.

``grouped_swiglu_db`` is the double-buffered variant: token blocks stay in
HBM (``pltpu.ANY``) and are DMA'd manually through two VMEM slots, so
skipped (unoccupied) row-blocks skip their HBM traffic too — the BlockSpec
pipeline cannot elide fetches for ``pl.when``-skipped steps, manual DMA can.

All kernels are ragged-safe: partial edge blocks (C % bm, F % bf, K % bk)
are masked explicitly, because Pallas pads out-of-bounds input blocks with
undefined values (NaN in interpret mode — by design, to catch exactly this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dim_sem(n: int):
    """Grid annotation: groups are parallel, row/col/reduce dims arbitrary."""
    return pltpu.TPUCompilerParams(
        dimension_semantics=("parallel",) + ("arbitrary",) * (n - 1))


def _norm_counts(counts, n_groups: int, cap: int):
    """Normalize counts to a flat (n_groups,) int32 vector clipped to the
    per-group capacity; None means fully dense."""
    if counts is None:
        return jnp.full((n_groups,), cap, jnp.int32), 1
    counts = jnp.asarray(counts, jnp.int32)
    B = 1 if counts.ndim == 1 else counts.shape[1]
    return jnp.minimum(counts.reshape(-1), cap), B


# ======================================================== grouped matmul ==
def _gm_kernel(cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, bm: int, bk: int,
               K: int, nk: int, mask_rows: bool):
    g, i, k = pl.program_id(0), pl.program_id(1), pl.program_id(3)
    cnt = cnt_ref[g]
    occ = i * bm < cnt
    mask_k = K % bk != 0          # static: ragged reduce-dim edge block

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ)
    def _():
        # mask rows beyond occupancy and (ragged K) reduce-dim padding —
        # OOB input blocks are undefined, and masked rows must contribute
        # 0.  Both masks are statically elided when shapes make them no-ops
        # (fully dense aligned blocks keep a pure MXU loop).
        xm, wm = x_ref[0], w_ref[0]
        if mask_rows:
            rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
            xm = jnp.where(rows < cnt, xm, 0)
        if mask_k:
            cols = jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1) + k * bk
            xm = jnp.where(cols < K, xm, 0)
            wm = jnp.where(cols.reshape(-1, 1) < K, wm, 0)
        acc_ref[...] += jnp.dot(xm, wm, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        # rows beyond occupancy are exact zeros (the masked-ref contract)
        o_ref[0] = jnp.where(occ, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_matmul_pallas(x: jax.Array, w: jax.Array,
                          counts: jax.Array | None = None, *, bm: int = 128,
                          bn: int = 128, bk: int = 512,
                          interpret: bool = False) -> jax.Array:
    """x: (G, M, K) @ w: (G, K, N) -> (G, M, N); rows >= counts[g] are
    skipped on the MXU and written as zeros."""
    G, M, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    nm, nn, nk = pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk)
    cnt, B = _norm_counts(counts, G, M)
    assert B == 1, "bucketed counts are a grouped_swiglu feature"
    mask_rows = counts is not None or M % bm != 0
    return pl.pallas_call(
        functools.partial(_gm_kernel, bm=bm, bk=bk, K=K, nk=nk,
                          mask_rows=mask_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(G, nm, nn, nk),
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda g, i, j, k, c: (g, i, k)),
                pl.BlockSpec((1, bk, bn), lambda g, i, j, k, c: (g, k, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k, c: (g, i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        compiler_params=_dim_sem(4),
        interpret=interpret,
    )(cnt, x, w)


# ======================================================== grouped swiglu ==
def _swiglu_block(x, wg, wu, wd, f, bf: int, F: int):
    """One f-block SwiGLU partial: silu(x@wg)*(x@wu) @ wd, masking the
    (ragged F) hidden-dim padding of the edge block — statically elided
    when bf divides F."""
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g) * u).astype(x.dtype)
    wdm = wd
    if F % bf != 0:
        fcols = jax.lax.broadcasted_iota(jnp.int32, (1, h.shape[1]), 1) \
            + f * bf
        h = jnp.where(fcols < F, h, 0)
        wdm = jnp.where(fcols.reshape(-1, 1) < F, wd, 0)
    return jnp.dot(h, wdm, preferred_element_type=jnp.float32)


def _swiglu_kernel(cnt_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                   bm: int, bf: int, F: int, nf: int, mask_rows: bool):
    g, i, f = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cnt = cnt_ref[g]
    occ = i * bm < cnt

    @pl.when(f == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ)
    def _():
        xm = x_ref[0]
        if mask_rows:
            rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
            xm = jnp.where(rows < cnt, xm, 0)
        acc_ref[...] += _swiglu_block(xm, wg_ref[0], wu_ref[0], wd_ref[0],
                                      f, bf, F)

    @pl.when(f == nf - 1)
    def _():
        o_ref[0] = jnp.where(occ, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def grouped_swiglu_pallas(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                          w_down: jax.Array,
                          counts: jax.Array | None = None, *, bm: int = 128,
                          bf: int = 256, interpret: bool = False) -> jax.Array:
    """Fused grouped expert SwiGLU.  x: (E, C, D); w_*: (E, D, F)/(E, F, D).

    ``counts``: per-expert occupied row counts, (E,) — or (E, B) sub-bucket
    counts where B divides C and each C//B sub-bucket is occupied-prefix
    (the post-a2a receive layout).  Rows beyond occupancy are skipped on
    the MXU and written as exact zeros.
    """
    E, C, D = x.shape
    F = w_gate.shape[2]
    if counts is None:
        cnt, B = jnp.full((E,), C, jnp.int32), 1
    else:
        counts = jnp.asarray(counts, jnp.int32)
        B = 1 if counts.ndim == 1 else counts.shape[1]
        assert C % B == 0, (C, B)
        cnt = jnp.minimum(counts.reshape(-1), C // B)
    if B > 1:
        C = C // B
        x = x.reshape(E * B, C, D)
    G = E * B
    bm, bf = min(bm, C), min(bf, F)
    nm, nf = pl.cdiv(C, bm), pl.cdiv(F, bf)
    mask_rows = counts is not None or C % bm != 0
    out = pl.pallas_call(
        functools.partial(_swiglu_kernel, bm=bm, bf=bf, F=F, nf=nf,
                          mask_rows=mask_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(G, nm, nf),
            in_specs=[
                pl.BlockSpec((1, bm, D), lambda g, i, f, c: (g, i, 0)),
                pl.BlockSpec((1, D, bf), lambda g, i, f, c: (g // B, 0, f)),
                pl.BlockSpec((1, D, bf), lambda g, i, f, c: (g // B, 0, f)),
                pl.BlockSpec((1, bf, D), lambda g, i, f, c: (g // B, f, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, D), lambda g, i, f, c: (g, i, 0)),
            scratch_shapes=[pltpu.VMEM((bm, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((G, C, D), x.dtype),
        compiler_params=_dim_sem(3),
        interpret=interpret,
    )(cnt, x, w_gate, w_up, w_down)
    return out.reshape(E, B * C, D) if B > 1 else out


# ===================================== double-buffered grouped swiglu =====
def _swiglu_db_kernel(cnt_ref, x_hbm, wg_ref, wu_ref, wd_ref, o_ref,
                      xbuf_ref, acc_ref, sem_ref, *, bm: int, bf: int,
                      F: int, nf: int, mask_rows: bool):
    g, i, f = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cnt = cnt_ref[g]
    occ = i * bm < cnt

    def dma(slot, grp, blk):
        return pltpu.make_async_copy(x_hbm.at[grp, pl.ds(blk * bm, bm), :],
                                     xbuf_ref.at[slot], sem_ref.at[slot])

    # warm-up: first occupied block of this group (i == 0 iff cnt > 0)
    @pl.when(occ & (i == 0) & (f == 0))
    def _():
        dma(0, g, 0).start()

    @pl.when(occ & (f == 0))
    def _():
        # prefetch the next occupied row-block while this one computes
        @pl.when((i + 1) * bm < cnt)
        def _():
            dma((i + 1) % 2, g, i + 1).start()
        dma(i % 2, g, i).wait()
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ)
    def _():
        xm = xbuf_ref[i % 2]
        if mask_rows:
            rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
            xm = jnp.where(rows < cnt, xm, 0)
        acc_ref[...] += _swiglu_block(xm, wg_ref[0], wu_ref[0], wd_ref[0],
                                      f, bf, F)

    @pl.when(f == nf - 1)
    def _():
        o_ref[0] = jnp.where(occ, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def grouped_swiglu_db_pallas(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                             w_down: jax.Array,
                             counts: jax.Array | None = None, *,
                             bm: int = 128, bf: int = 256,
                             interpret: bool = False) -> jax.Array:
    """Double-buffered occupancy-aware grouped SwiGLU: token row-blocks stay
    in HBM and are DMA'd through two VMEM slots, so skipped blocks skip
    their HBM reads too.  Requires bm | C (manual DMA sizes are static);
    when the largest divisor of C degenerates below a useful sublane count
    (< 8 rows, e.g. prime C) the pipelined kernel is used instead."""
    E, C, D = x.shape
    F = w_gate.shape[2]
    bm = min(bm, C)
    while C % bm:           # largest divisor of C <= requested bm
        bm -= 1
    if bm < min(8, C):
        return grouped_swiglu_pallas(x, w_gate, w_up, w_down, counts,
                                     bm=min(8, C), bf=bf,
                                     interpret=interpret)
    bf = min(bf, F)
    nm, nf = C // bm, pl.cdiv(F, bf)
    cnt, B = _norm_counts(counts, E, C)
    assert B == 1, "bucketed counts: reshape to (E*B, C//B, D) first"
    return pl.pallas_call(
        functools.partial(_swiglu_db_kernel, bm=bm, bf=bf, F=F, nf=nf,
                          mask_rows=counts is not None),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, nm, nf),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((1, D, bf), lambda g, i, f, c: (g, 0, f)),
                pl.BlockSpec((1, D, bf), lambda g, i, f, c: (g, 0, f)),
                pl.BlockSpec((1, bf, D), lambda g, i, f, c: (g, f, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, D), lambda g, i, f, c: (g, i, 0)),
            scratch_shapes=[pltpu.VMEM((2, bm, D), x.dtype),
                            pltpu.VMEM((bm, D), jnp.float32),
                            pltpu.SemaphoreType.DMA((2,))],
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, D), x.dtype),
        compiler_params=_dim_sem(3),
        interpret=interpret,
    )(cnt, x, w_gate, w_up, w_down)


# ================================== fused gather -> swiglu -> scatter =====
def _gss_kernel(src_ref, cnt_ref, x_ref, ws_ref, wg_ref, wu_ref, wd_ref,
                o_ref, xs_ref, acc_ref, oacc_ref, *, bm: int, bf: int,
                C: int, F: int, nf: int):
    e, i, f = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    ne, nm = pl.num_programs(0), pl.num_programs(1)
    n_slots = ne * C
    cnt = cnt_ref[e]
    occ = i * bm < cnt

    @pl.when((e == 0) & (i == 0) & (f == 0))
    def _():
        oacc_ref[...] = jnp.zeros_like(oacc_ref)

    # in-kernel gather, driven by the scalar-prefetched src_of_slot table:
    # row r of this slot-block reads token row src[e*C + i*bm + r]
    @pl.when(occ & (f == 0))
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def gather(r, _):
            s = src_ref[jnp.minimum(e * C + i * bm + r, n_slots - 1)]
            xs_ref[pl.ds(r, 1), :] = x_ref[pl.ds(s, 1), :]
            return 0
        jax.lax.fori_loop(0, bm, gather, 0)

    @pl.when(occ)
    def _():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
        xm = jnp.where(rows < cnt, xs_ref[...], 0)
        acc_ref[...] += _swiglu_block(xm, wg_ref[0], wu_ref[0], wd_ref[0],
                                      f, bf, F)

    # weighted fp32 scatter-add into the persistent per-token accumulator
    @pl.when(occ & (f == nf - 1))
    def _():
        y = acc_ref[...] * ws_ref[0, :].astype(jnp.float32)[:, None]

        def scatter(r, _):
            @pl.when(i * bm + r < cnt)
            def _():
                s = src_ref[jnp.minimum(e * C + i * bm + r, n_slots - 1)]
                oacc_ref[pl.ds(s, 1), :] += jax.lax.dynamic_slice(
                    y, (r, 0), (1, y.shape[1]))
            return 0
        jax.lax.fori_loop(0, bm, scatter, 0)

    @pl.when((e == ne - 1) & (i == nm - 1) & (f == nf - 1))
    def _():
        o_ref[...] = oacc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def gather_swiglu_scatter_pallas(x_ext: jax.Array, src_of_slot: jax.Array,
                                 w_slot: jax.Array, w_gate: jax.Array,
                                 w_up: jax.Array, w_down: jax.Array,
                                 counts: jax.Array | None = None, *,
                                 bm: int = 128, bf: int = 256,
                                 interpret: bool = False) -> jax.Array:
    """Fused EP hot path: for each occupied receive slot, gather its token
    row from ``x_ext`` ((T+1, D); row T is the zero scratch row), apply the
    owning expert's SwiGLU, and scatter-add ``w_slot[slot] * y`` in fp32
    into the per-token output.

    src_of_slot: (E*C,) int32 token row per slot (T for empty slots);
    w_slot: (E*C,) combine weights (0 for empty slots); counts: (E,)
    occupied prefix per expert bucket.  Returns (T, D) float32 partials.

    The (T+1, D) token table and fp32 accumulator are VMEM-resident, which
    bounds T: callers should fall back to gather -> grouped_swiglu ->
    scatter (the unfused composition, same math) when they do not fit —
    see ``kernels.ops.gather_swiglu_scatter``.
    """
    Tp1, D = x_ext.shape
    E, _, F = w_gate.shape
    n_slots = src_of_slot.shape[0]
    assert n_slots % E == 0, (n_slots, E)
    C = n_slots // E
    cnt, B = _norm_counts(counts, E, C)
    assert B == 1, "fused kernel takes flat per-expert counts"
    bm, bf = min(bm, C), min(bf, F)
    nm, nf = pl.cdiv(C, bm), pl.cdiv(F, bf)
    # pad the per-slot weights to whole row-blocks so the (1, bm) weight
    # block of the ragged edge never reads past C
    ws = jnp.zeros((E, nm * bm), jnp.float32).at[:, :C].set(
        jnp.asarray(w_slot, jnp.float32).reshape(E, C))
    out = pl.pallas_call(
        functools.partial(_gss_kernel, bm=bm, bf=bf, C=C, F=F, nf=nf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(E, nm, nf),
            in_specs=[
                pl.BlockSpec((Tp1, D), lambda e, i, f, s, c: (0, 0)),
                pl.BlockSpec((1, bm), lambda e, i, f, s, c: (e, i)),
                pl.BlockSpec((1, D, bf), lambda e, i, f, s, c: (e, 0, f)),
                pl.BlockSpec((1, D, bf), lambda e, i, f, s, c: (e, 0, f)),
                pl.BlockSpec((1, bf, D), lambda e, i, f, s, c: (e, f, 0)),
            ],
            out_specs=pl.BlockSpec((Tp1, D), lambda e, i, f, s, c: (0, 0)),
            scratch_shapes=[pltpu.VMEM((bm, D), x_ext.dtype),
                            pltpu.VMEM((bm, D), jnp.float32),
                            pltpu.VMEM((Tp1, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Tp1, D), jnp.float32),
        # every grid dim is 'arbitrary': the per-token accumulator crosses
        # the expert dim (zero-init at the first step, flush at the last),
        # so a Megacore-parallel split of it would shear the accumulation
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * 3),
        interpret=interpret,
    )(jnp.asarray(src_of_slot, jnp.int32), cnt, x_ext, ws,
      w_gate, w_up, w_down)
    return out[:-1]
