"""Causal flash attention Pallas TPU kernel (prefill/training shapes).

Grid (B, H, nq, nk) with the kv index innermost: the (m, l, acc) running
softmax state lives in VMEM scratch and persists across the nk steps of one
q block (TPU grid iteration is sequential).  Fully-masked blocks (kv block
strictly above the diagonal) are skipped with ``pl.when`` — on TPU this
avoids issuing the MXU ops entirely, the kernel-level analogue of the
hierarchical causal decomposition used by the jnp path.

GQA is handled by mapping q head h to kv head h // (H // Hkv) in the
BlockSpec index maps — no materialised repeat.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, bq: int, bk: int, nk: int, causal: bool):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _():
        q = q_ref[0, 0]                       # (bq, d)
        k = k_ref[0, 0]                       # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        corr = jnp.exp(m_prev - m_safe)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 512, bk: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, H, D)."""
    Bt, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bq, bk = min(bq, Sq), min(bk, Skv)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Skv, bk)
    scale = 1.0 / math.sqrt(D)
    qT = q.transpose(0, 2, 1, 3)      # (B, H, Sq, D)
    kT = k.transpose(0, 2, 1, 3)      # (B, Hkv, Skv, D)
    vT = v.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, bq=bq, bk=bk, nk=nk,
                          causal=causal),
        grid=(Bt, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qT, kT, vT)
    return out.transpose(0, 2, 1, 3)
