"""Jitted public wrappers around the Pallas kernels with mode dispatch.

Modes (set ``repro.kernels.ops.KERNEL_MODE`` or env ``REPRO_KERNEL_MODE``):
- "ref":       pure-jnp oracle (default on CPU; what the dry-run lowers)
- "interpret": pl.pallas_call(interpret=True) — CPU validation of kernel code
- "pallas":    compiled Pallas kernel (TPU target)
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

KERNEL_MODE = os.environ.get("REPRO_KERNEL_MODE", "ref")


def _mode(override: str | None = None) -> str:
    return override or KERNEL_MODE


def grouped_matmul(x, w, counts=None, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.grouped_matmul_ref(x, w, counts=counts)
    from repro.kernels.grouped_matmul import grouped_matmul_pallas
    return grouped_matmul_pallas(x, w, counts, interpret=(m == "interpret"))


def grouped_swiglu(x, w_gate, w_up, w_down, counts=None, *,
                   mode: str | None = None, zero_padded: bool = False):
    """Grouped expert SwiGLU; ``counts`` are per-expert (or per-sub-bucket,
    shape (E, B)) occupied row counts — rows beyond occupancy cost no MXU
    flops on the kernel path and are zero on every path.

    ``zero_padded=True`` declares that rows beyond occupancy are already
    exact zeros (EP dispatch buffers: scratch-row gathers); since
    swiglu(0) == 0, the jnp ref then skips the occupancy mask — it would
    be pure overhead on XLA — while the kernel paths still use counts to
    skip the padding's flops.

    ``REPRO_SWIGLU_DB=1`` selects the double-buffered variant (manual
    HBM->VMEM token DMA: occupancy-skipped blocks skip their HBM reads,
    which the BlockSpec pipeline cannot do); flat counts only.
    """
    m = _mode(mode)
    if m == "ref":
        return _ref.grouped_swiglu_ref(x, w_gate, w_up, w_down,
                                       counts=None if zero_padded else counts)
    flat = counts is None or getattr(counts, "ndim", 1) == 1
    if os.environ.get("REPRO_SWIGLU_DB") == "1" and flat:
        from repro.kernels.grouped_matmul import grouped_swiglu_db_pallas
        return grouped_swiglu_db_pallas(x, w_gate, w_up, w_down, counts,
                                        interpret=(m == "interpret"))
    from repro.kernels.grouped_matmul import grouped_swiglu_pallas
    return grouped_swiglu_pallas(x, w_gate, w_up, w_down, counts,
                                 interpret=(m == "interpret"))


# VMEM budget for the fused kernel's (T+1, D)-sized resident buffers: the
# token table (input dtype) + the fp32 accumulator scratch + the fp32
# output block, all live simultaneously (see gather_swiglu_scatter_pallas);
# above this the unfused composition is used — same math, one materialized
# intermediate.
GSS_VMEM_BYTES = 8 * 1024 * 1024


def gather_swiglu_scatter(x_ext, src_of_slot, w_slot, w_gate, w_up, w_down,
                          counts=None, *, mode: str | None = None,
                          zero_padded: bool = False):
    """Fused EP hot path (gather -> expert SwiGLU -> weighted fp32
    scatter-add); see kernels.grouped_matmul.gather_swiglu_scatter_pallas.
    Returns (T, D) float32 where T = x_ext rows - 1.

    ``zero_padded`` as in :func:`grouped_swiglu`: empty slots gather the
    zero scratch row and carry zero weights, so the jnp ref skips the
    occupancy mask."""
    m = _mode(mode)
    Tp1, D = x_ext.shape
    resident = Tp1 * D * (x_ext.dtype.itemsize + 4 + 4)
    if m != "ref" and resident <= GSS_VMEM_BYTES:
        from repro.kernels.grouped_matmul import gather_swiglu_scatter_pallas
        return gather_swiglu_scatter_pallas(
            x_ext, src_of_slot, w_slot, w_gate, w_up, w_down, counts,
            interpret=(m == "interpret"))
    if m == "ref":
        return _ref.gather_swiglu_scatter_ref(
            x_ext, src_of_slot, w_slot, w_gate, w_up, w_down,
            counts=None if zero_padded else counts)
    # unfused fallback: same math through the occupancy-aware grouped kernel
    import jax.numpy as jnp

    E = w_gate.shape[0]
    C = src_of_slot.shape[0] // E
    buf = x_ext[src_of_slot].reshape(E, C, D)
    y = grouped_swiglu(buf, w_gate, w_up, w_down, counts, mode=m)
    w_f = jnp.asarray(w_slot, jnp.float32)
    return jnp.zeros((Tp1, D), jnp.float32).at[src_of_slot].add(
        y.reshape(E * C, D).astype(jnp.float32) * w_f[:, None])[:-1]


def gather_quantize(x_ext, src_of_slot, counts=None, *, wire_dtype: str,
                    mode: str | None = None):
    """Fused routing-gather -> block-quantize for low-precision wire
    dispatch (DESIGN.md §14): returns ``(q, scales)`` of shapes
    (n_slots, D) wire dtype and (n_slots, n_blocks) fp32.  Slots beyond a
    bucket's occupied count are exact zeros with zero scales on every path.
    """
    from repro.kernels.quantize_pack import (gather_quantize_pallas,
                                             gather_quantize_ref)
    m = _mode(mode)
    Tp1, D = x_ext.shape
    if m == "ref" or Tp1 * D * x_ext.dtype.itemsize > GSS_VMEM_BYTES:
        return gather_quantize_ref(x_ext, src_of_slot, counts,
                                   wire_dtype=wire_dtype)
    return gather_quantize_pallas(x_ext, src_of_slot, counts,
                                  wire_dtype=wire_dtype,
                                  interpret=(m == "interpret"))


def dequantize_tokens(q, scales, *, mode: str | None = None):
    """Inverse of :func:`gather_quantize` (per-row): fp32 out, the combine
    side's accumulation dtype."""
    m = _mode(mode)
    if m == "ref":
        from repro.kernels.quantize_pack import dequantize_ref
        return dequantize_ref(q, scales)
    from repro.kernels.quantize_pack import dequantize_pallas
    return dequantize_pallas(q, scales, interpret=(m == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    from repro.kernels.flash_attention import flash_attention_pallas
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=(m == "interpret"))


def mamba_scan(x, dt, A, B, C, D, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.mamba_scan_ref(x, dt, A, B, C, D)
    from repro.kernels.mamba_scan import mamba_scan_pallas
    return mamba_scan_pallas(x, dt, A, B, C, D, interpret=(m == "interpret"))


def combine_reduce(parts, weights, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.combine_reduce_ref(parts, weights)
    from repro.kernels.combine_reduce import combine_reduce_pallas
    return combine_reduce_pallas(parts, weights, interpret=(m == "interpret"))


def decode_attention(q, k, v, pos, *, start: int = 0, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        import jax.numpy as jnp
        from repro.models.layers import decode_attention_local
        part = decode_attention_local(q[:, None], k, v, pos, start=start)
        l = jnp.where(part.l == 0, 1.0, part.l)
        return (part.o / l[..., None])[:, 0].astype(q.dtype)
    from repro.kernels.decode_attention import decode_attention_pallas
    return decode_attention_pallas(q, k, v, pos, start=start,
                                   interpret=(m == "interpret"))


def rmsnorm(x, scale, eps: float = 1e-5, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.rmsnorm_ref(x, scale, eps)
    from repro.kernels.rmsnorm import rmsnorm_pallas
    return rmsnorm_pallas(x, scale, eps, interpret=(m == "interpret"))
