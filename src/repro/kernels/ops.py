"""Jitted public wrappers around the Pallas kernels with mode dispatch.

Modes (set ``repro.kernels.ops.KERNEL_MODE`` or env ``REPRO_KERNEL_MODE``):
- "ref":       pure-jnp oracle (default on CPU; what the dry-run lowers)
- "interpret": pl.pallas_call(interpret=True) — CPU validation of kernel code
- "pallas":    compiled Pallas kernel (TPU target)
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

KERNEL_MODE = os.environ.get("REPRO_KERNEL_MODE", "ref")


def _mode(override: str | None = None) -> str:
    return override or KERNEL_MODE


def grouped_matmul(x, w, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.grouped_matmul_ref(x, w)
    from repro.kernels.grouped_matmul import grouped_matmul_pallas
    return grouped_matmul_pallas(x, w, interpret=(m == "interpret"))


def grouped_swiglu(x, w_gate, w_up, w_down, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.grouped_swiglu_ref(x, w_gate, w_up, w_down)
    from repro.kernels.grouped_matmul import grouped_swiglu_pallas
    return grouped_swiglu_pallas(x, w_gate, w_up, w_down,
                                 interpret=(m == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    from repro.kernels.flash_attention import flash_attention_pallas
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=(m == "interpret"))


def mamba_scan(x, dt, A, B, C, D, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.mamba_scan_ref(x, dt, A, B, C, D)
    from repro.kernels.mamba_scan import mamba_scan_pallas
    return mamba_scan_pallas(x, dt, A, B, C, D, interpret=(m == "interpret"))


def combine_reduce(parts, weights, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.combine_reduce_ref(parts, weights)
    from repro.kernels.combine_reduce import combine_reduce_pallas
    return combine_reduce_pallas(parts, weights, interpret=(m == "interpret"))


def decode_attention(q, k, v, pos, *, start: int = 0, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        import jax.numpy as jnp
        from repro.models.layers import decode_attention_local
        part = decode_attention_local(q[:, None], k, v, pos, start=start)
        l = jnp.where(part.l == 0, 1.0, part.l)
        return (part.o / l[..., None])[:, 0].astype(q.dtype)
    from repro.kernels.decode_attention import decode_attention_pallas
    return decode_attention_pallas(q, k, v, pos, start=start,
                                   interpret=(m == "interpret"))


def rmsnorm(x, scale, eps: float = 1e-5, *, mode: str | None = None):
    m = _mode(mode)
    if m == "ref":
        return _ref.rmsnorm_ref(x, scale, eps)
    from repro.kernels.rmsnorm import rmsnorm_pallas
    return rmsnorm_pallas(x, scale, eps, interpret=(m == "interpret"))
