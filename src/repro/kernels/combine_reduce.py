"""Weighted combine-reduce Pallas kernel: the final step of EP combine
(out[t] = sum_k w[t,k] * parts[t,k,:]) with fp32 accumulation in VMEM.
Memory-bound; the kernel fuses the K reads with the reduce so parts never
round-trips through HBM twice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cr_kernel(p_ref, w_ref, o_ref):
    p = p_ref[...].astype(jnp.float32)          # (bt, K, D)
    w = w_ref[...].astype(jnp.float32)          # (bt, K)
    o_ref[...] = jnp.einsum("tkd,tk->td", p, w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def combine_reduce_pallas(parts: jax.Array, weights: jax.Array, *,
                          bt: int = 256, interpret: bool = False) -> jax.Array:
    """parts: (T, K, D); weights: (T, K) -> (T, D)."""
    T, K, D = parts.shape
    bt = min(bt, T)
    nt = pl.cdiv(T, bt)
    return pl.pallas_call(
        _cr_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, K, D), lambda i: (i, 0, 0)),
                  pl.BlockSpec((bt, K), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), parts.dtype),
        interpret=interpret,
    )(parts, weights)
