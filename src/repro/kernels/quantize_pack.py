"""Fused routing-gather -> block-quantize -> scale-pack Pallas TPU kernel
(the dispatch half of the low-precision wire path, DESIGN.md §14).

After planning, each kept (token, choice) owns a receive slot; the dispatch
payload for slot ``s`` is token row ``src_of_slot[s]`` quantized to the wire
dtype with one fp32 absmax scale per :data:`repro.core.plan.WIRE_BLOCK`
features.  This kernel fuses the slot gather with the quantize so the
``(n_slots, D)`` fp32 send buffer never materializes: rows are gathered
through the scalar-prefetched indirection into VMEM, masked by the
occupied-prefix counts (occupancy-aware like ``grouped_matmul``: slots
beyond a bucket's count cost no VPU work and emit exact zeros/zero scales),
quantized per 128-feature block, and written straight into the command
payload layout — quantized bytes and scale blocks as separate dense arrays
that the caller packs or all-to-alls.

The rounding contract is pinned by ``repro.core.transport.codec``
(fp8: f32 -> f16 -> f8e4m3, int8: RTNE + clip), so the jnp/numpy refs here
are bit-identical to the kernel in interpret mode and to the substrate's
byte codec.  Dequantize accumulates in fp32 by contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.plan import WIRE_BLOCK, occupancy_mask, wire_layout
from repro.core.transport.codec import (_QINV, FP8_MAX, INT8_MAX,
                                        dequantize_blocked, quantize_blocked)


def _qdtype(wire_dtype: str):
    return jnp.float8_e4m3fn if wire_dtype == "fp8" else jnp.int8


# ------------------------------------------------------------------- refs --
def gather_quantize_ref(x_ext, src_of_slot, counts=None, *,
                        wire_dtype: str = "fp8"):
    """Dual-dialect (numpy/jnp) oracle for the fused kernel.

    x_ext: (T+1, D); row T is the zero scratch row empty slots gather.
    src_of_slot: (n_slots,) int32; counts: (E,) occupied-prefix counts with
    E * C == n_slots (None = fully dense).  Returns ``(q, scales)`` of
    shapes (n_slots, D) and (n_slots, n_blocks) — rows at or beyond their
    bucket's count are exact zeros with zero scales, matching the kernel's
    occupancy skip bit-for-bit.
    """
    import numpy as np
    xp = np if isinstance(x_ext, (np.ndarray, np.generic)) else jnp
    buf = x_ext[src_of_slot].astype(xp.float32)
    if counts is not None:
        E = int(counts.shape[0])          # static even for traced counts
        n_slots = src_of_slot.shape[0]
        C = n_slots // E
        m = occupancy_mask(counts.reshape(E), E, C).reshape(-1)
        buf = xp.where(m[:, None], buf, xp.float32(0))
    return quantize_blocked(buf, wire_dtype)


# ----------------------------------------------------------------- kernel --
def _gq_kernel(src_ref, cnt_ref, x_ref, q_ref, s_ref, xs_ref, *, bm: int,
               C: int, d: int, nb: int, qmax: float, qinv: float, f8: bool):
    e, i = pl.program_id(0), pl.program_id(1)
    n_slots = pl.num_programs(0) * C
    cnt = cnt_ref[e]
    occ = i * bm < cnt

    @pl.when(occ)
    def _():
        # in-kernel gather through the scalar-prefetched slot table
        def gather(r, _):
            s = src_ref[jnp.minimum(e * C + i * bm + r, n_slots - 1)]
            xs_ref[pl.ds(r, 1), :] = x_ref[pl.ds(s, 1), :]
            return 0
        jax.lax.fori_loop(0, bm, gather, 0)
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
        xm = jnp.where(rows < cnt, xs_ref[...].astype(jnp.float32), 0)
        scales = []
        for j in range(nb):                      # static unroll over blocks
            seg = xm[:, j * WIRE_BLOCK:min((j + 1) * WIRE_BLOCK, d)]
            # reciprocal multiply, same pre-rounded f32 constant as the
            # codec (division by a constant strength-reduces differently)
            scale = jnp.max(jnp.abs(seg), axis=1, keepdims=True) * qinv
            sg = jnp.where(scale == 0, 1.0, scale)
            y = jnp.clip(seg / sg, -qmax, qmax)
            if f8:   # wire rounding contract: f32 -> f16 -> f8e4m3 (codec)
                qv = y.astype(jnp.float16).astype(jnp.float8_e4m3fn)
            else:
                qv = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
            q_ref[0, :, j * WIRE_BLOCK:min((j + 1) * WIRE_BLOCK, d)] = qv
            scales.append(scale)
        s_ref[0] = jnp.concatenate(scales, axis=1)

    @pl.when(~occ)
    def _():
        q_ref[...] = jnp.zeros_like(q_ref)
        s_ref[...] = jnp.zeros_like(s_ref)


@functools.partial(jax.jit,
                   static_argnames=("wire_dtype", "bm", "interpret"))
def gather_quantize_pallas(x_ext: jax.Array, src_of_slot: jax.Array,
                           counts: jax.Array | None = None, *,
                           wire_dtype: str = "fp8", bm: int = 128,
                           interpret: bool = False):
    """Fused gather + block-quantize; see :func:`gather_quantize_ref` for
    the contract.  The (T+1, D) token table is VMEM-resident (callers gate
    on size — ``kernels.ops.gather_quantize`` falls back to the ref)."""
    Tp1, D = x_ext.shape
    n_slots = src_of_slot.shape[0]
    if counts is None:
        E, C = 1, n_slots
        cnt = jnp.full((1,), n_slots, jnp.int32)
    else:
        cnt = jnp.asarray(counts, jnp.int32).reshape(-1)
        E = cnt.shape[0]
        assert n_slots % E == 0, (n_slots, E)
        C = n_slots // E
        cnt = jnp.minimum(cnt, C)
    lo = wire_layout(D, wire_dtype)
    nb = lo.n_blocks
    bm = min(bm, C)
    nm = pl.cdiv(C, bm)
    qmax = FP8_MAX if wire_dtype == "fp8" else INT8_MAX
    qinv = float(_QINV[wire_dtype])
    q, s = pl.pallas_call(
        functools.partial(_gq_kernel, bm=bm, C=C, d=D, nb=nb, qmax=qmax,
                          qinv=qinv, f8=(wire_dtype == "fp8")),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(E, nm),
            in_specs=[pl.BlockSpec((Tp1, D), lambda e, i, s, c: (0, 0))],
            out_specs=[
                pl.BlockSpec((1, bm, D), lambda e, i, s, c: (e, i, 0)),
                pl.BlockSpec((1, bm, nb), lambda e, i, s, c: (e, i, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((bm, D), x_ext.dtype)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((E, C, D), _qdtype(wire_dtype)),
            jax.ShapeDtypeStruct((E, C, nb), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(src_of_slot, jnp.int32), cnt, x_ext)
    return q.reshape(n_slots, D), s.reshape(n_slots, nb)


def _dq_kernel(q_ref, s_ref, o_ref, *, d: int, nb: int):
    qf = q_ref[...].astype(jnp.float32)
    for j in range(nb):
        seg = slice(j * WIRE_BLOCK, min((j + 1) * WIRE_BLOCK, d))
        o_ref[:, seg] = qf[:, seg] * s_ref[:, j:j + 1]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def dequantize_pallas(q: jax.Array, scales: jax.Array, *, bm: int = 256,
                      interpret: bool = False) -> jax.Array:
    """(N, D) wire dtype + (N, nb) fp32 scales -> (N, D) fp32 (the combine
    side's fp32 accumulation input)."""
    N, D = q.shape
    nb = scales.shape[1]
    bm = min(bm, N)
    return pl.pallas_call(
        functools.partial(_dq_kernel, d=D, nb=nb),
        grid=(pl.cdiv(N, bm),),
        in_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0)),
                  pl.BlockSpec((bm, nb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(q, scales)


def dequantize_ref(q, scales):
    """Dual-dialect oracle for :func:`dequantize_pallas`."""
    return dequantize_blocked(q, scales)
