"""Flash-decoding Pallas TPU kernel: single-query attention over a long KV
cache, split over sequence blocks with running-softmax state in VMEM.

Complements the split-sequence *cross-shard* decode in
``models.blocks._decode_attn_dist``: that island splits the cache across
chips and LSE-merges; this kernel is the per-chip inner loop, streaming the
local cache HBM->VMEM once with no (H, S) score materialisation.  Cache
blocks entirely beyond ``pos`` are skipped with ``pl.when`` — decode touches
only the live prefix.

Validated against ``ref.flash_attention_ref`` semantics in interpret mode
(tests/test_kernels.py::test_decode_attention).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dec_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, bk: int, nk: int, start: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]

    @pl.when(start + j * bk <= pos)          # skip dead cache blocks
    def _():
        q = q_ref[0, 0]                       # (rep, d) q heads of this kv head
        k = k_ref[0, 0]                       # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(q.shape[-1]))
        kpos = start + j * bk + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], bk), 1)
        s = jnp.where(kpos <= pos, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        corr = jnp.exp(m_prev - m_safe)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "start", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            pos: jax.Array, *, bk: int = 512, start: int = 0,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, D) one query per sequence; k/v: (B, S, Hkv, D) cache slice
    covering global positions [start, start+S); pos: scalar current position.
    GQA: q head h reads kv head h // (H // Hkv).  Returns (B, H, D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bk = min(bk, S)
    nk = pl.cdiv(S, bk)
    qg = q.reshape(B, Hkv, rep, D)
    kT = k.transpose(0, 2, 1, 3)              # (B, Hkv, S, D)
    vT = v.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        functools.partial(_dec_kernel, bk=bk, nk=nk, start=start),
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # pos scalar prefetch
            pl.BlockSpec((1, 1, rep, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((rep,), jnp.float32),
                        pltpu.VMEM((rep,), jnp.float32),
                        pltpu.VMEM((rep, D), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32)[None], qg, kT, vT)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------- paged ---
def _dec_paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, bs: int, nb: int):
    """Paged flash-decoding step: grid cell (b, h, j) covers physical block
    ``bt_ref[b, j]`` of sequence b.  Unallocated (-1) and fully-dead blocks
    are ``pl.when``-skipped — the same trick the grouped-matmul kernels use
    for unoccupied expert rows; their DMA reads a clamped (always-valid)
    block index whose data is never consumed."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]

    @pl.when((bt_ref[b, j] >= 0) & (j * bs <= pos))
    def _():
        q = q_ref[0, 0]                       # (rep, d)
        k = k_ref[0, :, 0, :]                 # (bs, d) pool block, kv head h
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(q.shape[-1]))
        kpos = j * bs + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], bs), 1)
        s = jnp.where(kpos <= pos, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        corr = jnp.exp(m_prev - m_safe)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_paged(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           pos: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """Flash decoding through a paged KV cache (DESIGN.md §18).

    q: (B, H, D) one query per sequence; k_pool/v_pool: (NB, bs, Hkv, D)
    physical block pools (``bs`` tokens per block); block_tables: (B, nb)
    int32 physical block ids, -1 = unallocated; pos: (B,) int32 per-sequence
    position of the newest token.  Sequence b attends to global positions
    [0, pos[b]], found at ``k_pool[block_tables[b, p // bs], p % bs]``.
    GQA exactly as the contiguous kernel.  Returns (B, H, D).

    The block table and positions ride scalar prefetch
    (``PrefetchScalarGridSpec``) so the k/v index maps can indirect through
    ``block_tables`` when scheduling block DMAs — unallocated entries are
    clamped to block 0 for a safe (discarded) read and skipped in-kernel.
    """
    B, H, D = q.shape
    NB, bs, Hkv = k_pool.shape[:3]
    nb = block_tables.shape[1]
    assert block_tables.shape[0] == B and pos.shape == (B,)
    rep = H // Hkv
    qg = q.reshape(B, Hkv, rep, D)
    bt = jnp.asarray(block_tables, jnp.int32)

    def _kv_map(b, h, j, bt_ref, pos_ref):
        # clamp -1 (unallocated) to block 0: the DMA must target a real
        # block, the kernel's liveness test discards whatever it carried
        del pos_ref
        return (jnp.maximum(bt_ref[b, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # (clamped) block table, pos
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rep, D),
                         lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), _kv_map),
            pl.BlockSpec((1, bs, 1, D), _kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, D),
                               lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((rep,), jnp.float32),
                        pltpu.VMEM((rep,), jnp.float32),
                        pltpu.VMEM((rep, D), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_dec_paged_kernel, bs=bs, nb=nb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, D), q.dtype),
        interpret=interpret,
    )(bt, jnp.asarray(pos, jnp.int32),
      qg, k_pool.reshape(NB, bs, Hkv, D), v_pool.reshape(NB, bs, Hkv, D))
    return out.reshape(B, H, D)


def decode_attention_paged_ref(q, k_pool, v_pool, block_tables, pos):
    """jnp reference for the paged kernel: gather each sequence's blocks
    into a contiguous cache, then masked single-query attention."""
    B, H, D = q.shape
    NB, bs, Hkv = k_pool.shape[:3]
    nb = block_tables.shape[1]
    rep = H // Hkv
    bt = jnp.maximum(jnp.asarray(block_tables, jnp.int32), 0)
    kc = k_pool[bt].reshape(B, nb * bs, Hkv, D)     # (B, S, Hkv, D)
    vc = v_pool[bt].reshape(B, nb * bs, Hkv, D)
    qg = q.reshape(B, Hkv, rep, D)
    s = jnp.einsum("bhrd,bshd->bhrs", qg, kc) / math.sqrt(D)
    kpos = jnp.arange(nb * bs)[None, None, None, :]
    s = jnp.where(kpos <= jnp.asarray(pos)[:, None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrs,bshd->bhrd", p, vc)
    return o.reshape(B, H, D).astype(q.dtype)
