"""Fused RMSNorm Pallas kernel (single HBM pass; fp32 reduction in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)             # (br, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-5, *,
                   br: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., D); scale: (D,)."""
    orig = x.shape
    D = orig[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(br, R)
    nr = pl.cdiv(R, br)
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(nr,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(xf, scale[None, :])
    return out.reshape(orig)
