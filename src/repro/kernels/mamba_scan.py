"""Selective-scan (Mamba-1) Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of warp-level shuffles,
the recurrent state h (bd x N) stays resident in VMEM scratch across the
sequential chunk dimension of the grid (TPU grids iterate in order), and
each chunk's inputs stream HBM->VMEM through the BlockSpec pipeline.  Within
a chunk the recurrence runs as a fori_loop over time steps on the VPU —
the op is elementwise-dominated (N=16), so MXU tiling buys nothing; the win
is keeping h out of HBM entirely.

Grid: (B, n_d_blocks, n_chunks); d-blocks are independent (parallel), chunks
are the sequential axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, o_ref, h_ref, *,
                 chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0]                      # (chunk, bd)
    dt = dt_ref[0]                    # (chunk, bd)
    A = A_ref[...]                    # (bd, N)
    Bs = B_ref[0]                     # (chunk, N)
    Cs = C_ref[0]                     # (chunk, N)
    Dp = D_ref[...]                   # (1, bd)

    def step(t, carry):
        h = carry                     # (bd, N)
        dt_t = dt[t][:, None]         # (bd, 1)
        dA = jnp.exp(dt_t * A)        # (bd, N)
        dBx = dt_t * Bs[t][None, :] * x[t][:, None]
        h = dA * h + dBx
        y = (h * Cs[t][None, :]).sum(axis=1)        # (bd,)
        o_ref[0, t, :] = (y + x[t] * Dp[0]).astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def mamba_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                      C: jax.Array, D: jax.Array, *, bd: int = 512,
                      chunk: int = 128, interpret: bool = False) -> jax.Array:
    """x/dt: (Bt, S, Di); A: (Di, N); B/C: (Bt, S, N); D: (Di,) -> (Bt, S, Di)."""
    Bt, S, Di = x.shape
    N = A.shape[1]
    bd = min(bd, Di)
    chunk = min(chunk, S)
    ndb, nc = pl.cdiv(Di, bd), pl.cdiv(S, chunk)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=(Bt, ndb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((bd, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, bd), lambda b, d, c: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D[None, :])
    return out
