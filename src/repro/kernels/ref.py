"""Pure-jnp oracles for every Pallas kernel.  Ground truth for tests and the
CPU lowering path used by the dry-run (kernels validate against these in
interpret mode; see tests/test_kernels.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def occupancy_mask(counts, n_groups: int, width: int) -> Array:
    """(G, N) bool occupancy mask; the bucket-layout math lives in the
    shared plan layer (numpy/jnp dual-dialect) so the jnp refs and the
    numpy substrate cannot drift."""
    from repro.core.plan import occupancy_mask as _om
    return _om(jnp.asarray(counts, jnp.int32), n_groups, width)


def grouped_matmul_ref(x: Array, w: Array, counts: Array | None = None) -> Array:
    """Per-group matmul: x (G, M, K) @ w (G, K, N) -> (G, M, N).
    Rows >= counts[g] read as zero and produce zero output rows."""
    if counts is not None:
        x = jnp.where(occupancy_mask(counts, x.shape[0],
                                     x.shape[1])[..., None], x, 0)
    return jnp.einsum("gmk,gkn->gmn", x, w.astype(x.dtype))


def grouped_swiglu_ref(x: Array, w_gate: Array, w_up: Array, w_down: Array,
                       counts: Array | None = None) -> Array:
    """Grouped expert SwiGLU: x (E, C, D); w_* (E, D, F)/(E, F, D).
    With counts, rows beyond each bucket's occupancy are zero in and out
    (swiglu(0) == 0, so masking the input suffices)."""
    dt = x.dtype
    if counts is not None:
        x = jnp.where(occupancy_mask(counts, x.shape[0],
                                     x.shape[1])[..., None], x, 0)
    g = jnp.einsum("ecd,edf->ecf", x, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(dt))


def gather_swiglu_scatter_ref(x_ext: Array, src_of_slot: Array, w_slot: Array,
                              w_gate: Array, w_up: Array, w_down: Array,
                              counts: Array | None = None) -> Array:
    """Oracle for the fused EP hot path (gather -> expert SwiGLU -> weighted
    fp32 scatter-add).  x_ext: (T+1, D) with zero scratch row T;
    src_of_slot/w_slot: (E*C,); returns (T, D) float32 partial sums."""
    E = w_gate.shape[0]
    Tp1, D = x_ext.shape
    C = src_of_slot.shape[0] // E
    buf = x_ext[src_of_slot].reshape(E, C, D)
    y = grouped_swiglu_ref(buf, w_gate, w_up, w_down, counts=counts)
    keep = (occupancy_mask(counts, E, C).reshape(-1) if counts is not None
            else jnp.ones((E * C,), bool))
    tgt = jnp.where(keep, src_of_slot, Tp1 - 1)
    out = jnp.zeros((Tp1, D), jnp.float32).at[tgt].add(
        y.reshape(E * C, D).astype(jnp.float32)
        * jnp.where(keep, w_slot.astype(jnp.float32), 0.0)[:, None])
    return out[:-1]


def flash_attention_ref(q: Array, k: Array, v: Array, causal: bool = True) -> Array:
    """Naive full-materialisation attention. q (B,S,H,D), k/v (B,S,Hkv,D)."""
    B, S, H, Dh = q.shape
    rep = H // k.shape[2]
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(Dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, k.shape[1]), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def mamba_scan_ref(x: Array, dt: Array, A: Array, B: Array, C: Array,
                   D: Array) -> Array:
    """Selective SSM scan oracle (Mamba-1 recurrence, sequential).

    x: (Bt, S, Di); dt: (Bt, S, Di) softplus-activated step sizes;
    A: (Di, N) negative-real; B, C: (Bt, S, N); D: (Di,) skip.
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D*x_t
    """
    Bt, S, Di = x.shape
    N = A.shape[1]
    dA = jnp.exp(dt[..., None] * A[None, None])                  # (Bt,S,Di,N)
    dBx = dt[..., None] * B[:, :, None, :] * x[..., None]        # (Bt,S,Di,N)

    def step(h, inp):
        da, dbx, c = inp
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    h0 = jnp.zeros((Bt, Di, N), x.dtype)
    _, ys = jax.lax.scan(step, h0,
                         (dA.swapaxes(0, 1), dBx.swapaxes(0, 1),
                          C.swapaxes(0, 1)))
    return ys.swapaxes(0, 1) + x * D[None, None]


def combine_reduce_ref(parts: Array, weights: Array) -> Array:
    """Weighted combine: parts (T, K, D), weights (T, K) -> (T, D) in fp32."""
    return jnp.einsum("tkd,tk->td", parts.astype(jnp.float32),
                      weights.astype(jnp.float32)).astype(parts.dtype)


def rmsnorm_ref(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)
