"""Pure-jnp oracles for every Pallas kernel.  Ground truth for tests and the
CPU lowering path used by the dry-run (kernels validate against these in
interpret mode; see tests/test_kernels.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def grouped_matmul_ref(x: Array, w: Array) -> Array:
    """Per-group matmul: x (G, M, K) @ w (G, K, N) -> (G, M, N)."""
    return jnp.einsum("gmk,gkn->gmn", x, w.astype(x.dtype))


def grouped_swiglu_ref(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """Grouped expert SwiGLU: x (E, C, D); w_* (E, D, F)/(E, F, D)."""
    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", x, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(dt))


def flash_attention_ref(q: Array, k: Array, v: Array, causal: bool = True) -> Array:
    """Naive full-materialisation attention. q (B,S,H,D), k/v (B,S,Hkv,D)."""
    B, S, H, Dh = q.shape
    rep = H // k.shape[2]
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(Dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, k.shape[1]), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def mamba_scan_ref(x: Array, dt: Array, A: Array, B: Array, C: Array,
                   D: Array) -> Array:
    """Selective SSM scan oracle (Mamba-1 recurrence, sequential).

    x: (Bt, S, Di); dt: (Bt, S, Di) softplus-activated step sizes;
    A: (Di, N) negative-real; B, C: (Bt, S, N); D: (Di,) skip.
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D*x_t
    """
    Bt, S, Di = x.shape
    N = A.shape[1]
    dA = jnp.exp(dt[..., None] * A[None, None])                  # (Bt,S,Di,N)
    dBx = dt[..., None] * B[:, :, None, :] * x[..., None]        # (Bt,S,Di,N)

    def step(h, inp):
        da, dbx, c = inp
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    h0 = jnp.zeros((Bt, Di, N), x.dtype)
    _, ys = jax.lax.scan(step, h0,
                         (dA.swapaxes(0, 1), dBx.swapaxes(0, 1),
                          C.swapaxes(0, 1)))
    return ys.swapaxes(0, 1) + x * D[None, None]


def combine_reduce_ref(parts: Array, weights: Array) -> Array:
    """Weighted combine: parts (T, K, D), weights (T, K) -> (T, D) in fp32."""
    return jnp.einsum("tkd,tk->td", parts.astype(jnp.float32),
                      weights.astype(jnp.float32)).astype(parts.dtype)


def rmsnorm_ref(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)
