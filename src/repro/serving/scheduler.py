"""Continuous-batching scheduler with prefill/decode disaggregation.

Forms one :class:`Microbatch` per engine step under two constraints:

- **token budget** — at most ``token_budget`` tokens per microbatch (the
  fixed EP geometry the persistent session is registered for; the engine
  pads the remainder with invalid routing entries that move no traffic);
- **cache pressure** — a token is scheduled ONLY after its KV block is
  allocated (``KVBlockPool.grow`` before the slice is emitted).  A decode
  step that cannot get a block stalls that sequence for the step; a prompt
  that cannot get its first chunk's blocks blocks admission (head-of-line,
  so admission stays FIFO and deterministic).

Decode runs first (keeps inter-token latency flat under load), then
*chunked prefill* fills the remaining budget — at most ``prefill_chunk``
prompt tokens per request per step, so one long prompt cannot freeze every
running decode (the prefill/decode disaggregation knob; chunk == budget
degenerates to whole-prompt prefill).  When a sequence's last prompt chunk
completes, that same model step's logits yield its first generated token —
time-to-first-token is measured to the END of that step on the event clock.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serving.kv_cache import KVBlockPool
from repro.serving.workload import Request


@dataclass(frozen=True)
class Slice:
    """One request's contribution to a microbatch: ``n_tokens`` tokens of
    ``kind`` ("prefill" | "decode") covering positions
    ``[start, start + n_tokens)`` of sequence ``rid``."""
    rid: int
    kind: str
    start: int
    n_tokens: int


@dataclass
class Microbatch:
    slices: list[Slice] = field(default_factory=list)

    @property
    def n_tokens(self) -> int:
        return sum(s.n_tokens for s in self.slices)

    def count(self, kind: str) -> int:
        return sum(s.n_tokens for s in self.slices if s.kind == kind)


@dataclass
class SeqState:
    req: Request
    admitted_us: float
    prefilled: int = 0         # prompt tokens staged into the KV cache
    generated: int = 0         # tokens produced (first comes with prefill)
    done: bool = False
    first_token_us: Optional[float] = None
    finish_us: Optional[float] = None
    token_times: list[float] = field(default_factory=list)

    @property
    def cache_len(self) -> int:
        """Tokens resident in the KV cache: the prompt prefix staged so far
        plus every generated token that has been fed back (all but the
        newest)."""
        return self.prefilled + max(0, self.generated - 1)


@dataclass(frozen=True)
class SchedulerConfig:
    token_budget: int          # microbatch size (== the session's T)
    prefill_chunk: int         # max prompt tokens per request per step
    max_running: int = 1 << 30

    def __post_init__(self):
        assert 0 < self.prefill_chunk <= self.token_budget


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, pool: KVBlockPool):
        self.cfg = cfg
        self.pool = pool
        self.waiting: deque[Request] = deque()
        self.running: dict[int, SeqState] = {}
        self.finished: dict[int, SeqState] = {}
        self.counters = {
            "scheduled_tokens": 0, "prefill_tokens": 0, "decode_tokens": 0,
            "generated_tokens": 0, "evicted_blocks": 0, "decode_stalls": 0,
            "admission_blocked": 0, "microbatches": 0, "completed": 0,
        }

    # -------------------------------------------------------------- intake --
    def add(self, req: Request) -> None:
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(not s.done
                                         for s in self.running.values())

    # ------------------------------------------------------------ schedule --
    def schedule(self, now_us: float) -> Optional[Microbatch]:
        """Form the next microbatch.  Every slice returned already has its
        KV blocks allocated (the no-token-without-a-block invariant)."""
        pool, c = self.pool, self.counters
        budget = self.cfg.token_budget
        mb = Microbatch()

        # 1) decode: one token per running, fully-prefilled, live sequence
        for rid, st in self.running.items():
            if budget == 0:
                break
            if st.done or st.prefilled < st.req.prompt_len:
                continue
            pos = st.cache_len                     # feed back the newest tok
            if not pool.can_grow(rid, pos + 1):
                c["decode_stalls"] += 1            # stalled, retried next mb
                continue
            pool.grow(rid, pos + 1)
            mb.slices.append(Slice(rid, "decode", pos, 1))
            budget -= 1

        # 2) chunked prefill of partially-staged running prompts
        for rid, st in self.running.items():
            if budget == 0:
                break
            if st.done or st.prefilled >= st.req.prompt_len:
                continue
            n = min(self.cfg.prefill_chunk, st.req.prompt_len - st.prefilled,
                    budget)
            if not pool.can_grow(rid, st.prefilled + n):
                c["decode_stalls"] += 1
                continue
            pool.grow(rid, st.prefilled + n)
            mb.slices.append(Slice(rid, "prefill", st.prefilled, n))
            budget -= n

        # 3) admit new requests (FIFO; head-of-line on cache pressure)
        n_live = sum(not s.done for s in self.running.values())
        while self.waiting and budget > 0 and n_live < self.cfg.max_running:
            req = self.waiting[0]
            n = min(self.cfg.prefill_chunk, req.prompt_len, budget)
            if not pool.can_grow(req.rid, n):
                c["admission_blocked"] += 1
                break
            self.waiting.popleft()
            pool.grow(req.rid, n)
            self.running[req.rid] = SeqState(req, admitted_us=now_us)
            mb.slices.append(Slice(req.rid, "prefill", 0, n))
            budget -= n
            n_live += 1

        if not mb.slices:
            return None
        c["microbatches"] += 1
        c["scheduled_tokens"] += mb.n_tokens
        c["prefill_tokens"] += mb.count("prefill")
        c["decode_tokens"] += mb.count("decode")
        return mb

    # ------------------------------------------------------------ complete --
    def complete_step(self, mb: Microbatch, t_end_us: float) -> list[int]:
        """Apply a finished microbatch at event-clock time ``t_end_us``:
        advance prefill offsets, emit tokens (the last prompt chunk's logits
        yield the first generated token), retire + evict finished sequences.
        Returns the rids that finished this step."""
        c = self.counters
        done_now: list[int] = []
        for s in mb.slices:
            st = self.running[s.rid]
            if s.kind == "prefill":
                assert s.start == st.prefilled, (s, st.prefilled)
                st.prefilled += s.n_tokens
                if st.prefilled == st.req.prompt_len:
                    st.generated = 1              # first token: last logit
                    st.first_token_us = t_end_us
                    st.token_times.append(t_end_us)
                    c["generated_tokens"] += 1
            else:
                st.generated += 1
                st.token_times.append(t_end_us)
                c["generated_tokens"] += 1
            if st.generated >= st.req.max_new_tokens and not st.done:
                st.done = True
                st.finish_us = t_end_us
                done_now.append(s.rid)
        for rid in done_now:
            c["evicted_blocks"] += self.pool.release(rid)
            c["completed"] += 1
            self.finished[rid] = self.running.pop(rid)
        return done_now

    # ------------------------------------------------------------- metrics --
    def latency_stats(self) -> dict:
        """TTFT and inter-token latency percentiles over finished (and
        in-flight) sequences, event-clock microseconds."""
        ttft, itl = [], []
        for st in list(self.finished.values()) + list(self.running.values()):
            if st.first_token_us is not None:
                ttft.append(st.first_token_us - st.req.arrival_us)
            ts = st.token_times
            itl.extend(float(b - a) for a, b in zip(ts, ts[1:]))
        out = {}
        for name, xs in (("ttft", ttft), ("itl", itl)):
            if xs:
                arr = np.asarray(xs)
                out[f"{name}_p50_us"] = float(np.percentile(arr, 50))
                out[f"{name}_p99_us"] = float(np.percentile(arr, 99))
        return out
