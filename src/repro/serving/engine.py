"""The EP-native continuous-batching serving engine (DESIGN.md §18).

:class:`ServingEngine` closes the loop the ROADMAP's top open item asked
for: requests (seeded arrival processes) -> continuous-batching scheduler
(:mod:`repro.serving.scheduler`) over a paged KV pool
(:mod:`repro.serving.kv_cache`) -> one *model step* per microbatch whose
``n_layers`` MoE layers dispatch through a persistent EP session
(``SimulatedRDMABackend.dispatch_step``) on the deterministic event clock.

Time accounting is entirely event-clock: a microbatch's cost is the span
``dispatch_step`` reports (L non-MoE attention segments + L LL
dispatch/combine rounds, overlapped or not per ``step_mode``), and the
engine clock jumps forward by that span.  Requests arrive on the same
clock, so tokens/s, TTFT and inter-token latency are deterministic
functions of (config, workload seed) — the property the exact-equality
benchmark rows gate on.

The serving A/B the fig13 benchmark measures is ``step_mode``:

- ``"pipelined"`` — persistent session, one quiesce drain per microbatch,
  rank-local cross-layer overlap (the PR 8 machinery, forward-only);
- ``"serial"``    — persistent session, one drain per layer;
- ``"per_layer"`` — naive: a fresh world per layer per microbatch
  (registration rebuilt every call), clocks summed.

Token embeddings and router choices are seeded functions of
``(rid, position, layer)`` ONLY — never of generated token values — so the
three modes run bit-identical routing and the cross-layer pipelining that
makes the session path fast is legitimate (layer l+1's dispatch does not
depend on layer l's combine output).  The replica path (PR 7) hangs a
:class:`~repro.distributed.elastic.LoadBalancer` off the router: logical
routing tables are split across replica slots per microbatch and the
placement is re-fit online when the load window skews.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import plan as planlib
from repro.core.backend import SimulatedRDMABackend
from repro.core.ep import EPSpec
from repro.serving.kv_cache import KVBlockPool
from repro.serving.scheduler import Microbatch, Scheduler, SchedulerConfig
from repro.serving.workload import Request


@dataclass(frozen=True)
class EngineConfig:
    """Static serving configuration (model geometry + EP + cache + step)."""

    n_layers: int = 2          # MoE layers per model step
    n_experts: int = 8         # logical experts
    top_k: int = 2
    d_model: int = 16
    d_ff: int = 32
    ep_degree: int = 4         # simulated EP ranks
    token_budget: int = 32     # microbatch tokens == the session's T
    prefill_chunk: int = 16
    block_size: int = 16       # KV block, tokens
    n_blocks: int = 512        # KV pool size
    step_mode: str = "pipelined"   # "pipelined" | "serial" | "per_layer"
    wire_dtype: str = "fp32"       # "fp32" | "fp8" | "int8" (PR 6 codec)
    nonmoe_us: float = 20.0    # attention/norm segment per layer, eventclock
    replicas_per_expert: int = 1   # >1 engages the LoadBalancer path (PR 7)
    route_alpha: float = 0.0   # Zipf skew of expert popularity (0 = uniform)
    seed: int = 0
    n_channels: int = 4
    net_cfg: Optional[object] = None   # transport NetConfig (seeded default)

    def __post_init__(self):
        assert self.token_budget % self.ep_degree == 0, \
            "token_budget must be divisible by ep_degree (session geometry)"
        assert self.step_mode in ("pipelined", "serial", "per_layer")
        E_phys = self.n_experts * self.replicas_per_expert
        assert E_phys % self.ep_degree == 0, (E_phys, self.ep_degree)


class ServingEngine:
    """Continuous-batching decode engine over a persistent EP session."""

    def __init__(self, cfg: EngineConfig):
        from repro.core.transport.simulator import NetConfig

        self.cfg = cfg
        self.clock_us = 0.0
        self.pool = KVBlockPool(cfg.n_blocks, cfg.block_size)
        self.sched = Scheduler(
            SchedulerConfig(cfg.token_budget, cfg.prefill_chunk), self.pool)
        net_cfg = cfg.net_cfg or NetConfig(mode="srd", seed=cfg.seed)
        session = cfg.step_mode != "per_layer"
        self.backend = SimulatedRDMABackend(
            net_cfg, n_channels=cfg.n_channels,
            session_layers=cfg.n_layers if session else 0)
        # expert FFN weights, shared across layers (serving replicas of one
        # deployment); physical slots view logical weights through p2l
        E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
        rng = np.random.default_rng((cfg.seed, 0xEF))
        self._wg = (rng.standard_normal((E, D, F)) * 0.1).astype(np.float32)
        self._wu = (rng.standard_normal((E, D, F)) * 0.1).astype(np.float32)
        self._wd = (rng.standard_normal((E, F, D)) * 0.1).astype(np.float32)
        # Zipf expert-popularity weights with a per-layer permutation, so
        # skew hits different physical ranks per layer (the LB stressor)
        p = 1.0 / np.arange(1, E + 1, dtype=np.float64) ** cfg.route_alpha
        self._route_p = []
        for l in range(cfg.n_layers):
            perm = np.random.default_rng((cfg.seed, 0x9E, l)).permutation(E)
            pl = np.empty(E)
            pl[perm] = p
            self._route_p.append(pl / pl.sum())
        # replica path: placement starts uniform, re-fit online by the LB
        self.lb = None
        if cfg.replicas_per_expert > 1:
            from repro.distributed.elastic import LoadBalancer
            self.lb = LoadBalancer(
                n_logical=E, n_ranks=cfg.ep_degree,
                slots_per_rank=E * cfg.replicas_per_expert // cfg.ep_degree)
        self.spec = EPSpec(
            axes=("ep",), sizes=(cfg.ep_degree,),
            n_experts=E * cfg.replicas_per_expert, top_k=cfg.top_k,
            mode="ll", wire_dtype=cfg.wire_dtype)
        self._pending: list[Request] = []    # not yet arrived, time-sorted
        self.counters = {
            "steps": 0, "rebalances": 0, "drains": 0, "cmds": 0,
            "dispatch_payload_bytes": 0, "dispatch_wire_bytes": 0,
            "dispatch_msgs": 0, "moe_elapsed_us": 0,
        }
        self.output_digest = 0.0   # order-independent sum over valid rows

    # ---------------------------------------------------------- submission --
    def submit(self, req: Request) -> None:
        self._pending.append(req)
        self._pending.sort(key=lambda r: (r.arrival_us, r.rid))

    def submit_all(self, reqs: list[Request]) -> None:
        self._pending.extend(reqs)
        self._pending.sort(key=lambda r: (r.arrival_us, r.rid))

    def _admit_arrived(self) -> None:
        while self._pending and self._pending[0].arrival_us <= self.clock_us:
            self.sched.add(self._pending.pop(0))

    # --------------------------------------------------------- model inputs --
    def _token_inputs(self, mb: Microbatch):
        """Build the padded step arrays for a microbatch: x ``(T, D)`` and
        per-layer LOGICAL routing ``(T, K)`` (+ weights), all seeded by
        ``(rid, position, layer)``.  Padding rows carry ``ti = -1`` and move
        no traffic, keeping the session's registered geometry fixed."""
        cfg = self.cfg
        T, D, K, L = cfg.token_budget, cfg.d_model, cfg.top_k, cfg.n_layers
        x = np.zeros((T, D), np.float32)
        tis = [np.full((T, K), -1, np.int32) for _ in range(L)]
        tws = [np.zeros((T, K), np.float32) for _ in range(L)]
        row = 0
        for s in mb.slices:
            for pos in range(s.start, s.start + s.n_tokens):
                rng = np.random.default_rng((cfg.seed, s.rid, pos))
                x[row] = rng.standard_normal(D).astype(np.float32)
                for l in range(L):
                    tis[l][row] = rng.choice(cfg.n_experts, size=K,
                                             replace=False, p=self._route_p[l])
                    w = rng.random(K).astype(np.float32) + 1e-3
                    tws[l][row] = w / w.sum()
                row += 1
        assert row == mb.n_tokens <= T
        return x, tis, tws

    def _physical(self, tis):
        """Translate logical routing to physical replica slots (identity
        when ``replicas_per_expert == 1``) and return per-layer ``(T, K)``
        physical tables plus the physical-slot weight views."""
        cfg = self.cfg
        if self.lb is None:
            return tis, self._wg, self._wu, self._wd
        pl_obj = self.lb.placement
        R, T = cfg.ep_degree, cfg.token_budget
        out = []
        for ti in tis:
            ti_r = ti.reshape(R, T // R, cfg.top_k)
            out.append(planlib.split_to_physical_world(pl_obj, ti_r)
                       .reshape(T, cfg.top_k))
        p2l = np.asarray(pl_obj.phys_to_logical)
        return out, self._wg[p2l], self._wu[p2l], self._wd[p2l]

    # -------------------------------------------------------------- stepping --
    def step(self) -> bool:
        """Run ONE engine step: admit arrivals, schedule a microbatch, run
        the model step on the event clock, apply completions.  Returns False
        when there is nothing left to do (now or in the future)."""
        self._admit_arrived()
        mb = self.sched.schedule(self.clock_us)
        if mb is None:
            if not self._pending:
                if self.sched.has_work:
                    raise RuntimeError(
                        "serving stalled: work queued but unschedulable "
                        "(KV pool too small for the running set)")
                return False
            # idle: jump the event clock to the next arrival
            self.clock_us = max(self.clock_us, self._pending[0].arrival_us)
            self._admit_arrived()
            mb = self.sched.schedule(self.clock_us)
            if mb is None:
                raise RuntimeError("arrival admitted but not schedulable")
        x, tis_log, tws = self._token_inputs(mb)
        tis, wg, wu, wd = self._physical(tis_log)
        outs, elapsed, stats = self.backend.dispatch_step(
            self.spec, [x] * self.cfg.n_layers, tis, tws, wg, wu, wd,
            nonmoe_fwd_us=self.cfg.nonmoe_us, mode=self.cfg.step_mode)
        self.clock_us += elapsed
        self.sched.complete_step(mb, self.clock_us)
        self.pool.assert_consistent()     # no double-alloc / leak, per step
        c = self.counters
        c["steps"] += 1
        c["drains"] += stats["drains_per_step"]
        c["cmds"] += stats["cmds_per_step"]
        c["dispatch_payload_bytes"] += stats["dispatch_payload_bytes"]
        c["dispatch_wire_bytes"] += stats["dispatch_wire_bytes"]
        c["dispatch_msgs"] += stats["dispatch_msgs"]
        c["moe_elapsed_us"] += int(round(elapsed))
        n = mb.n_tokens
        self.output_digest += float(np.abs(outs[-1][:n]).sum())
        if self.lb is not None:
            # observe LOGICAL loads of the last layer's routing; re-fit the
            # placement when the window imbalance trips the threshold
            flat = tis_log[-1].reshape(-1)
            self.lb.observe(planlib.group_counts(
                flat, self.cfg.n_experts, flat >= 0))
            if self.lb.maybe_replace() is not None:
                c["rebalances"] += 1
        return True

    def run(self, max_steps: int = 1 << 30) -> dict:
        """Drive the engine until every submitted request completes (or
        ``max_steps``), then return :meth:`stats`."""
        steps = 0
        while steps < max_steps and self.step():
            steps += 1
        return self.stats()

    # --------------------------------------------------------------- stats --
    def stats(self) -> dict:
        sc = self.sched.counters
        gen = sc["generated_tokens"]
        out = {
            "elapsed_us": self.clock_us,
            "generated_tokens": gen,
            "tokens_per_s": gen / (self.clock_us / 1e6)
            if self.clock_us > 0 else 0.0,
            **{f"sched_{k}": v for k, v in sc.items()},
            **dict(self.counters),
            "kv_allocs": self.pool.allocs, "kv_frees": self.pool.frees,
            "kv_high_water": self.pool.high_water,
            **self.sched.latency_stats(),
        }
        return out
