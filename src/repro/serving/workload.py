"""Request workload models: seeded arrival processes on the event clock.

A :class:`Request` is one user call: it arrives at ``arrival_us`` (event
clock, not wall clock), carries ``prompt_len`` tokens to prefill and asks
for ``max_new_tokens`` decode tokens.  Arrival processes are deterministic
functions of their seed so every benchmark/test run sees the same traffic:

- :func:`poisson_arrivals` — memoryless traffic at one offered load
  (exponential inter-arrival gaps), the open-loop load-sweep workhorse.
- :func:`bursty_arrivals` — on/off (interrupted-Poisson) traffic: bursts at
  ``rate_rps * burst_factor`` separated by idle gaps, same mean load.
- :func:`load_curve_arrivals` — piecewise-constant offered-load curve
  (ramps, spikes, diurnal shapes) for scenario tests.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Request:
    """One serving request, timestamped on the event clock."""

    rid: int
    arrival_us: float
    prompt_len: int
    max_new_tokens: int

    def __post_init__(self):
        assert self.prompt_len > 0 and self.max_new_tokens > 0, self


def _lengths(rng: np.random.Generator, n: int, lo_hi: tuple[int, int],
             ) -> np.ndarray:
    lo, hi = lo_hi
    assert 0 < lo <= hi, lo_hi
    return rng.integers(lo, hi + 1, size=n)


def poisson_arrivals(rate_rps: float, n: int, *, seed: int,
                     prompt_len: tuple[int, int] = (16, 64),
                     gen_len: tuple[int, int] = (8, 32),
                     start_us: float = 0.0, rid0: int = 0) -> list[Request]:
    """``n`` requests with exponential inter-arrival gaps at ``rate_rps``
    requests/second (event-clock microseconds)."""
    assert rate_rps > 0 and n > 0
    rng = np.random.default_rng(seed)
    gaps_us = rng.exponential(1e6 / rate_rps, size=n)
    t = start_us + np.cumsum(gaps_us)
    pl = _lengths(rng, n, prompt_len)
    gl = _lengths(rng, n, gen_len)
    return [Request(rid0 + i, float(t[i]), int(pl[i]), int(gl[i]))
            for i in range(n)]


def bursty_arrivals(rate_rps: float, n: int, *, seed: int,
                    burst_factor: float = 4.0, burst_len: int = 8,
                    prompt_len: tuple[int, int] = (16, 64),
                    gen_len: tuple[int, int] = (8, 32),
                    start_us: float = 0.0) -> list[Request]:
    """Interrupted-Poisson traffic: bursts of ``burst_len`` requests at
    ``rate_rps * burst_factor``, separated by idle gaps sized so the MEAN
    offered load stays ``rate_rps`` — the tail-latency stressor."""
    assert burst_factor > 1.0 and burst_len > 0
    rng = np.random.default_rng(seed)
    in_burst = rng.exponential(1e6 / (rate_rps * burst_factor), size=n)
    # each burst of B requests owes (B gaps at the mean rate) total time;
    # the idle gap carries what the fast in-burst gaps did not spend
    idle_gap = burst_len * 1e6 * (1.0 / rate_rps
                                  - 1.0 / (rate_rps * burst_factor))
    gaps = in_burst.copy()
    gaps[burst_len - 1::burst_len] += idle_gap * rng.uniform(
        0.5, 1.5, size=len(gaps[burst_len - 1::burst_len]))
    t = start_us + np.cumsum(gaps)
    pl = _lengths(rng, n, prompt_len)
    gl = _lengths(rng, n, gen_len)
    return [Request(i, float(t[i]), int(pl[i]), int(gl[i]))
            for i in range(n)]


def load_curve_arrivals(curve: list[tuple[float, float]], *, seed: int,
                        prompt_len: tuple[int, int] = (16, 64),
                        gen_len: tuple[int, int] = (8, 32)) -> list[Request]:
    """Piecewise-constant offered load: ``curve`` is a list of
    ``(duration_us, rate_rps)`` segments; requests are Poisson within each
    segment.  ``rate_rps == 0`` segments are idle gaps."""
    rng = np.random.default_rng(seed)
    out: list[Request] = []
    t0 = 0.0
    rid = 0
    for dur_us, rate in curve:
        assert dur_us > 0 and rate >= 0, (dur_us, rate)
        t = t0
        while rate > 0:
            t += rng.exponential(1e6 / rate)
            if t >= t0 + dur_us:
                break
            out.append(Request(rid, float(t),
                               int(_lengths(rng, 1, prompt_len)[0]),
                               int(_lengths(rng, 1, gen_len)[0])))
            rid += 1
        t0 += dur_us
    return out
