"""Block-allocated paged KV cache bookkeeping (DESIGN.md §18).

:class:`KVBlockPool` manages a fixed pool of fixed-size KV blocks with
free-list allocation and per-sequence block tables — the vLLM-style paging
model the paged decode-attention kernel
(:func:`repro.kernels.decode_attention.decode_attention_paged`) reads
through.  This module owns only the *metadata*: which physical block backs
which (sequence, block-index) slot.  The payload arrays live with the model
(jax) or are abstracted away entirely (the event-clock serving engine).

Invariants (asserted here, re-checked by ``assert_consistent`` and the
serving tests):

- a physical block is either on the free list or in exactly ONE sequence's
  block table, never both, never two tables (no double allocation);
- a sequence's table covers ``ceil(len / block_size)`` blocks for its
  current length — no token position exists without an allocated block;
- ``release`` returns every block of a sequence to the free list (eviction
  on completion), in deterministic LIFO order so runs are reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class KVBlockPool:
    n_blocks: int
    block_size: int
    free: list[int] = field(init=False)
    tables: dict[int, list[int]] = field(init=False)
    lengths: dict[int, int] = field(init=False)
    # deterministic counters (exact-gated by the serving benchmark rows)
    allocs: int = 0
    frees: int = 0
    high_water: int = 0

    def __post_init__(self):
        assert self.n_blocks > 0 and self.block_size > 0
        # LIFO free list: block reuse order is deterministic
        self.free = list(range(self.n_blocks - 1, -1, -1))
        self.tables = {}
        self.lengths = {}

    # ------------------------------------------------------------ queries --
    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_used(self) -> int:
        return self.n_blocks - len(self.free)

    def blocks_needed(self, seq_id: int, new_len: int) -> int:
        """How many new blocks growing ``seq_id`` to ``new_len`` tokens
        requires (0 when the current table already covers it)."""
        have = len(self.tables.get(seq_id, ()))
        need = -(-new_len // self.block_size)
        return max(0, need - have)

    def can_grow(self, seq_id: int, new_len: int) -> bool:
        return self.blocks_needed(seq_id, new_len) <= len(self.free)

    def block_table(self, seq_id: int) -> list[int]:
        return list(self.tables[seq_id])

    # --------------------------------------------------------- transitions --
    def grow(self, seq_id: int, new_len: int) -> list[int]:
        """Extend ``seq_id``'s table to cover ``new_len`` tokens, allocating
        from the free list.  Raises when the pool cannot cover it — callers
        must check :meth:`can_grow` first (the scheduler's admission rule:
        no token is ever scheduled without its block allocated)."""
        n = self.blocks_needed(seq_id, new_len)
        if n > len(self.free):
            raise MemoryError(
                f"KV pool exhausted: seq {seq_id} needs {n} blocks, "
                f"{len(self.free)} free")
        tab = self.tables.setdefault(seq_id, [])
        for _ in range(n):
            tab.append(self.free.pop())
        self.lengths[seq_id] = max(self.lengths.get(seq_id, 0), new_len)
        self.allocs += n
        self.high_water = max(self.high_water, self.n_used)
        return tab[-n:] if n else []

    def release(self, seq_id: int) -> int:
        """Evict a finished sequence: return its blocks to the free list
        (reverse order — LIFO reuse) and drop its table."""
        tab = self.tables.pop(seq_id)
        self.lengths.pop(seq_id, None)
        for b in reversed(tab):
            self.free.append(b)
        self.frees += len(tab)
        return len(tab)

    # --------------------------------------------------------- invariants --
    def assert_consistent(self) -> None:
        held = [b for tab in self.tables.values() for b in tab]
        assert len(held) == len(set(held)), "block in two tables"
        assert len(self.free) == len(set(self.free)), "free-list duplicate"
        both = set(held) & set(self.free)
        assert not both, f"blocks both free and allocated: {sorted(both)}"
        universe = set(held) | set(self.free)
        assert universe == set(range(self.n_blocks)), "block leaked"
        for sid, n in self.lengths.items():
            assert len(self.tables[sid]) * self.block_size >= n, (sid, n)
