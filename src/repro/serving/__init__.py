"""EP-native continuous-batching serving engine (DESIGN.md §18).

The inference-side counterpart of the PR 8 training-step pipeline: a request
queue with seeded arrival-process simulation (Poisson / bursty offered-load
curves standing in for production traffic), a block-allocated paged KV cache
(:class:`KVBlockPool`), a continuous-batching scheduler with prefill/decode
disaggregation (chunked prefill interleaved with decode steps under a token
budget and cache pressure), and a model step whose MoE layers dispatch
through a persistent EP session (``SimulatedRDMABackend(session_layers=)``)
per microbatch on the deterministic event clock.

Everything here is host-side and seeded: two engines with the same config
and workload produce bit-identical counters, latencies and outputs — the
property the exact-equality ``fig13_serving/counters/*`` benchmark rows and
the CI serving smoke gate on.
"""
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kv_cache import KVBlockPool
from repro.serving.scheduler import (Microbatch, SchedulerConfig, Scheduler,
                                     SeqState, Slice)
from repro.serving.workload import (Request, bursty_arrivals, load_curve_arrivals,
                                    poisson_arrivals)

__all__ = [
    "EngineConfig", "ServingEngine", "KVBlockPool", "Microbatch",
    "SchedulerConfig", "Scheduler", "SeqState", "Slice", "Request",
    "bursty_arrivals", "load_curve_arrivals", "poisson_arrivals",
]
