"""ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
shardable, no device allocation.  Used by the dry-run (lower + compile) for
every (architecture x input shape x mesh) cell.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.sharding import (DistCtx, cache_pspecs,
                                        effective_batch_axes, param_pspecs)
from repro.models import model_zoo as Z


def _sds(shape, dtype, dist: Optional[DistCtx], spec: Optional[P]):
    if dist is None or spec is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(dist.mesh, spec))


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                dist: Optional[DistCtx] = None) -> dict:
    """Batch input stand-ins for one shape cell."""
    B, S = cell.global_batch, cell.seq_len
    bd = effective_batch_axes(dist, B) if dist else None
    m = dist.seq_axis if dist else None
    if cell.is_decode:
        out = {"tokens": _sds((B, 1), jnp.int32, dist, P(bd, None)),
               "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        return out
    s_txt = S - cfg.frontend_prefix
    sq = m if (m and s_txt % dist.mesh.shape[m] == 0) else None
    out = {"tokens": _sds((B, s_txt), jnp.int32, dist, P(bd, sq)),
           "labels": _sds((B, s_txt), jnp.int32, dist, P(bd, sq))}
    if cfg.frontend_prefix:
        psq = m if (m and cfg.frontend_prefix % dist.mesh.shape[m] == 0) else None
        out["prefix"] = _sds((B, cfg.frontend_prefix, cfg.d_model),
                             jnp.bfloat16, dist, P(bd, psq, None))
    return out


def param_specs_sds(cfg: ModelConfig, dist: Optional[DistCtx]) -> dict:
    shapes = jax.eval_shape(lambda k: Z.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    if dist is None:
        return shapes
    specs = param_pspecs(cfg, dist, shapes)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(dist.mesh, sp)),
        shapes, specs)


def state_specs_sds(cfg: ModelConfig, dist: Optional[DistCtx]):
    """TrainState (params + optimizer) stand-ins with shardings."""
    from repro.optim import adamw
    from repro.training.train_loop import TrainState
    p = param_specs_sds(cfg, dist)
    state_shapes = jax.eval_shape(
        lambda pp: TrainState(pp, adamw.init_state(
            pp, factored=(cfg.optimizer == "adafactor"))), p)
    if dist is None:
        return state_shapes

    def shard(tree):
        specs = param_pspecs(cfg, dist, tree)
        return jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(dist.mesh, sp)),
            tree, specs)

    opt = state_shapes.opt
    scalar = jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(dist.mesh, P()))
    return TrainState(params=shard(state_shapes.params),
                      opt=opt._replace(step=scalar, mu=shard(opt.mu),
                                       nu=shard(opt.nu)))


def cache_specs_sds(cfg: ModelConfig, cell: ShapeCell,
                    dist: Optional[DistCtx], dtype=jnp.bfloat16) -> dict:
    B, S = cell.global_batch, cell.seq_len
    shapes = jax.eval_shape(lambda: Z.init_cache(cfg, B, S, dtype))
    if dist is None:
        return shapes
    specs = cache_pspecs(cfg, dist, shapes, B)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(dist.mesh, sp)),
        shapes, specs)
