"""Production meshes (assignment spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  The single-pod mesh is (data=16, model=16) = 256
chips; the multi-pod mesh is (pod=2, data=16, model=16) = 512 chips (the
"pod" axis is the paper's RDMA domain; "model" is the intra-pod ICI/NVLink
domain).
"""
from __future__ import annotations

import jax

from repro.compat import AxisType  # jax version shims (make_mesh/AxisType)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_bench_mesh(n_devices: int, model: int = 4):
    """Small CPU-device mesh for benchmarks/integration tests."""
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


# TPU v5e hardware constants for the roofline (assignment spec)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
