"""Roofline analysis (assignment deliverable g).

Three terms per (arch x shape x mesh), derived from the compiled dry-run:

  t_compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
  t_memory     = HLO_bytes_per_device / HBM_BW
  t_collective = collective_bytes_per_device / ICI_BW

cost_analysis() reports the per-device (SPMD-partitioned) module; collective
bytes are parsed from the partitioned HLO text (result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) gives the useful-compute
ratio that exposes remat/recompute and masked-attention waste.
"""
from __future__ import annotations

import math
import re
from typing import Optional

from repro.configs.base import ModelConfig, ShapeCell
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (result-shape bytes),
    from the SPMD-partitioned HLO."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if kind.endswith("-done"):
            continue
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": out, "count_by_kind": counts,
            "total_bytes": sum(out.values())}


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed."""
    n = cfg.active_param_count() if cfg.moe.enabled else cfg.param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens          # fwd only
    return 2.0 * n * cell.global_batch   # one token per sequence


def decode_ideal_bytes(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Minimum HBM traffic for one decode step (global): read the active
    params once + the live KV/SSM cache once.  Decode is memory-bound by
    construction, so its roofline fraction is measured against this."""
    n = cfg.active_param_count() if cfg.moe.enabled else cfg.param_count()
    params = n * 2                                     # bf16
    B, S = cell.global_batch, cell.seq_len
    cache = 0.0
    for i in range(cfg.n_layers):
        if cfg.is_attn_layer(i):
            cache += 2 * B * S * cfg.n_kv_heads * cfg.head_dim_ * 2
        elif cfg.mamba.enabled:
            di = cfg.mamba.expand * cfg.d_model
            cache += B * di * cfg.mamba.d_state * 4 + \
                B * (cfg.mamba.d_conv - 1) * di * 2
    return params + cache


def roofline_terms(cfg: ModelConfig, cell: ShapeCell, rec: dict) -> dict:
    chips = rec["chips"]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes_accessed"]
    coll_dev = rec["collectives"]["total_bytes"]
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"t_compute_s": t_comp, "t_memory_s": t_mem,
             "t_collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)
    hlo_total = flops_dev * chips
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful model flops at peak vs. the step's bound time
    t_ideal = mf / (chips * PEAK_FLOPS_BF16)
    if cell.is_decode:
        # decode is memory-bound by construction: the ideal step time is
        # one pass over active params + live cache, not a FLOP bound
        t_ideal = max(t_ideal,
                      decode_ideal_bytes(cfg, cell) / (chips * HBM_BW))
    out = {
        **terms,
        "dominant": {"t_compute_s": "compute", "t_memory_s": "memory",
                     "t_collective_s": "collective"}[dom],
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": mf / hlo_total if hlo_total else 0.0,
        "t_ideal_s": t_ideal,
        "roofline_fraction": t_ideal / bound if bound else 0.0,
    }
    # kernel-adjusted view: memory term without the S^2 score traffic that
    # the Pallas flash-attention kernel keeps in VMEM (see dryrun fit)
    adj = rec["cost"].get("bytes_accessed_kernel_adj")
    if adj is not None:
        t_mem_k = adj / HBM_BW
        bound_k = max(t_comp, t_mem_k, t_coll)
        terms_k = {"t_compute_s": t_comp, "t_memory_s": t_mem_k,
                   "t_collective_s": t_coll}
        dom_k = max(terms_k, key=terms_k.get)
        out["t_memory_kernel_s"] = t_mem_k
        out["dominant_kernel"] = {
            "t_compute_s": "compute", "t_memory_s": "memory",
            "t_collective_s": "collective"}[dom_k]
        out["roofline_fraction_kernel"] = t_ideal / bound_k if bound_k else 0.0
    return out
