"""Recompute rec['roofline'] for every saved dry-run record (no recompile)
after roofline-methodology changes, and emit the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.regen_roofline results/dryrun
"""
import json
import sys
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch import roofline


def regen(d: Path) -> list[dict]:
    recs = []
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok" and "cost" in r:
            cfg = get_config(r["arch"])
            cell = SHAPES[r["cell"]]
            r["roofline"] = roofline.roofline_terms(cfg, cell, r)
            p.write_text(json.dumps(r, indent=1))
        recs.append(r)
    return recs


def table(recs: list[dict], tag: str = "baseline", chips: int = 256) -> str:
    rows = []
    head = ("| arch | cell | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
            "| useful | RF | RF(kernel) |")
    rows.append(head)
    rows.append("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("tag") != tag or r.get("chips") != chips:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['cell']} | — | — | — | skipped |"
                        f" — | — | — |")
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        ro = r["roofline"]
        rfk = ro.get("roofline_fraction_kernel")
        rows.append(
            f"| {r['arch']} | {r['cell']} | {ro['t_compute_s']:.3g} "
            f"| {ro['t_memory_s']:.3g} | {ro['t_collective_s']:.3g} "
            f"| {ro['dominant']} | {ro['useful_flops_ratio']:.2f} "
            f"| {ro['roofline_fraction']:.3f} "
            f"| {'' if rfk is None else f'{rfk:.3f}'} |")
    return "\n".join(rows)


if __name__ == "__main__":
    d = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    recs = regen(d)
    print(table(recs))
