"""Serving launcher: batched prefill + decode loop with the LL EP mode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_moe_a2_7b \
      --reduced --batch 4 --prompt-len 32 --gen 16

Prefill is ONE batched forward pass (``model_zoo.prefill``) that fills the
KV cache for the whole prompt, then decode proceeds token-at-a-time in LL
mode — the prefill/decode split the EP-native serving engine
(``repro.serving``) schedules continuously.  ``--ep-backend``/``--wire-dtype``
mirror ``launch/train.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="none", choices=["none", "local"])
    ap.add_argument("--local-model-axis", type=int, default=4)
    ap.add_argument("--ep-backend", default="",
                    help="EP transport backend (e.g. jax_collectives, "
                         "simulated_rdma); default: the config's choice")
    ap.add_argument("--wire-dtype", default="",
                    choices=["", "fp32", "fp8", "int8"],
                    help="dispatch wire payload dtype (DESIGN §14)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from functools import partial
    from repro.configs import get_config, reduced_config
    from repro.distributed.sharding import make_dist_ctx
    from repro.launch.mesh import make_bench_mesh
    from repro.models import model_zoo as Z

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, n_layers=args.layers, d_model=args.d_model,
                             vocab=args.vocab)
    moe_over = {}
    if args.ep_backend:
        moe_over["ep_backend"] = args.ep_backend
    if args.wire_dtype:
        moe_over["wire_dtype"] = args.wire_dtype
    if moe_over:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    dist = None
    if args.mesh == "local":
        mesh = make_bench_mesh(len(jax.devices()), model=args.local_model_axis)
        dist = make_dist_ctx(cfg, mesh)

    key = jax.random.PRNGKey(args.seed)
    params = Z.init_params(cfg, key)
    B = args.batch
    max_len = args.prompt_len + args.gen
    cache = Z.init_cache(cfg, B, max_len)
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)

    step = jax.jit(partial(Z.decode_step, cfg, dist=dist, moe_mode="ll"),
                   donate_argnums=(1,))
    batched_prefill = (not cfg.mamba.enabled
                       and (dist is None or dist.model_axis is None))
    t0 = time.perf_counter()
    out_tokens = []
    if batched_prefill:
        # ONE forward pass fills cache[:, :prompt_len] and yields the
        # first generated token from the last prompt position's logits
        pre = jax.jit(partial(Z.prefill, cfg, moe_mode="ht"),
                      donate_argnums=(1,))
        logits, cache = pre(params, cache, prompts)
        t_first = time.perf_counter() - t0
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        tok = nxt[:, None].astype(jnp.int32)
        out_tokens.append(tok)
        t_start = args.prompt_len
    else:
        # sharded-cache / mamba fallback: prefill via decode steps
        tok = prompts[:, :1]
        for t in range(args.prompt_len - 1):
            logits, cache = step(params, cache, tok, jnp.int32(t))
            tok = prompts[:, t + 1:t + 2]
        t_first = None
        t_start = args.prompt_len - 1
    for t in range(t_start, max_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        tok = nxt[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.perf_counter() - t0
    total = B * len(out_tokens)
    ttft = f", ttft {t_first * 1e3:.0f}ms" if t_first is not None else ""
    print(f"[serve] generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s{ttft}), first sequence: "
          f"{[int(t[0, 0]) for t in out_tokens[:8]]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
