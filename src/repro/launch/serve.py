"""Serving launcher: batched prefill + decode loop with the LL EP mode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_moe_a2_7b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="none", choices=["none", "local"])
    ap.add_argument("--local-model-axis", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from functools import partial
    from repro.configs import get_config, reduced_config
    from repro.distributed.sharding import make_dist_ctx
    from repro.launch.mesh import make_bench_mesh
    from repro.models import model_zoo as Z

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, n_layers=args.layers, d_model=args.d_model,
                             vocab=args.vocab)
    dist = None
    if args.mesh == "local":
        mesh = make_bench_mesh(len(jax.devices()), model=args.local_model_axis)
        dist = make_dist_ctx(cfg, mesh)

    key = jax.random.PRNGKey(args.seed)
    params = Z.init_params(cfg, key)
    B = args.batch
    max_len = args.prompt_len + args.gen
    cache = Z.init_cache(cfg, B, max_len)
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)

    step = jax.jit(partial(Z.decode_step, cfg, dist=dist, moe_mode="ll"),
                   donate_argnums=(1,))
    # prefill via decode steps (simple serving path; HT prefill is the
    # benchmarked path in benchmarks/fig13_serving.py)
    t0 = time.perf_counter()
    tok = prompts[:, :1]
    out_tokens = []
    for t in range(max_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        if t + 1 < args.prompt_len:
            tok = prompts[:, t + 1:t + 2]
        else:
            nxt = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
            tok = nxt[:, None].astype(jnp.int32)
            out_tokens.append(tok)
    dt = time.perf_counter() - t0
    total = B * len(out_tokens)
    print(f"[serve] generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s), first sequence: "
          f"{[int(t[0, 0]) for t in out_tokens[:8]]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
