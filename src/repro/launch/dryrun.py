import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape x mesh) cell: build ShapeDtypeStruct
stand-ins, ``jax.jit(step).lower(...).compile()`` against the production
mesh, and record memory_analysis / cost_analysis / per-collective bytes for
the roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --cell all \
      --mesh single --out results/dryrun [--moe-mode ht] [--force]
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config
from repro.distributed.sharding import make_dist_ctx
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (cache_specs_sds, input_specs, param_specs_sds,
                                state_specs_sds)
from repro.models import model_zoo as Z
from repro.training.train_loop import HParams, train_step


def build_step(cfg, cell, dist, moe_mode_train="ht", moe_chunks=1,
               causal_skip=False, unroll=False, sp_islands=False,
               remat_policy="full"):
    """Returns (fn, example_args (SDS), donate) for this cell kind."""
    if cell.kind == "train":
        hp = HParams(moe_mode=moe_mode_train, moe_chunks=moe_chunks,
                     causal_skip=causal_skip, unroll=unroll,
                     sp_islands=sp_islands, remat_policy=remat_policy)
        state = state_specs_sds(cfg, dist)
        batch = input_specs(cfg, cell, dist)
        fn = partial(train_step, cfg, hp, dist)
        return fn, (state, batch), (0,)
    if cell.kind == "prefill":
        batch = input_specs(cfg, cell, dist)
        params = param_specs_sds(cfg, dist)

        def prefill(params, batch):
            cp = Z.cast_params(params, jnp.bfloat16)
            h, _ = Z.forward(cfg, cp, batch["tokens"], batch.get("prefix"),
                             dist=dist, moe_mode=moe_mode_train,
                             moe_chunks=moe_chunks, causal_skip=causal_skip,
                             unroll=unroll, sp_islands=sp_islands,
                             remat_policy=remat_policy)
            head = Z.lm_head_weight(cfg, cp)
            return (h[:, -1] @ head).astype(jnp.float32)

        return prefill, (params, batch), ()
    # decode
    params = param_specs_sds(cfg, dist)
    cache = cache_specs_sds(cfg, cell, dist)
    batch = input_specs(cfg, cell, dist)

    def serve(params, cache, tokens, pos):
        return Z.decode_step(cfg, params, cache, tokens, pos, dist=dist,
                             moe_mode="ll", unroll=unroll)

    return serve, (params, cache, batch["tokens"], batch["pos"]), (1,)


def _compile_cell(cfg, cell, dist, *, moe_mode, moe_chunks, causal_skip,
                  unroll, sp_islands=False, remat_policy="full"):
    fn, args, donate = build_step(cfg, cell, dist, moe_mode_train=moe_mode,
                                  moe_chunks=moe_chunks,
                                  causal_skip=causal_skip, unroll=unroll,
                                  sp_islands=sp_islands,
                                  remat_policy=remat_policy)
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    return lowered.compile()


def _cost_record(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    coll = roofline.collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "collectives": coll}


def _extrapolated_costs(cfg, cell, dist, n_periods, **kw) -> dict:
    """XLA's cost_analysis counts a while (scan) body ONCE, so compile the
    model truncated to 1 and 2 periods with the layer loop unrolled and
    extrapolate linearly: cost(N) = c1 + (N-1) * (c2 - c1)."""
    import dataclasses
    from repro.distributed.sharding import scan_period
    period, _ = scan_period(cfg)
    cfg1 = dataclasses.replace(cfg, n_layers=period)
    cfg2 = dataclasses.replace(cfg, n_layers=2 * period)
    c1 = _cost_record(_compile_cell(cfg1, cell, dist, unroll=True, **kw))
    c2 = _cost_record(_compile_cell(cfg2, cell, dist, unroll=True, **kw))

    def lerp(a, b):
        return a + (n_periods - 1) * (b - a)

    kinds = set(c1["collectives"]["bytes_by_kind"]) | \
        set(c2["collectives"]["bytes_by_kind"])
    bbk = {k: lerp(c1["collectives"]["bytes_by_kind"].get(k, 0),
                   c2["collectives"]["bytes_by_kind"].get(k, 0))
           for k in kinds}
    cbk = {k: int(lerp(c1["collectives"]["count_by_kind"].get(k, 0),
                       c2["collectives"]["count_by_kind"].get(k, 0)))
           for k in kinds}
    out = {
        "flops": lerp(c1["flops"], c2["flops"]),
        "bytes_accessed": lerp(c1["bytes_accessed"], c2["bytes_accessed"]),
        "collectives": {"bytes_by_kind": bbk, "count_by_kind": cbk,
                        "total_bytes": sum(bbk.values())},
        "one_period": c1, "two_period": c2,
    }
    # kernel-adjusted memory: the jnp reference attention materialises the
    # S^2 score matrices to HBM; the shipped Pallas flash kernel keeps them
    # in VMEM.  Fit bytes(S) = a*S + b*S^2 on the one-period model at S and
    # S/2; b*S^2*N is the score traffic the kernel eliminates.
    if cell.kind in ("train", "prefill") and not cfg.attention_free \
            and cell.seq_len % (2 * 16) == 0:
        import dataclasses as _dc
        half = _dc.replace(cell, seq_len=cell.seq_len // 2)
        c1h = _cost_record(_compile_cell(cfg1, half, dist, unroll=True, **kw))
        S = cell.seq_len
        beta = max(0.0, (c1["bytes_accessed"] - 2 * c1h["bytes_accessed"])
                   / (S * S / 2))
        quad = beta * S * S * n_periods
        out["bytes_quadratic_per_dev"] = quad
        out["bytes_accessed_kernel_adj"] = max(
            out["bytes_accessed"] - quad, out["bytes_accessed"] * 0.05)
        # same fit for flops: the masked-block waste the kernel/causal-skip
        # path avoids is ~half the quadratic term (report, don't subtract)
        beta_f = max(0.0, (c1["flops"] - 2 * c1h["flops"]) / (S * S / 2))
        out["flops_quadratic_per_dev"] = beta_f * S * S * n_periods
    # mamba-kernel adjustment: the jnp selective-scan materialises
    # (B, S, d_inner, N) decay tensors to HBM; the Pallas kernel keeps the
    # state in VMEM.  Fit bytes(d_state): the N-linear slope IS that traffic.
    if cell.kind in ("train", "prefill") and cfg.mamba.enabled \
            and cfg.mamba.d_state >= 16:
        import dataclasses as _dc
        cfg1n = _dc.replace(cfg1, mamba=_dc.replace(
            cfg1.mamba, d_state=cfg.mamba.d_state // 2))
        c1n = _cost_record(_compile_cell(cfg1n, cell, dist, unroll=True, **kw))
        nst = cfg.mamba.d_state
        slope = max(0.0, (c1["bytes_accessed"] - c1n["bytes_accessed"])
                    / (nst - nst // 2))
        scan_traffic = slope * nst * n_periods
        out["bytes_mamba_scan_per_dev"] = scan_traffic
        prev = out.get("bytes_accessed_kernel_adj", out["bytes_accessed"])
        out["bytes_accessed_kernel_adj"] = max(
            prev - scan_traffic, out["bytes_accessed"] * 0.05)
    return out


def run_cell(arch: str, cell_name: str, mesh, out_dir: Path, *,
             force=False, tag="baseline", moe_mode="ht", moe_chunks=1,
             causal_skip=False, extrapolate=True, sp_islands=False,
             cap_factor=0.0, remat_policy="full") -> dict:
    n_chips = mesh.devices.size
    out_path = out_dir / f"{arch}__{cell_name}__{n_chips}__{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    cfg = get_config(arch)
    if cap_factor:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                               capacity_factor=cap_factor))
    cell = SHAPES[cell_name]
    rec = {"arch": arch, "cell": cell_name, "chips": int(n_chips),
           "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
           "tag": tag, "status": "running"}
    if cell_name not in cells_for(cfg):
        rec["status"] = "skipped"
        rec["reason"] = "long_500k requires sub-quadratic attention (DESIGN.md §5)"
        out_path.write_text(json.dumps(rec, indent=1))
        return rec
    try:
        from repro.distributed.sharding import scan_period
        dist = make_dist_ctx(cfg, mesh)
        kw = dict(moe_mode=moe_mode, moe_chunks=moe_chunks,
                  causal_skip=causal_skip, sp_islands=sp_islands,
                  remat_policy=remat_policy)
        # (1) full-model compile: THE deliverable — proves lowering/sharding
        # and gives real per-device memory for the production mesh
        fn, args, donate = build_step(cfg, cell, dist, moe_mode_train=moe_mode,
                                      moe_chunks=moe_chunks,
                                      causal_skip=causal_skip,
                                      sp_islands=sp_islands,
                                      remat_policy=remat_policy)
        t0 = time.time()
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_scan_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        del compiled, lowered
        if extrapolate:
            # (2)+(3) truncated unrolled compiles -> extrapolated true costs
            # (XLA counts a while body once; see _extrapolated_costs)
            t0 = time.time()
            _, n_periods = scan_period(cfg)
            ex = _extrapolated_costs(cfg, cell, dist, n_periods, **kw)
            rec["extrapolate_s"] = round(time.time() - t0, 1)
            rec["cost"] = {"flops": ex["flops"],
                           "bytes_accessed": ex["bytes_accessed"]}
            for k in ("bytes_accessed_kernel_adj", "bytes_quadratic_per_dev",
                      "flops_quadratic_per_dev", "bytes_mamba_scan_per_dev"):
                if k in ex:
                    rec["cost"][k] = ex[k]
            rec["collectives"] = ex["collectives"]
            rec["roofline"] = roofline.roofline_terms(cfg, cell, rec)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--moe-mode", default="ht")
    ap.add_argument("--moe-chunks", type=int, default=1)
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--sp-islands", action="store_true")
    ap.add_argument("--cap-factor", type=float, default=0.0)
    ap.add_argument("--remat-policy", default="full")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="compile-proof + memory only (multi-pod pass)")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    cells = list(SHAPES) if args.cell == "all" else args.cell.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_err = n_skip = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for cell in cells:
                rec = run_cell(arch, cell, mesh, out_dir, force=args.force,
                               tag=args.tag, moe_mode=args.moe_mode,
                               moe_chunks=args.moe_chunks,
                               causal_skip=args.causal_skip,
                               extrapolate=not args.no_extrapolate,
                               sp_islands=args.sp_islands,
                               cap_factor=args.cap_factor,
                               remat_policy=args.remat_policy)
                st = rec["status"]
                n_ok += st == "ok"
                n_err += st == "error"
                n_skip += st == "skipped"
                msg = (f"[dryrun] {arch} x {cell} x {rec['chips']}chips "
                       f"[{rec['tag']}]: {st}")
                if st == "ok":
                    msg += (f" compile={rec.get('compile_s')}s "
                            f"bytes/dev={rec['memory']['argument_bytes']/1e9:.2f}GB")
                    r = rec.get("roofline")
                    if r:
                        msg += (f" dom={r['dominant']} "
                                f"t_comp={r['t_compute_s']:.2e}s "
                                f"t_mem={r['t_memory_s']:.2e}s "
                                f"t_coll={r['t_collective_s']:.2e}s")
                elif st == "error":
                    msg += " " + rec["error"][:200]
                print(msg, flush=True)
    print(f"[dryrun] done: {n_ok} ok, {n_err} error, {n_skip} skipped")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
