"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch moonshot_v1_16b_a3b \
      --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` runs the family-faithful small config on local devices (the
CPU path used by examples/CI); the full config targets the production mesh.
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

# XLA latency-hiding / pipelined-collective preset (--xla-pipelining): the
# collective-side analogue of the substrate's cross-layer comm/compute
# overlap — async streams + pipelined all-gather/reduce-scatter/all-reduce
# let XLA overlap EP collectives with non-MoE compute (MaxText's production
# flag set).  Must land in XLA_FLAGS before jax is imported.
XLA_PIPELINING_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_triton_gemm=false",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_all_reduce_combine_threshold_bytes=134217728",
    "--xla_gpu_all_gather_combine_threshold_bytes=134217728",
    "--xla_gpu_reduce_scatter_combine_threshold_bytes=67108864",
    "--xla_gpu_enable_pipelined_all_gather=true",
    "--xla_gpu_enable_pipelined_reduce_scatter=true",
    "--xla_gpu_enable_pipelined_all_reduce=true",
    "--xla_gpu_enable_while_loop_double_buffering=true",
    "--xla_gpu_enable_all_gather_combine_by_dim=false",
    "--xla_gpu_enable_reduce_scatter_combine_by_dim=false",
)


def apply_xla_pipelining_flags(env=os.environ) -> str:
    """Append the pipelining preset to XLA_FLAGS (idempotent); returns the
    resulting value.  Call before the first ``import jax``."""
    cur = env.get("XLA_FLAGS", "")
    add = [f for f in XLA_PIPELINING_FLAGS if f not in cur]
    val = " ".join(filter(None, [cur, *add]))
    env["XLA_FLAGS"] = val
    return val


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--moe-mode", default="ht", choices=["ht", "ll", "ref"])
    ap.add_argument("--moe-chunks", type=int, default=1)
    ap.add_argument("--ep-backend", default="",
                    help="EP transport backend (e.g. jax_collectives, "
                         "simulated_rdma); default: the config's choice")
    ap.add_argument("--wire-dtype", default="",
                    choices=["", "fp32", "fp8", "int8"],
                    help="dispatch wire payload dtype (DESIGN §14)")
    ap.add_argument("--xla-pipelining", action="store_true",
                    help="enable the XLA latency-hiding/pipelined-collective "
                         "flag preset (set before jax imports)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "local", "single", "multi"])
    ap.add_argument("--local-model-axis", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", default="",
                    help="comma-separated steps to inject failures (demo)")
    ap.add_argument("--history-out", default="")
    args = ap.parse_args(argv)

    if args.xla_pipelining:
        apply_xla_pipelining_flags()

    import dataclasses

    import jax
    from repro.checkpoint import Checkpointer
    from repro.configs import SHAPES, get_config, reduced_config
    from repro.data.pipeline import DataConfig, data_iterator
    from repro.distributed.fault import FailureInjector
    from repro.distributed.sharding import make_dist_ctx
    from repro.launch.mesh import make_bench_mesh, make_production_mesh
    from repro.training.train_loop import HParams, Watchdog, train_loop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, n_layers=args.layers, d_model=args.d_model,
                             vocab=args.vocab)
    moe_over = {}
    if args.ep_backend:
        moe_over["ep_backend"] = args.ep_backend
    if args.wire_dtype:
        moe_over["wire_dtype"] = args.wire_dtype
    if moe_over:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    dist = None
    if args.mesh == "local":
        mesh = make_bench_mesh(len(jax.devices()), model=args.local_model_axis)
        dist = make_dist_ctx(cfg, mesh)
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        dist = make_dist_ctx(cfg, mesh)

    hp = HParams(peak_lr=args.lr, total_steps=args.steps,
                 warmup=max(1, args.steps // 10), moe_mode=args.moe_mode,
                 moe_chunks=args.moe_chunks, seed=args.seed)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                    seq_len=args.seq, seed=args.seed,
                    prefix_len=cfg.frontend_prefix, d_model=cfg.d_model)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    injector = None
    if args.fail_at:
        injector = FailureInjector(tuple(int(s) for s in
                                         args.fail_at.split(",")))
    state, history = train_loop(
        cfg, hp, dist, data_iterator(dc), steps=args.steps,
        checkpointer=ckpt, ckpt_every=args.ckpt_every,
        log_every=args.log_every, watchdog=Watchdog(),
        fail_injector=injector)
    if args.history_out:
        Path(args.history_out).write_text(json.dumps(history))
    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(f"[train] finished: loss {first:.4f} -> {last:.4f} "
          f"over {len(history)} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
