"""Explicit sequence-parallel collectives (beyond-paper optimisation).

GSPMD resolves the SP layout transitions around attention/MLP blocks
(seq-sharded residual -> gathered compute -> seq-sharded residual) with
all-reduce + dynamic-slice pairs in the backward pass — ~P x more bytes than
needed.  These custom-vjp shard_map islands pin the minimal schedule:

    sp_gather :  fwd all-gather(seq)      bwd reduce-scatter(seq)
    sp_scatter:  fwd reduce-scatter(seq)  bwd all-gather(seq)

(Megatron-LM sequence parallelism, done manually because the automatic
partitioner picks the slow transpose; see EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

from functools import partial

import repro.compat  # noqa: F401  jax version shims (jax.shard_map)
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import DistCtx


def _mk(dist: DistCtx, bd, m):
    mesh = dist.mesh

    def gather_island(x):
        return lax.all_gather(x, m, axis=1, tiled=True)

    def scatter_island(x):
        return lax.psum_scatter(x, m, scatter_dimension=1, tiled=True)

    g = jax.shard_map(gather_island, mesh=mesh,
                      in_specs=P(bd, m, None), out_specs=P(bd, None, None),
                      check_vma=False)
    s = jax.shard_map(scatter_island, mesh=mesh,
                      in_specs=P(bd, None, None), out_specs=P(bd, m, None),
                      check_vma=False)
    return g, s


def sp_gather(dist: DistCtx, x: jax.Array) -> jax.Array:
    """(B, S/m sharded, D) -> (B, S, D) replicated over model."""
    if dist is None or dist.model_axis is None:
        return x
    bd, m = dist.batch_axes, dist.model_axis
    if x.shape[1] % dist.mesh.shape[m] or x.shape[0] % _bdsz(dist):
        return dist.constraint(x, bd, None, None)
    g, s = _mk(dist, bd, m)

    @jax.custom_vjp
    def f(x):
        return g(x)

    def fwd(x):
        return g(x), None

    def bwd(_, ct):
        # cotangent of all-gather is the SUM-scatter of per-shard grads;
        # replicated-compute cotangents are identical, so scatter-slice of
        # psum == psum_scatter of one copy
        return (s(ct),)

    f.defvjp(fwd, bwd)
    return f(x)


def sp_scatter(dist: DistCtx, x: jax.Array) -> jax.Array:
    """(B, S, D) partial-sums over model -> (B, S/m sharded, D) reduced."""
    if dist is None or dist.model_axis is None:
        return x
    bd, m = dist.batch_axes, dist.model_axis
    if x.shape[1] % dist.mesh.shape[m] or x.shape[0] % _bdsz(dist):
        return dist.constraint(x, bd, m, None)
    g, s = _mk(dist, bd, m)

    @jax.custom_vjp
    def f(x):
        return s(x)

    def fwd(x):
        return s(x), None

    def bwd(_, ct):
        return (g(ct),)

    f.defvjp(fwd, bwd)
    return f(x)


def _bdsz(dist: DistCtx) -> int:
    import math
    return math.prod(dist.mesh.shape[a] for a in dist.batch_axes)
