"""Gradient compression: int8 block-quantised ring reduce-scatter with error
feedback (beyond-paper distributed-optimization trick; DESIGN.md §7).

On a ring of P shards (the "data" axis), each hop sends an int8-quantised
partial sum instead of fp32 — 4x fewer bytes over the wire.  Error feedback
accumulates the per-shard quantisation residual into the next step's
gradient, which keeps the compressed SGD unbiased over time.

The quantizer itself lives in ``repro.core.transport.codec`` (the repo's
single block-quantization implementation, shared with the wire-dispatch
codec; DESIGN.md §14) — this module only supplies the ring/EF orchestration
on top of it, at the gradient-friendly block width ``BLOCK``.
"""
from __future__ import annotations

from typing import NamedTuple

import repro.compat  # noqa: F401  jax version shims (jax.shard_map)
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.transport.codec import dequantize_blocked, quantize_blocked

Array = jax.Array

BLOCK = 256


class QChunk(NamedTuple):
    q: Array        # int8 payload, (nb, BLOCK)
    scale: Array    # fp32 per-block scales, (nb,)


def quantize(x: Array) -> QChunk:
    """Symmetric per-block int8 quantisation of a flat fp32 vector."""
    n = x.shape[0]
    nb = -(-n // BLOCK)
    xp = jnp.pad(x, (0, nb * BLOCK - n)).reshape(nb, BLOCK)
    q, scale = quantize_blocked(xp, "int8", block=BLOCK)
    return QChunk(q=q, scale=scale[:, 0])


def dequantize(c: QChunk, n: int) -> Array:
    return dequantize_blocked(c.q, c.scale[:, None],
                              block=BLOCK).reshape(-1)[:n]


def compressed_psum_scatter(x: Array, axis: str) -> Array:
    """Ring reduce-scatter of a flat fp32 vector with int8 hops.

    Runs inside shard_map.  x: (n,) identical-shape on each shard; returns
    this shard's (n/P,) reduced chunk.  Each of the P-1 hops dequantises,
    adds its local chunk, and requantises (fp32 accumulation, int8 wire).
    """
    P = lax.axis_size(axis)
    n = x.shape[0]
    assert n % P == 0, (n, P)
    chunk = n // P
    idx = lax.axis_index(axis)
    xc = x.reshape(P, chunk)
    perm = [(i, (i + 1) % P) for i in range(P)]

    # node idx starts accumulating chunk (idx-1); chunks move rightward one
    # hop per step so that after P-1 hops node i holds chunk i fully reduced
    # (required for the tiled all-gather to reassemble in order).
    acc_i = (idx - 1) % P
    q = quantize(lax.dynamic_index_in_dim(xc, acc_i, 0, keepdims=False))
    for step in range(P - 1):
        q = QChunk(q=lax.ppermute(q.q, axis, perm),
                   scale=lax.ppermute(q.scale, axis, perm))
        acc_i = (acc_i - 1) % P          # chunk id now held locally
        local = lax.dynamic_index_in_dim(xc, acc_i, 0, keepdims=False)
        acc = dequantize(q, chunk) + local
        q = quantize(acc)
    return dequantize(q, chunk)


def ef_compressed_mean(per_shard: Array, mesh, axis: str,
                       residual: Array | None = None) -> tuple[Array, Array]:
    """Error-feedback compressed all-reduce mean (EF14 + int8 ring hops).

    ``per_shard``: (P, n) — row i is shard i's local gradient vector,
    sharded ``P(axis)`` on dim 0 (the manual-DP layout used by examples and
    benchmarks).  ``residual``: (P, n) per-shard EF memory from the previous
    step (same layout), or None.

    Each shard adds its residual, quantises its contribution to int8 (the
    wire format), keeps the quantisation error as the new residual, and the
    ring reduce-scatter (int8 hops, fp32 accumulation) + all-gather produces
    the mean on every shard.  Returns (mean (n,), new_residual (P, n)).
    """
    from jax.sharding import PartitionSpec as P
    Pax = mesh.shape[axis]
    n = per_shard.shape[1]
    assert n % (Pax * BLOCK) == 0, f"pad input to a multiple of {Pax * BLOCK}"
    if residual is None:
        residual = jnp.zeros_like(per_shard)

    def island(g, e):
        g, e = g[0], e[0]                       # local row
        contrib = g + e
        q = quantize(contrib)
        deq = dequantize(q, n)
        new_e = contrib - deq                   # EF memory
        mine = compressed_psum_scatter(deq, axis)       # (n/P,) summed
        full = lax.all_gather(mine, axis, axis=0, tiled=True)
        return (full / Pax)[None], new_e[None]

    other = tuple(a for a in mesh.axis_names if a != axis)
    mean, new_res = jax.shard_map(
        island, mesh=mesh, in_specs=(P(axis, None), P(axis, None)),
        out_specs=(P(axis, None), P(axis, None)),
        check_vma=False)(per_shard, residual)
    # every row of `mean` is identical; return row 0 plus the residuals
    return mean[0], new_res


def pad_to_ring(x: Array, P: int) -> Array:
    pad = (-x.size) % (P * BLOCK)
    return jnp.pad(x.reshape(-1), (0, pad))
