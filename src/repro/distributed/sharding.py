"""Mesh-aware distribution context and partition rules.

One ``DistCtx`` describes how a model maps onto the production mesh:
- batch over ("pod","data") (multi-pod) or ("data",)
- sequence (residual stream) over "model"  (sequence parallelism)
- attention heads / FFN inner / vocab over "model"  (tensor parallelism)
- experts over ("pod","model") when divisible, else ("model",)  (EP)
- master params / optimizer moments additionally over "data"  (ZeRO/FSDP)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DistCtx:
    mesh: Mesh
    batch_axes: tuple[str, ...]
    seq_axis: Optional[str]
    model_axis: Optional[str]
    ep_axes: tuple[str, ...]
    fsdp_axes: tuple[str, ...]      # axes opt-state / master params shard over

    @property
    def ep_degree(self) -> int:
        return int(jnp.prod(jnp.array(
            [self.mesh.shape[a] for a in self.ep_axes]))) if self.ep_axes else 1

    def axis_size(self, name: Optional[str]) -> int:
        return self.mesh.shape[name] if name else 1

    def sh(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def constraint(self, x, *spec):
        return jax.lax.with_sharding_constraint(x, self.sh(*spec))


def make_dist_ctx(cfg: ModelConfig, mesh: Mesh) -> DistCtx:
    axes = list(mesh.axis_names)
    multi_pod = "pod" in axes
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    model_axis = "model" if "model" in axes else None
    # EP spans (pod, model) when the padded expert count divides that degree,
    # else model only (pod becomes pure DP for experts) — DESIGN.md §4.
    ep_axes: tuple[str, ...] = ()
    if cfg.moe.enabled and model_axis:
        pm = mesh.shape[model_axis]
        if multi_pod and cfg.padded_experts(mesh.shape["pod"] * pm) % (
                mesh.shape["pod"] * pm) == 0 and cfg.moe.n_experts >= mesh.shape["pod"] * pm:
            ep_axes = ("pod", model_axis)
        else:
            ep_axes = (model_axis,)
    fsdp = ("data",) if "data" in axes else ()
    return DistCtx(mesh=mesh, batch_axes=batch_axes, seq_axis=model_axis,
                   model_axis=model_axis, ep_axes=ep_axes, fsdp_axes=fsdp)


# -------------------------------------------------------- partition rules --
def _leaf_rule(cfg: ModelConfig, dist: DistCtx, path: tuple, leaf) -> P:
    """PartitionSpec for one param leaf, keyed on its tree path.

    Weights are sharded over "model" (TP / EP) and over the fsdp axis on a
    free dim (ZeRO: master params, moments, and the bf16 compute copy all
    live sharded; per-layer all-gathers happen inside the scan)."""
    m = dist.model_axis
    f = dist.fsdp_axes[0] if dist.fsdp_axes else None
    ep = tuple(dist.ep_axes) if dist.ep_axes else ((m,) if m else ())
    ep_s = ep if len(ep) > 1 else (ep[0] if ep else None)
    keys = [getattr(k, "key", None) or getattr(k, "name", None) or str(k)
            for k in path]
    name = keys[-1]
    in_blocks = keys and keys[0] == "blocks"
    in_moe = "moe" in keys and "shared" not in keys

    def spec(*dims):  # left-pad with None for the stacked period dim
        pad = (None,) * (leaf.ndim - len(dims))
        dims = pad + dims
        # drop axes that don't divide the dim (e.g. 8 kv heads on model=16);
        # input shardings must be exactly divisible.
        out = []
        for size, ax in zip(leaf.shape, dims):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            import math as _m
            deg = _m.prod(dist.mesh.shape[a] for a in axes)
            out.append(ax if size % deg == 0 else None)
        return P(*out)

    if name == "embed":
        return P(m, f)
    if name == "lm_head":
        return P(f, m)
    if name in ("final_ln", "ln1", "ln2", "q_norm", "k_norm", "router_w",
                "router_b"):
        return P(*((None,) * leaf.ndim))
    if in_moe and name in ("w_gate", "w_up"):
        return spec(ep_s, f, None)          # (E, D, F)
    if in_moe and name == "w_down":
        return spec(ep_s, f, None)          # (E, F, D)
    if name in ("w_gate", "w_up"):
        return spec(f, m)                   # (D, F)
    if name == "w_down":
        return spec(m, f)                   # (F, D)
    msize = dist.mesh.shape[m] if m else 1
    if name in ("wq", "wk", "wv"):
        # shard heads over model when divisible (e.g. 8 kv heads on a
        # 16-way model axis); otherwise replicate (GQA kv projections are
        # small, and head-dim sharding triggers involuntary SPMD remat)
        if leaf.shape[-2] % msize == 0:
            return spec(f, m, None)         # (D, H, hd)
        return spec(f, None, None)
    if name == "wo":
        if leaf.shape[-3] % msize == 0:
            return spec(m, None, f)         # (H, hd, D)
        return spec(None, None, f)
    if name in ("bq", "bk", "bv"):
        if leaf.shape[-2] % msize == 0:
            return spec(m, None)            # (H, hd)
        return spec(None, None)
    if name in ("in_proj", "z_proj"):
        return spec(f, m)                   # (D, Di)
    if name == "conv_w":
        return spec(None, m)                # (dc, Di)
    if name in ("conv_b", "dt_b", "D"):
        return spec(m)                      # (Di,)
    if name in ("x_proj", "A_log"):
        return spec(m, None)                # (Di, *)
    if name == "dt_w":
        return spec(None, m)                # (R, Di)
    if name == "out_proj":
        return spec(m, f)                   # (Di, D)
    if name in ("row", "col"):              # factored optimizer moments
        return P(*((None,) * leaf.ndim))
    return P(*((None,) * leaf.ndim))


def param_pspecs(cfg: ModelConfig, dist: DistCtx, params) -> dict:
    """PartitionSpec pytree mirroring ``params`` (works for optimizer moment
    trees too, since they mirror the param structure)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_rule(cfg, dist, p, l), params)


def param_shardings(cfg: ModelConfig, dist: DistCtx, params):
    return jax.tree.map(lambda s: NamedSharding(dist.mesh, s),
                        param_pspecs(cfg, dist, params))


def cache_pspecs(cfg: ModelConfig, dist: DistCtx, cache, batch: int) -> dict:
    """KV/Mamba cache specs: batch over effective batch axes, cache sequence
    over (idle batch axes + model) — see cache_seq_axes."""
    bd = effective_batch_axes(dist, batch)
    sq = cache_seq_axes(dist, batch)
    sq_s = sq if len(sq) > 1 else (sq[0] if sq else None)
    m = dist.model_axis

    def f(path, leaf):
        last = path[-1]
        name = getattr(last, "name", None) or getattr(last, "key", None) or str(last)
        if name in ("k", "v"):
            if leaf.ndim == 5 and leaf.shape[2] > 1:   # (P_, B, S, Hkv, hd)
                return P(None, bd, sq_s, None, None)
            return P(*((None,) * leaf.ndim))
        if name == "conv":                              # (P_, B, dc-1, Di)
            if leaf.shape[-1] > 1:
                return P(None, bd, None, m)
            return P(*((None,) * leaf.ndim))
        if name == "ssm":                               # (P_, B, Di, N)
            if leaf.shape[-2] > 1:
                return P(None, bd, m, None)
            return P(*((None,) * leaf.ndim))
        return P(*((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(f, cache)


def scan_period(cfg: ModelConfig) -> tuple[int, int]:
    """(period, n_periods): layers repeat with this period; params for each
    slot in the period are stacked over n_periods and scanned."""
    import math
    period = 1
    if cfg.attn_every > 1:
        period = math.lcm(period, cfg.attn_every)
    if cfg.moe.enabled and cfg.moe.moe_every > 1:
        period = math.lcm(period, cfg.moe.moe_every)
    assert cfg.n_layers % period == 0, (cfg.arch_id, cfg.n_layers, period)
    return period, cfg.n_layers // period


def effective_batch_axes(dist: DistCtx, batch: int) -> tuple[str, ...]:
    """Batch axes usable for this global batch (all-or-nothing: decode
    batches smaller than the DP degree replicate instead)."""
    import math as _m
    prod = _m.prod(dist.mesh.shape[a] for a in dist.batch_axes)
    return dist.batch_axes if batch % prod == 0 else ()


def cache_seq_axes(dist: DistCtx, batch: int) -> tuple[str, ...]:
    """Axes the KV-cache sequence dim shards over: the model axis plus any
    batch axes left idle by a tiny decode batch (long_500k: all three)."""
    eff = effective_batch_axes(dist, batch)
    idle = tuple(a for a in dist.batch_axes if a not in eff)
    m = (dist.model_axis,) if dist.model_axis else ()
    return idle + m


def batch_spec(dist: DistCtx) -> P:
    """(B, S) token batches."""
    return P(dist.batch_axes, dist.seq_axis)


def act_spec(dist: DistCtx) -> P:
    """(B, S, D) residual stream."""
    return P(dist.batch_axes, dist.seq_axis, None)
