from repro.distributed.sharding import DistCtx, make_dist_ctx

__all__ = ["DistCtx", "make_dist_ctx"]
