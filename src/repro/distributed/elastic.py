"""Elastic EP (paper §6 "Elastic EP with CPU proxy", made concrete for TPU):
re-shard a TrainState onto a different mesh after node loss / addition.

On TPU, elasticity is a *restart* operation: the single-program SPMD world
cannot shrink in place, so the recovery path is (1) checkpoint (or use the
latest), (2) rebuild the mesh at the new size, (3) re-derive the DistCtx —
EP capacity, expert placement and FSDP layouts all fall out of the sharding
rules — and (4) restore the state under the new shardings.  Because our
checkpoints are logical (full arrays, path-keyed), restore-to-any-mesh is
free; this module packages the policy and validates divisibility.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DistCtx, make_dist_ctx, param_shardings


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple
    new_shape: tuple
    new_axis_names: tuple
    ep_degree_old: int
    ep_degree_new: int
    notes: list


def plan_remesh(cfg: ModelConfig, old: DistCtx, new_mesh: Mesh) -> ElasticPlan:
    """Validate a re-mesh and describe what changes."""
    new = make_dist_ctx(cfg, new_mesh)
    notes = []
    if cfg.moe.enabled:
        from repro.core.moe import padded_experts_static
        e = padded_experts_static(cfg)
        if e % max(new.ep_degree, 1):
            raise ValueError(
                f"padded experts {e} not divisible by new EP degree "
                f"{new.ep_degree}; choose a mesh whose EP axes divide {e}")
        notes.append(f"experts/shard: {e // max(old.ep_degree, 1)} -> "
                     f"{e // max(new.ep_degree, 1)}")
    for name in new_mesh.axis_names:
        if name == "model" and cfg.d_model % new_mesh.shape[name]:
            raise ValueError("d_model must divide the model axis")
    return ElasticPlan(
        old_shape=tuple(old.mesh.devices.shape),
        new_shape=tuple(new_mesh.devices.shape),
        new_axis_names=tuple(new_mesh.axis_names),
        ep_degree_old=old.ep_degree, ep_degree_new=new.ep_degree,
        notes=notes)


def reshard_state(cfg: ModelConfig, state, new_mesh: Mesh):
    """Device_put the (logical) state under the new mesh's shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    new_dist = make_dist_ctx(cfg, new_mesh)

    def move(subtree):
        sh = param_shardings(cfg, new_dist, subtree)
        return jax.tree.map(lambda x, s: jax.device_put(x, s), subtree, sh)

    params = move(state.params)
    # every leaf must land on the new mesh, including replicated scalars
    step = jax.device_put(state.opt.step, NamedSharding(new_mesh, P()))
    opt = state.opt._replace(step=step, mu=move(state.opt.mu),
                             nu=move(state.opt.nu))
    return state._replace(params=params, opt=opt), new_dist


# ================================================ expert-level elasticity ==
# Online expert re-placement (UltraEP arxiv 2606.04101 / UBEP 2607.06202,
# DESIGN.md §15): the *expert-level* elasticity path, distinct from the
# mesh-restart machinery above.  A LoadBalancer tracks per-logical-expert
# token counts (the ``aux["load"]`` stat every backend reports) over a
# sliding window and periodically recomputes a replicated placement by
# greedy bin-packing; rank-degradation recovery reuses the exact same
# placement-mutation code path (``degrade`` -> ``plan.greedy_placement`` ->
# ``migrate_expert_weights``), so a hot expert and a dead rank are the same
# event from the transport's point of view: a placement delta whose weight
# rows move through the substrate as coalesced, fenced bulk writes.
from repro.core import plan as planlib  # noqa: E402


@dataclasses.dataclass
class LoadBalancer:
    """Sliding-window load tracker + greedy re-placement policy.

    ``observe()`` per EP round with the logical load vector; every
    ``interval`` observations ``maybe_replace()`` recomputes the placement
    when the window's physical-slot imbalance (max/mean, the shared
    ``aux["imbalance"]`` stat) exceeds ``threshold``.  ``degrade()`` is the
    rank-failure entry point onto the same code path.
    """

    n_logical: int
    n_ranks: int
    slots_per_rank: int
    window: int = 8            # sliding load window, in observations
    interval: int = 4          # re-placement cadence, in observations
    threshold: float = 1.25    # re-place only above this imbalance
    placement: Optional[planlib.Placement] = None
    _hist: list = dataclasses.field(default_factory=list)
    _steps: int = 0

    def __post_init__(self):
        assert self.n_physical >= self.n_logical
        if self.placement is None:
            self.placement = planlib.greedy_placement(
                np.ones(self.n_logical), self.n_physical, self.n_ranks)

    @property
    def n_physical(self) -> int:
        return self.slots_per_rank * self.n_ranks

    def observe(self, load) -> None:
        self._hist.append(np.asarray(load, np.float64).reshape(-1))
        if len(self._hist) > self.window:
            self._hist.pop(0)
        self._steps += 1

    def window_load(self) -> np.ndarray:
        if not self._hist:
            return np.ones(self.n_logical, np.float64)
        return np.sum(self._hist, axis=0)

    def imbalance(self) -> float:
        """Window imbalance under the CURRENT placement: each replica slot
        carries its expert's per-replica load share."""
        p = self.placement
        share = (self.window_load()[p.phys_to_logical]
                 / p.n_replicas[p.phys_to_logical])
        return planlib.load_imbalance(share)

    def maybe_replace(self) -> Optional[planlib.Placement]:
        """Returns the new placement when one is due and different, else
        None (caller then migrates weights and re-splits routing)."""
        if self._steps % self.interval or self.imbalance() <= self.threshold:
            return None
        new = planlib.greedy_placement(self.window_load(), self.n_physical,
                                       self.n_ranks)
        if new.key() == self.placement.key():
            return None
        self.placement = new
        return new

    def degrade(self, dead_rank: int) -> planlib.Placement:
        """Rank loss: re-place every expert onto the survivors via the same
        greedy bin-packing as hot-expert re-placement.  The caller renumbers
        ranks (survivors keep relative order) and migrates weights; the slot
        budget grows to the next multiple that still fits every expert."""
        assert 0 <= dead_rank < self.n_ranks and self.n_ranks > 1
        self.n_ranks -= 1
        while self.n_physical < self.n_logical:
            self.slots_per_rank += 1
        self.placement = planlib.greedy_placement(
            self.window_load(), self.n_physical, self.n_ranks)
        return self.placement


@dataclasses.dataclass(frozen=True)
class MigrationStats:
    """What one placement migration actually moved, on the event clock."""

    wire_slots: int        # slots filled by cross-rank transfer
    local_slots: int       # slots filled by same-rank copy (no wire)
    restored_slots: int    # slots restored from checkpoint (no survivor)
    bytes_moved: int       # wire payload bytes
    clock_us: float        # event-clock time to quiesce
    msgs: int              # wire messages (post-coalescing)
    sub_writes: int        # chunk writes carried (pre-coalescing)


def migrate_expert_weights(old_holdings, new: planlib.Placement,
                           w_full: np.ndarray, *, net_cfg=None,
                           chunk_bytes: int = 4096, n_channels: int = 4,
                           ) -> tuple[np.ndarray, MigrationStats]:
    """Move expert weights into placement ``new`` through the transport
    substrate as coalesced bulk writes, fenced like any other guarded
    region (DESIGN.md §15 migration-fence protocol).

    ``old_holdings``: per rank of the NEW world (survivors renumbered, in
    order), the logical expert ids whose weight rows that rank currently
    holds.  ``w_full``: (E_log, Wb) uint8 — the logical weight rows (also
    the checkpoint reference for experts with no surviving holder, which
    the lowest rank restores and re-distributes).

    Every destination slot is one guarded region: its row is chunked into
    ``chunk_bytes`` WRITE commands forming a contiguous ascending run (what
    the proxy coalescer merges into single RDMA messages) followed by one
    FENCE_ATOMIC carrying the chunk count; the fence fires only when every
    chunk has applied at the receiver.  Same-rank moves are local copies.

    Returns ``(tables, stats)`` with ``tables[r, s]`` the Wb-byte row of
    physical slot ``r * slots_per_rank + s``.
    """
    from repro.core.transport.fifo import FLAG_FENCE, Op, pack_cmds
    from repro.core.transport.proxy import Proxy, SymmetricMemory
    from repro.core.transport.simulator import Network, NetConfig

    R = len(old_holdings)
    assert new.n_physical % R == 0
    eps = new.n_physical // R
    E_log, Wb = w_full.shape
    w_full = np.ascontiguousarray(w_full, np.uint8)

    # source selection per destination slot: prefer a same-rank holder
    # (free local copy), else the lowest-rank survivor, else restore from
    # the checkpoint via the lowest rank (a fresh staging row there)
    holders: dict[int, list[tuple[int, int]]] = {}
    send_rows: list[list[int]] = []
    for r, es in enumerate(old_holdings):
        es = [int(e) for e in np.asarray(es, np.int64).reshape(-1)]
        send_rows.append(es)
        for i, e in enumerate(es):
            holders.setdefault(e, []).append((r, i))
    moves, restored = [], 0
    for p in range(new.n_physical):
        e = int(new.phys_to_logical[p])
        dr, dslot = divmod(p, eps)
        hs = holders.get(e)
        if hs:
            same = [row for r, row in hs if r == dr]
            src = (dr, same[0]) if same else hs[0]
        else:
            restored += 1
            send_rows[0].append(e)
            src = (0, len(send_rows[0]) - 1)
        moves.append((*src, dr, dslot))

    ns_max = max(len(rows) for rows in send_rows)
    send0, recv0 = 0, ns_max * Wb
    total = recv0 + eps * Wb
    net = Network(net_cfg or NetConfig(mode="srd", seed=0), R)
    mems = [SymmetricMemory.create(total, n_counters=eps) for _ in range(R)]
    proxies = [Proxy(r, net, mems[r], n_channels=n_channels)
               for r in range(R)]
    table = planlib.receive_bucket_table(eps, recv0, Wb)
    for p in proxies:
        p.register_table(*table)
    for r, rows in enumerate(send_rows):
        if rows:
            mems[r].data[send0:send0 + len(rows) * Wb] = \
                w_full[np.asarray(rows)].reshape(-1)

    n_chunks = -(-Wb // chunk_bytes)
    off = np.arange(n_chunks, dtype=np.int64) * chunk_bytes
    ln = np.minimum(chunk_bytes, Wb - off)
    stats = dict(wire=0, local=0, bytes=0, subw=0)

    def push(r, ch, words):
        done = 0
        while done < len(words):
            done += proxies[r].push_batch(ch, words[done:], block=False)
            if done < len(words):
                proxies[r].drain_inline()

    fence_slots: list[tuple[int, int]] = []
    for sr, srow, dr, dslot in moves:
        if sr == dr:                       # same-rank: free local copy
            b = send0 + srow * Wb
            mems[dr].data[recv0 + dslot * Wb:recv0 + (dslot + 1) * Wb] = \
                mems[dr].data[b:b + Wb]
            stats["local"] += 1
            continue
        ch = dslot % n_channels
        # contiguous ascending chunk run -> the coalescer's ideal input
        writes = pack_cmds(int(Op.WRITE), dr, ch, send0 + srow * Wb + off,
                           recv0 + dslot * Wb + off, ln, 0)
        push(sr, ch, writes)
        # one completion fence per guarded destination slot: applies only
        # after all n_chunks writes into the slot's registered range
        push(sr, ch, pack_cmds(int(Op.ATOMIC), dr, ch, n_chunks, dslot,
                               0, 0, FLAG_FENCE))
        fence_slots.append((dr, dslot))
        stats["wire"] += 1
        stats["bytes"] += Wb
        stats["subw"] += n_chunks

    msgs = 0

    def hook(msg):
        nonlocal msgs
        if msg.kind == "write":
            msgs += 1
    net.on_deliver_hook = hook
    for p in proxies:
        p.drain_inline()
    while net.deliver_ready():
        for p in proxies:
            p.drain_inline()
    net.on_deliver_hook = None
    # clean quiesce + every migration fence fired exactly once
    assert not net.pending and not any(p.busy for p in proxies)
    for dr, dslot in fence_slots:
        assert mems[dr].counters[dslot] == 1, (dr, dslot)

    tables = np.stack([mems[r].data[recv0:total].reshape(eps, Wb)
                       for r in range(R)])
    return tables, MigrationStats(
        wire_slots=stats["wire"], local_slots=stats["local"],
        restored_slots=restored, bytes_moved=stats["bytes"],
        clock_us=float(net.clock_us), msgs=msgs, sub_writes=stats["subw"])
