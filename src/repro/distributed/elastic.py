"""Elastic EP (paper §6 "Elastic EP with CPU proxy", made concrete for TPU):
re-shard a TrainState onto a different mesh after node loss / addition.

On TPU, elasticity is a *restart* operation: the single-program SPMD world
cannot shrink in place, so the recovery path is (1) checkpoint (or use the
latest), (2) rebuild the mesh at the new size, (3) re-derive the DistCtx —
EP capacity, expert placement and FSDP layouts all fall out of the sharding
rules — and (4) restore the state under the new shardings.  Because our
checkpoints are logical (full arrays, path-keyed), restore-to-any-mesh is
free; this module packages the policy and validates divisibility.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DistCtx, make_dist_ctx, param_shardings


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple
    new_shape: tuple
    new_axis_names: tuple
    ep_degree_old: int
    ep_degree_new: int
    notes: list


def plan_remesh(cfg: ModelConfig, old: DistCtx, new_mesh: Mesh) -> ElasticPlan:
    """Validate a re-mesh and describe what changes."""
    new = make_dist_ctx(cfg, new_mesh)
    notes = []
    if cfg.moe.enabled:
        from repro.core.moe import padded_experts_static
        e = padded_experts_static(cfg)
        if e % max(new.ep_degree, 1):
            raise ValueError(
                f"padded experts {e} not divisible by new EP degree "
                f"{new.ep_degree}; choose a mesh whose EP axes divide {e}")
        notes.append(f"experts/shard: {e // max(old.ep_degree, 1)} -> "
                     f"{e // max(new.ep_degree, 1)}")
    for name in new_mesh.axis_names:
        if name == "model" and cfg.d_model % new_mesh.shape[name]:
            raise ValueError("d_model must divide the model axis")
    return ElasticPlan(
        old_shape=tuple(old.mesh.devices.shape),
        new_shape=tuple(new_mesh.devices.shape),
        new_axis_names=tuple(new_mesh.axis_names),
        ep_degree_old=old.ep_degree, ep_degree_new=new.ep_degree,
        notes=notes)


def reshard_state(cfg: ModelConfig, state, new_mesh: Mesh):
    """Device_put the (logical) state under the new mesh's shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    new_dist = make_dist_ctx(cfg, new_mesh)

    def move(subtree):
        sh = param_shardings(cfg, new_dist, subtree)
        return jax.tree.map(lambda x, s: jax.device_put(x, s), subtree, sh)

    params = move(state.params)
    # every leaf must land on the new mesh, including replicated scalars
    step = jax.device_put(state.opt.step, NamedSharding(new_mesh, P()))
    opt = state.opt._replace(step=step, mu=move(state.opt.mu),
                             nu=move(state.opt.nu))
    return state._replace(params=params, opt=opt), new_dist
