"""Failure injection + recovery policy used by tests and examples.

Real deployments get failure signals from the platform (missing heartbeat,
XLA halo errors); here a deterministic injector stands in so the
checkpoint-restore-retrain path is exercised end to end.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class FailureInjector:
    """Fails exactly once at each step listed in ``at_steps``."""
    at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def __call__(self, step: int) -> bool:
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            return True
        return False


@dataclass
class RecoveryPolicy:
    max_restarts: int = 3
    restarts: int = 0

    def should_restart(self) -> bool:
        self.restarts += 1
        return self.restarts <= self.max_restarts
