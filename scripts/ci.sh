#!/usr/bin/env bash
# CI entrypoint: install dev deps (best-effort in hermetic envs) and run the
# tier-1 suite exactly as ROADMAP.md specifies.
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras (pytest, hypothesis).  Offline/hermetic containers already bake
# in what they allow; a failed install must not fail CI — the conftest shim
# skips property tests when hypothesis is absent.
python -m pip install -e '.[dev]' 2>/dev/null \
    || echo "ci.sh: pip install skipped (offline env); running with baked-in deps"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
