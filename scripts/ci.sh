#!/usr/bin/env bash
# CI entrypoint: install dev deps (best-effort in hermetic envs), run the
# tier-1 suite exactly as ROADMAP.md specifies, then a benchmark smoke step
# (fig15 + JSON schema validation) so benchmark bit-rot fails fast.
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras (pytest, hypothesis).  Offline/hermetic containers already bake
# in what they allow; a failed install must not fail CI — the conftest shim
# skips property tests when hypothesis is absent.
python -m pip install -e '.[dev]' 2>/dev/null \
    || echo "ci.sh: pip install skipped (offline env); running with baked-in deps"

# Tier-1 suite (includes the transport-semantics conformance fuzz harness,
# tests/test_transport_fuzz.py).  The default run is bounded: the slowest
# arch/kernel sweeps sit behind `-m slow` (pyproject addopts deselects
# them; run `scripts/ci.sh -m ''` for the full matrix), every test carries
# a wall-clock timeout (conftest, REPRO_TEST_TIMEOUT_S) so a hung transport
# quiesce fails fast, and --durations keeps the slowest-test list visible
# so the bound doesn't silently erode.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    --durations=20 "$@"

# Bounded interpret-mode step: execute the Pallas kernel bodies (not just
# the jnp refs) through the ops-level mode dispatch on every run.
REPRO_KERNEL_MODE=interpret PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_kernel_modes.py

# Benchmark smoke: two host benchmarks end-to-end (fig15 FIFO stress +
# the bench_transport batched-path microbench, whose counter rows are
# exact-gated), plus the machine-readable results file the perf trajectory
# is tracked with across PRs, gated against the committed baseline (fails
# on >25% us_per_call regressions; counter rows must match exactly).
BENCH_JSON="$(mktemp -t bench_smoke.XXXXXX.json)"
trap 'rm -f "$BENCH_JSON"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only fig15,bench_transport \
    --json "$BENCH_JSON" --compare BENCH_results.json > /dev/null
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} BENCH_JSON="$BENCH_JSON" python - <<'EOF'
import json, os
from benchmarks.run import validate_results
results = json.load(open(os.environ["BENCH_JSON"]))
validate_results(results)
print(f"ci.sh: benchmark smoke OK ({len(results)} results)")
EOF
