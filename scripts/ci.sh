#!/usr/bin/env bash
# CI entrypoint: install dev deps (best-effort in hermetic envs), run the
# tier-1 suite exactly as ROADMAP.md specifies, then a benchmark smoke step
# (fig15 + JSON schema validation) so benchmark bit-rot fails fast.
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras (pytest, hypothesis).  Offline/hermetic containers already bake
# in what they allow; a failed install must not fail CI — the conftest shim
# skips property tests when hypothesis is absent.
python -m pip install -e '.[dev]' 2>/dev/null \
    || echo "ci.sh: pip install skipped (offline env); running with baked-in deps"

# Repo lint (repro.analysis.lint, DESIGN.md §17): no magic bit masks
# outside wire_format.py, no constant division in quantization-scale math,
# no bare protocol asserts in the transport, occupancy kernels gated with
# pl.when.  Fails fast before the test suite.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis.lint src/repro

# Tier-1 suite (includes the transport-semantics conformance fuzz harness,
# tests/test_transport_fuzz.py).  The default run is bounded: the slowest
# arch/kernel sweeps sit behind `-m slow` (pyproject addopts deselects
# them; run `scripts/ci.sh -m ''` for the full matrix), every test carries
# a wall-clock timeout (conftest, REPRO_TEST_TIMEOUT_S) so a hung transport
# quiesce fails fast, and --durations keeps the slowest-test list visible
# so the bound doesn't silently erode.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    --durations=20 "$@"

# Bounded interpret-mode step: execute the Pallas kernel bodies (not just
# the jnp refs) through the ops-level mode dispatch on every run.
REPRO_KERNEL_MODE=interpret PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_kernel_modes.py

# Static-analysis gate (DESIGN.md §17): the protocol verifier over
# fig08-shaped one-shot plans and the fig14-shaped persistent-session slot
# layout (zero findings on everything the generators emit), plus the
# Eraser-style race detector — zero findings on the shipped threaded path,
# while a seeded lock-removal mutant IS flagged (detector liveness).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import numpy as np
import threading

from repro.analysis import verify
from repro.analysis.racecheck import RaceChecker
from repro.analysis.verify import verify_session_slots
from repro.core.plan import wire_layout
from repro.core.transport import EPWorld, NetConfig
from repro.core.transport.ep_executor import build_command_streams
from repro.core.transport.fifo import FifoChannel, pack_cmds

# fig08-shaped one-shot LL plans (EP degree 4, 64 experts, dispatch +
# combine) across {fp32, fp8} x {rc, srd}: zero findings
rng = np.random.default_rng(0)
R, eps, Tl, K, D = 4, 16, 32, 4, 32
E = R * eps
cap = Tl * K
ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
for wdt in ("fp32", "fp8"):
    wb = wire_layout(D, wdt).token_bytes
    recv0 = Tl * wb
    cs = build_command_streams(ti, E, eps, cap, 4 * D, 8, 0, recv0,
                               recv0 + R * eps * cap * wb, wire_bytes=wb)
    for mode in ("rc", "srd"):
        fs = verify(cs, net_cfg=NetConfig(mode=mode, seed=0), n_channels=8)
        assert fs == [], [str(f) for f in fs]

# fig14-shaped persistent session (mirrored, L=2): the slot layout passes
# the namespace-disjointness rules (EPV-009); verify_or_raise is also live
# inside _session_layout and on every per-layer stream build
from benchmarks.fig14_training import _make_session, _step_problem
xs, tis, tws, wg, wu, wd, occ = _step_problem(4, 2)
ws = _make_session(4, 2)
ws.run_step_serial(xs, tis, tws, wg, wu, wd)
fs = verify_session_slots(ws._slots, n_channels=ws.n_channels,
                          counter_stride=ws._counter_stride)
assert fs == [], [str(f) for f in fs]

# race gate 1: the shipped threaded path runs with ZERO candidate races
rng = np.random.default_rng(1)
R2, eps2, K2, D2, Tl2 = 2, 2, 2, 8, 4
E2 = R2 * eps2
x = rng.standard_normal((R2, Tl2, D2)).astype(np.float32)
ti2 = rng.integers(0, E2, size=(R2, Tl2, K2)).astype(np.int32)
tw2 = np.full((R2, Tl2, K2), 1.0 / K2, np.float32)
wgs = (rng.standard_normal((E2, D2, 8)) * 0.2).astype(np.float32)
wus = (rng.standard_normal((E2, D2, 8)) * 0.2).astype(np.float32)
wds = (rng.standard_normal((E2, 8, D2)) * 0.2).astype(np.float32)
with RaceChecker() as rc:
    w = EPWorld(n_ranks=R2, n_experts=E2, top_k=K2, d=D2, f=8,
                capacity=Tl2 * K2, net_cfg=NetConfig(mode="srd", seed=0),
                use_threads=True, n_threads=2)
    try:
        w.run(x, ti2, tw2, wgs, wus, wds)
    finally:
        for p in w.proxies:
            p.stop()
assert rc.findings() == [], [str(f) for f in rc.findings()]

# race gate 2: a lock-removal mutant on the SPSC ring IS flagged
with RaceChecker() as rc:
    ch = FifoChannel(16)
    rc.instrument(ch, strip_locks=True)
    words = pack_cmds(1, np.zeros(100, np.int64), 0, np.arange(100),
                      np.arange(100), 8, 0)
    got = []

    def consumer():
        while len(got) < 100:
            out = ch.pop_all()
            if out is None:
                ch.wait_nonempty(0.01)
            else:
                got.extend(out.tolist())

    t = threading.Thread(target=consumer)
    t.start()
    done = 0
    while done < 100:
        done += ch.try_push_batch(words[done:done + 7])
    t.join(timeout=10)
assert any(f.rule == "RACE-LOCKSET" for f in rc.findings()), \
    "race detector failed to flag the seeded lock-removal mutant"
print("ci.sh: static-analysis gate OK (verifier clean on fig08/fig14 "
      "plans, race detector clean on shipped path, mutant flagged)")
EOF

# Compressed-dispatch smoke: the quantize-pack kernel body (interpret mode)
# stays bit-identical to the numpy codec, and an fp8 LL run on the
# substrate hits the honest-accounting floor (>=3.5x payload reduction at
# D=1024 with the event clock improving) — the same invariants the
# exact-gated bench_transport/counters/compression rows pin.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import numpy as np
from benchmarks.bench_transport import bench_compression
from repro.kernels import ops as kops
from repro.kernels.quantize_pack import gather_quantize_ref
import jax.numpy as jnp

x_ext = np.concatenate([np.random.default_rng(0).standard_normal(
    (9, 200)).astype(np.float32), np.zeros((1, 200), np.float32)])
src = np.random.default_rng(1).integers(0, 9, 16).astype(np.int32)
for wdt in ("fp8", "int8"):
    qr, sr = gather_quantize_ref(x_ext, src, wire_dtype=wdt)
    qi, si = kops.gather_quantize(jnp.asarray(x_ext), jnp.asarray(src),
                                  wire_dtype=wdt, mode="interpret")
    assert (np.ascontiguousarray(qr).view(np.uint8) ==
            np.ascontiguousarray(np.asarray(qi)).view(np.uint8)).all(), wdt
    assert (sr == np.asarray(si)).all(), wdt
worlds = bench_compression()
p32 = worlds["fp32"].timeline["dispatch_payload_bytes"]
pq = worlds["fp8"].timeline["dispatch_payload_bytes"]
assert p32 / pq >= 3.5 and worlds["fp8"].net.clock_us < worlds["fp32"].net.clock_us
print(f"ci.sh: compressed-dispatch smoke OK ({p32 / pq:.2f}x payload reduction)")
EOF

# Replicated-experts smoke: one Zipf skew point end-to-end (single vs
# online-rebalanced replicated placement, weight migration over the
# substrate included) must hold the p99 event-clock win the exact-gated
# fig16_ep_sweep/skew_clock rows pin, plus a fast replication fuzz point
# (skewed routing x replicas x {rc, srd} against the logical oracle).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import numpy as np
from benchmarks.fig16_ep_sweep import P99_GATE_RATIO, run_skew_point
from repro.core import plan as planlib
from repro.core.transport.ep_executor import EPWorld
from repro.core.transport.simulator import NetConfig

s = run_skew_point(1.0)
assert s["p99_ratio"] >= P99_GATE_RATIO, s

# replication fuzz point: skewed routing x replicas {1, 2} x {rc, srd},
# physical world vs the LOGICAL dense oracle (pytest runs the full Part 5)
rng = np.random.default_rng(0)
R, E, K, D, F, Tl = 2, 8, 2, 8, 8, 8
x = rng.standard_normal((R, Tl, D)).astype(np.float32)
p = (1.0 + np.arange(E)) ** -1.2
ti = rng.choice(E, size=(R, Tl, K), p=p / p.sum()).astype(np.int32)
tw = rng.random((R, Tl, K)).astype(np.float32)
tw /= tw.sum(-1, keepdims=True)
wg, wu, wd = ((rng.standard_normal(sh) * 0.2).astype(np.float32)
              for sh in ((E, D, F), (E, D, F), (E, F, D)))
ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
loads = planlib.group_counts(ti.reshape(-1), E, ti.reshape(-1) >= 0)
for mode in ("rc", "srd"):
    for factor in (1, 2):
        pl = (planlib.identity_placement(E) if factor == 1
              else planlib.greedy_placement(loads, E * factor, R))
        tis = planlib.split_to_physical_world(pl, ti)
        p2l = np.asarray(pl.phys_to_logical)
        w = EPWorld(n_ranks=R, n_experts=pl.n_physical, top_k=K, d=D, f=F,
                    capacity=Tl * K, net_cfg=NetConfig(mode=mode, seed=0))
        out = w.run(x, tis, tw, wg[p2l], wu[p2l], wd[p2l])
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        assert not w.net.pending and not any(pr.busy for pr in w.proxies)
print(f"ci.sh: replicated-experts smoke OK "
      f"(alpha=1.0 p99 win {s['p99_ratio']:.2f}x, "
      f"migrate {s['migrate_bytes']} bytes in {s['migrate_us']:.0f}us)")
EOF

# Training-step pipeline smoke (bounded fig14 point): the persistent-session
# serial-vs-pipelined A/B at EP=8, L=2 must keep bit-identical outputs, the
# exact L->1 drain collapse (drains_per_step: 2L -> 1), and a >=1.2x
# event-clock win — the invariants the exact-gated fig14_training/counters
# rows pin at the full flagship sweep.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from benchmarks.fig14_training import run_substrate_point
s = run_substrate_point(8, 2)
assert s["drains_batched"] == 1 and s["drains_serial"] == 4, s
assert s["speedup"] >= 1.2, s
print(f"ci.sh: training-pipeline smoke OK (EP=8 L=2 "
      f"{s['speedup']:.2f}x, drains {s['drains_serial']} -> 1)")
EOF

# Serving smoke (DESIGN.md §18): a short Poisson run through the
# continuous-batching engine on the event clock — every request completes,
# the run is bit-deterministic (exact counters), the persistent session
# quiesces clean after the last microbatch, and the PR 9 verifier (already
# live on every microbatch's stream builds) re-checks the session slot
# layout with zero findings.  The naive per-layer path must cost more
# event-clock time on the same schedule.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from repro.analysis.verify import verify_session_slots
from repro.serving import EngineConfig, ServingEngine, poisson_arrivals

def run(step_mode):
    cfg = EngineConfig(n_layers=2, n_experts=8, top_k=2, d_model=16,
                       d_ff=32, ep_degree=4, token_budget=16,
                       prefill_chunk=8, block_size=8, n_blocks=64,
                       step_mode=step_mode, nonmoe_us=10.0, seed=0)
    eng = ServingEngine(cfg)
    eng.submit_all(poisson_arrivals(50_000.0, 8, seed=11,
                                    prompt_len=(6, 20), gen_len=(3, 8)))
    return eng, eng.run()

eng, s = run("pipelined")
_, s2 = run("pipelined")
assert s == s2, "serving engine is not deterministic"
assert s["sched_completed"] == 8 and s["kv_allocs"] == s["kv_frees"], s
assert s["drains"] == s["steps"], s                # one drain/microbatch
(world,) = eng.backend._sessions.values()
assert not world.net.pending, "session left traffic in flight"
fs = verify_session_slots(world._slots, n_channels=world.n_channels,
                          counter_stride=world._counter_stride)
assert fs == [], [str(f) for f in fs]
_, n = run("per_layer")
for k in (k for k in s if k.startswith("sched_")):
    assert s[k] == n[k], k                        # identical schedule
assert s["elapsed_us"] < n["elapsed_us"], (s["elapsed_us"], n["elapsed_us"])
print(f"ci.sh: serving smoke OK ({s['generated_tokens']} tokens, "
      f"{s['steps']} microbatches, session {s['elapsed_us']:.0f}us vs "
      f"naive {n['elapsed_us']:.0f}us, verifier clean)")
EOF

# Benchmark smoke: three host benchmarks end-to-end (fig15 FIFO stress,
# the bench_transport batched-path microbench, and the fig13 serving load
# sweep — both with exact-gated counter rows), plus the machine-readable
# results file the perf trajectory is tracked with across PRs, gated
# against the committed baseline (fails on >25% us_per_call regressions;
# counter rows must match exactly).
BENCH_JSON="$(mktemp -t bench_smoke.XXXXXX.json)"
trap 'rm -f "$BENCH_JSON"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only fig15,bench_transport,fig13_serving \
    --json "$BENCH_JSON" --compare BENCH_results.json > /dev/null
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} BENCH_JSON="$BENCH_JSON" python - <<'EOF'
import json, os
from benchmarks.run import validate_results
results = json.load(open(os.environ["BENCH_JSON"]))
validate_results(results)
print(f"ci.sh: benchmark smoke OK ({len(results)} results)")
EOF
