#!/usr/bin/env bash
# CI entrypoint: install dev deps (best-effort in hermetic envs), run the
# tier-1 suite exactly as ROADMAP.md specifies, then a benchmark smoke step
# (fig15 + JSON schema validation) so benchmark bit-rot fails fast.
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras (pytest, hypothesis).  Offline/hermetic containers already bake
# in what they allow; a failed install must not fail CI — the conftest shim
# skips property tests when hypothesis is absent.
python -m pip install -e '.[dev]' 2>/dev/null \
    || echo "ci.sh: pip install skipped (offline env); running with baked-in deps"

# Tier-1 suite (includes the transport-semantics conformance fuzz harness,
# tests/test_transport_fuzz.py).  The default run is bounded: the slowest
# arch/kernel sweeps sit behind `-m slow` (pyproject addopts deselects
# them; run `scripts/ci.sh -m ''` for the full matrix), every test carries
# a wall-clock timeout (conftest, REPRO_TEST_TIMEOUT_S) so a hung transport
# quiesce fails fast, and --durations keeps the slowest-test list visible
# so the bound doesn't silently erode.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    --durations=20 "$@"

# Bounded interpret-mode step: execute the Pallas kernel bodies (not just
# the jnp refs) through the ops-level mode dispatch on every run.
REPRO_KERNEL_MODE=interpret PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_kernel_modes.py

# Compressed-dispatch smoke: the quantize-pack kernel body (interpret mode)
# stays bit-identical to the numpy codec, and an fp8 LL run on the
# substrate hits the honest-accounting floor (>=3.5x payload reduction at
# D=1024 with the event clock improving) — the same invariants the
# exact-gated bench_transport/counters/compression rows pin.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import numpy as np
from benchmarks.bench_transport import bench_compression
from repro.kernels import ops as kops
from repro.kernels.quantize_pack import gather_quantize_ref
import jax.numpy as jnp

x_ext = np.concatenate([np.random.default_rng(0).standard_normal(
    (9, 200)).astype(np.float32), np.zeros((1, 200), np.float32)])
src = np.random.default_rng(1).integers(0, 9, 16).astype(np.int32)
for wdt in ("fp8", "int8"):
    qr, sr = gather_quantize_ref(x_ext, src, wire_dtype=wdt)
    qi, si = kops.gather_quantize(jnp.asarray(x_ext), jnp.asarray(src),
                                  wire_dtype=wdt, mode="interpret")
    assert (np.ascontiguousarray(qr).view(np.uint8) ==
            np.ascontiguousarray(np.asarray(qi)).view(np.uint8)).all(), wdt
    assert (sr == np.asarray(si)).all(), wdt
worlds = bench_compression()
p32 = worlds["fp32"].timeline["dispatch_payload_bytes"]
pq = worlds["fp8"].timeline["dispatch_payload_bytes"]
assert p32 / pq >= 3.5 and worlds["fp8"].net.clock_us < worlds["fp32"].net.clock_us
print(f"ci.sh: compressed-dispatch smoke OK ({p32 / pq:.2f}x payload reduction)")
EOF

# Benchmark smoke: two host benchmarks end-to-end (fig15 FIFO stress +
# the bench_transport batched-path microbench, whose counter rows are
# exact-gated), plus the machine-readable results file the perf trajectory
# is tracked with across PRs, gated against the committed baseline (fails
# on >25% us_per_call regressions; counter rows must match exactly).
BENCH_JSON="$(mktemp -t bench_smoke.XXXXXX.json)"
trap 'rm -f "$BENCH_JSON"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only fig15,bench_transport \
    --json "$BENCH_JSON" --compare BENCH_results.json > /dev/null
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} BENCH_JSON="$BENCH_JSON" python - <<'EOF'
import json, os
from benchmarks.run import validate_results
results = json.load(open(os.environ["BENCH_JSON"]))
validate_results(results)
print(f"ci.sh: benchmark smoke OK ({len(results)} results)")
EOF
