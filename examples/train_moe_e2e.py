"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps on
an 8-device CPU mesh with the HT (dedup + hierarchical) EP path, checkpoints,
watchdog, and a mid-run injected failure that recovers from the checkpoint.

  python examples/train_moe_e2e.py [--steps 200]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import tempfile

import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced_config
from functools import partial

from repro.data.pipeline import DataConfig, synth_batch
from repro.distributed.fault import FailureInjector
from repro.distributed.sharding import make_dist_ctx
from repro.launch.mesh import make_bench_mesh
from repro.training.train_loop import HParams, Watchdog, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: 4 layers, d=512, 8 experts of f=1024, vocab 8192
    base = get_config("moonshot_v1_16b_a3b")
    cfg = reduced_config(base, n_layers=4, d_model=512, n_experts=8,
                         vocab=8192)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, d_expert=1024, top_k=2))
    n = cfg.param_count()
    print(f"[e2e] model: {n/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active), "
          f"{cfg.moe.n_experts} experts top-{cfg.moe.top_k}")

    mesh = make_bench_mesh(len(jax.devices()), model=4)
    dist = make_dist_ctx(cfg, mesh)
    print(f"[e2e] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"EP axes: {dist.ep_axes}")

    hp = HParams(peak_lr=1e-3, total_steps=args.steps, warmup=20,
                 moe_mode="ht", moe_chunks=1, loss_chunk=args.seq)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                    seq_len=args.seq, seed=0)
    with tempfile.TemporaryDirectory() as td:
        ckpt = Checkpointer(td, keep=2)
        injector = FailureInjector(at_steps=(args.steps // 2,))
        state, hist = train_loop(
            cfg, hp, dist, partial(synth_batch, dc), steps=args.steps,
            checkpointer=ckpt, ckpt_every=25, log_every=20,
            watchdog=Watchdog(), fail_injector=injector)
    losses = [h["loss"] for h in hist]
    print(f"[e2e] loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    assert losses[-1] < losses[0] - 0.3, "loss did not decrease"
    print("[e2e] OK: loss decreased and failure recovery exercised")


if __name__ == "__main__":
    main()
