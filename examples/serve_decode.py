"""Batched serving example: greedy decode with LL-mode EP dispatch and a
sharded KV cache (split-sequence decode attention) on a local mesh.

  python examples/serve_decode.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.distributed.sharding import make_dist_ctx
from repro.launch.mesh import make_bench_mesh
from repro.models import model_zoo as Z


def main():
    cfg = reduced_config(get_config("qwen2_moe_a2_7b"), n_layers=2,
                         d_model=128, vocab=2048)
    mesh = make_bench_mesh(len(jax.devices()), model=4)
    dist = make_dist_ctx(cfg, mesh)
    key = jax.random.PRNGKey(0)
    params = Z.init_params(cfg, key)
    B, prompt_len, gen = 8, 16, 24
    max_len = prompt_len + gen
    cache = Z.init_cache(cfg, B, max_len)
    prompts = jax.random.randint(key, (B, prompt_len), 0, cfg.vocab_size)

    step = jax.jit(partial(Z.decode_step, cfg, dist=dist, moe_mode="ll"),
                   donate_argnums=(1,))
    tok = prompts[:, :1]
    t0 = time.perf_counter()
    generated = []
    for t in range(max_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        if t + 1 < prompt_len:
            tok = prompts[:, t + 1:t + 2]
        else:
            tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
            generated.append(int(tok[0, 0]))
    dt = time.perf_counter() - t0
    n = B * gen
    print(f"[serve] {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s) on "
          f"{len(jax.devices())} devices; sample continuation: {generated[:10]}")
    assert all(jnp.isfinite(logits).all() for _ in [0])
    print("[serve] OK")


if __name__ == "__main__":
    main()
