"""Continuous-batching serving example on the EP-native engine.

Submits a burst of Poisson-arriving requests to :class:`ServingEngine`,
runs the scheduler loop (chunked prefill interleaved with decode over a
paged KV cache, every microbatch's MoE layers dispatched through ONE
persistent EP session), and prints per-request latencies measured on the
deterministic event clock.

  python examples/serve_decode.py
"""
from repro.serving import EngineConfig, ServingEngine, poisson_arrivals


def main():
    cfg = EngineConfig(n_layers=4, n_experts=16, top_k=2, d_model=32,
                       d_ff=64, ep_degree=4, token_budget=32,
                       prefill_chunk=16, block_size=16, n_blocks=256,
                       step_mode="pipelined", nonmoe_us=12.0, seed=0)
    engine = ServingEngine(cfg)
    reqs = poisson_arrivals(rate_rps=50000.0, n=16, seed=3,
                            prompt_len=(8, 32), gen_len=(4, 16))
    engine.submit_all(reqs)
    stats = engine.run()

    print(f"[serve] {stats['generated_tokens']} tokens over "
          f"{stats['steps']} microbatches in "
          f"{stats['elapsed_us'] / 1e3:.1f} ms event-clock "
          f"({stats['tokens_per_s']:.0f} tok/s); "
          f"{stats['drains']} transport drains, "
          f"{stats['dispatch_wire_bytes']} dispatch wire bytes")
    print(f"[serve] TTFT p50/p99: {stats['ttft_p50_us']:.0f}/"
          f"{stats['ttft_p99_us']:.0f} us; inter-token p50/p99: "
          f"{stats['itl_p50_us']:.0f}/{stats['itl_p99_us']:.0f} us")
    print(f"{'rid':>4} {'arrive_us':>10} {'ttft_us':>9} "
          f"{'finish_us':>10} {'tokens':>6}")
    for rid in sorted(engine.sched.finished):
        st = engine.sched.finished[rid]
        print(f"{rid:>4} {st.req.arrival_us:>10.1f} "
              f"{st.first_token_us - st.req.arrival_us:>9.1f} "
              f"{st.finish_us:>10.1f} {st.generated:>6}")
    assert stats["sched_completed"] == len(reqs)
    print("[serve] OK")


if __name__ == "__main__":
    main()
