"""Quickstart: UCCL-EP dispatch/combine on a local device mesh.

Runs the paper's two EP modes (LL one-shot, HT dedup+hierarchical) on an
8-device CPU mesh and checks both against the dense MoE oracle — the
60-second tour of the core API.

  python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import repro.compat  # noqa: F401  jax version shims
from jax.sharding import AxisType, PartitionSpec as P

from repro.core.ep import (EPSpec, dispatch_combine_ht, dispatch_combine_ll,
                           moe_ref)
from repro.kernels.ref import grouped_swiglu_ref


def main():
    E, K, D, F, T = 16, 3, 64, 96, 128
    mesh = jax.make_mesh((2, 4), ("pod", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    key = jax.random.PRNGKey(0)
    kx, kw, ki, kg, ku, kd = jax.random.split(key, 6)
    x = jax.random.normal(kx, (T, D), jnp.float32)
    top_idx = jax.random.randint(ki, (T, K), 0, E).astype(jnp.int32)
    top_w = jax.nn.softmax(jax.random.normal(kw, (T, K)), axis=-1)
    wg = jax.random.normal(kg, (E, D, F)) * 0.1
    wu = jax.random.normal(ku, (E, D, F)) * 0.1
    wd = jax.random.normal(kd, (E, F, D)) * 0.1

    ref = moe_ref(x, top_idx, top_w, wg, wu, wd)

    for mode, fn in [("LL (one-shot, decode)", dispatch_combine_ll),
                     ("HT (dedup + hierarchical, train)", dispatch_combine_ht)]:
        spec = EPSpec(axes=("pod", "model"), sizes=(2, 4), n_experts=E,
                      top_k=K, capacity_factor=4.0,
                      chunks=2 if "HT" in mode else 1, dtype=jnp.float32)

        def island(x_l, ti, tw, g, u, d):
            r = fn(spec, x_l, ti, tw,
                   lambda t: grouped_swiglu_ref(t, g, u, d))
            return r.out, r.aux["dropped"]

        out, dropped = jax.jit(jax.shard_map(
            island, mesh=mesh,
            in_specs=(P(("pod", "model")), P(("pod", "model")),
                      P(("pod", "model")), P(("pod", "model"), None, None),
                      P(("pod", "model"), None, None),
                      P(("pod", "model"), None, None)),
            out_specs=(P(("pod", "model")), P()),
            check_vma=False))(x, top_idx, top_w, wg, wu, wd)
        err = float(jnp.abs(out - ref).max())
        print(f"{mode:36s} max|err| vs oracle = {err:.2e}  "
              f"dropped = {float(dropped):.3f}")
        assert err < 1e-4, "EP output diverged from the oracle"
    print("quickstart OK")


if __name__ == "__main__":
    main()
