"""Elastic EP demo (paper §6 made concrete): train on an 8-device mesh,
checkpoint, "lose" half the nodes, re-mesh to 4 devices, restore, and keep
training — loss continues from where it left off.

  python examples/elastic_restart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import repro.compat  # noqa: F401  jax version shims
import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced_config
from repro.data.pipeline import DataConfig, data_iterator
from repro.distributed.elastic import plan_remesh, reshard_state
from repro.distributed.sharding import make_dist_ctx
from repro.launch.mesh import make_bench_mesh
from repro.training.train_loop import HParams, init_state, train_loop


def main():
    cfg = reduced_config(get_config("moonshot_v1_16b_a3b"), n_layers=2,
                         d_model=128, n_experts=8, vocab=1024)
    hp = HParams(peak_lr=1e-3, total_steps=120, warmup=10, moe_mode="ht",
                 loss_chunk=64)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=8, seq_len=64, seed=0)

    mesh8 = make_bench_mesh(8, model=4)          # (data=2, model=4)
    dist8 = make_dist_ctx(cfg, mesh8)
    with tempfile.TemporaryDirectory() as td:
        ckpt = Checkpointer(td)
        print("[elastic] phase 1: 8 devices", dict(zip(
            mesh8.axis_names, mesh8.devices.shape)))
        state, hist1 = train_loop(cfg, hp, dist8, data_iterator(dc), steps=60,
                                  checkpointer=ckpt, ckpt_every=30,
                                  log_every=20)
        ckpt.save(state, 60)

        # "node failure": only 4 devices remain -> re-mesh (data=2, model=2)
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,) * 2,
                              devices=jax.devices()[:4])
        plan = plan_remesh(cfg, dist8, mesh4)
        print(f"[elastic] re-mesh {plan.old_shape} -> {plan.new_shape}; "
              f"EP {plan.ep_degree_old} -> {plan.ep_degree_new}; {plan.notes}")
        restored, _ = ckpt.restore_latest(init_state(cfg, jax.random.PRNGKey(0)))
        state4, dist4 = reshard_state(cfg, restored, mesh4)
        state4, hist2 = train_loop(cfg, hp, dist4,
                                   data_iterator(dc, start_step=60),
                                   steps=120, state=state4, log_every=20)
    l0, l1, l2 = hist1[0]["loss"], hist1[-1]["loss"], hist2[-1]["loss"]
    print(f"[elastic] loss: start={l0:.4f} before-failure={l1:.4f} "
          f"after-remesh-end={l2:.4f}")
    assert l2 <= l1 + 0.2, "training regressed after elastic re-mesh"
    print("[elastic] OK: training continued across the re-mesh")


if __name__ == "__main__":
    main()
