"""Optimizer + data-pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, data_iterator, synth_batch
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup


def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw.init_state(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = adamw.apply_updates(
            params, grads, state, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_factored_matches_adamw_direction():
    """On a rank-1 |gradient| structure the factored second moment is exact,
    so the update direction must match full AdamW."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (256, 256))
    params = {"w": w}
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (256, 1))) + 0.1
    b = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (1, 256))) + 0.1
    sign = jnp.sign(jax.random.normal(jax.random.PRNGKey(3), (256, 256)))
    g = {"w": a * b * sign}
    pa, sa, _ = adamw.apply_updates(
        params, g, adamw.init_state(params), lr=1e-2, weight_decay=0.0)
    pf, sf, _ = adamw.apply_updates(
        params, g, adamw.init_state(params, factored=True), lr=1e-2,
        weight_decay=0.0, factored=True)
    da = np.asarray(pa["w"] - w).ravel()
    df = np.asarray(pf["w"] - w).ravel()
    cos = np.dot(da, df) / (np.linalg.norm(da) * np.linalg.norm(df))
    assert cos > 0.9                      # same descent direction


def test_factored_state_is_small():
    params = {"w": jnp.zeros((512, 512))}
    s = adamw.init_state(params, factored=True)
    n_nu = sum(l.size for l in jax.tree.leaves(s.nu))
    assert n_nu == 1024                   # row + col, not 512*512


def test_grad_clip():
    params = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    p2, s2, m = adamw.apply_updates(params, g, adamw.init_state(params),
                                    lr=1.0, max_grad_norm=1.0,
                                    weight_decay=0.0)
    assert float(m["grad_norm"]) > 100   # reported pre-clip
    assert float(jnp.abs(p2["w"]).max()) < 10


def test_schedule():
    lr0 = float(cosine_with_warmup(0, peak_lr=1.0, warmup=10, total=100))
    lr10 = float(cosine_with_warmup(10, peak_lr=1.0, warmup=10, total=100))
    lr100 = float(cosine_with_warmup(100, peak_lr=1.0, warmup=10, total=100))
    # warmup ramps from peak/warmup (first step is never a zero-lr no-op)
    assert abs(lr0 - 0.1) < 1e-6 and abs(lr10 - 1.0) < 1e-6 and lr100 < 0.11


def test_data_deterministic_and_resumable():
    dc = DataConfig(vocab_size=1000, batch=4, seq_len=32, seed=3)
    b1 = synth_batch(dc, 5)
    b2 = synth_batch(dc, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = data_iterator(dc, start_step=5)
    b3 = next(it)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])
    # labels are the shifted stream
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_learnable_structure():
    """The synthetic stream is predictable: next token is a fixed affine map
    of the current one >=95% of the time."""
    dc = DataConfig(vocab_size=4096, batch=8, seq_len=256, seed=0)
    b = synth_batch(dc, 0)
    toks, labs = b["tokens"], b["labels"]
    hits = 0
    total = 0
    for r in range(8):
        # infer (a, b) from the first transition
        for a in range(2, 8):
            bb = (labs[r, 0] - a * toks[r, 0]) % 4096
            pred = (a * toks[r] + bb) % 4096
            frac = (pred == labs[r]).mean()
            if frac > 0.9:
                hits += 1
                break
        total += 1
    assert hits >= 6
