"""Router unit tests: top-k selection, padding masks, aux-free bias."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core.routing import (RouterParams, route, router_init,
                                update_aux_free_bias)


def _setup(e_real=6, e_pad=8, k=2, t=32, d=16, bias=True, seed=0):
    moe = MoEConfig(n_experts=e_real, top_k=k, d_expert=4)
    key = jax.random.PRNGKey(seed)
    p = router_init(d, e_pad, key, bias)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, d))
    return moe, p, x


def test_topk_valid_and_masked():
    moe, p, x = _setup()
    out = route(moe, p, x, 6)
    assert out.top_idx.shape == (32, 2)
    assert int(out.top_idx.max()) < 6          # padding experts never chosen
    # weights normalised
    np.testing.assert_allclose(np.asarray(out.top_w.sum(-1)), 1.0, rtol=1e-3)


def test_weights_from_unbiased_probs():
    """Aux-free bias shifts selection but weights stay = probs of chosen."""
    moe, p, x = _setup(bias=True)
    p2 = p._replace(bias=p.bias.at[0].set(100.0))   # force expert 0 selection
    out = route(moe, p2, x, 6)
    assert bool((out.top_idx == 0).any(axis=1).all())
    probs0 = np.asarray(out.probs[:, 0])
    k0 = np.asarray(out.top_idx) == 0
    w = np.asarray(out.top_w / jnp.maximum(
        jnp.take_along_axis(out.probs, out.top_idx, 1).sum(-1, keepdims=True), 1e-9))
    # chosen weight for expert 0 proportional to its UNbiased prob
    tw = np.asarray(out.top_w)
    for t in range(x.shape[0]):
        sel = np.where(k0[t])[0]
        assert len(sel) == 1
        assert tw[t, sel[0]] < 1.0 or probs0[t] > 0.5


def test_aux_loss_uniform_lower_than_skewed():
    moe, p, x = _setup(bias=False, t=256)
    out = route(moe, p, x, 6)
    # force skew: all logits to one expert
    w = p.w.at[:, 1:].set(-10.0)
    out_skew = route(moe, p._replace(w=w), x, 6)
    assert float(out_skew.aux_loss) > float(out.aux_loss)


def test_bias_update_pushes_toward_uniform():
    moe, p, x = _setup(bias=True, t=256)
    w = p.w.at[:, 0].set(5.0)                  # expert 0 overloaded
    p = p._replace(w=w)
    out = route(moe, p, x, 6)
    p2 = update_aux_free_bias(p, out, 6, lr=0.1)
    assert float(p2.bias[0]) < float(p.bias[0])       # overloaded: bias down
    load = jax.nn.one_hot(out.top_idx, 8).sum((0, 1))
    under = int(jnp.argmin(load[:6]))
    assert float(p2.bias[under]) > float(p.bias[under])
