"""Transport substrate tests: FIFO invariants, delivery-semantics bridging,
and the end-to-end EP protocol over unordered networks — the paper's §3
correctness claims, property-tested with hypothesis."""
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.transport import (EPWorld, FLAG_FENCE, ControlBuffer,
                                  FifoChannel, ImmKind, NetConfig, Op,
                                  TransferCmd, pack_imm, unpack_imm)


# ------------------------------------------------------------------ FIFO --
def test_transfercmd_pack_roundtrip():
    cmd = TransferCmd(op=Op.WRITE_ATOMIC, dst_rank=1234, channel=200,
                      src_off=0xDEADBEEF, dst_off=0x12345678,
                      length=0xFFFFF, value=0xABC, flags=FLAG_FENCE)
    words = cmd.pack()
    assert words.nbytes == 16                  # exactly 128 bits
    assert TransferCmd.unpack(words) == cmd


def test_fifo_spsc_order_and_flow_control():
    ch = FifoChannel(k_max_inflight=8)
    sent, recv = [], []

    def consumer():
        while len(recv) < 100:
            got = ch.pop()
            if got is None:
                continue
            recv.append(got[1].src_off)

    th = threading.Thread(target=consumer)
    th.start()
    for i in range(100):
        idx = ch.push(TransferCmd(Op.WRITE, 0, 0, i, 0, 16, 0))
        sent.append(i)
        assert ch.inflight <= 8                # kMaxInflight bound
    th.join(timeout=5)
    assert recv == sent                        # no loss, no dup, in order


def test_fifo_try_push_full_and_completion():
    ch = FifoChannel(k_max_inflight=2)
    i0 = ch.try_push(TransferCmd(Op.WRITE, 0, 0, 0, 0, 16, 0))
    i1 = ch.try_push(TransferCmd(Op.WRITE, 0, 0, 1, 0, 16, 0))
    assert ch.try_push(TransferCmd(Op.WRITE, 0, 0, 2, 0, 16, 0)) is None
    assert not ch.check_completion(i0)
    ch.pop()
    assert ch.check_completion(i0) and not ch.check_completion(i1)


def test_fifo_cached_head_limits_pcie_reads():
    """The producer's cached head means far fewer 'PCIe' reads than pushes."""
    ch = FifoChannel(k_max_inflight=64)
    for i in range(64):
        ch.push(TransferCmd(Op.WRITE, 0, 0, i, 0, 16, 0))
    assert ch.pcie_reads <= 1


# ------------------------------------------------------ immediate data ----
@given(ch=st.integers(0, 63), seq=st.integers(0, 4095), slot=st.integers(0, 63),
       val=st.integers(0, 63),
       kind=st.sampled_from(list(ImmKind)))
def test_imm_codec_roundtrip(ch, seq, slot, val, kind):
    imm = pack_imm(kind, ch, seq, slot, val)
    assert 0 <= imm < 2 ** 32
    assert unpack_imm(imm) == (kind, ch, seq, slot, val)


# --------------------------------------------------- control buffer -------
def _oracle_apply_order(events):
    """In-order oracle: writes apply immediately; fence atomics wait for
    their count; seq atomics wait for per-channel predecessor seqs."""
    cb = ControlBuffer()
    for kind, imm in events:
        if kind == "w":
            cb.on_write(imm, lambda: None)
        else:
            cb.on_atomic(imm, lambda: None)
    return cb


@settings(max_examples=60, deadline=None)
@given(data=st.data(), n_writes=st.integers(1, 20), seed=st.integers(0, 9999))
def test_fence_atomic_never_applies_early(data, n_writes, seed):
    """LL fence: for ANY delivery permutation, the fence atomic applies
    after >= X writes to its expert slot have applied."""
    rng = np.random.default_rng(seed)
    slot = 3
    writes = [("w", pack_imm(ImmKind.WRITE, ch % 64, s, slot, 0))
              for s, ch in enumerate(range(n_writes))]
    fence = ("a", pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, slot, n_writes))
    events = writes + [fence]
    perm = rng.permutation(len(events))
    cb = ControlBuffer()
    applied = []
    for i in perm:
        kind, imm = events[i]
        if kind == "w":
            cb.on_write(imm, lambda: applied.append("w"))
        else:
            cb.on_atomic(imm, lambda: applied.append("A"))
    assert applied.count("w") == n_writes
    assert applied.count("A") == 1
    # the fence applied only after all n_writes writes
    assert applied.index("A") >= n_writes


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 9999), n=st.integers(2, 24))
def test_seq_atomics_apply_in_channel_order(seed, n):
    """HT partial ordering: per-channel seq atomics apply in sequence order
    regardless of arrival order; cross-channel order is unconstrained."""
    rng = np.random.default_rng(seed)
    events = []
    for ch in (0, 1):
        for s in range(n):
            kind = "w" if s % 2 == 0 else "a"
            ik = ImmKind.WRITE if kind == "w" else ImmKind.SEQ_ATOMIC
            events.append((kind, ch, s, pack_imm(ik, ch, s, 0, 0)))
    perm = rng.permutation(len(events))
    cb = ControlBuffer()
    applied = []
    for i in perm:
        kind, ch, s, imm = events[i]
        if kind == "w":
            cb.on_write(imm, lambda ch=ch, s=s: applied.append((ch, s)))
        else:
            cb.on_atomic(imm, lambda ch=ch, s=s: applied.append((ch, s)))
    assert len(applied) == len(events)
    for ch in (0, 1):
        atomics = [s for c, s in applied if c == ch and s % 2 == 1]
        # each atomic s applied only after everything < s on its channel
        seen = set()
        for c, s in applied:
            if c != ch:
                continue
            if s % 2 == 1:      # atomic
                assert seen >= set(range(s)), (s, seen)
            seen.add(s)
    assert cb.n_held == 0


# ------------------------------------------------ end-to-end EP protocol --
@pytest.mark.parametrize("mode", ["rc", "srd"])
def test_ep_protocol_matches_oracle(mode):
    rng = np.random.default_rng(1)
    R, E, K, D, F, Tl = 4, 8, 3, 16, 24, 10
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.2).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=7, reorder_window=64))
    out = w.run(x, ti, tw, wg, wu, wd)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    if mode == "srd":
        held = max(p.stats["held_max"] for p in w.proxies)
        assert held >= 0      # control buffer exercised (may be 0 on lucky order)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_ep_protocol_property_random_routing(seed):
    rng = np.random.default_rng(seed)
    R, E, K, D, F, Tl = 2, 4, 2, 8, 8, 6
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.3).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.3).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.3).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode="srd", seed=seed, reorder_window=16))
    out = w.run(x, ti, tw, wg, wu, wd)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
