"""Transport substrate tests: FIFO invariants, delivery-semantics bridging,
and the end-to-end EP protocol over unordered networks — the paper's §3
correctness claims, property-tested with hypothesis."""
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.transport import (EPWorld, FLAG_FENCE, ControlBuffer,
                                  FifoChannel, GuardTable, ImmKind, Message,
                                  NetConfig, Network, Op, ProtocolError,
                                  Proxy, SymmetricMemory, TransferCmd,
                                  pack_cmds, pack_imm, unpack_cmds,
                                  unpack_imm)


# ------------------------------------------------------------------ FIFO --
def test_transfercmd_pack_roundtrip():
    cmd = TransferCmd(op=Op.WRITE_ATOMIC, dst_rank=1234, channel=200,
                      src_off=0xDEADBEEF, dst_off=0x12345678,
                      length=0xFFFFF, value=0xABC, flags=FLAG_FENCE)
    words = cmd.pack()
    assert words.nbytes == 16                  # exactly 128 bits
    assert TransferCmd.unpack(words) == cmd


def test_fifo_spsc_order_and_flow_control():
    ch = FifoChannel(k_max_inflight=8)
    sent, recv = [], []

    def consumer():
        while len(recv) < 100:
            got = ch.pop()
            if got is None:
                continue
            recv.append(got[1].src_off)

    th = threading.Thread(target=consumer)
    th.start()
    for i in range(100):
        idx = ch.push(TransferCmd(Op.WRITE, 0, 0, i, 0, 16, 0))
        sent.append(i)
        assert ch.inflight <= 8                # kMaxInflight bound
    th.join(timeout=5)
    assert recv == sent                        # no loss, no dup, in order


def test_fifo_try_push_full_and_completion():
    ch = FifoChannel(k_max_inflight=2)
    i0 = ch.try_push(TransferCmd(Op.WRITE, 0, 0, 0, 0, 16, 0))
    i1 = ch.try_push(TransferCmd(Op.WRITE, 0, 0, 1, 0, 16, 0))
    assert ch.try_push(TransferCmd(Op.WRITE, 0, 0, 2, 0, 16, 0)) is None
    assert not ch.check_completion(i0)
    ch.pop()
    assert ch.check_completion(i0) and not ch.check_completion(i1)


def test_fifo_cached_head_limits_pcie_reads():
    """The producer's cached head means far fewer 'PCIe' reads than pushes."""
    ch = FifoChannel(k_max_inflight=64)
    for i in range(64):
        ch.push(TransferCmd(Op.WRITE, 0, 0, i, 0, 16, 0))
    assert ch.pcie_reads <= 1


def test_fifo_push_deadline_is_absolute():
    """Blocking pushes against a stalled consumer fail at ONE absolute
    deadline — the seed reset the 10 s timeout on every wait cycle (and
    `push` recursed unboundedly), so a consumer draining one slot per
    wake-up could extend the 'timeout' forever."""
    import time as _time
    from repro.core.transport.fifo import pack_cmds as _pack

    ch = FifoChannel(k_max_inflight=2)
    ch.push(TransferCmd(Op.WRITE, 0, 0, 0, 0, 16, 0))
    ch.push(TransferCmd(Op.WRITE, 0, 0, 1, 0, 16, 0))

    # a consumer that frees exactly one slot per wait cycle: each pop wakes
    # the producer, which under per-cycle timeouts would never expire
    stop = threading.Event()

    def dribble():
        while not stop.is_set():
            _time.sleep(0.05)
            ch.pop()

    th = threading.Thread(target=dribble, daemon=True)
    th.start()
    try:
        t0 = _time.monotonic()
        with pytest.raises(TimeoutError):
            # 10 rows can never fit within 0.25 s at ~1 slot / 50 ms
            ch.push_batch(_pack(int(Op.WRITE), 0, 0, np.arange(10), 0, 16, 0),
                          timeout=0.25)
        elapsed = _time.monotonic() - t0
        assert elapsed < 2.0, f"deadline extended: {elapsed:.2f}s"
    finally:
        stop.set()
        th.join(timeout=2)

    ch2 = FifoChannel(k_max_inflight=1)
    ch2.push(TransferCmd(Op.WRITE, 0, 0, 0, 0, 16, 0))
    t0 = _time.monotonic()
    with pytest.raises(TimeoutError):
        ch2.push(TransferCmd(Op.WRITE, 0, 0, 1, 0, 16, 0), timeout=0.1)
    assert _time.monotonic() - t0 < 2.0


def test_unpack_cmds_columnar_matches_scalar_codec():
    """The columnar decoder's column row i must equal the fields the
    scalar TransferCmd.unpack produces for the same 128-bit descriptor."""
    rng = np.random.default_rng(3)
    n = 64
    words = pack_cmds(rng.integers(1, 6, n), rng.integers(0, 1 << 12, n),
                      rng.integers(0, 256, n), rng.integers(0, 1 << 32, n),
                      rng.integers(0, 1 << 32, n), rng.integers(0, 1 << 20, n),
                      rng.integers(0, 1 << 12, n), rng.integers(0, 256, n))
    cols = unpack_cmds(words)
    for i in range(n):
        cmd = TransferCmd.unpack(words[i])
        assert (int(cols.op[i]), int(cols.dst_rank[i]), int(cols.channel[i]),
                int(cols.src_off[i]), int(cols.dst_off[i]),
                int(cols.length[i]), int(cols.value[i]),
                int(cols.flags[i])) == \
            (int(cmd.op), cmd.dst_rank, cmd.channel, cmd.src_off,
             cmd.dst_off, cmd.length, cmd.value, cmd.flags)


def test_fifo_check_completion_batch():
    """One locked head read answers a whole index window."""
    ch = FifoChannel(k_max_inflight=8)
    idxs = [ch.push(TransferCmd(Op.WRITE, 0, 0, i, 0, 16, 0))
            for i in range(5)]
    assert not ch.check_completion_batch(idxs).any()
    ch.pop()
    ch.pop()
    np.testing.assert_array_equal(ch.check_completion_batch(idxs),
                                  [True, True, False, False, False])
    # agrees with the scalar probe on every index
    for i in idxs:
        assert ch.check_completion(i) == bool(
            ch.check_completion_batch([i])[0])


# ------------------------------------------------------ immediate data ----
@given(ch=st.integers(0, 7), seq=st.integers(0, 2047),
       val=st.integers(0, (1 << 16) - 1),
       kind=st.sampled_from([ImmKind.WRITE, ImmKind.SEQ_ATOMIC,
                             ImmKind.BARRIER]))
def test_imm_codec_roundtrip(ch, seq, val, kind):
    imm = pack_imm(kind, ch, seq, val)
    assert 0 <= imm < 2 ** 32
    assert unpack_imm(imm) == (kind, ch, seq, val)


@given(ch=st.integers(0, 7), count=st.integers(0, (1 << 21) - 1))
def test_imm_codec_fence_wide_count(ch, count):
    """Fences trade the (unused) seq field for a 21-bit write count — the
    seed's 6-bit field silently corrupted any bucket larger than 63."""
    imm = pack_imm(ImmKind.FENCE_ATOMIC, ch, 0, count)
    assert 0 <= imm < 2 ** 32
    assert unpack_imm(imm) == (ImmKind.FENCE_ATOMIC, ch, 0, count)


# ------------------------------------------------------- guard table ------
def test_guard_table_resolves_ranges_and_rejects_overlap():
    gt = GuardTable()
    gt.register(100, 50, 7)
    gt.register(0, 100, 3)
    gt.register(1000, 8, 9)
    assert gt.resolve(0) == 3 and gt.resolve(99) == 3
    assert gt.resolve(100) == 7 and gt.resolve(149) == 7
    assert gt.resolve(150) is None and gt.resolve(999) is None
    assert gt.resolve(1000) == 9 and gt.resolve(1008) is None
    with pytest.raises(ProtocolError):
        gt.register(140, 20, 11)          # overlaps [100, 150)


def test_guard_table_resolve_batch_matches_scalar():
    """The vectorized searchsorted resolve agrees with the bisect resolve
    on every offset (registered, unregistered, boundaries), including
    registrations made after a resolve (cache invalidation) and the empty
    table."""
    gt = GuardTable()
    assert (gt.resolve_batch([0, 5, 100]) == -1).all()
    gt.register(100, 50, 7)
    gt.register(0, 100, 3)
    offs = np.array([0, 50, 99, 100, 149, 150, 999, 1000, 1007, 1008])

    def scalar():
        return [-1 if gt.resolve(int(o)) is None else gt.resolve(int(o))
                for o in offs]
    np.testing.assert_array_equal(gt.resolve_batch(offs), scalar())
    gt.register(1000, 8, 9)               # invalidates the cached arrays
    np.testing.assert_array_equal(gt.resolve_batch(offs), scalar())


# --------------------------------------------------- control buffer -------
def _bucket_guards(n_buckets=8, bucket_bytes=64):
    """One registered receive bucket per guard id (gid g covers
    [g*bucket_bytes, (g+1)*bucket_bytes))."""
    gt = GuardTable()
    for g in range(n_buckets):
        gt.register(g * bucket_bytes, bucket_bytes, g)
    return gt


@settings(max_examples=60, deadline=None)
@given(data=st.data(), n_writes=st.integers(1, 20), seed=st.integers(0, 9999))
def test_fence_atomic_never_applies_early(data, n_writes, seed):
    """LL fence: for ANY delivery permutation, the fence atomic applies
    after >= X writes landed in its registered bucket range."""
    rng = np.random.default_rng(seed)
    gt = _bucket_guards()
    gid, bucket = 3, 64
    writes = [("w", pack_imm(ImmKind.WRITE, ch % 8, s, 0),
               gid * bucket + (s * 4) % bucket)
              for s, ch in enumerate(range(n_writes))]
    fence = ("a", pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, n_writes), gid)
    events = writes + [fence]
    perm = rng.permutation(len(events))
    cb = ControlBuffer(guards=gt)
    applied = []
    for i in perm:
        kind, imm, off = events[i]
        if kind == "w":
            cb.on_write(imm, lambda: applied.append("w"), off)
        else:
            cb.on_atomic(imm, lambda: applied.append("A"), guard=off)
    assert applied.count("w") == n_writes
    assert applied.count("A") == 1
    # the fence applied only after all n_writes writes
    assert applied.index("A") >= n_writes


def test_fence_ignores_writes_outside_registered_ranges():
    """A write landing in unregistered memory (e.g. the combine return
    region) must never satisfy a fence guard."""
    gt = _bucket_guards(n_buckets=2)
    cb = ControlBuffer(guards=gt)
    applied = []
    # two writes into unregistered space, one into bucket 1
    cb.on_write(pack_imm(ImmKind.WRITE, 0, 0, 0), lambda: None, 5000)
    cb.on_write(pack_imm(ImmKind.WRITE, 0, 1, 0), lambda: None, 6000)
    cb.on_atomic(pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, 1),
                 lambda: applied.append("A"), guard=1)
    assert not applied and cb.n_held == 1
    cb.on_write(pack_imm(ImmKind.WRITE, 0, 2, 0), lambda: None, 64)
    assert applied == ["A"] and cb.n_held == 0


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 9999), n=st.integers(2, 24))
def test_seq_atomics_apply_in_channel_order(seed, n):
    """HT partial ordering: per-channel seq atomics apply in sequence order
    regardless of arrival order; cross-channel order is unconstrained."""
    rng = np.random.default_rng(seed)
    events = []
    for ch in (0, 1):
        for s in range(n):
            kind = "w" if s % 2 == 0 else "a"
            ik = ImmKind.WRITE if kind == "w" else ImmKind.SEQ_ATOMIC
            events.append((kind, ch, s, pack_imm(ik, ch, s, 0)))
    perm = rng.permutation(len(events))
    cb = ControlBuffer()
    applied = []
    for i in perm:
        kind, ch, s, imm = events[i]
        if kind == "w":
            cb.on_write(imm, lambda ch=ch, s=s: applied.append((ch, s)))
        else:
            cb.on_atomic(imm, lambda ch=ch, s=s: applied.append((ch, s)))
    assert len(applied) == len(events)
    for ch in (0, 1):
        atomics = [s for c, s in applied if c == ch and s % 2 == 1]
        # each atomic s applied only after everything < s on its channel
        seen = set()
        for c, s in applied:
            if c != ch:
                continue
            if s % 2 == 1:      # atomic
                assert seen >= set(range(s)), (s, seen)
            seen.add(s)
    assert cb.n_held == 0


# ------------------------------------------------ end-to-end EP protocol --
@pytest.mark.parametrize("mode", ["rc", "srd"])
def test_ep_protocol_matches_oracle(mode):
    rng = np.random.default_rng(1)
    R, E, K, D, F, Tl = 4, 8, 3, 16, 24, 10
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.2).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=7, reorder_window=64))
    out = w.run(x, ti, tw, wg, wu, wd)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    if mode == "srd":
        held = max(p.stats["held_max"] for p in w.proxies)
        assert held >= 0      # control buffer exercised (may be 0 on lucky order)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_ep_protocol_property_random_routing(seed):
    rng = np.random.default_rng(seed)
    R, E, K, D, F, Tl = 2, 4, 2, 8, 8, 6
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.3).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.3).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.3).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode="srd", seed=seed, reorder_window=16))
    out = w.run(x, ti, tw, wg, wu, wd)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def _problem(seed, R, E, K, D, F, Tl):
    # the one seeded EP-problem generator, shared with the transport benches
    from benchmarks.common import make_ep_problem
    return make_ep_problem(seed, R, E, K, D, F, Tl, scale=0.2)


@pytest.mark.parametrize("mode", ["rc", "srd"])
def test_ll_fence_counts_beyond_63(mode):
    """Regression for the 6-bit fence-count truncation: buckets holding
    >= 64 tokens must fence (and therefore combine) correctly.  The seed
    packed min(count, 63) into the immediate, so a 100-token bucket's guard
    passed ~40 writes early under reorder."""
    from repro.core.plan import make_world_plan

    R, E, K, D, F, Tl = 2, 2, 2, 8, 8, 96
    x, ti, tw, wg, wu, wd = _problem(11, R, E, K, D, F, Tl)
    assert int(make_world_plan(ti, E, Tl * K).counts.max()) >= 64
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=5, reorder_window=128))
    out = w.run(x, ti, tw, wg, wu, wd)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def test_ll_combine_writes_cannot_satisfy_dispatch_fences():
    """Regression: combine writes share the per-peer ControlBuffer with that
    peer's own dispatch writes.  They land in the return region, which is
    NOT in the registered bucket table — were they attributed to a dispatch
    guard, an early expert's combine stream would inflate writes_seen and
    let a fence pass before its dispatch bucket is complete.  Crossed
    routing makes one expert finish (and start combining) while the other's
    dispatches are in flight; a huge reorder window lets combines overtake
    them."""
    R, E, K, D, F, Tl = 2, 2, 1, 256, 8, 32
    rng = np.random.default_rng(6)
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = np.zeros((R, Tl, K), np.int32)
    ti[0] = 1
    tw = np.ones((R, Tl, K), np.float32)
    wg = (rng.standard_normal((E, D, F)) * 0.05).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.05).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.05).astype(np.float32)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    for seed in range(8):
        w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F,
                    capacity=Tl * K,
                    net_cfg=NetConfig(mode="srd", seed=seed,
                                      reorder_window=500))
        out = w.run(x, ti, tw, wg, wu, wd)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


# ------------------------------------- >63 experts/rank (DeepSeek-V3 EP) --
@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("n_ranks", [2, 4])
def test_ep_256_experts_matches_oracle(mode, n_ranks):
    """256 routed experts at EP degree 2 and 4 (64 and 128 experts per rank
    — the DeepSeek-V3-class regime the paper targets): both LL and HT match
    the dense oracle on ordered and unordered transports.  The seed could
    not represent this at all (``eps < 64`` assert; 6-bit wire slot aliased
    expert e onto guard e % 64) — guards are now keyed by registered
    address ranges, so there is no experts-per-rank ceiling."""
    R, E, K, D, F, Tl = n_ranks, 256, 4, 8, 8, 8
    x, ti, tw, wg, wu, wd = _problem(21, R, E, K, D, F, Tl)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=3, reorder_window=64))
    assert w.eps >= 64          # the regime the seed's codec excluded
    out = w.run(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F,
                net_cfg=NetConfig(mode=mode, seed=4, reorder_window=64))
    out = w.run_ht(x, ti, tw, wg, wu, wd, n_chunks=2)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------- HT mode on the substrate --
@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("n_chunks", [1, 4])
def test_ht_protocol_matches_oracle(mode, n_chunks):
    """Chunked dedup'd dispatch + hierarchical reduce, executed literally on
    the substrate (SEQ_ATOMIC chunk boundaries), matches the dense oracle
    under both ordered and unordered delivery."""
    R, E, K, D, F, Tl = 4, 8, 3, 16, 24, 12
    x, ti, tw, wg, wu, wd = _problem(2, R, E, K, D, F, Tl)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F,
                net_cfg=NetConfig(mode=mode, seed=7, reorder_window=64))
    out = w.run_ht(x, ti, tw, wg, wu, wd, n_chunks=n_chunks)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    assert w.ht_dropped == 0


def test_ht_generic_expert_fn_matches_oracle():
    """The grouped (E, N, D) expert_fn contract works per HT bucket too."""
    from repro.core.transport.ep_executor import np_grouped_swiglu

    R, E, K, D, F, Tl = 2, 4, 2, 8, 8, 8
    x, ti, tw, wg, wu, wd = _problem(3, R, E, K, D, F, Tl)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D,
                net_cfg=NetConfig(mode="srd", seed=1))
    out = w.run_ht(x, ti, tw, n_chunks=2,
                   expert_fn=lambda t: np_grouped_swiglu(t, wg, wu, wd))
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


# ----------------------------------- pipelined dispatch/compute overlap ---
@pytest.mark.parametrize("protocol", ["ll", "ht"])
def test_compute_overlaps_dispatch_on_event_clock(protocol):
    """The pipelined state machine launches expert FFN for a ready bucket
    while other buckets' dispatch writes are still in flight: on the event
    clock, the first compute must start before the last dispatch write is
    delivered."""
    R, E, K, D, F, Tl = 4, 16, 4, 16, 16, 32
    x, ti, tw, wg, wu, wd = _problem(4, R, E, K, D, F, Tl)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode="srd", seed=9, reorder_window=32))
    if protocol == "ll":
        out = w.run(x, ti, tw, wg, wu, wd)
    else:
        out = w.run_ht(x, ti, tw, wg, wu, wd, n_chunks=4)
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    tl = w.timeline
    assert tl["first_compute_us"] is not None
    assert tl["first_compute_us"] < tl["last_dispatch_write_us"], tl
    assert tl["overlap_us"] > 0.0


# --------------------------------------------- SRD reorder-window stress --
@pytest.mark.parametrize("protocol", ["ll", "ht"])
def test_srd_reorder_window_sweep(protocol):
    """Exactness under growing reorder pressure: every window size matches
    the dense oracle bit-for-bit-in-float, and the receiver control buffer
    holds more guarded atomics as the window widens."""
    R, E, K, D, F, Tl = 4, 8, 4, 8, 8, 24
    held_by_window = {}
    for window in (1, 16, 256):
        held = 0
        for seed in (0, 1, 2):
            x, ti, tw, wg, wu, wd = _problem(seed, R, E, K, D, F, Tl)
            w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F,
                        capacity=Tl * K,
                        net_cfg=NetConfig(mode="srd", seed=seed,
                                          reorder_window=window))
            if protocol == "ll":
                out = w.run(x, ti, tw, wg, wu, wd)
            else:
                out = w.run_ht(x, ti, tw, wg, wu, wd, n_chunks=4)
            ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
            held += sum(p.stats["held_max"] for p in w.proxies)
        held_by_window[window] = held
    assert held_by_window[16] >= held_by_window[1], held_by_window
    assert held_by_window[256] >= held_by_window[16], held_by_window
    assert held_by_window[256] > held_by_window[1], held_by_window


# ------------------------------------------------- network event queue ----
def _rand_msgs(rng, n, n_ranks=4):
    out = []
    for _ in range(n):
        size = int(rng.integers(0, 3))
        payload = None if size == 0 else \
            rng.integers(0, 256, size * 64).astype(np.uint8)
        src = int(rng.integers(0, n_ranks))
        dst = int(rng.integers(0, n_ranks))
        out.append(Message(src=src, dst=dst, qp=0,
                           kind="imm" if payload is None else "write",
                           dst_off=0, payload=payload, imm=0))
    return out


@pytest.mark.parametrize("mode", ["rc", "srd"])
def test_network_send_batch_matches_sequential_sends(mode):
    """send_batch must schedule bit-identically to N send() calls: same
    link serialization recurrence, same jitter draws in the same order,
    same heap order — so a batched sender is indistinguishable on the
    wire from a scalar one."""
    rng = np.random.default_rng(11)
    for trial in range(4):
        msgs = _rand_msgs(rng, int(rng.integers(2, 90)))
        import copy
        a_net = Network(NetConfig(mode=mode, seed=5), 4, threadsafe=False)
        b_net = Network(NetConfig(mode=mode, seed=5), 4, threadsafe=False)
        a_msgs = [copy.copy(m) for m in msgs]
        for m in a_msgs:
            a_net.send(m)
        b_msgs = [copy.copy(m) for m in msgs]
        b_net.send_batch(b_msgs)
        assert [m.deliver_t for m in a_msgs] == \
            [m.deliver_t for m in b_msgs]
        assert a_net._link_free == b_net._link_free
        a_got, b_got = [], []
        a_net.register(0, a_got.append)
        b_net.register(0, b_got.append)
        for r in range(1, 4):
            a_net.register(r, a_got.append)
            b_net.register(r, b_got.append)
        a_net.flush()
        b_net.flush()
        assert [(m.src, m.dst, m.deliver_t) for m in a_got] == \
            [(m.src, m.dst, m.deliver_t) for m in b_got]


def test_network_deliver_ready_pops_whole_frontier():
    """Every event sharing the frontier timestamp is delivered by ONE
    deliver_ready call; later timestamps wait for the next call."""
    net = Network(NetConfig(mode="rc"), n_ranks=3, threadsafe=False)
    got = []
    net.register(1, got.append)
    net.register(2, got.append)
    # same size from two different links to two receivers: identical
    # serialization + latency => identical arrival timestamps
    net.send(Message(src=0, dst=1, qp=0, kind="imm", dst_off=0,
                     payload=None, imm=0))
    net.send(Message(src=0, dst=2, qp=0, kind="imm", dst_off=1,
                     payload=None, imm=0))
    big = np.zeros(4096, np.uint8)
    net.send(Message(src=0, dst=1, qp=0, kind="write", dst_off=2,
                     payload=big, imm=0))
    assert net.deliver_ready() == 2 and len(got) == 2
    assert {m.dst_off for m in got} == {0, 1}
    assert net.deliver_ready() == 1 and len(got) == 3
    assert net.deliver_ready() == 0


def test_coalesced_write_message_unrolls_at_receiver():
    """A contiguous run drained through the columnar proxy goes on the
    wire as ONE message carrying an immediate vector; the receiver lands
    the payload in one copy, counts every sub-write toward its guard, and
    the fence gated on those writes still fires exactly once."""
    net = Network(NetConfig(mode="rc"), n_ranks=2, threadsafe=False)
    mem0, mem1 = SymmetricMemory.create(4096), SymmetricMemory.create(4096)
    p0 = Proxy(0, net, mem0, n_channels=2)
    p1 = Proxy(1, net, mem1, n_channels=2)
    p1.register_region(1024, 256, guard_id=5)
    rng = np.random.default_rng(0)
    mem0.data[:256] = rng.integers(0, 256, 256)
    n = 8
    words = pack_cmds(int(Op.WRITE), 1, 0, np.arange(n) * 32,
                      1024 + np.arange(n) * 32, 32, 0)
    fence = pack_cmds(int(Op.ATOMIC), 1, 0, n, 5, 0, 0, FLAG_FENCE)
    p0.channels[0].try_push_batch(np.concatenate([words, fence]))
    p0.drain_inline()
    assert net.pending == 2                  # one coalesced write + fence
    net.flush()
    assert net.coalesced_msgs == 1 and net.coalesced_writes == n
    np.testing.assert_array_equal(mem1.data[1024:1024 + n * 32],
                                  mem0.data[:n * 32])
    assert p1.ctrl[0].writes_seen[5] == n
    assert mem1.counters[5] == 1             # the fence applied once
    assert p1.ctrl[0].n_held == 0


def test_wire_header_accounting_exact():
    """Exact-count pin of the serialization model's metadata charges: every
    message pays hdr_bytes, and a coalesced message additionally pays
    sub_hdr_bytes for each imm_vec/sub_off entry beyond the first —
    coalescing amortizes the message header, never the per-write metadata.
    (The seed charged coalesced runs a single flat header, undercounting
    the wire by 16 bytes per extra sub-write.)"""
    def run(coalesce):
        net = Network(NetConfig(mode="rc"), n_ranks=2, threadsafe=False)
        mem0 = SymmetricMemory.create(4096)
        mem1 = SymmetricMemory.create(4096)
        p0 = Proxy(0, net, mem0, n_channels=2, coalesce=coalesce)
        p1 = Proxy(1, net, mem1, n_channels=2)
        p1.register_region(1024, 256, guard_id=5)
        n = 8
        words = pack_cmds(int(Op.WRITE), 1, 0, np.arange(n) * 32,
                          1024 + np.arange(n) * 32, 32, 0)
        p0.channels[0].try_push_batch(words)
        p0.drain_inline()
        net.flush()
        return net

    cfg = NetConfig()
    a = run(coalesce=False)
    assert a.bytes_moved == 8 * 32
    assert a.hdr_bytes_moved == 8 * cfg.hdr_bytes
    assert a.wire_bytes_moved == 8 * 32 + 8 * 64
    b = run(coalesce=True)
    assert b.bytes_moved == 8 * 32               # payload bytes unchanged
    assert b.coalesced_msgs == 1 and b.coalesced_writes == 8
    assert b.hdr_bytes_moved == cfg.hdr_bytes + 7 * cfg.sub_hdr_bytes
    assert b.wire_bytes_moved == 8 * 32 + 64 + 7 * 16
    # the coalescing win is exactly (n-1) * (hdr - sub_hdr) metadata bytes,
    # and the modeled serialization time shrinks with it
    assert a.wire_bytes_moved - b.wire_bytes_moved == 7 * (64 - 16)
    assert b.clock_us < a.clock_us


def test_network_flush_honors_step_bound():
    """flush(steps=N) delivers at most N events (the seed accepted and
    silently ignored the parameter); flush() still drains completely."""
    net = Network(NetConfig(mode="rc"), n_ranks=2, threadsafe=False)
    got = []
    net.register(1, got.append)
    for i in range(10):
        net.send(Message(src=0, dst=1, qp=0, kind="imm", dst_off=i,
                         payload=None, imm=0))
    assert net.flush(steps=3) == 3
    assert len(got) == 3 and net.pending == 7
    assert net.flush(steps=0) == 0 and len(got) == 3
    assert net.flush() == 7
    assert len(got) == 10 and net.pending == 0


def test_network_threadsafe_concurrent_send_and_quiesce():
    """Threaded-mode stress for the locked pending/next_event_t readers:
    worker threads send() while the main thread steps and polls the
    quiesce condition — no lost events, no races, heap drains to zero."""
    n_threads, per_thread = 4, 200
    net = Network(NetConfig(mode="srd", seed=3, reorder_window=32),
                  n_ranks=2, threadsafe=True)
    got = []
    net.register(1, got.append)
    done = threading.Event()

    def sender(tid):
        for i in range(per_thread):
            net.send(Message(src=0, dst=1, qp=tid % 4, kind="imm",
                             dst_off=tid * per_thread + i, payload=None,
                             imm=0))

    threads = [threading.Thread(target=sender, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()

    def drain():
        # the quiesce loop shape: poll pending/next_event_t between steps
        while not (done.is_set() and net.pending == 0):
            t = net.next_event_t()
            assert t is None or t >= 0.0
            _ = net.pending
            if not net.step():
                pass
    dr = threading.Thread(target=drain)
    dr.start()
    for th in threads:
        th.join(timeout=10)
    done.set()
    dr.join(timeout=10)
    assert not dr.is_alive()
    assert len(got) == n_threads * per_thread
    assert sorted(m.dst_off for m in got) == \
        list(range(n_threads * per_thread))
    assert net.pending == 0
