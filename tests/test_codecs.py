"""Descriptor codec tests (ISSUE 1 satellite): the 128-bit TransferCmd and
32-bit immediate layouts at every field-boundary value, plus the vectorized
batch codec, so the wire formats can't silently regress."""
import numpy as np
import pytest

from repro.core.transport.fifo import (FLAG_FENCE, FifoChannel, Op,
                                       TransferCmd, pack_cmds)
from repro.core.transport.semantics import (ImmKind, ProtocolError, pack_imm,
                                            unpack_imm)

# field boundary values: (dst_rank, channel, src_off, dst_off, length,
# value, flags) at zero, max, and a mid pattern
CMD_BOUNDARY_CASES = [
    dict(dst_rank=0, channel=0, src_off=0, dst_off=0, length=0, value=0,
         flags=0),
    dict(dst_rank=4095, channel=255, src_off=0xFFFFFFFF, dst_off=0xFFFFFFFF,
         length=0xFFFFF, value=0xFFF, flags=0xFF),
    dict(dst_rank=2048, channel=128, src_off=0x80000000, dst_off=0x7FFFFFFF,
         length=0x80000, value=0x800, flags=FLAG_FENCE),
    dict(dst_rank=1, channel=7, src_off=0xDEADBEEF, dst_off=0x12345678,
         length=1, value=1, flags=0),
]


@pytest.mark.parametrize("op", list(Op))
@pytest.mark.parametrize("fields", CMD_BOUNDARY_CASES)
def test_transfercmd_roundtrip_boundaries(op, fields):
    cmd = TransferCmd(op=op, **fields)
    words = cmd.pack()
    assert words.dtype == np.uint32 and words.nbytes == 16   # 128 bits
    assert TransferCmd.unpack(words) == cmd


def test_transfercmd_fields_do_not_bleed():
    """Max-ing one field must leave every other field zero."""
    base = dict(dst_rank=0, channel=0, src_off=0, dst_off=0, length=0,
                value=0, flags=0)
    maxes = dict(dst_rank=4095, channel=255, src_off=0xFFFFFFFF,
                 dst_off=0xFFFFFFFF, length=0xFFFFF, value=0xFFF, flags=0xFF)
    for name, mx in maxes.items():
        cmd = TransferCmd(op=Op.WRITE, **{**base, name: mx})
        back = TransferCmd.unpack(cmd.pack())
        assert getattr(back, name) == mx, name
        for other in maxes:
            if other != name:
                assert getattr(back, other) == 0, (name, other)


def test_pack_cmds_matches_scalar_pack():
    """The vectorized (N, 4) batch codec is bit-identical to per-command
    TransferCmd.pack, including at field boundaries."""
    rng = np.random.default_rng(0)
    n = 257
    ops = rng.choice([int(o) for o in Op], n)
    dst = rng.integers(0, 4096, n)
    ch = rng.integers(0, 256, n)
    so = rng.integers(0, 2 ** 32, n, dtype=np.uint64)
    do = rng.integers(0, 2 ** 32, n, dtype=np.uint64)
    ln = rng.integers(0, 2 ** 20, n)
    val = rng.integers(0, 2 ** 12, n)
    fl = rng.integers(0, 256, n)
    words = pack_cmds(ops, dst, ch, so, do, ln, val, fl)
    assert words.shape == (n, 4) and words.dtype == np.uint32
    for i in range(n):
        ref = TransferCmd(op=Op(int(ops[i])), dst_rank=int(dst[i]),
                          channel=int(ch[i]), src_off=int(so[i]),
                          dst_off=int(do[i]), length=int(ln[i]),
                          value=int(val[i]), flags=int(fl[i])).pack()
        np.testing.assert_array_equal(words[i], ref)


def test_pack_cmds_broadcasts_scalars():
    words = pack_cmds(int(Op.WRITE), 3, np.arange(5), 0, np.arange(5) * 64,
                      64, 0)
    assert words.shape == (5, 4)
    for i in range(5):
        c = TransferCmd.unpack(words[i])
        assert (c.op, c.dst_rank, c.channel, c.dst_off, c.length) == \
            (Op.WRITE, 3, i, i * 64, 64)


def test_fifo_push_batch_roundtrip_with_wraparound():
    """Bulk push through a small ring: every descriptor pops out in order
    and bit-identical, across multiple wraparounds."""
    ch = FifoChannel(k_max_inflight=16)
    n = 100
    words = pack_cmds(int(Op.WRITE), 1, 0, np.arange(n), np.arange(n) * 2,
                      64, 0)
    popped = []
    done = 0
    while done < n:
        done += ch.try_push_batch(words[done:])
        while True:
            got = ch.pop()
            if got is None:
                break
            popped.append(got[1])
    assert len(popped) == n
    for i, cmd in enumerate(popped):
        assert cmd.src_off == i and cmd.dst_off == 2 * i


# sequence-carrying kinds: kind(2) | channel(3) | seq(11) | value(16) —
# no expert slot on the wire; fence guards are keyed by registered address
# ranges at the receiver (DESIGN.md §12)
@pytest.mark.parametrize("kind", [ImmKind.WRITE, ImmKind.SEQ_ATOMIC,
                                  ImmKind.BARRIER])
@pytest.mark.parametrize("ch,seq,val", [
    (0, 0, 0), (7, 2047, (1 << 16) - 1), (1, 1024, 1), (7, 1, 512),
])
def test_imm_codec_roundtrip_boundaries(kind, ch, seq, val):
    imm = pack_imm(kind, ch, seq, val)
    assert 0 <= imm < 2 ** 32
    assert unpack_imm(imm) == (kind, ch, seq, val)


# fences carry no sequence: kind(2) | channel(3) | count(21) | unused(6)
@pytest.mark.parametrize("ch,count", [
    (0, 0), (7, (1 << 21) - 1), (3, 64), (1, 1 << 20),
])
def test_imm_codec_fence_roundtrip_boundaries(ch, count):
    imm = pack_imm(ImmKind.FENCE_ATOMIC, ch, 0, count)
    assert 0 <= imm < 2 ** 32
    assert unpack_imm(imm) == (ImmKind.FENCE_ATOMIC, ch, 0, count)


def test_imm_codec_rejects_out_of_range():
    # explicit ProtocolError raises, not asserts: the wire contract must
    # survive ``python -O`` (ISSUE 9)
    with pytest.raises(ProtocolError):
        pack_imm(ImmKind.WRITE, 8, 0, 0)          # channel > 3 bits
    with pytest.raises(ProtocolError):
        pack_imm(ImmKind.WRITE, 0, 2048, 0)       # seq > 11 bits
    with pytest.raises(ProtocolError):
        pack_imm(ImmKind.WRITE, 0, 0, 1 << 16)    # value > 16 bits
    with pytest.raises(ProtocolError):
        pack_imm(ImmKind.FENCE_ATOMIC, 0, 1, 0)         # fences carry no seq
    with pytest.raises(ProtocolError):
        pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, 1 << 21)   # count > 21 bits
