"""Low-precision wire dispatch (ISSUE 6): codec, quantize-pack kernels,
and loss parity of compressed dispatch against the dense fp32 oracles.

Tolerance notes (documented contract, DESIGN.md §14):

- **fp8-e4m3**: 3 mantissa bits -> worst-case relative quantization error
  of 2^-4 = 6.25% per element *of its block's absmax* (plus the fp32->fp16
  pre-rounding, which is negligible at these magnitudes).  After the
  expert FFN and the weighted combine, empirical end-to-end error stays
  under 5% of the output range; the tests pin 20% as a loud-failure bound.
- **int8**: symmetric 8-bit -> <= 1/254 of block absmax per element
  (~0.4%); end-to-end bound pinned at 5% of output range.
- **fp32**: passthrough, bit-exact.

Parity between the numpy codec, the jnp ref, and the Pallas kernel bodies
is *bit-exact* by construction: the wire rounding contract is fp32 ->
fp16 -> fp8-e4m3 (RTNE at both steps) and scales are computed as
``absmax * (1/qmax)`` with a pre-rounded fp32 reciprocal in every dialect
(XLA strength-reduces division-by-constant to a reciprocal multiply;
doing it explicitly keeps numpy and XLA on the same floats).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import WIRE_BLOCK, wire_layout
from repro.core.transport.codec import (WIRE_DTYPES, dequantize_blocked,
                                        get_codec, quantize_blocked)
from repro.kernels import ops as kops
from repro.kernels.quantize_pack import (gather_quantize_pallas,
                                         gather_quantize_ref)

# end-to-end loss-parity bounds vs the dense fp32 oracle (see module doc)
E2E_TOL = {"fp32": 0.0, "fp8": 0.2, "int8": 0.05}
# elementwise roundtrip bounds relative to each block's absmax
RT_TOL = {"fp8": 0.0625 + 1e-3, "int8": 1.0 / 254 + 1e-4}


# ================================================================ codec ==
def test_wire_layout_math():
    assert wire_layout(1024, "fp32").token_bytes == 4096
    wl = wire_layout(1024, "fp8")
    assert (wl.token_bytes, wl.q_bytes, wl.n_blocks) == (1024 + 32, 1024, 8)
    wl = wire_layout(200, "int8")    # ragged last block
    assert (wl.token_bytes, wl.n_blocks) == (200 + 8, 2)
    assert wire_layout(8, "fp8").token_bytes == 12
    with pytest.raises(ValueError):
        wire_layout(8, "fp16")


@pytest.mark.parametrize("wdt", ["fp8", "int8"])
@pytest.mark.parametrize("d", [8, 128, 200, 1024])
def test_quantize_roundtrip_bounded(wdt, d):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((16, d)) * 10 ** rng.uniform(
        -2, 2, (16, 1))).astype(np.float32)
    q, s = quantize_blocked(x, wdt)
    y = dequantize_blocked(q, s)
    nb = -(-d // WIRE_BLOCK)
    pad = nb * WIRE_BLOCK - d
    xb = np.pad(x, ((0, 0), (0, pad))).reshape(16, nb, WIRE_BLOCK)
    absmax = np.abs(xb).max(-1)                       # (16, nb)
    err = np.abs(np.pad(y, ((0, 0), (0, pad))).reshape(16, nb, WIRE_BLOCK)
                 - xb).max(-1)
    assert (err <= RT_TOL[wdt] * np.maximum(absmax, 1e-30)).all()


def test_quantize_zero_rows_exact():
    x = np.zeros((4, 200), np.float32)
    for wdt in ("fp8", "int8"):
        q, s = quantize_blocked(x, wdt)
        assert (np.asarray(q, np.float32) == 0).all()
        np.testing.assert_array_equal(dequantize_blocked(q, s), x)


@pytest.mark.parametrize("wdt", ["fp8", "int8"])
@pytest.mark.parametrize("d", [8, 128, 200, 1024])
def test_quantize_np_jnp_bit_parity(wdt, d):
    """The numpy codec (substrate) and the jnp ref (jax path) must agree
    bit-for-bit — the wire bytes are the protocol, not an approximation."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((32, d)).astype(np.float32)
    qn, sn = quantize_blocked(x, wdt)
    qj, sj = quantize_blocked(jnp.asarray(x), wdt)
    np.testing.assert_array_equal(
        np.ascontiguousarray(qn).view(np.uint8),
        np.ascontiguousarray(np.asarray(qj)).view(np.uint8))
    np.testing.assert_array_equal(sn, np.asarray(sj))
    np.testing.assert_array_equal(
        dequantize_blocked(qn, sn),
        np.asarray(dequantize_blocked(qj, sj)))


@pytest.mark.parametrize("wdt", WIRE_DTYPES)
@pytest.mark.parametrize("d", [8, 200, 1024])
def test_codec_encode_decode_roundtrip(wdt, d):
    codec = get_codec(wdt)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((8, d)).astype(np.float32)
    buf = codec.encode(x)
    assert buf.dtype == np.uint8
    assert buf.shape == (8, codec.wire_bytes(d))
    assert codec.wire_bytes(d) == wire_layout(d, wdt).token_bytes
    y = codec.decode(buf, d)
    if wdt == "fp32":
        np.testing.assert_array_equal(y, x)
    else:
        q, s = quantize_blocked(x, wdt)
        np.testing.assert_array_equal(y, dequantize_blocked(q, s))


def test_get_codec_unknown():
    with pytest.raises(ValueError):
        get_codec("fp16")


# =============================================================== kernels ==
def _gq_problem(seed, e, c, d, t):
    rng = np.random.default_rng(seed)
    x_ext = np.concatenate([rng.standard_normal((t, d)).astype(np.float32),
                            np.zeros((1, d), np.float32)], 0)
    counts = rng.integers(0, c + 1, e).astype(np.int32)
    src = np.full((e * c,), t, np.int32)
    for g in range(e):
        src[g * c:g * c + counts[g]] = rng.integers(0, t, counts[g])
    return x_ext, src, counts


@pytest.mark.parametrize("wdt", ["fp8", "int8"])
@pytest.mark.parametrize("e,c,d,t", [(4, 6, 200, 11), (2, 16, 128, 9)])
def test_gather_quantize_kernel_parity(wdt, e, c, d, t):
    """Pallas kernel (interpret mode) == jnp ref == numpy codec, bit-exact,
    including occupancy zeroing of unoccupied slots."""
    x_ext, src, counts = _gq_problem(3, e, c, d, t)
    qr, sr = gather_quantize_ref(x_ext, src, counts, wire_dtype=wdt)
    qk, sk = gather_quantize_pallas(jnp.asarray(x_ext), jnp.asarray(src),
                                    jnp.asarray(counts), wire_dtype=wdt,
                                    bm=8, interpret=True)
    np.testing.assert_array_equal(
        np.ascontiguousarray(qr).view(np.uint8),
        np.ascontiguousarray(np.asarray(qk)).view(np.uint8))
    np.testing.assert_array_equal(sr, np.asarray(sk))
    # unoccupied slots are exact zeros with zero scales
    occ = np.zeros((e * c,), bool)
    for g in range(e):
        occ[g * c:g * c + counts[g]] = True
    assert (np.asarray(qk, np.float32)[~occ] == 0).all()
    assert (np.asarray(sk)[~occ] == 0).all()


def test_ops_gather_quantize_mode_parity():
    """The ops-level wrapper: ref and interpret modes agree bit-for-bit,
    and dequantize_tokens round-trips both."""
    x_ext, src, counts = _gq_problem(4, 3, 8, 200, 7)
    for wdt in ("fp8", "int8"):
        qr, sr = kops.gather_quantize(jnp.asarray(x_ext), jnp.asarray(src),
                                      jnp.asarray(counts), wire_dtype=wdt,
                                      mode="ref")
        qi, si = kops.gather_quantize(jnp.asarray(x_ext), jnp.asarray(src),
                                      jnp.asarray(counts), wire_dtype=wdt,
                                      mode="interpret")
        np.testing.assert_array_equal(
            np.ascontiguousarray(np.asarray(qr)).view(np.uint8),
            np.ascontiguousarray(np.asarray(qi)).view(np.uint8))
        np.testing.assert_array_equal(np.asarray(sr), np.asarray(si))
        yr = kops.dequantize_tokens(qr, sr, mode="ref")
        yi = kops.dequantize_tokens(qi, si, mode="interpret")
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yi))


def test_kernel_bytes_match_codec_encode():
    """The kernel's packed output is byte-identical to codec.encode of the
    gathered rows — the substrate and jax paths put the SAME bytes on the
    wire (modulo layout: kernel returns (q, scales) planes, codec packs
    rows; compare after packing)."""
    d = 200
    x_ext, src, counts = _gq_problem(5, 2, 8, d, 9)
    for wdt in ("fp8", "int8"):
        codec = get_codec(wdt)
        q, s = gather_quantize_ref(x_ext, src, counts, wire_dtype=wdt)
        wl = wire_layout(d, wdt)
        packed = np.zeros((q.shape[0], wl.token_bytes), np.uint8)
        packed[:, :wl.q_bytes] = np.ascontiguousarray(q).view(np.uint8)
        packed[:, wl.q_bytes:] = np.ascontiguousarray(s).view(np.uint8)
        buf = x_ext[src]
        occ = np.zeros((len(src),), bool)
        for g in range(2):
            occ[g * 8:g * 8 + counts[g]] = True
        buf = np.where(occ[:, None], buf, 0.0).astype(np.float32)
        np.testing.assert_array_equal(packed, codec.encode(buf))


# ====================================================== loss parity (e2e) ==
def _substrate_case(proto, wdt, seed=0, d=64):
    from repro.core.transport import EPWorld, NetConfig
    rng = np.random.default_rng(seed)
    R, eps, K, F, Tl = 2, 4, 2, 16, 8
    E = R * eps
    x = rng.standard_normal((R, Tl, d)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, d, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, d, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, d)) * 0.2).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=d, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode="srd", seed=seed), wire_dtype=wdt)
    out = (w.run(x, ti, tw, wg, wu, wd) if proto == "ll"
           else w.run_ht(x, ti, tw, wg, wu, wd, n_chunks=2))
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
    return out, ref, w


@pytest.mark.parametrize("proto", ["ll", "ht"])
@pytest.mark.parametrize("wdt", WIRE_DTYPES)
def test_substrate_loss_parity(proto, wdt):
    """Compressed dispatch through the full transport substrate vs the
    dense fp32 oracle, within the documented tolerance for the dtype."""
    out, ref, _ = _substrate_case(proto, wdt)
    if wdt == "fp32":
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    else:
        err = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err <= E2E_TOL[wdt], f"{proto}/{wdt} relerr {err:.4f}"


@pytest.mark.parametrize("wdt", ["fp8", "int8"])
def test_substrate_compression_reduces_payload(wdt):
    """Honest wire accounting: the compressed run's dispatch payload bytes
    are the fp32 run's scaled by wb/4d (exactly — same message schedule)."""
    d = 64
    _, _, w32 = _substrate_case("ll", "fp32", d=d)
    _, _, wq = _substrate_case("ll", wdt, d=d)
    p32 = w32.timeline["dispatch_payload_bytes"]
    pq = wq.timeline["dispatch_payload_bytes"]
    wb = wire_layout(d, wdt).token_bytes
    assert p32 > 0 and pq * 4 * d == p32 * wb
    assert wq.timeline["dispatch_wire_bytes"] > pq


@pytest.mark.parametrize("mode", ["ll", "ht"])
@pytest.mark.parametrize("wdt", ["fp8", "int8"])
def test_jax_dispatch_loss_parity(mode, wdt):
    """jax-collectives compressed dispatch vs moe_ref (single-shard mesh:
    collectives degenerate, quantize/dequantize still on the path)."""
    from jax.sharding import AxisType, PartitionSpec as P
    from repro.core.ep import (EPSpec, dispatch_combine_ht,
                               dispatch_combine_ll, moe_ref)
    from repro.kernels.ref import grouped_swiglu_ref
    t, d, f, e, k = 32, 200, 24, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (t, d))
    ti = jax.random.randint(ks[1], (t, k), 0, e).astype(jnp.int32)
    tw = jax.nn.softmax(jax.random.normal(ks[2], (t, k)), -1)
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[4], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[5], (e, f, d)) * 0.2
    mesh = jax.make_mesh((1,), ("model",), axis_types=(AxisType.Auto,))
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, dtype=jnp.float32, wire_dtype=wdt,
                  chunks=2 if mode == "ht" else 1)
    fn = dispatch_combine_ll if mode == "ll" else dispatch_combine_ht

    def island(x, ti, tw, wg, wu, wd):
        r = fn(spec, x, ti, tw, lambda tk: grouped_swiglu_ref(tk, wg, wu, wd))
        return r.out, r.aux["dropped"]

    out, dropped = jax.jit(jax.shard_map(
        island, mesh=mesh, in_specs=(P(),) * 6, out_specs=(P(), P()),
        check_vma=False))(x, ti, tw, wg, wu, wd)
    assert float(dropped) == 0.0
    ref = np.asarray(moe_ref(x, ti, tw, wg, wu, wd))
    err = np.abs(np.asarray(out) - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err <= E2E_TOL[wdt], f"{mode}/{wdt} relerr {err:.4f}"


def test_distributed_compression_delegates_to_codec():
    """distributed.compression is a thin wrapper over the transport codec
    (one quantizer in the repo): its int8 chunks must round-trip through
    the same blocked math."""
    from repro.distributed.compression import BLOCK, dequantize, quantize
    rng = np.random.default_rng(6)
    g = rng.standard_normal(1000).astype(np.float32)
    c = quantize(jnp.asarray(g))
    y = np.asarray(dequantize(c, g.size))
    nb = -(-g.size // BLOCK)
    xb = np.pad(g, (0, nb * BLOCK - g.size)).reshape(nb, BLOCK)
    q, s = quantize_blocked(xb, "int8", block=BLOCK)
    np.testing.assert_array_equal(np.asarray(c.q), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(c.scale), np.asarray(s[:, 0]))
    err = np.abs(y - g).max()
    assert err <= np.abs(xb).max() / 100


@pytest.mark.parametrize("wdt", ["fp8", "int8"])
def test_moe_apply_wire_dtype_reaches_backend(wdt):
    """Config seam regression: ``cfg.moe.wire_dtype`` must reach the EPSpec
    on the no-dist simulated path (it was silently dropped once).  The
    compressed run must differ from fp32 (compression actually engaged)
    while staying within the documented tolerance of the ref oracle."""
    import dataclasses

    from repro.configs import get_config, reduced_config
    from repro.core.moe import moe_apply, moe_init

    cfg = reduced_config(get_config("qwen2_moe_a2_7b"), n_layers=2,
                         d_model=256, n_experts=4)
    p = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 256), jnp.float32)
    y_ref, _ = moe_apply(cfg, None, p, x, mode="ref")
    cfg_q = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, wire_dtype=wdt))
    y_q, _ = moe_apply(cfg_q, None, p, x, mode="ll",
                       backend="simulated_rdma")
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-9
    err = float(jnp.max(jnp.abs(y_q - y_ref))) / scale
    assert 0.0 < err <= E2E_TOL[wdt], f"{wdt} relerr {err:.4f}"
