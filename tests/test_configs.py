"""Config sanity: all 10 assigned archs load, param counts land in the
ballpark their names claim, shape-cell applicability matches DESIGN.md."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_configs, cells_for, get_config

# (arch, min_params, max_params) — total params, loose public-ballpark bands
BANDS = {
    "moonshot_v1_16b_a3b": (14e9, 30e9),     # assigned 48L variant is larger
    "qwen2_moe_a2_7b": (12e9, 17e9),
    "qwen3_1_7b": (1.4e9, 2.4e9),
    "phi3_medium_14b": (12e9, 16e9),
    "qwen2_72b": (68e9, 76e9),
    "qwen3_4b": (3.2e9, 5.0e9),
    "internvl2_26b": (17e9, 26e9),           # LM backbone only (ViT stubbed)
    "musicgen_large": (2.5e9, 4.0e9),
    "falcon_mamba_7b": (6.0e9, 8.5e9),
    "jamba_1_5_large_398b": (350e9, 430e9),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.arch_id == arch
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_band(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    lo, hi = BANDS[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_active_params_moe():
    cfg = get_config("moonshot_v1_16b_a3b")
    act = cfg.active_param_count()
    assert act < cfg.param_count() / 3          # top-6 of 64 is sparse
    dense = get_config("qwen2_72b")
    assert dense.active_param_count() == dense.param_count()


def test_long_500k_applicability():
    runs_long = {a for a in ARCH_IDS if "long_500k" in cells_for(get_config(a))}
    assert runs_long == {"falcon_mamba_7b", "jamba_1_5_large_398b"}


def test_alias_lookup():
    assert get_config("qwen3-1.7b").arch_id == "qwen3_1_7b"


def test_shapes_table():
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288
    assert SHAPES["decode_32k"].is_decode


def test_jamba_period_structure():
    cfg = get_config("jamba_1_5_large_398b")
    attn_layers = [i for i in range(cfg.n_layers) if cfg.is_attn_layer(i)]
    assert len(attn_layers) == cfg.n_layers // 8      # 1:7 interleave
    moe_layers = [i for i in range(cfg.n_layers) if cfg.is_moe_layer(i)]
    assert len(moe_layers) == cfg.n_layers // 2       # MoE every 2nd


def test_padded_vocab_divisible():
    for arch, cfg in all_configs().items():
        assert cfg.padded_vocab() % 16 == 0, arch
