"""Online expert re-placement + rank-degradation recovery (DESIGN.md §15).

Covers the expert-level elasticity path: the sliding-window LoadBalancer,
greedy re-placement, weight migration through the transport substrate
(coalesced, fenced bulk writes), and the degraded-rank drill — a
FailureInjector kills a rank mid-run, the SAME re-placement code path moves
its experts onto the survivors, and the post-recovery world must quiesce
cleanly and agree with the dense oracle.
"""
import numpy as np
import pytest

from repro.core import plan as planlib
from repro.core.transport.ep_executor import EPWorld, np_grouped_swiglu
from repro.core.transport.simulator import NetConfig
from repro.distributed.elastic import (LoadBalancer, MigrationStats,
                                       migrate_expert_weights)
from repro.distributed.fault import FailureInjector


def _weights(rng, e, d, f):
    wg = rng.standard_normal((e, d, f)).astype(np.float32) / np.sqrt(d)
    wu = rng.standard_normal((e, d, f)).astype(np.float32) / np.sqrt(d)
    wd = rng.standard_normal((e, f, d)).astype(np.float32) / np.sqrt(f)
    return wg, wu, wd


def _pack_rows(wg, wu, wd):
    """(E, Wb) uint8 checkpoint rows: each logical expert's wg|wu|wd."""
    e = wg.shape[0]
    flat = np.concatenate([wg.reshape(e, -1), wu.reshape(e, -1),
                           wd.reshape(e, -1)], axis=1).astype(np.float32)
    return np.ascontiguousarray(flat).view(np.uint8).reshape(e, -1)


def _unpack_tables(tables, d, f):
    """(R, eps, Wb) uint8 -> physical (wg, wu, wd) stacked over slots."""
    r, eps, wb = tables.shape
    rows = tables.reshape(r * eps, wb).view(np.float32)
    n = d * f
    wg = rows[:, :n].reshape(-1, d, f)
    wu = rows[:, n:2 * n].reshape(-1, d, f)
    wd = rows[:, 2 * n:].reshape(-1, f, d)
    return wg, wu, wd


# ================================================== LoadBalancer policy ==
class TestLoadBalancer:
    def test_initial_placement_covers_all_experts(self):
        lb = LoadBalancer(n_logical=8, n_ranks=4, slots_per_rank=3)
        p = lb.placement
        assert p.n_physical == 12
        assert set(np.asarray(p.phys_to_logical)) == set(range(8))
        assert int(p.n_replicas.sum()) == 12

    def test_no_replace_below_threshold(self):
        lb = LoadBalancer(n_logical=8, n_ranks=4, slots_per_rank=2,
                          interval=1, threshold=1.25)
        lb.observe(np.ones(8))
        assert lb.maybe_replace() is None

    def test_no_replace_off_interval(self):
        lb = LoadBalancer(n_logical=8, n_ranks=4, slots_per_rank=4,
                          interval=4, threshold=1.0)
        skew = np.array([100.0, 1, 1, 1, 1, 1, 1, 1])
        for i in range(1, 4):
            lb.observe(skew)
            assert lb.maybe_replace() is None, i   # steps 1..3: off cadence
        lb.observe(skew)
        assert lb.maybe_replace() is not None      # step 4: due + skewed

    def test_hot_expert_gets_most_replicas(self):
        lb = LoadBalancer(n_logical=8, n_ranks=4, slots_per_rank=4,
                          interval=1, threshold=1.0)
        lb.observe(np.array([100.0, 1, 1, 1, 1, 1, 1, 1]))
        new = lb.maybe_replace()
        assert new is not None
        reps = np.asarray(new.n_replicas)
        assert reps[0] == reps.max() and reps[0] > 1
        # re-placement drops the windowed imbalance
        assert lb.imbalance() < 100.0 / (108.0 / 8)

    def test_replace_is_idempotent_on_stable_load(self):
        lb = LoadBalancer(n_logical=8, n_ranks=4, slots_per_rank=4,
                          interval=1, threshold=1.0)
        lb.observe(np.array([50.0, 1, 1, 1, 1, 1, 1, 1]))
        assert lb.maybe_replace() is not None
        lb.observe(np.array([50.0, 1, 1, 1, 1, 1, 1, 1]))
        assert lb.maybe_replace() is None          # same greedy answer

    def test_window_slides(self):
        lb = LoadBalancer(n_logical=4, n_ranks=2, slots_per_rank=2, window=2)
        lb.observe([8.0, 0, 0, 0])
        lb.observe([0.0, 4, 0, 0])
        lb.observe([0.0, 0, 2, 0])                 # evicts the first
        np.testing.assert_allclose(lb.window_load(), [0, 4, 2, 0])

    def test_degrade_shares_replacement_code_path(self):
        lb = LoadBalancer(n_logical=8, n_ranks=4, slots_per_rank=2)
        p = lb.degrade(dead_rank=2)
        assert lb.n_ranks == 3
        assert p.n_physical % 3 == 0 and p.n_physical >= 8
        assert set(np.asarray(p.phys_to_logical)) == set(range(8))


# ===================================================== weight migration ==
class TestMigration:
    def test_rows_land_correctly_with_coalescing(self):
        rng = np.random.default_rng(3)
        e, wb = 8, 1024
        w_full = rng.integers(0, 256, size=(e, wb), dtype=np.uint8)
        new = planlib.replicate_uniform(e, 2)      # 16 slots over 4 ranks
        holdings = [[0, 1], [2, 3], [4, 5], [6, 7]]
        tables, st = migrate_expert_weights(holdings, new, w_full,
                                            chunk_bytes=128)
        eps = new.n_physical // 4
        for p in range(new.n_physical):
            r, s = divmod(p, eps)
            assert np.array_equal(tables[r, s],
                                  w_full[int(new.phys_to_logical[p])])
        # chunked contiguous runs coalesce into fewer wire messages
        assert st.sub_writes == st.wire_slots * (wb // 128)
        assert st.msgs < st.sub_writes
        assert st.bytes_moved == st.wire_slots * wb
        assert st.restored_slots == 0

    def test_same_rank_moves_are_free(self):
        rng = np.random.default_rng(4)
        e, wb = 4, 256
        w_full = rng.integers(0, 256, size=(e, wb), dtype=np.uint8)
        ident = planlib.identity_placement(e)      # 4 slots over 2 ranks
        holdings = [[0, 1], [2, 3]]
        tables, st = migrate_expert_weights(holdings, ident, w_full)
        assert st.wire_slots == 0 and st.bytes_moved == 0
        assert st.local_slots == e
        np.testing.assert_array_equal(
            tables.reshape(e, wb), w_full)

    def test_restore_path_when_no_holder_survives(self):
        rng = np.random.default_rng(5)
        e, wb = 4, 512
        w_full = rng.integers(0, 256, size=(e, wb), dtype=np.uint8)
        ident = planlib.identity_placement(e)
        # nobody holds experts 2 and 3 -> checkpoint restore via rank 0
        holdings = [[0, 1], []]
        tables, st = migrate_expert_weights(holdings, ident, w_full)
        assert st.restored_slots == 2
        np.testing.assert_array_equal(tables.reshape(e, wb), w_full)

    def test_rc_and_srd_agree(self):
        rng = np.random.default_rng(6)
        e, wb = 6, 768
        w_full = rng.integers(0, 256, size=(e, wb), dtype=np.uint8)
        new = planlib.greedy_placement(
            np.array([9.0, 1, 1, 1, 1, 1]), 12, 3)
        holdings = [[0, 1], [2, 3], [4, 5]]
        outs = []
        for mode in ("rc", "srd"):
            t, st = migrate_expert_weights(
                holdings, new, w_full, chunk_bytes=64,
                net_cfg=NetConfig(mode=mode, seed=1, reorder_window=16))
            assert isinstance(st, MigrationStats) and st.clock_us > 0
            outs.append(t)
        np.testing.assert_array_equal(outs[0], outs[1])


# ========================================= degraded-rank recovery drill ==
class TestDegradedRank:
    def test_failure_injection_replace_quiesce_oracle(self):
        """Rank 2 of 4 dies mid-run (FailureInjector); survivors re-place
        the dead rank's experts via the LoadBalancer's shared code path,
        migrate weights over the substrate, and the recovered world must
        quiesce cleanly and agree with the dense oracle."""
        R0, E, K, D, F = 4, 8, 2, 16, 12
        T = 24                                    # divisible by 4 and by 3
        rng = np.random.default_rng(11)
        wg, wu, wd = _weights(rng, E, D, F)
        w_full = _pack_rows(wg, wu, wd)
        x = rng.standard_normal((T, D)).astype(np.float32)
        ti = rng.integers(0, E, size=(T, K)).astype(np.int32)
        tw = rng.random((T, K)).astype(np.float32)
        tw /= tw.sum(1, keepdims=True)
        want = EPWorld.oracle(x.reshape(1, T, D), ti.reshape(1, T, K),
                              tw.reshape(1, T, K), wg, wu, wd
                              ).reshape(T, D)

        inj = FailureInjector(at_steps=(1,))
        lb = LoadBalancer(n_logical=E, n_ranks=R0, slots_per_rank=E // R0,
                          placement=planlib.identity_placement(E))
        dead = 2
        ranks, eps0 = R0, E // R0

        def run_world(n_ranks, placement, wgp, wup, wdp):
            world = EPWorld(n_ranks=n_ranks,
                            n_experts=placement.n_physical, top_k=K, d=D,
                            capacity=(T // n_ranks) * K,
                            net_cfg=NetConfig(mode="srd", seed=7))
            tis = planlib.split_to_physical_world(
                placement, ti.reshape(n_ranks, T // n_ranks, K))
            out = world.run(
                x.reshape(n_ranks, T // n_ranks, D), tis,
                tw.reshape(n_ranks, T // n_ranks, K),
                expert_fn=lambda t, counts=None: np_grouped_swiglu(
                    t, wgp, wup, wdp, counts=counts))
            # clean quiesce: nothing in flight anywhere
            assert not world.net.pending
            assert not any(p.busy for p in world.proxies)
            return out.reshape(T, D)

        for step in range(3):
            if inj(step):
                # --- recovery: degrade onto survivors, migrate weights ----
                new = lb.degrade(dead_rank=dead)
                ranks = lb.n_ranks
                # survivors keep relative order; the dead rank's holdings
                # are gone -> its sole-replica experts hit the restore path
                survivors = [r for r in range(R0) if r != dead]
                holdings = [[r * eps0 + i for i in range(eps0)]
                            for r in survivors]
                tables, st = migrate_expert_weights(holdings, new, w_full,
                                                    chunk_bytes=256)
                assert st.restored_slots >= 1     # experts 4, 5 lost
                wgp, wup, wdp = _unpack_tables(tables, D, F)
                assert wgp.shape[0] == new.n_physical
            if ranks == R0:
                got = run_world(R0, lb.placement, wg, wu, wd)
            else:
                got = run_world(ranks, lb.placement, wgp, wup, wdp)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

        assert inj.fired == {1} and ranks == R0 - 1

    def test_degraded_world_rejects_dead_rank_traffic(self):
        """After degrade, the new placement never maps a slot onto a rank
        id >= the survivor count (renumbering invariant)."""
        lb = LoadBalancer(n_logical=8, n_ranks=4, slots_per_rank=2)
        new = lb.degrade(dead_rank=0)
        eps = new.n_physical // lb.n_ranks
        assert (np.asarray(new.logical_to_phys).max() <
                lb.n_ranks * eps)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
