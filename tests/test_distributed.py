"""Multi-device integration tests (subprocess with 8 fake CPU devices; the
main pytest process keeps 1 device per assignment rule)."""
import textwrap

import pytest

pytestmark = pytest.mark.slow


def test_ep_modes_match_oracle_8dev(dist_runner):
    out = dist_runner(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, AxisType
        from repro.core.ep import EPSpec, dispatch_combine_ll, \\
            dispatch_combine_ht, moe_ref
        from repro.kernels.ref import grouped_swiglu_ref
        E, K, D, F, T = 16, 3, 32, 48, 64
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (T, D), jnp.float32)
        ti = jax.random.randint(ks[1], (T, K), 0, E).astype(jnp.int32)
        tw = jax.nn.softmax(jax.random.normal(ks[2], (T, K)), -1)
        wg = jax.random.normal(ks[3], (E, D, F)) * 0.1
        wu = jax.random.normal(ks[4], (E, D, F)) * 0.1
        wd = jax.random.normal(ks[5], (E, F, D)) * 0.1
        ref = moe_ref(x, ti, tw, wg, wu, wd)
        for shape, axes, ep_axes, mode in [
            ((8,), ("model",), ("model",), "ll"),
            ((8,), ("model",), ("model",), "ht"),
            ((2, 4), ("pod", "model"), ("pod", "model"), "ll"),
            ((2, 4), ("pod", "model"), ("pod", "model"), "ht"),
        ]:
            mesh = jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
            sizes = tuple(mesh.shape[a] for a in ep_axes)
            spec = EPSpec(axes=ep_axes, sizes=sizes, n_experts=E, top_k=K,
                          capacity_factor=8.0,
                          chunks=2 if mode == "ht" else 1, dtype=jnp.float32)
            fn = dispatch_combine_ll if mode == "ll" else dispatch_combine_ht
            ep_p = ep_axes if len(ep_axes) > 1 else ep_axes[0]
            def island(x, ti, tw, wg, wu, wd):
                r = fn(spec, x, ti, tw,
                       lambda t: grouped_swiglu_ref(t, wg, wu, wd))
                return r.out, r.aux["dropped"]
            out, dropped = jax.jit(jax.shard_map(island, mesh=mesh,
                in_specs=(P(axes), P(axes), P(axes), P(ep_p, None, None),
                          P(ep_p, None, None), P(ep_p, None, None)),
                out_specs=(P(axes), P()), check_vma=False))(
                x, ti, tw, wg, wu, wd)
            err = float(jnp.abs(out - ref).max())
            assert err < 1e-4 and float(dropped) == 0, (axes, mode, err)
        print("EP-8DEV-OK")
    """))
    assert "EP-8DEV-OK" in out


def test_loss_parity_all_archs_8dev(dist_runner):
    out = dist_runner(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, reduced_config, ARCH_IDS
        from repro.distributed.sharding import make_dist_ctx
        from repro.models import model_zoo as Z
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        for arch in ARCH_IDS:
            cfg = reduced_config(get_config(arch), n_layers=2, d_model=64,
                                 vocab=512)
            key = jax.random.PRNGKey(0)
            params = Z.init_params(cfg, key)
            B, S = 4, 32
            tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
            labels = jnp.roll(tokens, -1, axis=1)
            pre = None
            if cfg.frontend_prefix:
                pre = jax.random.normal(key, (B, cfg.frontend_prefix,
                                              cfg.d_model), jnp.float32)
            loss1, _ = Z.loss_fn(cfg, params, tokens, labels, pre)
            dist = make_dist_ctx(cfg, mesh)
            with jax.set_mesh(mesh):
                loss2, _ = jax.jit(lambda p, t, l: Z.loss_fn(
                    cfg, p, t, l, pre, dist=dist))(params, tokens, labels)
            d = abs(float(loss1) - float(loss2))
            # MoE archs compare capacity-bucketed bf16 dispatch against the
            # dense oracle path: summation order differs -> wider tolerance.
            # The exact drift varies with the jax/XLA version's reduction
            # order (0.054 on qwen2_moe under jax 0.4.37; the dispatch path
            # itself is bit-identical to the seed implementation there)
            tol = 8e-2 if cfg.moe.enabled else 2e-2
            assert d < tol and np.isfinite(float(loss2)), (arch, d)
        print("PARITY-OK")
    """, ), timeout=1800)
    assert "PARITY-OK" in out


def test_dist_decode_matches_forward(dist_runner):
    out = dist_runner(textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, reduced_config
        from repro.distributed.sharding import make_dist_ctx
        from repro.models import model_zoo as Z
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        for arch in ["qwen3_1_7b", "jamba_1_5_large_398b"]:
            cfg = reduced_config(get_config(arch), n_layers=2, d_model=64,
                                 vocab=512)
            cfg = dataclasses.replace(cfg, dtype="float32", remat=False)
            key = jax.random.PRNGKey(0)
            params = Z.init_params(cfg, key)
            B, S = 4, 16
            tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
            h, _ = Z.forward(cfg, Z.cast_params(params, jnp.float32), tokens)
            ref = h[:, -1] @ Z.lm_head_weight(
                cfg, Z.cast_params(params, jnp.float32))
            dist = make_dist_ctx(cfg, mesh)
            cache = Z.init_cache(cfg, B, max_len=32, dtype=jnp.float32)
            with jax.set_mesh(mesh):
                step = jax.jit(lambda p, c, t, i: Z.decode_step(
                    cfg, p, c, t, i, dist=dist))
                for t in range(S):
                    logits, cache = step(params, cache, tokens[:, t:t+1], t)
            err = float(jnp.abs(logits - ref).max())
            assert err < 1e-3, (arch, err)
        print("DECODE-OK")
    """), timeout=1200)
    assert "DECODE-OK" in out


def test_compressed_reduce_8dev(dist_runner):
    out = dist_runner(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.distributed.compression import (BLOCK, ef_compressed_mean,
                                                   pad_to_ring)
        mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        P = 8
        rng = np.random.default_rng(0)
        n = P * BLOCK * 2
        g = jnp.asarray(rng.standard_normal((P, n)), jnp.float32)
        true_mean = np.asarray(g).mean(0)
        mean, res = ef_compressed_mean(g, mesh, "data")
        err = np.abs(np.asarray(mean) - true_mean).max()
        scale = np.abs(true_mean).max()
        assert err < 0.05 * scale + 0.05, err
        # error feedback: residuals carry the quantisation error; a second
        # identical round with residuals reduces the accumulated bias
        mean2, _ = ef_compressed_mean(g, mesh, "data", residual=res)
        two_step = (np.asarray(mean) + np.asarray(mean2)) / 2
        base_err = np.abs(np.asarray(mean) - true_mean).mean()
        ef_err = np.abs(two_step - true_mean).mean()
        assert ef_err <= base_err * 1.05
        print("COMPRESS-OK", err)
    """), timeout=600)
    assert "COMPRESS-OK" in out


def test_elastic_remesh_8_to_4(dist_runner):
    out = dist_runner(textwrap.dedent("""
        import jax, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, reduced_config
        from repro.data.pipeline import DataConfig, data_iterator
        from repro.distributed.elastic import plan_remesh, reshard_state
        from repro.distributed.sharding import make_dist_ctx
        from repro.launch.mesh import make_bench_mesh
        from repro.training.train_loop import HParams, train_loop
        cfg = reduced_config(get_config("moonshot_v1_16b_a3b"), n_layers=2,
                             d_model=64, n_experts=8, vocab=256)
        hp = HParams(peak_lr=1e-3, total_steps=20, warmup=2, loss_chunk=32,
                     moe_mode="ht")
        dc = DataConfig(vocab_size=cfg.vocab_size, batch=8, seq_len=32, seed=0)
        mesh8 = make_bench_mesh(8, model=4)
        dist8 = make_dist_ctx(cfg, mesh8)
        state, h1 = train_loop(cfg, hp, dist8, data_iterator(dc), steps=10,
                               log_every=0, log_fn=lambda s: None)
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              axis_types=(AxisType.Auto,) * 2,
                              devices=jax.devices()[:4])
        plan = plan_remesh(cfg, dist8, mesh4)
        assert plan.ep_degree_old == 4 and plan.ep_degree_new == 2
        state4, dist4 = reshard_state(cfg, state, mesh4)
        state4, h2 = train_loop(cfg, hp, dist4, data_iterator(dc, 10),
                                steps=20, state=state4, log_every=0,
                                log_fn=lambda s: None)
        l1 = h1[-1]["loss"]; l2 = h2[-1]["loss"]
        assert np.isfinite(l2) and l2 <= l1 + 0.3, (l1, l2)
        print("ELASTIC-OK", l1, l2)
    """), timeout=1200)
    assert "ELASTIC-OK" in out
