"""Train-loop behaviour: loss decreases, checkpoint/restart recovery after an
injected failure, watchdog straggler detection, router bias balancing."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced_config
from functools import partial

from repro.data.pipeline import DataConfig, synth_batch
from repro.distributed.fault import FailureInjector
from repro.training.train_loop import (HParams, Watchdog, init_state,
                                       train_loop)


def _cfg(arch="qwen3_1_7b", **kw):
    return reduced_config(get_config(arch), n_layers=2, d_model=64,
                          vocab=256, **kw)


def test_loss_decreases():
    cfg = _cfg()
    hp = HParams(peak_lr=1e-2, total_steps=80, warmup=5, loss_chunk=64)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=4, seq_len=64, seed=0)
    _, hist = train_loop(cfg, hp, None, partial(synth_batch, dc), steps=80,
                         log_every=0, log_fn=lambda s: None)
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(l) for l in losses)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    cfg = _cfg()
    hp = HParams(peak_lr=1e-3, total_steps=30, warmup=2, loss_chunk=32)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=32, seed=0)
    ck = Checkpointer(tmp_path)
    inj = FailureInjector(at_steps=(17,))
    logs = []
    _, hist = train_loop(cfg, hp, None, partial(synth_batch, dc), steps=30,
                         checkpointer=ck, ckpt_every=10, log_every=0,
                         fail_injector=inj, log_fn=logs.append)
    assert any("simulated failure" in l for l in logs)
    assert any("restored checkpoint" not in l for l in logs)
    # the loop replayed steps 10..16 after restoring the step-10 checkpoint
    assert len(hist) > 30 - 10
    assert inj.fired == {17}


def test_failure_without_progress_loss_is_deterministic(tmp_path):
    """Resume determinism: the data pipeline is a pure function of step, so
    re-running a step after restore yields the identical loss."""
    cfg = _cfg()
    hp = HParams(peak_lr=1e-3, total_steps=12, warmup=1, loss_chunk=32)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=32, seed=1)
    ck = Checkpointer(tmp_path)
    inj = FailureInjector(at_steps=(11,))
    _, hist = train_loop(cfg, hp, None, partial(synth_batch, dc), steps=12,
                         checkpointer=ck, ckpt_every=10, log_every=0,
                         fail_injector=inj, log_fn=lambda s: None)
    # step 10 ran twice (before failure at 11 and again after restore)
    losses_by_rerun = [h["loss"] for h in hist]
    assert len(losses_by_rerun) == 13           # 12 steps + 1 replay
    assert abs(losses_by_rerun[10] - losses_by_rerun[11]) < 1e-5


def test_watchdog_flags_stragglers():
    wd = Watchdog(deadline_s=100.0, straggler_factor=2.0)
    for i in range(10):
        assert wd.observe(i, 1.0) is None
    ev = wd.observe(10, 5.0)
    assert ev is not None and ev.kind == "straggler"
    ev2 = wd.observe(11, 1000.0)
    assert ev2.kind == "failure"


def test_router_bias_moves_during_training():
    cfg = _cfg("moonshot_v1_16b_a3b")
    hp = HParams(peak_lr=1e-3, total_steps=10, warmup=1, loss_chunk=32,
                 router_bias_lr=1e-2, moe_mode="ref")
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=32, seed=0)
    state, _ = train_loop(cfg, hp, None, partial(synth_batch, dc), steps=10,
                          log_every=0, log_fn=lambda s: None)
    b = np.asarray(state.params["blocks"]["slot0"]["moe"]["router_b"])
    assert np.abs(b).max() > 0                 # bias updated by sign rule
