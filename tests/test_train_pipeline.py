"""ISSUE 8 acceptance: the persistent-session training-step pipeline.

Covers the pipeline from the substrate up through the jax train step:

- Network timers (``call_at``) interleave with deliveries in timestamp order
  (the primitive the comm/backward overlap model is built on).
- A persistent session re-used across consecutive MoE layers is
  bit-identical to isolated per-layer ``dispatch_combine`` calls on the
  scalar-oracle drain path (``columnar=False``), including step reuse
  (the wrap back to layer 0).
- ``run_step_pipelined`` keeps bit-identical outputs vs ``run_step_serial``
  while collapsing the per-step proxy drains from 2L to 1 and finishing
  earlier on the event clock.
- Train-step loss/grad parity for ``moe_mode`` in {ref, ll, ht} through the
  jax_collectives backend, and forward-loss parity through simulated_rdma
  (the host substrate cannot be differentiated, so forward-only there).
- The model-level session path (one backend instance shared by all MoE
  layers) matches fresh-per-layer backends bit-exactly.
- Watchdog's incremental median matches the brute-force
  ``sorted(history)[len // 2]`` reference decision-for-decision.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AxisType

from repro.configs import get_config, reduced_config
from repro.core.backend import get_backend
from repro.core.ep import EPSpec
from repro.core.transport.ep_executor import EPWorld, np_grouped_swiglu
from repro.core.transport.simulator import NetConfig, Network
from repro.models import model_zoo as Z
from repro.training.train_loop import Watchdog


# ------------------------------------------------------------ helpers -----
def _ep_problem(seed, R, E, K, D, F, Tl):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, (R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg, wu, wd = ((rng.standard_normal(sh) * 0.2).astype(np.float32)
                  for sh in ((E, D, F), (E, D, F), (E, F, D)))
    return x, ti, tw, wg, wu, wd


def _small_moe_cfg(**moe_over):
    cfg = reduced_config(get_config("moonshot_v1_16b_a3b"), n_layers=2,
                         d_model=64, n_experts=8, vocab=256)
    return dataclasses.replace(
        cfg, dtype="float32", remat=False,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0, **moe_over))


def _batch(cfg, seed=1, B=2, S=16):
    key = jax.random.PRNGKey(seed)
    return (jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                               cfg.vocab_size))


# -------------------------------------------------- event-clock timers ----
def test_network_timers_fire_in_timestamp_order():
    net = Network(NetConfig(mode="srd", seed=0), n_ranks=2, threadsafe=False)
    fired = []
    net.call_at(5.0, lambda: fired.append("late"))
    net.call_at(1.0, lambda: fired.append("a"))
    net.call_at(1.0, lambda: fired.append("b"))       # FIFO at equal t
    while net.pending:
        net.deliver_ready()
    assert fired == ["a", "b", "late"]
    assert net.clock_us == 5.0
    # a timer in the past clamps to "now" rather than rewinding the clock
    net.advance(10.0)
    net.call_at(3.0, lambda: fired.append("clamped"))
    net.deliver_ready()
    assert fired[-1] == "clamped" and net.clock_us == 15.0


# ------------------------------------- session reuse, scalar oracle -------
@pytest.mark.parametrize("mode", ["ll", "ht"])
def test_session_reuse_bit_identical_scalar_oracle(mode):
    """Two consecutive MoE layers through ONE persistent session must be
    bit-identical to two isolated dispatch_combine calls, on the scalar
    TransferCmd drain (the conformance-oracle path); a third call wraps to
    layer 0 (a new step) and must reproduce layer 0's result bit-exactly."""
    R, E, K, T = 2, 8, 2, 32
    spec = EPSpec(axes=("sim",), sizes=(R,), n_experts=E, top_k=K,
                  mode=mode, chunks=2)
    probs = []
    for layer in range(2):
        x, ti, tw, wg, wu, wd = _ep_problem(10 + layer, 1, E, K, 16, 24, T)
        fn = (lambda toks, counts=None, w=(wg, wu, wd):
              np_grouped_swiglu(toks, *w, counts=counts))
        probs.append((x[0], ti[0], tw[0], fn))

    sess = get_backend("simulated_rdma", columnar=False, session_layers=2)
    outs_sess = [sess.dispatch_combine(spec, x, ti, tw, fn).out
                 for x, ti, tw, fn in probs]
    outs_iso = [get_backend("simulated_rdma", columnar=False)
                .dispatch_combine(spec, x, ti, tw, fn).out
                for x, ti, tw, fn in probs]
    for got, want in zip(outs_sess, outs_iso):
        np.testing.assert_array_equal(got, want)
    # wrap: third call is layer 0 of step 2 on cleared (not re-registered)
    # session state
    x, ti, tw, fn = probs[0]
    np.testing.assert_array_equal(
        sess.dispatch_combine(spec, x, ti, tw, fn).out, outs_iso[0])


# ------------------------------ pipelined vs serial step (substrate) ------
def test_pipelined_step_matches_serial_and_batches_drains():
    R, L = 2, 2
    E, K, D, F, Tl = 8, 2, 8, 12, 16
    xs, tis, tws = [], [], []
    wg = wu = wd = None
    for layer in range(L):
        x, ti, tw, wg, wu, wd = _ep_problem(layer, R, E, K, D, F, Tl)
        xs.append(x)
        tis.append(ti)
        tws.append(tw)
    kw = dict(nonmoe_fwd_us=20.0, nonmoe_bwd_us=40.0)

    def session():
        return EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F,
                       capacity=Tl * K, net_cfg=NetConfig(mode="srd", seed=0),
                       session=True, n_layers=L, mirror=True)

    ws = session()
    outs_s = ws.run_step_serial(xs, tis, tws, wg, wu, wd, **kw)
    wp = session()
    outs_p = wp.run_step_pipelined(xs, tis, tws, wg, wu, wd, **kw)
    for a, b in zip(outs_s, outs_p):
        np.testing.assert_array_equal(a, b)
    # the whole point: L forward + L mirrored backward drains collapse to 1
    assert ws.timeline["drains_per_step"] == 2 * L
    assert wp.timeline["drains_per_step"] == 1
    assert ws.timeline["cmds_per_step"] == wp.timeline["cmds_per_step"]
    assert wp.timeline["step_us"] < ws.timeline["step_us"]
    assert not ws.net.pending and not wp.net.pending


# ----------------------------- train-step parity, jax_collectives ---------
def test_train_step_loss_and_grad_parity_jax_collectives():
    """value_and_grad of the full loss agrees across moe_mode in
    {ref, ll, ht} on a degree-1 mesh (jax_collectives backend): the EP
    machinery must be gradient-transparent, not just forward-equal."""
    from repro.distributed.sharding import make_dist_ctx

    cfg = _small_moe_cfg()
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    dist = make_dist_ctx(cfg, mesh)
    assert dist.ep_axes == ("model",)
    params = Z.init_params(cfg, jax.random.PRNGKey(0))
    tokens, labels = _batch(cfg)
    results = {}
    with jax.set_mesh(mesh):
        for mode, d in (("ref", None), ("ll", dist), ("ht", dist)):
            def lf(p, mode=mode, d=d):
                loss, _ = Z.loss_fn(cfg, p, tokens, labels, dist=d,
                                    moe_mode=mode, loss_chunk=32)
                return loss
            loss, grads = jax.jit(jax.value_and_grad(lf))(params)
            results[mode] = (float(loss), jax.tree.map(np.asarray, grads))
    loss_ref, g_ref = results["ref"]
    for mode in ("ll", "ht"):
        loss_m, g_m = results[mode]
        assert abs(loss_m - loss_ref) < 1e-3 * max(1.0, abs(loss_ref)), mode
        flat_r, _ = jax.tree.flatten(g_ref)
        flat_m, _ = jax.tree.flatten(g_m)
        assert len(flat_r) == len(flat_m)
        for a, b in zip(flat_r, flat_m):
            np.testing.assert_allclose(b, a, rtol=2e-3, atol=2e-3,
                                       err_msg=mode)


# --------------------------- forward-loss parity, simulated_rdma ----------
@pytest.mark.parametrize("mode", ["ll", "ht"])
def test_forward_loss_parity_simulated_rdma(mode):
    """The host substrate path (eager, unrolled) reproduces the dense ref
    loss — the simulated backend cannot be differentiated, so the training
    parity claim there is forward-loss equality."""
    cfg = _small_moe_cfg(ep_backend="simulated_rdma")
    params = Z.init_params(cfg, jax.random.PRNGKey(0))
    tokens, labels = _batch(cfg)
    loss_ref, _ = Z.loss_fn(cfg, params, tokens, labels, moe_mode="ref",
                            loss_chunk=32, unroll=True)
    loss_sim, _ = Z.loss_fn(cfg, params, tokens, labels, moe_mode=mode,
                            loss_chunk=32, unroll=True)
    np.testing.assert_allclose(float(loss_sim), float(loss_ref), rtol=2e-3)


def test_model_session_backend_matches_isolated():
    """One persistent backend instance shared by all MoE layers of the model
    (the DESIGN §16 session path) is bit-identical to fresh per-layer
    backends, for both protocol modes."""
    cfg = _small_moe_cfg(ep_backend="simulated_rdma")
    params = Z.init_params(cfg, jax.random.PRNGKey(0))
    tokens, labels = _batch(cfg)
    for mode in ("ll", "ht"):
        sess = get_backend("simulated_rdma", session_layers=2)
        loss_sess, _ = Z.loss_fn(cfg, params, tokens, labels, moe_mode=mode,
                                 loss_chunk=32, unroll=True,
                                 moe_backend=sess)
        loss_iso, _ = Z.loss_fn(cfg, params, tokens, labels, moe_mode=mode,
                                loss_chunk=32, unroll=True,
                                moe_backend="simulated_rdma")
        np.testing.assert_array_equal(np.asarray(loss_sess),
                                      np.asarray(loss_iso))
        assert len(sess._sessions) == 1   # one EPWorld reused across layers


# ----------------------------------------------- watchdog median ----------
def test_watchdog_incremental_median_matches_bruteforce():
    rng = np.random.default_rng(0)
    seq = rng.gamma(4.0, 0.25, 150)
    seq[rng.choice(150, 10, replace=False)] *= 5.0    # straggler spikes
    seq[77] = 60.0                                     # deadline breach
    wd = Watchdog(deadline_s=50.0, straggler_factor=2.0)
    hist: list[float] = []
    for step, e in enumerate(seq.tolist()):
        want = None
        if e > 50.0:
            want = "failure"
        elif hist and len(hist) >= 5 and e > 2.0 * sorted(hist)[len(hist) // 2]:
            want = "straggler"
        hist.append(e)
        if len(hist) > 100:
            hist.pop(0)
        got = wd.observe(step, e)
        assert (got.kind if got else None) == want, step
        assert wd._sorted == sorted(wd.history), step
    assert any(ev.kind == "failure" for ev in wd.events)
    assert any(ev.kind == "straggler" for ev in wd.events)
