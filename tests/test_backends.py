"""Cross-backend equivalence (ISSUE 1 acceptance): the jax_collectives and
simulated_rdma EP backends must match the dense oracle *and each other* on
identical routing tables — the portability claim made executable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AxisType, PartitionSpec as P

from repro.core.backend import (EPBackend, available_backends, get_backend)
from repro.core.ep import EPSpec, moe_ref
from repro.core.transport.ep_executor import np_grouped_swiglu
from repro.kernels.ref import grouped_swiglu_ref


def _mesh11():
    return jax.make_mesh((1,), ("model",), axis_types=(AxisType.Auto,))


def _problem(seed, e, k, t, d=16, f=24):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    ti = jax.random.randint(ks[1], (t, k), 0, e).astype(jnp.int32)
    tw = jax.nn.softmax(jax.random.normal(ks[2], (t, k)), -1)
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[4], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[5], (e, f, d)) * 0.2
    return x, ti, tw, wg, wu, wd


def test_registry_contents():
    names = available_backends()
    assert "jax_collectives" in names and "simulated_rdma" in names
    for n in names:
        assert isinstance(get_backend(n), EPBackend)
    with pytest.raises(KeyError):
        get_backend("no_such_transport")


@pytest.mark.parametrize("net", ["rc", "srd"])
@pytest.mark.parametrize("mode", ["ll", "ht"])
@pytest.mark.parametrize("seed,e,k,t", [(0, 8, 2, 32), (1, 4, 3, 16)])
def test_backends_match_oracle_and_each_other(mode, net, seed, e, k, t):
    """ISSUE 2 acceptance: both protocol modes, on both backends, under
    both ordered (rc) and unordered (srd) network configs, match the dense
    oracle and each other."""
    from repro.core.transport.simulator import NetConfig

    x, ti, tw, wg, wu, wd = _problem(seed, e, k, t)

    # --- jax_collectives under a degenerate (1,) mesh ---------------------
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, dtype=jnp.float32, mode=mode)
    jb = get_backend("jax_collectives")

    def island(x, ti, tw, wg, wu, wd):
        r = jb.dispatch_combine(spec, x, ti, tw,
                                lambda b: grouped_swiglu_ref(b, wg, wu, wd))
        return r.out, r.aux["dropped"]

    out_jax, dropped = jax.jit(jax.shard_map(
        island, mesh=_mesh11(), in_specs=(P(),) * 6, out_specs=(P(), P()),
        check_vma=False))(x, ti, tw, wg, wu, wd)
    assert float(dropped) == 0.0

    # --- simulated_rdma over the transport substrate, degree 4 ------------
    spec_sim = EPSpec(axes=("sim",), sizes=(4,), n_experts=e, top_k=k,
                      mode=mode, chunks=2)
    sb = get_backend("simulated_rdma",
                     net_cfg=NetConfig(mode=net, seed=seed,
                                       reorder_window=64))
    wg_n, wu_n, wd_n = (np.asarray(w, np.float32) for w in (wg, wu, wd))
    res_sim = sb.dispatch_combine(
        spec_sim, np.asarray(x), np.asarray(ti), np.asarray(tw),
        lambda toks: np_grouped_swiglu(toks, wg_n, wu_n, wd_n))

    # --- all three agree --------------------------------------------------
    ref = np.asarray(moe_ref(x, ti, tw, wg, wu, wd))
    np.testing.assert_allclose(np.asarray(out_jax), ref, rtol=3e-4,
                               atol=3e-5)
    np.testing.assert_allclose(res_sim.out, ref, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(out_jax), res_sim.out, rtol=3e-4,
                               atol=3e-5)


@pytest.mark.parametrize("mode", ["ll", "ht"])
def test_backends_occupancy_contract_equivalence(mode):
    """ISSUE 3 acceptance: with the occupancy-carrying expert_fn contract
    (counts flowing into the kernels), both backends still match the dense
    oracle and each other."""
    from repro.core.transport.simulator import NetConfig

    x, ti, tw, wg, wu, wd = _problem(5, 8, 2, 32)

    def jfn(b, counts=None):
        return grouped_swiglu_ref(b, wg, wu, wd, counts=counts)

    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=8, top_k=2,
                  capacity_factor=8.0, dtype=jnp.float32, mode=mode)
    jb = get_backend("jax_collectives")

    def island(x, ti, tw):
        return jb.dispatch_combine(spec, x, ti, tw, jfn).out

    out_jax = jax.jit(jax.shard_map(
        island, mesh=_mesh11(), in_specs=(P(),) * 3, out_specs=P(),
        check_vma=False))(x, ti, tw)

    wg_n, wu_n, wd_n = (np.asarray(w, np.float32) for w in (wg, wu, wd))
    calls = []

    def nfn(toks, counts=None):
        calls.append(counts is not None)
        return np_grouped_swiglu(toks, wg_n, wu_n, wd_n, counts=counts)

    spec_sim = EPSpec(axes=("sim",), sizes=(4,), n_experts=8, top_k=2,
                      mode=mode, chunks=2)
    sb = get_backend("simulated_rdma", net_cfg=NetConfig(mode="srd", seed=5))
    res_sim = sb.dispatch_combine(spec_sim, np.asarray(x), np.asarray(ti),
                                  np.asarray(tw), nfn)
    assert calls and all(calls)

    ref = np.asarray(moe_ref(x, ti, tw, wg, wu, wd))
    np.testing.assert_allclose(np.asarray(out_jax), ref, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(res_sim.out, ref, rtol=3e-4, atol=3e-5)


def test_moe_apply_simulated_rdma_matches_default():
    """Backend selection through the config/moe seam: the simulated_rdma
    reference path reproduces the dense-oracle MoE layer output."""
    from repro.configs import get_config, reduced_config
    from repro.core.moe import moe_apply, moe_init

    cfg = reduced_config(get_config("qwen2_moe_a2_7b"), n_layers=2,
                         d_model=32, n_experts=4)
    key = jax.random.PRNGKey(0)
    p = moe_init(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    y_ref, _ = moe_apply(cfg, None, p, x, mode="ref")
    y_sim, aux = moe_apply(cfg, None, p, x, mode="ht",
                           backend="simulated_rdma")
    np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-5)
    assert float(aux["dropped"]) == 0.0


@pytest.mark.parametrize("net", ["rc", "srd"])
@pytest.mark.parametrize("mode", ["ll", "ht"])
@pytest.mark.parametrize("factor", [1, 2, 4])
def test_backends_replicated_placement_equivalence(mode, net, factor):
    """Replicated expert groups: both backends consume the same replicated
    placement (one logical expert -> ``factor`` physical slots), split
    tokens deterministically across replicas, and still match the LOGICAL
    dense oracle — replication must be output-invariant."""
    from repro.core import plan as planlib
    from repro.core.transport.simulator import NetConfig

    e, k, t = 8, 2, 32
    x, ti, tw, wg, wu, wd = _problem(2, e, k, t)
    pl = planlib.replicate_uniform(e, factor)
    p2l = np.asarray(pl.phys_to_logical)
    # physical expert weights: slot p holds logical expert p2l[p]'s rows
    wg_p, wu_p, wd_p = wg[p2l], wu[p2l], wd[p2l]

    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, dtype=jnp.float32, mode=mode,
                  placement=tuple(int(v) for v in p2l))
    assert spec.n_physical == e * factor
    jb = get_backend("jax_collectives")

    def island(x, ti, tw):
        r = jb.dispatch_combine(
            spec, x, ti, tw,
            lambda b, counts=None: grouped_swiglu_ref(b, wg_p, wu_p, wd_p,
                                                      counts=counts))
        return r.out, r.aux["dropped"], r.aux["imbalance"]

    out_jax, dropped, imb = jax.jit(jax.shard_map(
        island, mesh=_mesh11(), in_specs=(P(),) * 3,
        out_specs=(P(), P(), P()), check_vma=False))(x, ti, tw)
    assert float(dropped) == 0.0
    assert float(imb) >= 1.0          # max/mean physical-slot load

    spec_sim = EPSpec(axes=("sim",), sizes=(4,), n_experts=e, top_k=k,
                      mode=mode, chunks=2,
                      placement=tuple(int(v) for v in p2l))
    sb = get_backend("simulated_rdma",
                     net_cfg=NetConfig(mode=net, seed=2, reorder_window=64))
    wg_n, wu_n, wd_n = (np.asarray(w, np.float32)
                        for w in (wg_p, wu_p, wd_p))
    res_sim = sb.dispatch_combine(
        spec_sim, np.asarray(x), np.asarray(ti), np.asarray(tw),
        lambda toks, counts=None: np_grouped_swiglu(toks, wg_n, wu_n, wd_n,
                                                    counts=counts))
    assert float(res_sim.aux["imbalance"]) >= 1.0
    assert res_sim.aux["load_phys"].shape == (e * factor,)

    ref = np.asarray(moe_ref(x, ti, tw, wg, wu, wd))   # LOGICAL oracle
    np.testing.assert_allclose(np.asarray(out_jax), ref, rtol=3e-4,
                               atol=3e-5)
    np.testing.assert_allclose(res_sim.out, ref, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(out_jax), res_sim.out, rtol=3e-4,
                               atol=3e-5)


@pytest.mark.parametrize("mode", ["ll", "ht"])
def test_replicas_one_is_bit_identical(mode):
    """The replicas=1 degenerate case: an identity placement must produce
    BIT-identical outputs to a placement-free spec on both backends (the
    pinned contract — replication must not perturb the existing path)."""
    from repro.core.transport.simulator import NetConfig

    e, k, t = 8, 2, 32
    x, ti, tw, wg, wu, wd = _problem(3, e, k, t)
    outs = {}
    for placement in (None, tuple(range(e))):
        spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                      capacity_factor=8.0, dtype=jnp.float32, mode=mode,
                      placement=placement)
        jb = get_backend("jax_collectives")

        def island(x, ti, tw):
            return jb.dispatch_combine(
                spec, x, ti, tw,
                lambda b, counts=None: grouped_swiglu_ref(
                    b, wg, wu, wd, counts=counts)).out

        out_jax = jax.jit(jax.shard_map(
            island, mesh=_mesh11(), in_specs=(P(),) * 3, out_specs=P(),
            check_vma=False))(x, ti, tw)

        spec_sim = EPSpec(axes=("sim",), sizes=(4,), n_experts=e, top_k=k,
                          mode=mode, chunks=2, placement=placement)
        sb = get_backend("simulated_rdma",
                         net_cfg=NetConfig(mode="srd", seed=3))
        wg_n, wu_n, wd_n = (np.asarray(w, np.float32)
                            for w in (wg, wu, wd))
        res = sb.dispatch_combine(
            spec_sim, np.asarray(x), np.asarray(ti), np.asarray(tw),
            lambda toks, counts=None: np_grouped_swiglu(
                toks, wg_n, wu_n, wd_n, counts=counts))
        outs[placement is None] = (np.asarray(out_jax), res.out)

    # bit identity, not allclose: same ops, same order, same bytes
    np.testing.assert_array_equal(outs[True][0], outs[False][0])
    np.testing.assert_array_equal(outs[True][1], outs[False][1])


def test_moe_apply_surfaces_imbalance_every_branch():
    """Satellite: aux["imbalance"] (max/mean physical-slot load) comes out
    of the ref path, the host-sim path and the backend seam alike."""
    from repro.configs import get_config, reduced_config
    from repro.core.moe import moe_apply, moe_init

    cfg = reduced_config(get_config("qwen2_moe_a2_7b"), n_layers=2,
                         d_model=32, n_experts=4)
    p = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    for kwargs in ({"mode": "ref"},
                   {"mode": "ht", "backend": "simulated_rdma"}):
        _, aux = moe_apply(cfg, None, p, x, **kwargs)
        assert float(aux["imbalance"]) >= 1.0, kwargs
