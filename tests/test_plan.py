"""Dispatch-plan layer tests: the numpy and jnp dialects must produce
bit-identical plans (they back different transport backends), and the plan
primitives must satisfy their slot/count/dedup invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as planlib


def _random_table(seed, t=48, k=3, e=8, pad_frac=0.2):
    rng = np.random.default_rng(seed)
    ti = rng.integers(0, e, size=(t, k)).astype(np.int32)
    ti[rng.random((t, k)) < pad_frac] = -1          # padded choices
    return ti


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rank_in_group_np_jnp_identical(seed):
    ti = _random_table(seed).reshape(-1)
    valid = ti >= 0
    r_np = planlib.rank_in_group(ti, 8, valid)
    r_jnp = planlib.rank_in_group(jnp.asarray(ti), 8, jnp.asarray(valid))
    np.testing.assert_array_equal(r_np[valid], np.asarray(r_jnp)[valid])


def test_rank_in_group_is_arrival_order():
    gid = np.array([2, 0, 2, 2, 0, 1], np.int32)
    valid = np.array([1, 1, 1, 0, 1, 1], bool)
    rank = planlib.rank_in_group(gid, 3, valid)
    # group 2 sees rows 0, 2 (row 3 invalid); group 0 sees rows 1, 4
    assert rank[0] == 0 and rank[2] == 1
    assert rank[1] == 0 and rank[4] == 1 and rank[5] == 0


@pytest.mark.parametrize("seed", [0, 3])
def test_make_plan_np_jnp_identical(seed):
    ti = _random_table(seed)
    cap = 6
    p_np = planlib.make_plan(ti, 8, cap)
    p_j = planlib.make_plan(jnp.asarray(ti), 8, cap)
    np.testing.assert_array_equal(p_np.counts, np.asarray(p_j.counts))
    np.testing.assert_array_equal(p_np.keep, np.asarray(p_j.keep))
    v = p_np.valid
    np.testing.assert_array_equal(p_np.rank[v], np.asarray(p_j.rank)[v])
    assert int(p_np.n_dropped) == int(p_j.n_dropped)
    # invariants: counts match valid mask; kept ranks are < capacity
    assert p_np.counts.sum() == v.sum()
    assert (p_np.rank[p_np.keep] < cap).all()


def test_make_world_plan_matches_per_rank_plans():
    rng = np.random.default_rng(7)
    R, T, K, E, cap = 3, 16, 2, 8, 5
    ti = rng.integers(0, E, size=(R, T, K)).astype(np.int32)
    wp = planlib.make_world_plan(ti, E, cap)
    for r in range(R):
        pr = planlib.make_plan(ti[r], E, cap)
        np.testing.assert_array_equal(wp.rank[r], pr.rank)
        np.testing.assert_array_equal(wp.counts[r], pr.counts)
        np.testing.assert_array_equal(wp.keep[r], pr.keep)


@pytest.mark.parametrize("seed", [0, 5])
def test_dedup_entry_table_np_jnp_identical(seed):
    t, k, g = 24, 4, 4
    rng = np.random.default_rng(seed)
    grp = rng.integers(0, g, size=(t, k)).astype(np.int32)
    valid = rng.random((t, k)) < 0.8
    grp = np.where(valid, grp, -1)
    cap = 10
    f_np, ev_np, rk_np, kp_np, dr_np = planlib.dedup_entry_table(
        grp, valid, g, cap)
    f_j, ev_j, rk_j, kp_j, dr_j = planlib.dedup_entry_table(
        jnp.asarray(grp), jnp.asarray(valid), g, cap)
    np.testing.assert_array_equal(f_np, np.asarray(f_j))
    np.testing.assert_array_equal(ev_np, np.asarray(ev_j))
    np.testing.assert_array_equal(kp_np, np.asarray(kp_j))
    np.testing.assert_array_equal(rk_np[ev_np], np.asarray(rk_j)[ev_np])
    assert int(dr_np) == int(dr_j)
    # dedup semantics: exactly one 'first' per (token, group) pair present
    for t_i in range(t):
        groups = grp[t_i][valid[t_i]]
        firsts = grp[t_i][f_np[t_i]]
        assert sorted(set(groups.tolist())) == sorted(firsts.tolist())


# ============================================== replicated placements ====
def test_identity_placement_split_is_noop():
    ti = _random_table(0)
    ident = planlib.identity_placement(8)
    assert ident.is_identity
    out = planlib.split_to_physical(ident, ti)
    assert out is ti                 # replicas=1 contract: no new ops at all


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("factor", [2, 4])
def test_split_np_jnp_identical(seed, factor):
    ti = _random_table(seed, t=64, k=3, e=8)
    pl = planlib.replicate_uniform(8, factor)
    s_np = planlib.split_to_physical(pl, ti)
    s_j = planlib.split_to_physical(pl, jnp.asarray(ti))
    np.testing.assert_array_equal(s_np, np.asarray(s_j))


@pytest.mark.parametrize("seed", [0, 4])
def test_split_preserves_logical_routing(seed):
    ti = _random_table(seed, t=64, k=3, e=8)
    pl = planlib.replicate_uniform(8, 2)
    phys = planlib.split_to_physical(pl, ti)
    v = ti >= 0
    # -1 pads pass through; valid choices land on a replica of their expert
    np.testing.assert_array_equal(phys[~v], ti[~v])
    np.testing.assert_array_equal(
        np.asarray(pl.phys_to_logical)[phys[v]], ti[v])


def test_split_round_robin_balances_replicas():
    e, reps, n = 4, 2, 40
    ti = np.tile(np.arange(e, dtype=np.int32), n).reshape(-1, 1)
    pl = planlib.replicate_uniform(e, reps)
    phys = planlib.split_to_physical(pl, ti).reshape(-1)
    counts = planlib.group_counts(phys, pl.n_physical, phys >= 0)
    # each expert's arrivals split exactly evenly across its replicas
    np.testing.assert_array_equal(counts, np.full(pl.n_physical, n // reps))


def test_split_world_matches_stacked_per_source_splits():
    rng = np.random.default_rng(9)
    R, T, K, E = 3, 16, 2, 8
    ti = rng.integers(0, E, size=(R, T, K)).astype(np.int32)
    pl = planlib.replicate_uniform(E, 2)
    world = planlib.split_to_physical_world(pl, ti)
    for r in range(R):
        np.testing.assert_array_equal(
            world[r], planlib.split_to_physical(pl, ti[r]))


def test_placement_from_table_roundtrip():
    p2l = np.array([0, 2, 1, 0, 2, 1], np.int32)
    pl = planlib.placement_from_table(p2l)
    np.testing.assert_array_equal(pl.phys_to_logical, p2l)
    np.testing.assert_array_equal(pl.n_replicas, [2, 2, 2])
    # replica order is ascending physical id
    for e in range(3):
        slots = pl.logical_to_phys[e][pl.logical_to_phys[e] >= 0]
        assert (np.diff(slots) > 0).all()
        np.testing.assert_array_equal(np.asarray(pl.phys_to_logical)[slots],
                                      e)


@pytest.mark.parametrize("n_physical,n_ranks", [(8, 4), (12, 4), (16, 4)])
def test_greedy_placement_invariants(n_physical, n_ranks):
    loads = np.array([100.0, 40, 10, 5, 2, 1, 1, 1])
    pl = planlib.greedy_placement(loads, n_physical, n_ranks)
    assert pl.n_physical == n_physical
    # every logical expert keeps at least one replica
    assert set(np.asarray(pl.phys_to_logical)) == set(range(8))
    # the hottest expert holds the (joint-)max replica count
    reps = np.asarray(pl.n_replicas)
    assert reps[0] == reps.max()
    # greedy packing stays within the LPT-style bound of the optimum's
    # lower bound (max single share, or the perfectly even split)
    share = loads[pl.phys_to_logical] / reps[pl.phys_to_logical]
    per_rank = share.reshape(n_ranks, -1).sum(1)
    opt_lb = max(share.max(), share.sum() / n_ranks)
    assert per_rank.max() <= 4.0 / 3.0 * opt_lb + 1e-9


def test_load_imbalance_math():
    assert planlib.load_imbalance(np.array([4.0, 4, 4, 4])) == 1.0
    assert planlib.load_imbalance(np.array([8.0, 0, 0, 0])) == 4.0
    assert planlib.load_imbalance(np.zeros(4)) == 1.0
    j = planlib.load_imbalance(jnp.array([8.0, 0, 0, 0]))
    assert float(j) == 4.0


def test_expert_load_matches_one_hot_sum():
    ti = _random_table(2, t=32, k=3, e=8)
    load = planlib.expert_load(jnp.asarray(ti), 8)
    ref = jnp.where(jnp.asarray(ti)[..., None] == jnp.arange(8), 1.0,
                    0.0).sum((0, 1))
    np.testing.assert_allclose(np.asarray(load), np.asarray(ref))
