"""Dispatch-plan layer tests: the numpy and jnp dialects must produce
bit-identical plans (they back different transport backends), and the plan
primitives must satisfy their slot/count/dedup invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as planlib


def _random_table(seed, t=48, k=3, e=8, pad_frac=0.2):
    rng = np.random.default_rng(seed)
    ti = rng.integers(0, e, size=(t, k)).astype(np.int32)
    ti[rng.random((t, k)) < pad_frac] = -1          # padded choices
    return ti


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rank_in_group_np_jnp_identical(seed):
    ti = _random_table(seed).reshape(-1)
    valid = ti >= 0
    r_np = planlib.rank_in_group(ti, 8, valid)
    r_jnp = planlib.rank_in_group(jnp.asarray(ti), 8, jnp.asarray(valid))
    np.testing.assert_array_equal(r_np[valid], np.asarray(r_jnp)[valid])


def test_rank_in_group_is_arrival_order():
    gid = np.array([2, 0, 2, 2, 0, 1], np.int32)
    valid = np.array([1, 1, 1, 0, 1, 1], bool)
    rank = planlib.rank_in_group(gid, 3, valid)
    # group 2 sees rows 0, 2 (row 3 invalid); group 0 sees rows 1, 4
    assert rank[0] == 0 and rank[2] == 1
    assert rank[1] == 0 and rank[4] == 1 and rank[5] == 0


@pytest.mark.parametrize("seed", [0, 3])
def test_make_plan_np_jnp_identical(seed):
    ti = _random_table(seed)
    cap = 6
    p_np = planlib.make_plan(ti, 8, cap)
    p_j = planlib.make_plan(jnp.asarray(ti), 8, cap)
    np.testing.assert_array_equal(p_np.counts, np.asarray(p_j.counts))
    np.testing.assert_array_equal(p_np.keep, np.asarray(p_j.keep))
    v = p_np.valid
    np.testing.assert_array_equal(p_np.rank[v], np.asarray(p_j.rank)[v])
    assert int(p_np.n_dropped) == int(p_j.n_dropped)
    # invariants: counts match valid mask; kept ranks are < capacity
    assert p_np.counts.sum() == v.sum()
    assert (p_np.rank[p_np.keep] < cap).all()


def test_make_world_plan_matches_per_rank_plans():
    rng = np.random.default_rng(7)
    R, T, K, E, cap = 3, 16, 2, 8, 5
    ti = rng.integers(0, E, size=(R, T, K)).astype(np.int32)
    wp = planlib.make_world_plan(ti, E, cap)
    for r in range(R):
        pr = planlib.make_plan(ti[r], E, cap)
        np.testing.assert_array_equal(wp.rank[r], pr.rank)
        np.testing.assert_array_equal(wp.counts[r], pr.counts)
        np.testing.assert_array_equal(wp.keep[r], pr.keep)


@pytest.mark.parametrize("seed", [0, 5])
def test_dedup_entry_table_np_jnp_identical(seed):
    t, k, g = 24, 4, 4
    rng = np.random.default_rng(seed)
    grp = rng.integers(0, g, size=(t, k)).astype(np.int32)
    valid = rng.random((t, k)) < 0.8
    grp = np.where(valid, grp, -1)
    cap = 10
    f_np, ev_np, rk_np, kp_np, dr_np = planlib.dedup_entry_table(
        grp, valid, g, cap)
    f_j, ev_j, rk_j, kp_j, dr_j = planlib.dedup_entry_table(
        jnp.asarray(grp), jnp.asarray(valid), g, cap)
    np.testing.assert_array_equal(f_np, np.asarray(f_j))
    np.testing.assert_array_equal(ev_np, np.asarray(ev_j))
    np.testing.assert_array_equal(kp_np, np.asarray(kp_j))
    np.testing.assert_array_equal(rk_np[ev_np], np.asarray(rk_j)[ev_np])
    assert int(dr_np) == int(dr_j)
    # dedup semantics: exactly one 'first' per (token, group) pair present
    for t_i in range(t):
        groups = grp[t_i][valid[t_i]]
        firsts = grp[t_i][f_np[t_i]]
        assert sorted(set(groups.tolist())) == sorted(firsts.tolist())
