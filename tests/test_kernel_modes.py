"""Ops-level kernel mode parity (ISSUE 3): the ``repro.kernels.ops``
wrappers must produce the same numbers in "ref" (jnp oracle) and
"interpret" (Pallas kernel body on CPU) modes, including the new
occupancy-aware counts contract — and the EP dispatch paths must deliver
counts to the expert kernels and still match the dense oracle when the
kernel bodies (not the jnp refs) execute.

``scripts/ci.sh`` runs this module under ``REPRO_KERNEL_MODE=interpret`` so
every CI run executes the Pallas kernels end-to-end, not just the refs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AxisType, PartitionSpec as P

from repro.core.ep import EPSpec, dispatch_combine_ht, dispatch_combine_ll, moe_ref
from repro.kernels import ops as kops


def _problem(seed, e, t, d, f, k):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    ti = jax.random.randint(ks[1], (t, k), 0, e).astype(jnp.int32)
    tw = jax.nn.softmax(jax.random.normal(ks[2], (t, k)), -1)
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[4], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[5], (e, f, d)) * 0.2
    return x, ti, tw, wg, wu, wd


@pytest.mark.parametrize("counts", [None, (5, 0, 20, 1)])
def test_ops_grouped_swiglu_mode_parity(counts):
    e, c, d, f = 4, 20, 16, 13
    x, _, _, wg, wu, wd = _problem(0, e, e * c, d, f, 1)
    x = x[:e * c].reshape(e, c, d)
    cnt = None if counts is None else jnp.asarray(counts, jnp.int32)
    ref = kops.grouped_swiglu(x, wg, wu, wd, cnt, mode="ref")
    got = kops.grouped_swiglu(x, wg, wu, wd, cnt, mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_ops_grouped_matmul_mode_parity():
    g, m, k, n = 3, 20, 13, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (g, m, k), jnp.float32)
    w = jax.random.normal(ks[1], (g, k, n), jnp.float32)
    cnt = jnp.array([7, 0, 20], jnp.int32)
    ref = kops.grouped_matmul(x, w, cnt, mode="ref")
    got = kops.grouped_matmul(x, w, cnt, mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ops_gather_swiglu_scatter_mode_parity():
    e, c, d, f, t = 3, 12, 16, 19, 9
    _, _, _, wg, wu, wd = _problem(2, e, t, d, f, 1)
    x_ext = jnp.concatenate([jax.random.normal(jax.random.PRNGKey(3), (t, d)),
                             jnp.zeros((1, d))], 0)
    rng = np.random.default_rng(0)
    cnt = jnp.array([4, 0, 12], jnp.int32)
    src = np.full((e * c,), t, np.int32)
    wsl = np.zeros((e * c,), np.float32)
    for g in range(e):
        for r in range(int(cnt[g])):
            src[g * c + r] = rng.integers(0, t)
            wsl[g * c + r] = rng.random() + 0.1
    args = (x_ext, jnp.asarray(src), jnp.asarray(wsl), wg, wu, wd, cnt)
    ref = kops.gather_swiglu_scatter(*args, mode="ref")
    got = kops.gather_swiglu_scatter(*args, mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("wdt", ["fp8", "int8"])
def test_ops_gather_quantize_mode_parity(wdt):
    """The fused routing-gather -> block-quantize -> scale-pack kernel
    (ISSUE 6 wire codec) in interpret mode is bit-identical to the jnp
    ref, and dequantize round-trips identically in both modes."""
    e, c, d, t = 3, 10, 200, 9
    rng = np.random.default_rng(8)
    x_ext = jnp.asarray(np.concatenate(
        [rng.standard_normal((t, d)).astype(np.float32),
         np.zeros((1, d), np.float32)], 0))
    counts = rng.integers(0, c + 1, e).astype(np.int32)
    src = np.full((e * c,), t, np.int32)
    for g in range(e):
        src[g * c:g * c + counts[g]] = rng.integers(0, t, counts[g])
    args = (x_ext, jnp.asarray(src), jnp.asarray(counts))
    qr, sr = kops.gather_quantize(*args, wire_dtype=wdt, mode="ref")
    qi, si = kops.gather_quantize(*args, wire_dtype=wdt, mode="interpret")
    np.testing.assert_array_equal(
        np.ascontiguousarray(np.asarray(qr)).view(np.uint8),
        np.ascontiguousarray(np.asarray(qi)).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(sr), np.asarray(si))
    np.testing.assert_array_equal(
        np.asarray(kops.dequantize_tokens(qr, sr, mode="ref")),
        np.asarray(kops.dequantize_tokens(qi, si, mode="interpret")))


def test_ops_swiglu_db_env_routing(monkeypatch):
    """REPRO_SWIGLU_DB=1 routes kernel modes through the double-buffered
    variant; results must stay on the masked-ref contract."""
    e, c, d, f = 3, 24, 16, 13
    x, _, _, wg, wu, wd = _problem(9, e, e * c, d, f, 1)
    x = x[:e * c].reshape(e, c, d)
    cnt = jnp.array([5, 0, 24], jnp.int32)
    ref = kops.grouped_swiglu(x, wg, wu, wd, cnt, mode="ref")
    monkeypatch.setenv("REPRO_SWIGLU_DB", "1")
    got = kops.grouped_swiglu(x, wg, wu, wd, cnt, mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def _mesh11():
    return jax.make_mesh((1,), ("model",), axis_types=(AxisType.Auto,))


@pytest.mark.parametrize("mode", ["ll", "ht"])
def test_dispatch_delivers_counts_to_expert_fn(mode):
    """Both dispatch paths hand plan-derived occupied counts to expert_fn
    (the occupancy contract), and the result matches the dense oracle."""
    e, k, t, d, f = 8, 2, 32, 16, 24
    x, ti, tw, wg, wu, wd = _problem(4, e, t, d, f, k)
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, dtype=jnp.float32)
    seen = []

    def expert_fn(tokens, counts=None):
        seen.append(counts is not None)
        assert counts is not None
        return kops.grouped_swiglu(tokens, wg, wu, wd, counts, mode="ref")

    fn = dispatch_combine_ll if mode == "ll" else dispatch_combine_ht

    def island(x, ti, tw):
        r = fn(spec, x, ti, tw, expert_fn)
        return r.out, r.aux["dropped"], r.aux["occupancy"]

    out, dropped, occ = jax.jit(jax.shard_map(
        island, mesh=_mesh11(), in_specs=(P(), P(), P()),
        out_specs=(P(), P(), P()), check_vma=False))(x, ti, tw)
    assert seen and all(seen)
    assert float(dropped) == 0.0
    assert 0.0 < float(occ) <= 1.0
    ref = moe_ref(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("kernel_mode", ["ref", "interpret"])
def test_moe_layer_kernel_mode_equivalence(kernel_mode, monkeypatch):
    """The MoE layer through kops mode dispatch: interpret-mode kernel
    bodies (occupancy-aware grouped SwiGLU + fused gather/scatter) must
    reproduce the ref-mode layer output."""
    from repro.configs import get_config, reduced_config
    from repro.core.moe import moe_apply, moe_init

    monkeypatch.setattr(kops, "KERNEL_MODE", kernel_mode)
    cfg = reduced_config(get_config("qwen2_moe_a2_7b"), n_layers=2,
                         d_model=32, n_experts=4)
    p = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    y_ref, _ = moe_apply(cfg, None, p, x, mode="ref")
    y, aux = moe_apply(cfg, None, p, x, mode="ht", backend="simulated_rdma")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-5)


def test_ht_chunk_degradation_surfaced():
    """T % chunks != 0 degrades to the largest divisor (not 1) and surfaces
    the effective chunk count in aux."""
    e, k, t, d, f = 4, 2, 30, 8, 12
    x, ti, tw, wg, wu, wd = _problem(7, e, t, d, f, k)
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, chunks=4, dtype=jnp.float32)

    def island(x, ti, tw):
        r = dispatch_combine_ht(
            spec, x, ti, tw,
            lambda tk, c=None: kops.grouped_swiglu(tk, wg, wu, wd, c,
                                                   mode="ref"))
        return r.out, r.aux["dropped"]

    out, dropped = jax.jit(jax.shard_map(
        island, mesh=_mesh11(), in_specs=(P(), P(), P()),
        out_specs=(P(), P()), check_vma=False))(x, ti, tw)
    # aux["chunks"] is static metadata: probe it outside jit
    from repro.core.plan import effective_chunks
    assert effective_chunks(30, 4) == 3
    assert effective_chunks(32, 4) == 4
    assert effective_chunks(31, 4) == 1
    assert effective_chunks(30, 1) == 1
    ref = moe_ref(x, ti, tw, wg, wu, wd)
    assert float(dropped) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)
