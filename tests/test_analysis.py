"""Static-analysis subsystem tests (ISSUE 9): lint rules, the Eraser
lockset race detector (synthetic traces, the clean shipped threaded path,
and a seeded lock-removal mutant), and catalog hygiene.

The verifier itself (accept-all-generated / reject-every-mutant) is
exercised in tests/test_transport_fuzz.py Part 6 — here we cover the
pieces the fuzz harness doesn't: the AST lint and the dynamic detector.
"""
import os
import threading

import numpy as np
import pytest

from repro.analysis.invariants import CATALOG
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.racecheck import TRACKED_FIELDS, RaceChecker
from repro.core.transport import EPWorld, NetConfig
from repro.core.transport.fifo import FifoChannel, pack_cmds

pytestmark = pytest.mark.timeout(120)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_REPO, "src", "repro")


# ======================================================================
# lint rules
# ======================================================================
def _ids(findings):
    return [f.rule for f in findings]


def test_lint_bitmask_flags_magic_masks_in_transport():
    src = "x = (w >> 16) & 0xFF\ny = s & 0b11111\n"
    ids = _ids(lint_source(src, "src/repro/core/transport/proxy.py"))
    assert ids == ["LNT-BITMASK", "LNT-BITMASK"]


def test_lint_bitmask_exempts_wire_format_and_other_modules():
    src = "CH_MASK = 0xFF\n"
    assert lint_source(src, "src/repro/core/transport/wire_format.py") == []
    assert lint_source(src, "src/repro/core/plan.py") == []
    # non-all-ones and tiny flag literals are fine anywhere
    ok = "a = f & 0x3\nb = f | 0x10\nc = 0xA0\n"
    assert lint_source(ok, "src/repro/core/transport/proxy.py") == []


def test_lint_scale_div_flags_constant_divisors():
    bad = ("def enc(x):\n"
           "    s = np.abs(x).max() / FP8_MAX\n"
           "    return x / np.float32(127.0)\n")
    ids = _ids(lint_source(bad, "src/repro/core/transport/codec.py"))
    assert ids == ["LNT-SCALE-DIV", "LNT-SCALE-DIV"]


def test_lint_scale_div_exempts_module_level_reciprocal_and_data_div():
    ok = ("_QINV = 1.0 / 448.0\n"
          "def enc(x, scale):\n"
          "    return x / scale\n")     # data-dependent divisor: fine
    assert lint_source(ok, "src/repro/core/transport/codec.py") == []
    # rule is scoped to quantization modules only
    bad = "def f(x):\n    return x / 2.0\n"
    assert lint_source(bad, "src/repro/core/transport/proxy.py") == []


def test_lint_assert_proto_flags_bare_protocol_asserts():
    bad = "def f(seq, ch):\n    assert seq < SEQ_MOD and ch >= 0\n"
    ids = _ids(lint_source(bad, "src/repro/core/transport/semantics.py"))
    assert ids == ["LNT-ASSERT-PROTO"]
    # non-protocol asserts and non-transport files stay clean
    assert lint_source("def f(a):\n    assert a\n",
                       "src/repro/core/transport/semantics.py") == []
    assert lint_source(bad, "src/repro/core/plan.py") == []


def test_lint_pl_when_flags_unguarded_occupancy_kernels():
    bad = ("def _foo_kernel(x_ref, cnt_ref, o_ref):\n"
           "    o_ref[...] = x_ref[...]\n")
    ids = _ids(lint_source(bad, "src/repro/kernels/grouped_matmul.py"))
    assert ids == ["LNT-PL-WHEN"]
    good = ("def _foo_kernel(x_ref, cnt_ref, o_ref):\n"
            "    @pl.when(i < cnt_ref[0])\n"
            "    def _():\n"
            "        o_ref[...] = x_ref[...]\n")
    assert lint_source(good, "src/repro/kernels/grouped_matmul.py") == []
    # kernels without an occupancy ref have nothing to guard
    noocc = "def _rms_kernel(x_ref, o_ref):\n    o_ref[...] = x_ref[...]\n"
    assert lint_source(noocc, "src/repro/kernels/fused_attention.py") == []


def test_lint_clean_on_repo():
    """The shipped tree passes its own lint — the CI gate."""
    findings = lint_paths([_SRC])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_rule_ids_in_catalog():
    for rid in ("LNT-BITMASK", "LNT-SCALE-DIV", "LNT-ASSERT-PROTO",
                "LNT-PL-WHEN", "RACE-LOCKSET"):
        assert rid in CATALOG
    assert all(r.startswith(("EPV-", "RACE-", "LNT-")) for r in CATALOG)


# ======================================================================
# race detector: synthetic traces through the state machine
# ======================================================================
def _trace(rc, accesses):
    for thread, held, write in accesses:
        rc.record_access((1, "x"), thread, frozenset(held), write)


def test_racecheck_exclusive_phase_never_reports():
    rc = RaceChecker()
    _trace(rc, [(1, (), True)] * 5 + [(1, (), False)] * 5)
    assert rc.findings() == []


def test_racecheck_consistent_lock_never_reports():
    rc = RaceChecker()
    _trace(rc, [(1, ("L",), True), (2, ("L",), False),
                (1, ("L", "M"), True), (2, ("L",), True)])
    assert rc.findings() == []


def test_racecheck_unsynchronized_write_reports():
    rc = RaceChecker()
    _trace(rc, [(1, (), True), (2, (), False)])
    f = rc.findings()
    assert len(f) == 1 and f[0].rule == "RACE-LOCKSET"


def test_racecheck_lockset_refinement_to_empty_reports():
    rc = RaceChecker()
    _trace(rc, [(1, ("L", "M"), True), (2, ("M",), True)])
    assert rc.findings() == []          # still guarded by M
    _trace(rc, [(2, ("L",), True)])     # intersection empties
    assert [f.rule for f in rc.findings()] == ["RACE-LOCKSET"]


def test_racecheck_sole_writer_lockless_read_exempt():
    """The SPSC pattern: the producer reads its own counter locklessly;
    the consumer only ever reads it under the lock."""
    rc = RaceChecker()
    _trace(rc, [(1, ("L",), True),      # producer publishes under lock
                (2, ("L",), False),     # consumer reads under lock
                (1, (), False),         # producer lockless read: exempt
                (1, (), False)])
    assert rc.findings() == []
    # ...but a lockless read by a NON-writer is a real candidate race
    _trace(rc, [(2, (), False)])
    assert [f.rule for f in rc.findings()] == ["RACE-LOCKSET"]


def test_racecheck_reports_once_per_variable():
    rc = RaceChecker()
    _trace(rc, [(1, (), True), (2, (), True), (1, (), True),
                (2, (), False)])
    assert len(rc.findings()) == 1


# ======================================================================
# race detector: real threads on the transport
# ======================================================================
def _spsc_workload(ch, n=200):
    """Drive a FifoChannel with a real producer/consumer pair using the
    shipped lockless-producer protocol."""
    words = pack_cmds(1, np.zeros(n, np.int64), 0,
                      np.arange(n), np.arange(n), 8, 0)
    got = []

    def consumer():
        while len(got) < n:
            out = ch.pop_all()
            if out is None:
                ch.wait_nonempty(0.01)
            else:
                got.extend(out.tolist())

    t = threading.Thread(target=consumer)
    t.start()
    done = 0
    while done < n:
        done += ch.try_push_batch(words[done:done + 7])
        ch.check_completion_batch([max(0, done - 1)])
        _ = ch.pcie_reads
    t.join(timeout=10)
    assert len(got) == n


def test_racecheck_clean_on_shipped_fifo():
    """The shipped SPSC ring under real concurrency: zero findings (the
    producer's lockless _tail/_cached_head reads are the exempt
    pattern, everything else is locked)."""
    with RaceChecker() as rc:
        ch = FifoChannel(16)
        _spsc_workload(ch)
    assert rc.findings() == [], [str(f) for f in rc.findings()]


def test_racecheck_flags_lock_removal_mutant():
    """Seeded mutant: same workload, but the checker can no longer see the
    ring's lock (as if `with self._lock:` were deleted) — the lockset
    empties and the shared counters are flagged."""
    with RaceChecker() as rc:
        ch = FifoChannel(16)
        rc.instrument(ch, strip_locks=True)
        _spsc_workload(ch)
    rules = {f.rule for f in rc.findings()}
    flagged = {f.where[1] for f in rc.findings()}
    assert rules == {"RACE-LOCKSET"}
    assert "_head" in flagged or "_tail" in flagged, flagged


def test_racecheck_clean_on_threaded_ep_world():
    """The full shipped threaded path — worker proxies draining FIFOs
    concurrently with the event-clock pump — runs with ZERO candidate
    races (the CI gate)."""
    rng = np.random.default_rng(0)
    R, eps, K, D, Tl = 2, 2, 2, 8, 4
    E = eps * R
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = np.full((R, Tl, K), 1.0 / K, np.float32)
    wg = (rng.standard_normal((E, D, 8)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, 8)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, 8, D)) * 0.2).astype(np.float32)
    with RaceChecker() as rc:
        w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=8,
                    capacity=Tl * K,
                    net_cfg=NetConfig(mode="srd", seed=0),
                    use_threads=True, n_threads=2)
        try:
            out = w.run(x, ti, tw, wg, wu, wd)
        finally:
            for p in w.proxies:
                p.stop()
    np.testing.assert_allclose(out, EPWorld.oracle(x, ti, tw, wg, wu, wd),
                               rtol=1e-4, atol=1e-5)
    assert rc.findings() == [], [str(f) for f in rc.findings()]


def test_racecheck_uninstall_restores_constructors():
    before = (FifoChannel.__init__,)
    with RaceChecker():
        assert FifoChannel.__init__ is not before[0]
    assert FifoChannel.__init__ is before[0]
    ch = FifoChannel(4)                 # plain instance, no tracking
    assert type(ch) is FifoChannel


def test_tracked_fields_exist():
    """Instrumentation tracks real attributes — a rename in the transport
    must update the detector's field map."""
    from repro.core.transport.proxy import Proxy, SymmetricMemory
    from repro.core.transport.simulator import Network
    ch = FifoChannel(4)
    for f in TRACKED_FIELDS["FifoChannel"]:
        assert hasattr(ch, f), f
    net = Network(NetConfig(mode="rc", seed=0), n_ranks=1)
    for f in TRACKED_FIELDS["Network"]:
        assert hasattr(net, f), f
    mem = SymmetricMemory(data=np.zeros(1024, np.uint8),
                          counters=np.zeros(8, np.int64))
    p = Proxy(rank=0, net=net, mem=mem)
    for f in TRACKED_FIELDS["Proxy"]:
        assert hasattr(p, f), f
