"""Transport-semantics conformance fuzz harness (ISSUE 4 tentpole;
extended by ISSUE 5 with the batched/coalesced-path oracle agreement).

Drives randomized command streams through the delivery-semantics layer and
the full EP substrate, asserting the invariants the paper's §3.3/§4.1
correctness story rests on:

1. **Fence safety** — no completion fence applies before >= count writes
   have landed *inside its registered bucket range* (and only writes from
   the same peer count);
2. **Per-channel seq-prefix closure** — a SEQ_ATOMIC applies only after
   every smaller sequence on its channel applied, and once delivery
   finishes each channel's applied prefix is contiguous;
3. **Quiesce** — after the world drains, nothing is held in any control
   buffer, no command is mid-execution, no message is in flight;
4. **Oracle agreement** — the EP result equals the dense oracle bit-for-
   bit-in-float.

The matrix covers {rc, srd} x {ll, ht} x {inline, threaded} proxies and
eps (experts per rank) in {1, 63, 64, 128} — the 64/128 points are exactly
the regime the seed's 6-bit slot codec could not represent (DeepSeek-V3:
256 routed experts at EP degree <= 4).  Each property runs both as a
deterministic seeded sweep (always on, pinned repro seeds) and as a
hypothesis property with shrinking when hypothesis is installed (the
conftest stub skips those cleanly otherwise).

The columnar fast path (ISSUE 5) is held to the scalar path as its
conformance oracle at two levels.  ControlBuffer level: the same stream of
wire messages (including coalesced runs carrying immediate vectors) is
delivered once through per-write ``on_write`` and once through
``on_write_batch``, asserting an IDENTICAL apply log — the batched
receiver may not reorder a single fence fire.  EP level: every randomized
world runs {scalar, columnar, columnar+coalesced}; scalar vs columnar
must agree on everything bit-for-bit including the per-peer apply logs
(their wire schedules are identical); coalescing changes the wire-message
boundaries, so there the assertions are bit-identical symmetric memories
and outputs, apply-log *multiset* equality per peer, strictly-not-more
delivered messages, and clean quiesce.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.transport import (ControlBuffer, EPWorld, GuardTable,
                                  ImmKind, NetConfig, pack_imm)

pytestmark = pytest.mark.timeout(120)   # a hung quiesce must fail fast

EPS_GRID = (1, 63, 64, 128)             # experts per rank; > 63 is the point


# ======================================================================
# Part 1: ControlBuffer-level conformance (pure semantics, no network)
# ======================================================================
def _gen_stream(rng, n_buckets=4, bucket_bytes=32, n_channels=3):
    """A random *sent* world: registered bucket table + per-channel command
    streams with consecutive sequence numbers, fences with satisfiable
    counts, and writes into unregistered memory (combine-return stand-ins).

    Returns (guards, events); each event is one of
      ("w", imm, dst_off, ch, seq)   write
      ("s", imm, ch, seq)            seq atomic
      ("f", imm, gid, need)          fence atomic
    """
    guards = GuardTable()
    for g in range(n_buckets):
        guards.register(g * bucket_bytes, bucket_bytes, g)
    unregistered0 = n_buckets * bucket_bytes + 17

    events = []
    next_seq = [0] * n_channels
    bucket_writes = [0] * n_buckets
    for _ in range(int(rng.integers(4, 40))):
        ch = int(rng.integers(0, n_channels))
        if rng.random() < 0.75:            # a write somewhere
            if rng.random() < 0.25:        # ... into unregistered memory
                off = unregistered0 + int(rng.integers(0, 64))
            else:
                g = int(rng.integers(0, n_buckets))
                off = g * bucket_bytes + int(rng.integers(0, bucket_bytes))
                bucket_writes[g] += 1
            seq = next_seq[ch]
            next_seq[ch] += 1
            events.append(("w", pack_imm(ImmKind.WRITE, ch, seq, 0), off,
                           ch, seq))
        else:                              # a seq atomic (HT chunk marker)
            seq = next_seq[ch]
            next_seq[ch] += 1
            events.append(("s", pack_imm(ImmKind.SEQ_ATOMIC, ch, seq,
                                         int(rng.integers(0, 1 << 16))),
                           ch, seq))
    # fences: required count <= writes landed in that bucket, so every
    # guard is eventually satisfiable (quiesce must leave nothing held)
    for g in range(n_buckets):
        if bucket_writes[g] and rng.random() < 0.8:
            need = int(rng.integers(1, bucket_writes[g] + 1))
            events.append(("f", pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, need),
                           g, need))
    return guards, events


def _replay_checked(guards, events, perm, cb_guards=None,
                    wire_gid=lambda g: g):
    """Deliver ``events`` in ``perm`` order through a ControlBuffer,
    asserting the fence/seq invariants at each apply, and the quiesce
    invariant at the end.  Returns the apply log.

    ``guards`` is the *ground-truth* bucket table the invariant checker
    attributes writes with; the system under test runs on ``cb_guards``
    (defaults to the same table) with fences addressed by ``wire_gid`` —
    the split lets the harness emulate a broken keying (e.g. the seed's
    slot aliasing) and prove the invariant catches it."""
    cb = ControlBuffer(guards=cb_guards if cb_guards is not None else guards)
    applied = []
    writes_in = {}                     # gid -> applied writes (ground truth)
    seqs_done = {}                     # ch -> set of applied seqs

    def on_write(off, ch, seq):
        gid = guards.resolve(off)
        if gid is not None:
            writes_in[gid] = writes_in.get(gid, 0) + 1
        seqs_done.setdefault(ch, set()).add(seq)
        applied.append(("w", ch, seq))

    def on_seq(ch, seq):
        done = seqs_done.setdefault(ch, set())
        assert done >= set(range(seq)), \
            f"SEQ_ATOMIC {seq} on ch {ch} applied before prefix closed"
        done.add(seq)
        applied.append(("s", ch, seq))

    def on_fence(gid, need):
        assert writes_in.get(gid, 0) >= need, \
            f"fence(guard={gid}, need={need}) applied after only " \
            f"{writes_in.get(gid, 0)} writes in its range"
        applied.append(("f", gid, need))

    for i in perm:
        ev = events[i]
        if ev[0] == "w":
            _, imm, off, ch, seq = ev
            cb.on_write(imm, lambda o=off, c=ch, s=seq: on_write(o, c, s),
                        off)
        elif ev[0] == "s":
            _, imm, ch, seq = ev
            cb.on_atomic(imm, lambda c=ch, s=seq: on_seq(c, s))
        else:
            _, imm, gid, need = ev
            cb.on_atomic(imm, lambda g=gid, n=need: on_fence(g, n),
                         guard=wire_gid(gid))
    # reliable transport: everything delivered => everything applied,
    # nothing held, every channel's seq prefix closed
    assert len(applied) == len(events)
    assert cb.n_held == 0
    assert all(not h for h in cb._arrived.values())
    return applied


def _cb_case(seed):
    rng = np.random.default_rng(seed)
    guards, events = _gen_stream(rng)
    perm = rng.permutation(len(events))
    _replay_checked(guards, events, perm)


@pytest.mark.parametrize("seed", range(40))
def test_control_buffer_conformance_seeded(seed):
    """Pinned-seed sweep of the semantics invariants (runs without
    hypothesis; the property version below adds shrinking)."""
    _cb_case(seed)


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_control_buffer_conformance_property(seed):
    _cb_case(seed)


def test_old_slot_keying_fence_aliasing_detected():
    """Pinned repro of the bug this PR fixes: the seed keyed guards by a
    6-bit wire slot, aliasing expert e onto guard e % 64 past 63 experts
    per rank — writes for expert 0 counted toward expert 64's fence, which
    then applied on a partially-landed bucket.  Emulating that keying as an
    aliased guard table, the harness's fence-safety invariant catches the
    corruption; the address-range table keeps the buckets distinct and the
    invariant holds."""
    bucket = 32
    # ground truth: expert 0 and expert 64 own distinct buckets/guards
    guards = GuardTable()
    guards.register(0 * bucket, bucket, 0)
    guards.register(64 * bucket, bucket, 64)
    # stream: 3 writes into expert-0's bucket, then a fence for expert 64's
    # bucket (count 3) — expert 64's own writes never sent
    events = [("w", pack_imm(ImmKind.WRITE, 0, s, 0), 0 * bucket + 4 * s,
               0, s) for s in range(3)]
    events.append(("f", pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, 3), 64, 3))
    perm = np.arange(len(events))

    # old keying: both buckets count toward guard 64 % 64 == 0 and the
    # fence addresses guard 0 too => it applies with ZERO writes in expert
    # 64's bucket — the harness's fence-safety invariant trips
    aliased = GuardTable()
    aliased.register(0 * bucket, bucket, 0)          # expert 0 -> guard 0
    aliased.register(64 * bucket, bucket, 64 % 64)   # expert 64 -> guard 0!
    with pytest.raises(AssertionError, match="applied after only"):
        _replay_checked(guards, events, perm, cb_guards=aliased,
                        wire_gid=lambda g: g % 64)

    # address-range keying: distinct guards; the fence is (correctly) held
    # until expert 64's writes land — deliver them and it applies
    cb = ControlBuffer(guards=guards)
    for _, imm, off, ch, seq in events[:3]:
        cb.on_write(imm, lambda: None, off)
    fired = []
    cb.on_atomic(events[3][1], lambda: fired.append(1), guard=64)
    assert not fired and cb.n_held == 1      # held: bucket 64 is empty
    for s in range(3):
        cb.on_write(pack_imm(ImmKind.WRITE, 1, s, 0), lambda: None,
                    64 * bucket + 4 * s)
    assert fired and cb.n_held == 0


# ======================================================================
# Part 2: end-to-end EP protocol over the full matrix
# ======================================================================
def _run_ep_case(mode, proto, eps, threaded, seed):
    rng = np.random.default_rng(seed)
    R = 2
    E = eps * R
    K = int(rng.integers(1, 4))
    D = F = 8
    Tl = int(rng.integers(4, 9))
    window = int(rng.choice([1, 16, 128]))
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.2).astype(np.float32)

    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=seed,
                                  reorder_window=window),
                use_threads=threaded, n_threads=2)
    try:
        if proto == "ll":
            out = w.run(x, ti, tw, wg, wu, wd)
        else:
            out = w.run_ht(x, ti, tw, wg, wu, wd,
                           n_chunks=int(rng.integers(1, 5)))
        ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        # quiesce invariants: nothing in flight, queued, or held anywhere
        assert w.net.pending == 0
        for p in w.proxies:
            assert p.error is None
            assert not p.busy
            for cb in p.ctrl.values():
                assert cb.n_held == 0, "quiesce left a guarded atomic held"
                # per-channel seq-prefix closure: every sequence the peer
                # consumed was applied contiguously
                assert all(not h for h in cb._arrived.values())
    finally:
        if threaded:
            for p in w.proxies:
                p.stop()


@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("eps", EPS_GRID)
def test_ep_conformance_inline_seeded(mode, eps):
    """Deterministic matrix sweep: {rc, srd} x {ll, ht} x inline proxies x
    eps in {1, 63, 64, 128} against the dense oracle + quiesce invariants."""
    for proto in ("ll", "ht"):
        for seed in (0, 1):
            _run_ep_case(mode, proto, eps, threaded=False, seed=seed)


@pytest.mark.parametrize("proto", ["ll", "ht"])
@pytest.mark.parametrize("eps", [1, 64])
def test_ep_conformance_threaded_seeded(proto, eps):
    """Threaded-proxy points of the matrix (worker threads drain FIFOs
    concurrently with the event-clock pump; exercises the locked
    pending/next_event_t quiesce path)."""
    _run_ep_case("srd", proto, eps, threaded=True, seed=2)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       mode=st.sampled_from(["rc", "srd"]),
       proto=st.sampled_from(["ll", "ht"]),
       eps=st.sampled_from(EPS_GRID))
def test_ep_conformance_property(seed, mode, proto, eps):
    """Hypothesis form of the matrix sweep: randomized routing/topology
    with shrinking toward a minimal failing (seed, mode, proto, eps)."""
    _run_ep_case(mode, proto, eps, threaded=False, seed=seed)


# ======================================================================
# Part 3: batched/coalesced fast path vs the scalar oracle (ISSUE 5)
# ======================================================================
def _batched_wire_stream(rng, guards, events):
    """Turn a sent event stream into wire messages the way the columnar
    proxy does: runs of consecutive same-channel writes (random run
    lengths) coalesce into one message carrying an immediate vector; every
    other event is its own message.  Returns a list of
    ('w', [(imm, off), ...]) / ('s', imm) / ('f', imm, gid) messages."""
    msgs, run = [], []
    for ev in events:
        if ev[0] == "w":
            _, imm, off, ch, _ = ev
            if run and (run[0][2] != ch or len(run) >= run[0][3]):
                msgs.append(("w", [(i, o) for i, o, _, _ in run]))
                run = []
            run.append((imm, off, ch, int(rng.integers(1, 8))))
        else:
            if run:
                msgs.append(("w", [(i, o) for i, o, _, _ in run]))
                run = []
            msgs.append(ev[:1] + ev[1:])
    if run:
        msgs.append(("w", [(i, o) for i, o, _, _ in run]))
    return msgs


def _deliver_msgs(guards, msgs, perm, batched):
    """Deliver wire messages in ``perm`` order through a ControlBuffer;
    coalesced write messages go through on_write_batch when ``batched``
    else unroll write-by-write (the scalar oracle).  Returns the buffer."""
    cb = ControlBuffer(guards=guards)
    for i in perm:
        m = msgs[i]
        if m[0] == "w":
            subs = m[1]
            if batched and len(subs) > 1:
                cb.on_write_batch(np.array([imm for imm, _ in subs],
                                           np.uint32),
                                  np.array([off for _, off in subs],
                                           np.int64))
            else:
                for imm, off in subs:
                    cb.on_write(imm, lambda: None, off)
        elif m[0] == "s":
            cb.on_atomic(m[1], lambda: None)
        else:
            cb.on_atomic(m[1], lambda: None, guard=m[2])
    return cb


def _cb_batched_case(seed):
    """The batched receiver must produce the IDENTICAL apply log, guard
    counters, and quiesce state as the scalar unroll of the same wire
    messages in the same delivery order — including the scalar-fallback
    corners (held fences on a run's own guards, held seq atomics on its
    channel, straggler runs)."""
    rng = np.random.default_rng(seed)
    guards, events = _gen_stream(rng)
    msgs = _batched_wire_stream(rng, guards, events)
    perm = rng.permutation(len(msgs))
    a = _deliver_msgs(guards, msgs, perm, batched=False)
    b = _deliver_msgs(guards, msgs, perm, batched=True)
    assert a.applied_log == b.applied_log       # exact fence-fire ordering
    assert a.writes_seen == b.writes_seen
    assert a.next_seq == b.next_seq
    assert b.n_held == a.n_held == 0
    assert all(not h for h in b._arrived.values())


@pytest.mark.parametrize("seed", range(60))
def test_control_buffer_batched_oracle_seeded(seed):
    _cb_batched_case(seed)


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_control_buffer_batched_oracle_property(seed):
    _cb_batched_case(seed)


def _ep_world_ab(mode, proto, eps, seed, columnar, coalesce, threaded,
                 wire_dtype="fp32"):
    """One EP run with the given drain configuration; returns
    (out, mems, per-peer apply logs, delivered count, world)."""
    rng = np.random.default_rng(seed)
    R = 2
    E = eps * R
    K = int(rng.integers(1, 4))
    D = F = 8
    Tl = int(rng.integers(4, 9))
    window = int(rng.choice([1, 16, 128]))
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.2).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=seed,
                                  reorder_window=window),
                use_threads=threaded, n_threads=2,
                columnar=columnar, coalesce=coalesce, wire_dtype=wire_dtype)
    try:
        if proto == "ll":
            out = w.run(x, ti, tw, wg, wu, wd)
        else:
            out = w.run_ht(x, ti, tw, wg, wu, wd,
                           n_chunks=int(rng.integers(1, 5)))
    finally:
        if threaded:
            for p in w.proxies:
                p.stop()
    mems = [p.mem.data.copy() for p in w.proxies]
    logs = {(p.rank, src): tuple(cb.applied_log)
            for p in w.proxies for src, cb in sorted(p.ctrl.items())}
    return out, mems, logs, w.net.delivered, w


def _quiesce_clean(w):
    assert w.net.pending == 0
    for p in w.proxies:
        assert p.error is None and not p.busy
        for cb in p.ctrl.values():
            assert cb.n_held == 0
            assert all(not h for h in cb._arrived.values())


def _ep_batched_oracle_case(mode, proto, eps, seed, threaded=False,
                            wire_dtype="fp32"):
    o_s, m_s, l_s, d_s, w_s = _ep_world_ab(
        mode, proto, eps, seed, columnar=False, coalesce=False,
        threaded=False, wire_dtype=wire_dtype)
    o_c, m_c, l_c, d_c, w_c = _ep_world_ab(
        mode, proto, eps, seed, columnar=True, coalesce=False,
        threaded=False, wire_dtype=wire_dtype)
    # columnar drain without coalescing issues the identical wire schedule:
    # bit-identical receive buffers, apply logs, and delivery counts
    np.testing.assert_array_equal(o_s, o_c)
    assert d_s == d_c
    assert l_s == l_c, "columnar drain reordered an apply"
    for a, b in zip(m_s, m_c):
        np.testing.assert_array_equal(a, b)
    _quiesce_clean(w_c)
    # coalescing changes wire-message boundaries (never content): buffers
    # and outputs stay bit-identical, each peer's applies are the same
    # multiset, and strictly no more messages are delivered
    o_z, m_z, l_z, d_z, w_z = _ep_world_ab(
        mode, proto, eps, seed, columnar=True, coalesce=True,
        threaded=threaded, wire_dtype=wire_dtype)
    np.testing.assert_array_equal(o_s, o_z)
    for a, b in zip(m_s, m_z):
        np.testing.assert_array_equal(a, b)
    assert d_z <= d_s
    assert set(l_z) == set(l_s)
    for k in l_s:
        assert sorted(l_z[k]) == sorted(l_s[k]), k
    _quiesce_clean(w_z)


@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("eps", [1, 63, 64])
def test_ep_batched_oracle_seeded(mode, eps):
    for proto in ("ll", "ht"):
        for seed in (0, 3):
            _ep_batched_oracle_case(mode, proto, eps, seed)


@pytest.mark.parametrize("proto", ["ll", "ht"])
def test_ep_batched_oracle_threaded(proto):
    """Threaded drains batch nondeterministically (worker pop_all timing),
    so coalescing boundaries differ run to run — the buffers, outputs, and
    apply multisets must not."""
    _ep_batched_oracle_case("srd", proto, 64, seed=5, threaded=True)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       mode=st.sampled_from(["rc", "srd"]),
       proto=st.sampled_from(["ll", "ht"]),
       eps=st.sampled_from(EPS_GRID))
def test_ep_batched_oracle_property(seed, mode, proto, eps):
    _ep_batched_oracle_case(mode, proto, eps, seed)


# ======================================================================
# Part 4: compressed-dispatch conformance (ISSUE 6 wire dtypes)
# ======================================================================
# Quantized payloads change wire-row sizes (d bytes + inline fp32 scales
# instead of 4d) but must not change protocol behavior: fences still fire
# after exactly the same write counts, guard ranges cover the scale bytes,
# drains quiesce clean, and the result matches the dense fp32 oracle
# within the dtype's quantization tolerance (exact for fp32 passthrough).
WIRE_TOL = {"fp32": 0.0, "fp8": 0.2, "int8": 0.05}


def _run_ep_wire_case(mode, proto, eps, wdt, threaded, seed):
    rng = np.random.default_rng(seed)
    R = 2
    E = eps * R
    K = int(rng.integers(1, 4))
    D = F = 8
    Tl = int(rng.integers(4, 9))
    window = int(rng.choice([1, 16, 128]))
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.2).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=seed,
                                  reorder_window=window),
                use_threads=threaded, n_threads=2, wire_dtype=wdt)
    try:
        if proto == "ll":
            out = w.run(x, ti, tw, wg, wu, wd)
        else:
            out = w.run_ht(x, ti, tw, wg, wu, wd,
                           n_chunks=int(rng.integers(1, 5)))
        ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
        if wdt == "fp32":
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        else:
            err = np.abs(out - ref).max()
            scale = np.abs(ref).max() + 1e-9
            assert err <= WIRE_TOL[wdt] * scale, \
                f"{wdt} relerr {err / scale:.4f} > {WIRE_TOL[wdt]}"
        assert w.timeline["wire_dtype"] == wdt
        assert w.timeline["dispatch_msgs"] > 0
        assert w.timeline["dispatch_wire_bytes"] > \
            w.timeline["dispatch_payload_bytes"]   # headers charged
        if wdt != "fp32" and proto == "ll":
            # honest accounting: LL dispatch payloads are whole wire rows,
            # each smaller than the 4D bytes fp32 would have moved
            assert w.wire_tok_bytes < 4 * D
            assert w.timeline["dispatch_payload_bytes"] % w.wire_tok_bytes \
                == 0
        assert w.net.pending == 0
        for p in w.proxies:
            assert p.error is None and not p.busy
            for cb in p.ctrl.values():
                assert cb.n_held == 0
                assert all(not h for h in cb._arrived.values())
    finally:
        if threaded:
            for p in w.proxies:
                p.stop()


@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("wdt", ["fp32", "fp8", "int8"])
def test_ep_wire_dtype_conformance_seeded(mode, wdt):
    """{rc, srd} x {ll, ht} x {fp32, fp8, int8}: oracle agreement within
    dtype tolerance + clean quiesce with compressed wire rows."""
    for proto in ("ll", "ht"):
        for seed in (0, 1):
            _run_ep_wire_case(mode, proto, 4, wdt, threaded=False, seed=seed)


@pytest.mark.parametrize("proto", ["ll", "ht"])
def test_ep_wire_dtype_threaded(proto):
    """Threaded-proxy point of the compressed matrix."""
    _run_ep_wire_case("srd", proto, 4, "fp8", threaded=True, seed=2)


@pytest.mark.parametrize("wdt", ["fp8", "int8"])
def test_ep_wire_batched_oracle_compressed(wdt):
    """Scalar vs columnar vs coalesced drains must agree bit-for-bit on
    compressed payload bytes too (apply-log equivalence from Part 3)."""
    for proto in ("ll", "ht"):
        for seed in (7, 8):
            _ep_batched_oracle_case("srd", proto, 4, seed, wire_dtype=wdt)


@pytest.mark.parametrize("wdt", ["fp32", "fp8", "int8"])
def test_ll_guard_ranges_cover_scale_blocks(wdt):
    """Guard-range exactness with inline scales: every byte of a receive
    bucket — quantized payload AND its scale words — resolves to that
    bucket's guard, and bucket boundaries stay exact (stride capacity*wb)."""
    R, eps, K, D, Tl = 2, 2, 2, 200, 4   # D=200 -> ragged last scale block
    E = eps * R
    rng = np.random.default_rng(0)
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = np.full((R, Tl, K), 1.0 / K, np.float32)
    wg = (rng.standard_normal((E, D, 8)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, 8)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, 8, D)) * 0.2).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=8,
                capacity=Tl * K, net_cfg=NetConfig(mode="rc", seed=0),
                wire_dtype=wdt)
    w.run(x, ti, tw, wg, wu, wd)
    wb = w.wire_tok_bytes
    from repro.core.plan import wire_layout
    assert wb == wire_layout(D, wdt).token_bytes
    cap = Tl * K
    recv0 = Tl * wb                       # LL layout: recv follows send
    for p in w.proxies:
        for b in range(R * eps):
            base = recv0 + b * cap * wb
            assert p.guards.resolve(base) == b
            assert p.guards.resolve(base + cap * wb - 1) == b, \
                "scale bytes fell outside their bucket's guard"
            if b + 1 < R * eps:
                assert p.guards.resolve(base + cap * wb) == b + 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       mode=st.sampled_from(["rc", "srd"]),
       proto=st.sampled_from(["ll", "ht"]),
       wdt=st.sampled_from(["fp32", "fp8", "int8"]))
def test_ep_wire_dtype_property(seed, mode, proto, wdt):
    _run_ep_wire_case(mode, proto, 4, wdt, threaded=False, seed=seed)


# ======================================================================
# Part 5: replicated placements under skewed routing (ISSUE 7)
# ======================================================================
# Replication re-keys everything downstream of the split — guard tables,
# fence counts, ret_pos return slots all size from the PHYSICAL layout —
# so the conformance bar is: any placement, any skew, any transport, the
# physical world still matches the LOGICAL dense oracle bit-for-bit-in-
# float, quiesces clean, and the replicas=1 degenerate split is the
# identity (same array out, not merely equal values).
def _zipf_routing(rng, R, Tl, K, E, alpha):
    """Zipf(alpha)-skewed routing table: expert e drawn with probability
    proportional to (1 + e) ** -alpha (alpha=0 -> uniform)."""
    p = (1.0 + np.arange(E)) ** -alpha
    p /= p.sum()
    return rng.choice(E, size=(R, Tl, K), p=p).astype(np.int32)


def _run_ep_replicated_case(mode, proto, factor, seed, alpha=1.2):
    from repro.core import plan as planlib

    rng = np.random.default_rng(seed)
    R = 2
    E = 8
    K = int(rng.integers(1, 4))
    D = F = 8
    Tl = int(rng.integers(4, 9))
    window = int(rng.choice([1, 16, 128]))
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = _zipf_routing(rng, R, Tl, K, E, alpha)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.2).astype(np.float32)

    # placement: greedy over the TRUE observed load (what the online
    # balancer would converge to), at `factor`x physical slots
    loads = planlib.group_counts(ti.reshape(-1), E,
                                 ti.reshape(-1) >= 0).astype(np.float64)
    pl = planlib.greedy_placement(loads, E * factor, R)
    if factor == 1:
        # replicas=1 contract: with one slot per expert the split is the
        # identity function — the same array object comes back
        ident = planlib.identity_placement(E)
        assert planlib.split_to_physical(ident, ti) is ti
        pl = ident
    tis = planlib.split_to_physical_world(pl, ti)
    p2l = np.asarray(pl.phys_to_logical)
    if factor == 1:
        np.testing.assert_array_equal(tis, ti)
    else:
        # the split never reroutes: every physical slot maps back to the
        # logical expert the router chose
        np.testing.assert_array_equal(p2l[tis], ti)
    wg_p, wu_p, wd_p = wg[p2l], wu[p2l], wd[p2l]

    w = EPWorld(n_ranks=R, n_experts=pl.n_physical, top_k=K, d=D, f=F,
                capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=seed,
                                  reorder_window=window))
    if proto == "ll":
        out = w.run(x, tis, tw, wg_p, wu_p, wd_p)
    else:
        out = w.run_ht(x, tis, tw, wg_p, wu_p, wd_p,
                       n_chunks=int(rng.integers(1, 5)))
    ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)     # LOGICAL oracle
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    _quiesce_clean(w)
    # event-clock completion rows exist and are sane: one per local token,
    # every routed token strictly positive
    comp = w.timeline["token_completion_us"]
    assert comp.shape == (R, Tl)
    assert (comp > 0).all()


@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("factor", [1, 2, 4])
def test_ep_replicated_conformance_seeded(mode, factor):
    """Deterministic sweep: {rc, srd} x {ll, ht} x replication factor
    {1, 2, 4} on Zipf-skewed routing against the logical dense oracle."""
    for proto in ("ll", "ht"):
        for seed in (0, 1):
            _run_ep_replicated_case(mode, proto, factor, seed)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       mode=st.sampled_from(["rc", "srd"]),
       proto=st.sampled_from(["ll", "ht"]),
       factor=st.sampled_from([1, 2, 4]),
       alpha=st.sampled_from([0.0, 0.8, 1.5]))
def test_ep_replicated_conformance_property(seed, mode, proto, factor,
                                            alpha):
    """Hypothesis form: randomized skew/replication/transport points with
    shrinking toward a minimal failing configuration."""
    _run_ep_replicated_case(mode, proto, factor, seed, alpha=alpha)


# ======================================================================
# Part 6: static protocol verification (ISSUE 9)
# ======================================================================
# Every stream the generator emits must verify clean; seeded invariant-
# breaking mutants must each be rejected with the *specific* rule id the
# catalog assigns them — including an exact reconstruction of PR 4's
# 6-bit slot-aliasing bug (EPV-005).
from repro.core.plan import receive_bucket_table, wire_layout
from repro.core.transport.ep_executor import (SessSlot,
                                              build_command_streams)
from repro.core.transport.fifo import (FLAG_FENCE, Op, pack_cmds,
                                       unpack_cmds)
from repro.analysis import verify
from repro.analysis.verify import verify_session_slots, verify_stream


def _build_ll_cs(wdt="fp32", eps=4, seed=0, n_channels=4, R=2, Tl=6, K=2,
                 D=8, ti=None):
    """A clean LL CommandStreams in the same memory layout EPWorld uses:
    send region, registered receive buckets, unregistered return region."""
    rng = np.random.default_rng(seed)
    E = eps * R
    if ti is None:
        ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    R, Tl, K = ti.shape
    cap = Tl * K
    tb = 4 * D
    wb = wire_layout(D, wdt).token_bytes
    send0 = 0
    recv0 = Tl * wb
    ret0 = recv0 + R * eps * cap * wb
    return build_command_streams(ti, E, eps, cap, tb, n_channels,
                                 send0, recv0, ret0, wire_bytes=wb), \
        n_channels


def _rule_ids(findings):
    return {f.rule for f in findings}


def _repack(words, **mut):
    """Unpack a descriptor batch, override whole field columns (or single
    rows via (row, value) tuples), repack."""
    c = unpack_cmds(np.asarray(words).reshape(-1, 4))
    f = {k: np.array(getattr(c, k)) for k in
         ("op", "dst_rank", "channel", "src_off", "dst_off", "length",
          "value", "flags")}
    for k, v in mut.items():
        if isinstance(v, tuple):
            f[k][v[0]] = v[1]
        else:
            f[k] = v
    return pack_cmds(f["op"], f["dst_rank"], f["channel"], f["src_off"],
                     f["dst_off"], f["length"], f["value"], f["flags"])


@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("wdt", ["fp32", "fp8", "int8"])
def test_verify_accepts_generated_ll_streams(mode, wdt):
    """Zero findings on every clean generator output across the
    {rc, srd} x {fp32, fp8, int8} LL matrix (several seeds and shapes,
    including the >63-experts-per-rank regime)."""
    for eps, seed in ((1, 0), (4, 1), (64, 2), (65, 3)):
        cs, nc = _build_ll_cs(wdt, eps=eps, seed=seed)
        findings = verify(cs, net_cfg=NetConfig(mode=mode, seed=seed),
                          n_channels=nc)
        assert findings == [], [str(f) for f in findings]


@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("wdt", ["fp32", "fp8", "int8"])
def test_verifier_live_in_ep_world_ht_and_ll(mode, wdt):
    """EPWorld calls verify_or_raise on every build (LL streams, session
    layouts) — a full run across the {rc, srd} x {ll, ht} x wire-dtype
    matrix completing is the verifier accepting the real executor's
    output."""
    for proto in ("ll", "ht"):
        _run_ep_wire_case(mode, proto, 4, wdt, threaded=False, seed=5)


def test_mutant_channel_overflow_epv001():
    """Channel id past the 3-bit immediate field."""
    cs, nc = _build_ll_cs()
    bad = cs._replace(writes=_repack(cs.writes, channel=(0, 8)))
    assert "EPV-001" in _rule_ids(verify(bad, n_channels=8))


def test_mutant_fence_count_overflow_epv002():
    """Fence count past the 21-bit immediate count field."""
    cs, nc = _build_ll_cs()
    bad = cs._replace(fences=_repack(cs.fences,
                                     src_off=(0, 2 ** 21)))
    ids = _rule_ids(verify(bad, n_channels=nc))
    assert "EPV-002" in ids


def test_mutant_atomic_operand_overflow_epv003():
    """Standalone (non-fence) atomic operand past the 16-bit value field —
    the HT chunk-id width bug class."""
    row = pack_cmds(int(Op.ATOMIC), 1, 0, 70000, 3, 0, 0)  # no FLAG_FENCE
    ids = _rule_ids(verify_stream(row))
    assert ids == {"EPV-003"}
    assert "EPV-003" not in _rule_ids(
        verify_stream(pack_cmds(int(Op.ATOMIC), 1, 0, 70000, 3, 0, 0,
                                FLAG_FENCE)))   # fences use the count field


def test_mutant_overlapping_guard_ranges_epv004():
    """Doubled guard extents: adjacent receive buckets overlap."""
    cs, nc = _build_ll_cs()
    bases, extents, gids = cs.guard_table
    bad = cs._replace(guard_table=(bases, np.asarray(extents) * 2, gids))
    assert "EPV-004" in _rule_ids(verify(bad, n_channels=nc))


def test_pr4_slot_aliasing_reconstruction_epv005():
    """Pinned regression: PR 4's seed bug, reconstructed.  The 6-bit slot
    codec keyed guards by ``expert % 64``, so at 65 experts/rank two
    buckets share a guard id — their write counts merge and fences fire
    early.  The verifier must reject this statically (EPV-005 duplicate
    id, EPV-007 merged counts)."""
    eps = 65
    # routing that lands tokens in both buckets (src 0, expert-local 0)
    # and (src 0, expert-local 64) — exactly the pair that aliases to
    # guard id 0 under the seed's % 64 keying
    ti = np.array([[[0, 64], [64, 3], [0, 7], [1, 2]],
                   [[65, 129], [5, 6], [70, 100], [8, 9]]], np.int32)
    cs, nc = _build_ll_cs(eps=eps, ti=ti)
    bases, extents, gids = cs.guard_table
    aliased = np.asarray(gids) % 64                  # the seed's keying
    fences = _repack(cs.fences,
                     dst_off=np.asarray(unpack_cmds(
                         np.asarray(cs.fences).reshape(-1, 4)).dst_off) % 64)
    bad = cs._replace(guard_table=(bases, extents, aliased), fences=fences)
    ids = _rule_ids(verify(bad, n_channels=nc))
    assert "EPV-005" in ids, "duplicate guard id not flagged"
    assert "EPV-007" in ids, "merged fence counts not flagged"
    # and the clean wide-id table at the same shape verifies clean
    assert verify(cs, n_channels=nc) == []


def test_mutant_write_straddles_guard_epv006():
    """A dispatch write whose landing range crosses a bucket boundary
    (inline scale block creeping past the registered extent)."""
    cs, nc = _build_ll_cs(wdt="fp8")
    c = unpack_cmds(np.asarray(cs.writes).reshape(-1, 4))
    bases, extents, gids = cs.guard_table
    bad = cs._replace(writes=_repack(
        cs.writes, length=(0, int(c.length[0]) + int(np.max(extents)))))
    assert "EPV-006" in _rule_ids(verify(bad, n_channels=nc))


def test_mutant_fence_count_off_by_one_epv007():
    """Fence requiring one more write than the stream sends."""
    cs, nc = _build_ll_cs()
    c = unpack_cmds(np.asarray(cs.fences).reshape(-1, 4))
    bad = cs._replace(fences=_repack(cs.fences,
                                     src_off=(0, int(c.src_off[0]) + 1)))
    ids = _rule_ids(verify(bad, n_channels=nc))
    assert ids == {"EPV-007"}


def test_mutant_reorder_window_epv008():
    """Raw NetConfig with a reorder window at the seq-unwrap bound — the
    simulator refuses to construct this; the verifier flags it statically
    (both the window itself and the cap x window product)."""
    cfg = NetConfig(mode="srd", reorder_window=600)
    findings = verify(net_cfg=cfg)
    assert [f.rule for f in findings] == ["EPV-008", "EPV-008"]
    assert verify(net_cfg=NetConfig(mode="rc", reorder_window=600)) == []


def test_mutant_overlapping_session_slots_epv009():
    """Two session layers sharing memory / guard ids / adjacent channels."""
    a = SessSlot(send0=0, recv0=64, mid0=128, ret0=192, end=256,
                 guard0=0, ch0=0, ncl=2)
    b = SessSlot(send0=200, recv0=264, mid0=328, ret0=392, end=456,
                 guard0=0, ch0=0, ncl=2)       # overlaps a in all three
    ids = {f.rule for f in verify_session_slots([a, b], n_channels=4,
                                                counter_stride=128)}
    assert ids == {"EPV-009"}
    c = SessSlot(send0=256, recv0=320, mid0=384, ret0=448, end=512,
                 guard0=128, ch0=2, ncl=2)
    assert verify_session_slots([a, c], n_channels=4,
                                counter_stride=128) == []


def test_mutant_unknown_op_epv010():
    """BARRIER is a reserved opcode with no consumer path."""
    cs, nc = _build_ll_cs()
    bad = cs._replace(writes=_repack(cs.writes, op=(0, int(Op.BARRIER))))
    ids = _rule_ids(verify(bad, n_channels=nc))
    assert "EPV-010" in ids


def test_mutant_combine_into_guarded_range_epv012():
    """A combine write relocated into a registered receive bucket — it
    would count toward (and prematurely fire) a dispatch fence."""
    cs, nc = _build_ll_cs()
    bases, _, _ = cs.guard_table
    bad = cs._replace(combines=_repack(cs.combines,
                                       dst_off=(0, int(np.min(bases)))))
    ids = _rule_ids(verify(bad, n_channels=nc))
    assert "EPV-012" in ids


def test_verify_or_raise_lists_rule_ids():
    """The raising form names the violated rules in its message."""
    from repro.analysis import verify_or_raise
    from repro.core.transport import ProtocolError
    cs, nc = _build_ll_cs()
    bad = cs._replace(writes=_repack(cs.writes, op=(0, int(Op.BARRIER))))
    with pytest.raises(ProtocolError, match="EPV-010"):
        verify_or_raise(bad, n_channels=nc)
