"""Transport-semantics conformance fuzz harness (ISSUE 4 tentpole).

Drives randomized command streams through the delivery-semantics layer and
the full EP substrate, asserting the invariants the paper's §3.3/§4.1
correctness story rests on:

1. **Fence safety** — no completion fence applies before >= count writes
   have landed *inside its registered bucket range* (and only writes from
   the same peer count);
2. **Per-channel seq-prefix closure** — a SEQ_ATOMIC applies only after
   every smaller sequence on its channel applied, and once delivery
   finishes each channel's applied prefix is contiguous;
3. **Quiesce** — after the world drains, nothing is held in any control
   buffer, no command is mid-execution, no message is in flight;
4. **Oracle agreement** — the EP result equals the dense oracle bit-for-
   bit-in-float.

The matrix covers {rc, srd} x {ll, ht} x {inline, threaded} proxies and
eps (experts per rank) in {1, 63, 64, 128} — the 64/128 points are exactly
the regime the seed's 6-bit slot codec could not represent (DeepSeek-V3:
256 routed experts at EP degree <= 4).  Each property runs both as a
deterministic seeded sweep (always on, pinned repro seeds) and as a
hypothesis property with shrinking when hypothesis is installed (the
conftest stub skips those cleanly otherwise).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.transport import (ControlBuffer, EPWorld, GuardTable,
                                  ImmKind, NetConfig, pack_imm)

pytestmark = pytest.mark.timeout(120)   # a hung quiesce must fail fast

EPS_GRID = (1, 63, 64, 128)             # experts per rank; > 63 is the point


# ======================================================================
# Part 1: ControlBuffer-level conformance (pure semantics, no network)
# ======================================================================
def _gen_stream(rng, n_buckets=4, bucket_bytes=32, n_channels=3):
    """A random *sent* world: registered bucket table + per-channel command
    streams with consecutive sequence numbers, fences with satisfiable
    counts, and writes into unregistered memory (combine-return stand-ins).

    Returns (guards, events); each event is one of
      ("w", imm, dst_off, ch, seq)   write
      ("s", imm, ch, seq)            seq atomic
      ("f", imm, gid, need)          fence atomic
    """
    guards = GuardTable()
    for g in range(n_buckets):
        guards.register(g * bucket_bytes, bucket_bytes, g)
    unregistered0 = n_buckets * bucket_bytes + 17

    events = []
    next_seq = [0] * n_channels
    bucket_writes = [0] * n_buckets
    for _ in range(int(rng.integers(4, 40))):
        ch = int(rng.integers(0, n_channels))
        if rng.random() < 0.75:            # a write somewhere
            if rng.random() < 0.25:        # ... into unregistered memory
                off = unregistered0 + int(rng.integers(0, 64))
            else:
                g = int(rng.integers(0, n_buckets))
                off = g * bucket_bytes + int(rng.integers(0, bucket_bytes))
                bucket_writes[g] += 1
            seq = next_seq[ch]
            next_seq[ch] += 1
            events.append(("w", pack_imm(ImmKind.WRITE, ch, seq, 0), off,
                           ch, seq))
        else:                              # a seq atomic (HT chunk marker)
            seq = next_seq[ch]
            next_seq[ch] += 1
            events.append(("s", pack_imm(ImmKind.SEQ_ATOMIC, ch, seq,
                                         int(rng.integers(0, 1 << 16))),
                           ch, seq))
    # fences: required count <= writes landed in that bucket, so every
    # guard is eventually satisfiable (quiesce must leave nothing held)
    for g in range(n_buckets):
        if bucket_writes[g] and rng.random() < 0.8:
            need = int(rng.integers(1, bucket_writes[g] + 1))
            events.append(("f", pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, need),
                           g, need))
    return guards, events


def _replay_checked(guards, events, perm, cb_guards=None,
                    wire_gid=lambda g: g):
    """Deliver ``events`` in ``perm`` order through a ControlBuffer,
    asserting the fence/seq invariants at each apply, and the quiesce
    invariant at the end.  Returns the apply log.

    ``guards`` is the *ground-truth* bucket table the invariant checker
    attributes writes with; the system under test runs on ``cb_guards``
    (defaults to the same table) with fences addressed by ``wire_gid`` —
    the split lets the harness emulate a broken keying (e.g. the seed's
    slot aliasing) and prove the invariant catches it."""
    cb = ControlBuffer(guards=cb_guards if cb_guards is not None else guards)
    applied = []
    writes_in = {}                     # gid -> applied writes (ground truth)
    seqs_done = {}                     # ch -> set of applied seqs

    def on_write(off, ch, seq):
        gid = guards.resolve(off)
        if gid is not None:
            writes_in[gid] = writes_in.get(gid, 0) + 1
        seqs_done.setdefault(ch, set()).add(seq)
        applied.append(("w", ch, seq))

    def on_seq(ch, seq):
        done = seqs_done.setdefault(ch, set())
        assert done >= set(range(seq)), \
            f"SEQ_ATOMIC {seq} on ch {ch} applied before prefix closed"
        done.add(seq)
        applied.append(("s", ch, seq))

    def on_fence(gid, need):
        assert writes_in.get(gid, 0) >= need, \
            f"fence(guard={gid}, need={need}) applied after only " \
            f"{writes_in.get(gid, 0)} writes in its range"
        applied.append(("f", gid, need))

    for i in perm:
        ev = events[i]
        if ev[0] == "w":
            _, imm, off, ch, seq = ev
            cb.on_write(imm, lambda o=off, c=ch, s=seq: on_write(o, c, s),
                        off)
        elif ev[0] == "s":
            _, imm, ch, seq = ev
            cb.on_atomic(imm, lambda c=ch, s=seq: on_seq(c, s))
        else:
            _, imm, gid, need = ev
            cb.on_atomic(imm, lambda g=gid, n=need: on_fence(g, n),
                         guard=wire_gid(gid))
    # reliable transport: everything delivered => everything applied,
    # nothing held, every channel's seq prefix closed
    assert len(applied) == len(events)
    assert cb.n_held == 0
    assert all(not h for h in cb._arrived.values())
    return applied


def _cb_case(seed):
    rng = np.random.default_rng(seed)
    guards, events = _gen_stream(rng)
    perm = rng.permutation(len(events))
    _replay_checked(guards, events, perm)


@pytest.mark.parametrize("seed", range(40))
def test_control_buffer_conformance_seeded(seed):
    """Pinned-seed sweep of the semantics invariants (runs without
    hypothesis; the property version below adds shrinking)."""
    _cb_case(seed)


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_control_buffer_conformance_property(seed):
    _cb_case(seed)


def test_old_slot_keying_fence_aliasing_detected():
    """Pinned repro of the bug this PR fixes: the seed keyed guards by a
    6-bit wire slot, aliasing expert e onto guard e % 64 past 63 experts
    per rank — writes for expert 0 counted toward expert 64's fence, which
    then applied on a partially-landed bucket.  Emulating that keying as an
    aliased guard table, the harness's fence-safety invariant catches the
    corruption; the address-range table keeps the buckets distinct and the
    invariant holds."""
    bucket = 32
    # ground truth: expert 0 and expert 64 own distinct buckets/guards
    guards = GuardTable()
    guards.register(0 * bucket, bucket, 0)
    guards.register(64 * bucket, bucket, 64)
    # stream: 3 writes into expert-0's bucket, then a fence for expert 64's
    # bucket (count 3) — expert 64's own writes never sent
    events = [("w", pack_imm(ImmKind.WRITE, 0, s, 0), 0 * bucket + 4 * s,
               0, s) for s in range(3)]
    events.append(("f", pack_imm(ImmKind.FENCE_ATOMIC, 0, 0, 3), 64, 3))
    perm = np.arange(len(events))

    # old keying: both buckets count toward guard 64 % 64 == 0 and the
    # fence addresses guard 0 too => it applies with ZERO writes in expert
    # 64's bucket — the harness's fence-safety invariant trips
    aliased = GuardTable()
    aliased.register(0 * bucket, bucket, 0)          # expert 0 -> guard 0
    aliased.register(64 * bucket, bucket, 64 % 64)   # expert 64 -> guard 0!
    with pytest.raises(AssertionError, match="applied after only"):
        _replay_checked(guards, events, perm, cb_guards=aliased,
                        wire_gid=lambda g: g % 64)

    # address-range keying: distinct guards; the fence is (correctly) held
    # until expert 64's writes land — deliver them and it applies
    cb = ControlBuffer(guards=guards)
    for _, imm, off, ch, seq in events[:3]:
        cb.on_write(imm, lambda: None, off)
    fired = []
    cb.on_atomic(events[3][1], lambda: fired.append(1), guard=64)
    assert not fired and cb.n_held == 1      # held: bucket 64 is empty
    for s in range(3):
        cb.on_write(pack_imm(ImmKind.WRITE, 1, s, 0), lambda: None,
                    64 * bucket + 4 * s)
    assert fired and cb.n_held == 0


# ======================================================================
# Part 2: end-to-end EP protocol over the full matrix
# ======================================================================
def _run_ep_case(mode, proto, eps, threaded, seed):
    rng = np.random.default_rng(seed)
    R = 2
    E = eps * R
    K = int(rng.integers(1, 4))
    D = F = 8
    Tl = int(rng.integers(4, 9))
    window = int(rng.choice([1, 16, 128]))
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.2).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.2).astype(np.float32)

    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode=mode, seed=seed,
                                  reorder_window=window),
                use_threads=threaded, n_threads=2)
    try:
        if proto == "ll":
            out = w.run(x, ti, tw, wg, wu, wd)
        else:
            out = w.run_ht(x, ti, tw, wg, wu, wd,
                           n_chunks=int(rng.integers(1, 5)))
        ref = EPWorld.oracle(x, ti, tw, wg, wu, wd)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        # quiesce invariants: nothing in flight, queued, or held anywhere
        assert w.net.pending == 0
        for p in w.proxies:
            assert p.error is None
            assert not p.busy
            for cb in p.ctrl.values():
                assert cb.n_held == 0, "quiesce left a guarded atomic held"
                # per-channel seq-prefix closure: every sequence the peer
                # consumed was applied contiguously
                assert all(not h for h in cb._arrived.values())
    finally:
        if threaded:
            for p in w.proxies:
                p.stop()


@pytest.mark.parametrize("mode", ["rc", "srd"])
@pytest.mark.parametrize("eps", EPS_GRID)
def test_ep_conformance_inline_seeded(mode, eps):
    """Deterministic matrix sweep: {rc, srd} x {ll, ht} x inline proxies x
    eps in {1, 63, 64, 128} against the dense oracle + quiesce invariants."""
    for proto in ("ll", "ht"):
        for seed in (0, 1):
            _run_ep_case(mode, proto, eps, threaded=False, seed=seed)


@pytest.mark.parametrize("proto", ["ll", "ht"])
@pytest.mark.parametrize("eps", [1, 64])
def test_ep_conformance_threaded_seeded(proto, eps):
    """Threaded-proxy points of the matrix (worker threads drain FIFOs
    concurrently with the event-clock pump; exercises the locked
    pending/next_event_t quiesce path)."""
    _run_ep_case("srd", proto, eps, threaded=True, seed=2)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       mode=st.sampled_from(["rc", "srd"]),
       proto=st.sampled_from(["ll", "ht"]),
       eps=st.sampled_from(EPS_GRID))
def test_ep_conformance_property(seed, mode, proto, eps):
    """Hypothesis form of the matrix sweep: randomized routing/topology
    with shrinking toward a minimal failing (seed, mode, proto, eps)."""
    _run_ep_case(mode, proto, eps, threaded=False, seed=seed)
