"""Per-kernel validation: pl.pallas_call(interpret=True) against the pure-jnp
oracles in repro.kernels.ref, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.combine_reduce import combine_reduce_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import (grouped_matmul_pallas,
                                          grouped_swiglu_pallas)
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

# fp32 tolerance allows K-blocked accumulation-order differences vs the
# single-einsum oracle (~1e-5 relative on 512-deep reductions)
TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dt):
    return TOL[dt]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("g,m,k,n,bm,bn,bk", [
    (2, 128, 128, 128, 128, 128, 128),
    (4, 256, 128, 256, 128, 128, 64),
    (1, 128, 512, 128, 64, 128, 256),
    (3, 384, 256, 128, 128, 128, 128),
])
def test_grouped_matmul(dtype, g, m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m * n)
    x = jax.random.normal(key, (g, m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (g, k, n), dtype)
    got = grouped_matmul_pallas(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = R.grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f,bm,bf", [
    (2, 128, 128, 256, 128, 128),
    (4, 256, 128, 128, 128, 128),
    (1, 128, 256, 384, 64, 128),
])
def test_grouped_swiglu_fused(dtype, e, c, d, f, bm, bf):
    """The fused kernel accumulates in fp32; in bf16 it must be at least as
    close to the fp32 oracle as the bf16 reference chain is (the kernel is
    MORE accurate than the ref — elementwise comparison to the bf16 ref
    over-penalises it)."""
    key = jax.random.PRNGKey(c)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    wg = jax.random.normal(ks[1], (e, d, f), dtype) * 0.1
    wu = jax.random.normal(ks[2], (e, d, f), dtype) * 0.1
    wd = jax.random.normal(ks[3], (e, f, d), dtype) * 0.1
    got = grouped_swiglu_pallas(x, wg, wu, wd, bm=bm, bf=bf, interpret=True)
    oracle = np.asarray(R.grouped_swiglu_ref(
        x.astype(jnp.float32), wg.astype(jnp.float32),
        wu.astype(jnp.float32), wd.astype(jnp.float32)), np.float32)
    if dtype == jnp.float32:
        np.testing.assert_allclose(np.asarray(got, np.float32), oracle,
                                   **_tol(dtype))
    else:
        ref = np.asarray(R.grouped_swiglu_ref(x, wg, wu, wd), np.float32)
        err_kernel = np.abs(np.asarray(got, np.float32) - oracle).mean()
        err_ref = np.abs(ref - oracle).mean()
        assert err_kernel <= err_ref * 1.5 + 1e-3, (err_kernel, err_ref)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,d,bq,bk", [
    (1, 256, 4, 4, 64, 128, 128),      # MHA
    (2, 256, 4, 2, 64, 128, 64),       # GQA 2:1
    (1, 512, 8, 2, 64, 256, 128),      # GQA 4:1
    (1, 128, 2, 1, 128, 128, 128),     # MQA, single block
])
def test_flash_attention_causal(dtype, b, s, h, hkv, d, bq, bk):
    key = jax.random.PRNGKey(s + h)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk,
                                 interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=True)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_flash_attention_noncausal():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    got = flash_attention_pallas(q, k, v, causal=False, bq=128, bk=128,
                                 interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bt,s,di,n,bd,chunk", [
    (1, 128, 256, 16, 128, 64),
    (2, 256, 128, 16, 128, 128),
    (1, 64, 512, 8, 256, 32),
])
def test_mamba_scan(bt, s, di, n, bd, chunk):
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bt, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    B = jax.random.normal(ks[3], (bt, s, n))
    C = jax.random.normal(ks[4], (bt, s, n))
    D = jnp.ones((di,))
    got = mamba_scan_pallas(x, dt, A, B, C, D, bd=bd, chunk=chunk,
                            interpret=True)
    ref = R.mamba_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,k,d", [(256, 4, 128), (512, 8, 64), (128, 1, 256)])
def test_combine_reduce(dtype, t, k, d):
    key = jax.random.PRNGKey(t + k)
    parts = jax.random.normal(key, (t, k, d), dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (t, k)), -1)
    got = combine_reduce_pallas(parts, w, interpret=True)
    ref = R.combine_reduce_ref(parts, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(256, 128), (4, 64, 256), (1024, 512)])
def test_rmsnorm(dtype, shape):
    key = jax.random.PRNGKey(shape[-1])
    x = jax.random.normal(key, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(2), (shape[-1],), jnp.float32)
    got = rmsnorm_pallas(x, s, interpret=True)
    ref = R.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_blocked_jnp_attention_matches_naive():
    """The model's blocked (flash-style) jnp attention == naive reference,
    including the hierarchical causal-skip decomposition."""
    from repro.models.layers import flash_attention_blocked
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    ref = R.flash_attention_ref(q, k, v, causal=True)
    for skip in (False, True):
        got = flash_attention_blocked(q, k, v, causal=True, q_block=64,
                                      kv_block=64, causal_skip=skip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4, err_msg=f"skip={skip}")


@pytest.mark.parametrize("b,h,hkv,d,s,pos", [
    (2, 8, 2, 64, 256, 100),
    (1, 4, 4, 128, 512, 511),
    (2, 16, 8, 64, 256, 0),
])
def test_decode_attention(b, h, hkv, d, s, pos):
    """Flash-decoding kernel vs the model's partial-attention reference."""
    from repro.kernels.decode_attention import decode_attention_pallas
    from repro.models.layers import decode_attention_local
    ks = jax.random.split(jax.random.PRNGKey(s + pos), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    got = decode_attention_pallas(q, k, v, pos, bk=128, interpret=True)
    part = decode_attention_local(q[:, None], k, v, jnp.int32(pos))
    l = jnp.where(part.l == 0, 1.0, part.l)
    ref = (part.o / l[..., None])[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
