"""Per-kernel validation: pl.pallas_call(interpret=True) against the pure-jnp
oracles in repro.kernels.ref, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.combine_reduce import combine_reduce_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import (gather_swiglu_scatter_pallas,
                                          grouped_matmul_pallas,
                                          grouped_swiglu_db_pallas,
                                          grouped_swiglu_pallas)
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

# fp32 tolerance allows K-blocked accumulation-order differences vs the
# single-einsum oracle (~1e-5 relative on 512-deep reductions)
TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dt):
    return TOL[dt]


# the largest interpret-mode shapes are slow-marked (bounded default run;
# the full sweep runs under `pytest -m slow`) — one representative shape per
# kernel always runs
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("g,m,k,n,bm,bn,bk", [
    (2, 128, 128, 128, 128, 128, 128),
    pytest.param(4, 256, 128, 256, 128, 128, 64, marks=pytest.mark.slow),
    pytest.param(1, 128, 512, 128, 64, 128, 256, marks=pytest.mark.slow),
    pytest.param(3, 384, 256, 128, 128, 128, 128, marks=pytest.mark.slow),
])
def test_grouped_matmul(dtype, g, m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m * n)
    x = jax.random.normal(key, (g, m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (g, k, n), dtype)
    got = grouped_matmul_pallas(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = R.grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f,bm,bf", [
    (2, 128, 128, 256, 128, 128),
    pytest.param(4, 256, 128, 128, 128, 128, marks=pytest.mark.slow),
    pytest.param(1, 128, 256, 384, 64, 128, marks=pytest.mark.slow),
])
def test_grouped_swiglu_fused(dtype, e, c, d, f, bm, bf):
    """The fused kernel accumulates in fp32; in bf16 it must be at least as
    close to the fp32 oracle as the bf16 reference chain is (the kernel is
    MORE accurate than the ref — elementwise comparison to the bf16 ref
    over-penalises it)."""
    key = jax.random.PRNGKey(c)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    wg = jax.random.normal(ks[1], (e, d, f), dtype) * 0.1
    wu = jax.random.normal(ks[2], (e, d, f), dtype) * 0.1
    wd = jax.random.normal(ks[3], (e, f, d), dtype) * 0.1
    got = grouped_swiglu_pallas(x, wg, wu, wd, bm=bm, bf=bf, interpret=True)
    oracle = np.asarray(R.grouped_swiglu_ref(
        x.astype(jnp.float32), wg.astype(jnp.float32),
        wu.astype(jnp.float32), wd.astype(jnp.float32)), np.float32)
    if dtype == jnp.float32:
        np.testing.assert_allclose(np.asarray(got, np.float32), oracle,
                                   **_tol(dtype))
    else:
        ref = np.asarray(R.grouped_swiglu_ref(x, wg, wu, wd), np.float32)
        err_kernel = np.abs(np.asarray(got, np.float32) - oracle).mean()
        err_ref = np.abs(ref - oracle).mean()
        assert err_kernel <= err_ref * 1.5 + 1e-3, (err_kernel, err_ref)


# ---------------- occupancy-aware + fused kernels (ISSUE 3) ---------------
# Ragged coverage by construction: C not a multiple of bm, F not a multiple
# of bf, an expert with 0 occupied rows, and a single-row expert — for both
# the occupancy-aware and the legacy (counts=None) entry points.
RAGGED = [
    # e, c, d, f, bm, bf, counts
    (4, 20, 16, 13, 8, 8, (5, 0, 20, 1)),          # ragged C and F
    (3, 17, 8, 24, 16, 16, (17, 1, 0)),            # single block, 1-row expert
    (2, 32, 16, 19, 8, 4, (0, 0)),                 # fully empty
    (1, 128, 32, 48, 128, 48, (64,)),              # aligned, half occupancy
]


def _ragged_problem(e, c, d, f, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (e, c, d), jnp.float32)
    wg = jax.random.normal(ks[1], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[2], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[3], (e, f, d)) * 0.2
    return x, wg, wu, wd


@pytest.mark.parametrize("variant", ["pipelined", "double_buffered"])
@pytest.mark.parametrize("e,c,d,f,bm,bf,counts", RAGGED)
def test_grouped_swiglu_occupancy_ragged(variant, e, c, d, f, bm, bf, counts):
    x, wg, wu, wd = _ragged_problem(e, c, d, f, seed=e * 7 + c)
    cnt = jnp.asarray(counts, jnp.int32)
    kern = (grouped_swiglu_db_pallas if variant == "double_buffered"
            else grouped_swiglu_pallas)
    for cc in (cnt, None):        # occupancy-aware and legacy entry points
        got = kern(x, wg, wu, wd, cc, bm=bm, bf=bf, interpret=True)
        ref = R.grouped_swiglu_ref(x, wg, wu, wd, counts=cc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
    # rows beyond occupancy are exact zeros (the masked-ref contract)
    got = np.asarray(kern(x, wg, wu, wd, cnt, bm=bm, bf=bf, interpret=True))
    for g in range(e):
        assert (got[g, int(cnt[g]):] == 0.0).all()


def test_grouped_swiglu_db_multiblock_partial_occupancy():
    """The double-buffered DMA pipeline itself (bm | C, so no pipelined
    fallback) with multi-block groups whose occupancy ends mid-block —
    exercising the prefetch-stop condition and tail-row masking."""
    e, c, d, f, bm = 2, 32, 16, 24, 8
    x, wg, wu, wd = _ragged_problem(e, c, d, f, seed=11)
    cnt = jnp.array([10, 25], jnp.int32)     # 0 < cnt % bm, several blocks
    got = grouped_swiglu_db_pallas(x, wg, wu, wd, cnt, bm=bm, bf=8,
                                   interpret=True)
    ref = R.grouped_swiglu_ref(x, wg, wu, wd, counts=cnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    assert (np.asarray(got)[0, 10:] == 0.0).all()
    assert (np.asarray(got)[1, 25:] == 0.0).all()


def test_grouped_swiglu_bucketed_counts():
    """(E, B) sub-bucket counts — the post-a2a LL receive layout where each
    source shard contributes its own occupied-prefix capacity bucket."""
    e, c, d, f = 4, 24, 16, 13
    x, wg, wu, wd = _ragged_problem(e, c, d, f, seed=3)
    cnt = jnp.array([[3, 5], [0, 0], [12, 2], [1, 0]], jnp.int32)
    got = grouped_swiglu_pallas(x, wg, wu, wd, cnt, bm=4, bf=8,
                                interpret=True)
    ref = R.grouped_swiglu_ref(x, wg, wu, wd, counts=cnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("g,m,k,n,bm,bk,counts", [
    (3, 20, 13, 16, 8, 8, (7, 0, 20)),             # ragged M and K
    (2, 128, 128, 64, 128, 64, (1, 100)),          # aligned, 1-row group
])
def test_grouped_matmul_occupancy_ragged(g, m, k, n, bm, bk, counts):
    ks = jax.random.split(jax.random.PRNGKey(m + k), 2)
    x = jax.random.normal(ks[0], (g, m, k), jnp.float32)
    w = jax.random.normal(ks[1], (g, k, n), jnp.float32)
    cnt = jnp.asarray(counts, jnp.int32)
    for cc in (cnt, None):
        got = grouped_matmul_pallas(x, w, cc, bm=bm, bn=64, bk=bk,
                                    interpret=True)
        ref = R.grouped_matmul_ref(x, w, counts=cc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def _slot_problem(e, c, t, counts, seed=0):
    """Random src_of_slot/w_slot tables with occupied-prefix buckets."""
    rng = np.random.default_rng(seed)
    src = np.full((e * c,), t, np.int32)
    wsl = np.zeros((e * c,), np.float32)
    for g in range(e):
        for r in range(int(counts[g])):
            src[g * c + r] = rng.integers(0, t)
            wsl[g * c + r] = rng.random() + 0.1
    return jnp.asarray(src), jnp.asarray(wsl)


@pytest.mark.parametrize("e,c,d,f,bm,bf,counts", RAGGED)
def test_gather_swiglu_scatter_fused(e, c, d, f, bm, bf, counts):
    """The fused gather->SwiGLU->scatter kernel == its jnp oracle on ragged
    shapes, for both the occupancy-aware and legacy entry points."""
    t = 11
    _, wg, wu, wd = _ragged_problem(e, c, d, f, seed=e + c)
    xt = jax.random.normal(jax.random.PRNGKey(5), (t, d), jnp.float32)
    x_ext = jnp.concatenate([xt, jnp.zeros((1, d))], 0)
    cnt = jnp.asarray(counts, jnp.int32)
    src, wsl = _slot_problem(e, c, t, counts, seed=c)
    for cc in (cnt, None):
        got = gather_swiglu_scatter_pallas(x_ext, src, wsl, wg, wu, wd, cc,
                                           bm=bm, bf=bf, interpret=True)
        ref = R.gather_swiglu_scatter_ref(x_ext, src, wsl, wg, wu, wd,
                                          counts=cc)
        assert got.shape == (t, d) and got.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


def test_gather_swiglu_scatter_duplicate_tokens():
    """A token appearing in several slots (top-k routing) accumulates every
    weighted contribution — the scatter-add must not last-write-win."""
    e, c, d, f, t = 2, 8, 16, 24, 3
    _, wg, wu, wd = _ragged_problem(e, c, d, f, seed=1)
    xt = jax.random.normal(jax.random.PRNGKey(2), (t, d), jnp.float32)
    x_ext = jnp.concatenate([xt, jnp.zeros((1, d))], 0)
    # token 0 hits both experts twice each
    src = jnp.asarray(np.array([0, 0, 1] + [t] * 5 + [0, 0, 2] + [t] * 5,
                               np.int32))
    wsl = jnp.asarray(np.array([.5, .25, 1.] + [0.] * 5) .tolist() * 2,
                      dtype=jnp.float32)
    cnt = jnp.array([3, 3], jnp.int32)
    got = gather_swiglu_scatter_pallas(x_ext, src, wsl, wg, wu, wd, cnt,
                                       bm=4, bf=8, interpret=True)
    ref = R.gather_swiglu_scatter_ref(x_ext, src, wsl, wg, wu, wd, counts=cnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,d,bq,bk", [
    pytest.param(1, 256, 4, 4, 64, 128, 128,       # MHA
                 marks=pytest.mark.slow),
    (2, 256, 4, 2, 64, 128, 64),       # GQA 2:1
    pytest.param(1, 512, 8, 2, 64, 256, 128,       # GQA 4:1
                 marks=pytest.mark.slow),
    (1, 128, 2, 1, 128, 128, 128),     # MQA, single block
])
def test_flash_attention_causal(dtype, b, s, h, hkv, d, bq, bk):
    key = jax.random.PRNGKey(s + h)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk,
                                 interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=True)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_flash_attention_noncausal():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    got = flash_attention_pallas(q, k, v, causal=False, bq=128, bk=128,
                                 interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bt,s,di,n,bd,chunk", [
    pytest.param(1, 128, 256, 16, 128, 64, marks=pytest.mark.slow),
    pytest.param(2, 256, 128, 16, 128, 128, marks=pytest.mark.slow),
    (1, 64, 512, 8, 256, 32),
])
def test_mamba_scan(bt, s, di, n, bd, chunk):
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bt, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    B = jax.random.normal(ks[3], (bt, s, n))
    C = jax.random.normal(ks[4], (bt, s, n))
    D = jnp.ones((di,))
    got = mamba_scan_pallas(x, dt, A, B, C, D, bd=bd, chunk=chunk,
                            interpret=True)
    ref = R.mamba_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,k,d", [(256, 4, 128), (512, 8, 64), (128, 1, 256)])
def test_combine_reduce(dtype, t, k, d):
    key = jax.random.PRNGKey(t + k)
    parts = jax.random.normal(key, (t, k, d), dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (t, k)), -1)
    got = combine_reduce_pallas(parts, w, interpret=True)
    ref = R.combine_reduce_ref(parts, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(256, 128), (4, 64, 256), (1024, 512)])
def test_rmsnorm(dtype, shape):
    key = jax.random.PRNGKey(shape[-1])
    x = jax.random.normal(key, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(2), (shape[-1],), jnp.float32)
    got = rmsnorm_pallas(x, s, interpret=True)
    ref = R.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_blocked_jnp_attention_matches_naive():
    """The model's blocked (flash-style) jnp attention == naive reference,
    including the hierarchical causal-skip decomposition."""
    from repro.models.layers import flash_attention_blocked
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    ref = R.flash_attention_ref(q, k, v, causal=True)
    for skip in (False, True):
        got = flash_attention_blocked(q, k, v, causal=True, q_block=64,
                                      kv_block=64, causal_skip=skip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4, err_msg=f"skip={skip}")


@pytest.mark.parametrize("b,h,hkv,d,s,pos", [
    (2, 8, 2, 64, 256, 100),
    pytest.param(1, 4, 4, 128, 512, 511, marks=pytest.mark.slow),
    (2, 16, 8, 64, 256, 0),
])
def test_decode_attention(b, h, hkv, d, s, pos):
    """Flash-decoding kernel vs the model's partial-attention reference."""
    from repro.kernels.decode_attention import decode_attention_pallas
    from repro.models.layers import decode_attention_local
    ks = jax.random.split(jax.random.PRNGKey(s + pos), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    got = decode_attention_pallas(q, k, v, pos, bk=128, interpret=True)
    part = decode_attention_local(q[:, None], k, v, jnp.int32(pos))
    l = jnp.where(part.l == 0, 1.0, part.l)
    ref = (part.o / l[..., None])[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# paged decode attention (serving, ISSUE 10)
# ---------------------------------------------------------------------------
def _paged_problem(seed, b, h, hkv, d, bs, nb_pool, nb_seq, pos):
    """Random pools + per-sequence block tables whose live prefix points at
    scattered physical blocks; dead tail entries are -1."""
    from repro.kernels.decode_attention import decode_attention_paged_ref
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb_pool, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb_pool, bs, hkv, d)), jnp.float32)
    bt = np.full((b, nb_seq), -1, np.int32)
    posv = np.asarray(pos, np.int32)
    for i in range(b):
        live = posv[i] // bs + 1
        bt[i, :live] = rng.choice(nb_pool, size=live, replace=False)
    ref = decode_attention_paged_ref(q, kp, vp, jnp.asarray(bt),
                                     jnp.asarray(posv))
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(posv), ref


@pytest.mark.parametrize("b,h,hkv,d,bs,pos", [
    (2, 4, 2, 32, 8, (19, 5)),        # GQA rep=2, scattered blocks
    (3, 6, 2, 32, 8, (7, 8, 23)),     # pos ON and just past a block edge
    (1, 9, 3, 32, 16, (0,)),          # rep=3, single live token
])
def test_decode_attention_paged_vs_ref(b, h, hkv, d, bs, pos):
    """Paged flash decoding == gather-then-mask oracle, including dead (-1)
    table entries and positions on block boundaries."""
    from repro.kernels.decode_attention import decode_attention_paged
    q, kp, vp, bt, posv, ref = _paged_problem(0, b, h, hkv, d, bs, 32,
                                              4, pos)
    got = decode_attention_paged(q, kp, vp, bt, posv, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_paged_matches_contiguous():
    """With an identity block table the paged kernel must reproduce the
    contiguous decode kernel bit-for-bit on the same (gathered) cache."""
    from repro.kernels.decode_attention import (decode_attention_paged,
                                                decode_attention_pallas)
    b, h, hkv, d, bs, nb = 2, 4, 2, 32, 8, 4
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((b * nb, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((b * nb, bs, hkv, d)), jnp.float32)
    bt = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
    pos = jnp.asarray([bs * nb - 1, bs + 2], jnp.int32)
    paged = decode_attention_paged(q, kp, vp, bt, pos, interpret=True)
    kc = kp.reshape(b, nb * bs, hkv, d)
    vc = vp.reshape(b, nb * bs, hkv, d)
    for i in range(b):   # contiguous kernel takes one scalar pos at a time
        cont = decode_attention_pallas(q[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                                       int(pos[i]), bk=bs, interpret=True)
        assert jnp.array_equal(paged[i], cont[0]), f"seq {i} diverged"


def test_decode_attention_paged_ignores_dead_blocks():
    """Whatever garbage the -1 (clamped-to-0) entries DMA in must not leak:
    mutating unreferenced pool blocks cannot change the output."""
    from repro.kernels.decode_attention import decode_attention_paged
    q, kp, vp, bt, posv, _ = _paged_problem(2, 2, 4, 2, 32, 8, 16, 4, (9, 3))
    out1 = decode_attention_paged(q, kp, vp, bt, posv, interpret=True)
    live = np.unique(np.asarray(bt)[np.asarray(bt) >= 0])
    dead = np.setdiff1d(np.arange(kp.shape[0]), live)
    kp2 = kp.at[jnp.asarray(dead)].set(1e9)
    vp2 = vp.at[jnp.asarray(dead)].set(-1e9)
    out2 = decode_attention_paged(q, kp2, vp2, bt, posv, interpret=True)
    assert jnp.array_equal(out1, out2)
