"""Per-arch smoke tests (assignment deliverable f): REDUCED same-family
configs, one forward + one train step on CPU, asserting shapes + no NaNs.
The FULL configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import model_zoo as Z
from repro.training.train_loop import HParams, init_state, train_step

# bounded default run (ISSUE 4 satellite): every invocation covers one
# attention, one MoE and one SSM family; the full arch matrix (~90 s of jit
# compiles) runs under `pytest -m slow`.
_DEFAULT_ARCHS = {"qwen3_1_7b", "moonshot_v1_16b_a3b", "falcon_mamba_7b"}
_ARCH_PARAMS = [a if a in _DEFAULT_ARCHS
                else pytest.param(a, marks=pytest.mark.slow)
                for a in ARCH_IDS]


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch), n_layers=2, d_model=64, vocab=512)
    key = jax.random.PRNGKey(0)
    params = Z.init_params(cfg, key)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pre = None
    if cfg.frontend_prefix:
        pre = jax.random.normal(key, (B, cfg.frontend_prefix, cfg.d_model))
    h, aux = Z.forward(cfg, Z.cast_params(params, jnp.bfloat16), tokens, pre)
    S_tot = S + cfg.frontend_prefix
    assert h.shape == (B, S_tot, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all()), f"{arch}: NaN/inf"


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_one_train_step(arch):
    cfg = reduced_config(get_config(arch), n_layers=2, d_model=64, vocab=512)
    # warmup=1 so the first step uses the full lr (the param-change check
    # below would otherwise sit inside allclose tolerance for norm scales)
    hp = HParams(moe_mode="ht", loss_chunk=32, peak_lr=1e-2, warmup=1)
    state = init_state(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend_prefix:
        batch["prefix"] = jax.random.normal(
            key, (B, cfg.frontend_prefix, cfg.d_model))
    state2, metrics = jax.jit(
        lambda s, b: train_step(cfg, hp, None, s, b))(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero gradient"
    assert int(state2.opt.step) == 1
    # params actually changed (embedding rows always receive gradient)
    d0 = np.asarray(state.params["embed"])
    d1 = np.asarray(state2.params["embed"])
    assert np.abs(d1 - d0).max() > 1e-6


@pytest.mark.parametrize("arch", [
    "qwen3_1_7b", "falcon_mamba_7b",
    pytest.param("moonshot_v1_16b_a3b", marks=pytest.mark.slow),
    pytest.param("jamba_1_5_large_398b", marks=pytest.mark.slow),
])
def test_decode_matches_forward(arch):
    cfg = reduced_config(get_config(arch), n_layers=2, d_model=64, vocab=512)
    cfg = dataclasses.replace(cfg, dtype="float32", remat=False)
    key = jax.random.PRNGKey(0)
    params = Z.init_params(cfg, key)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _ = Z.forward(cfg, Z.cast_params(params, jnp.float32), tokens)
    ref_logits = h[:, -1] @ Z.lm_head_weight(
        cfg, Z.cast_params(params, jnp.float32))
    cache = Z.init_cache(cfg, B, max_len=16, dtype=jnp.float32)
    for t in range(S):
        logits, cache = Z.decode_step(cfg, params, cache, tokens[:, t:t + 1], t)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=1e-3, atol=1e-3)


def test_moe_modes_agree():
    """LL, HT and the dense ref path produce the same layer output
    (mesh (1,1): the EP machinery runs with degree-1 collectives)."""
    from jax.sharding import AxisType
    from repro.distributed.sharding import make_dist_ctx
    cfg = reduced_config(get_config("moonshot_v1_16b_a3b"), n_layers=2,
                         d_model=64, n_experts=8, vocab=256)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    dist = make_dist_ctx(cfg, mesh)
    assert dist.ep_axes == ("model",)
    key = jax.random.PRNGKey(0)
    params = Z.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    outs = {}
    with jax.set_mesh(mesh):
        for mode, d in (("ref", None), ("ll", dist), ("ht", dist)):
            h, _ = jax.jit(lambda p, t, mode=mode, d=d: Z.forward(
                cfg, Z.cast_params(p, jnp.float32), t, dist=d,
                moe_mode=mode))(params, tokens)
            outs[mode] = np.asarray(h, np.float32)
    np.testing.assert_allclose(outs["ll"], outs["ref"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["ht"], outs["ref"], rtol=2e-4, atol=2e-4)
