import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# NOTE: no XLA_FLAGS here — smoke tests must see 1 device (assignment rule).
# Multi-device tests run via run_distributed() subprocesses.


def run_distributed(script: str, n_devices: int = 8, timeout: int = 900):
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"distributed subprocess failed:\nSTDOUT:\n{proc.stdout[-4000:]}"
            f"\nSTDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def dist_runner():
    return run_distributed
