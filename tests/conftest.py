import os
import signal
import subprocess
import sys
import threading
import types
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import repro.compat  # noqa: E402,F401  jax version shims (AxisType, shard_map)

# ---- hypothesis shim -------------------------------------------------------
# Property tests use hypothesis, which is a dev extra.  In a clean env the
# suite must still collect and run: install a stub module whose @given turns
# each property test into a zero-arg skipper, so only the property tests are
# skipped and everything else runs.
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given_stub(*_a, **_k):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed (property test)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings_stub(*_a, **_k):
        return lambda fn: fn

    def _strategy_stub(*_a, **_k):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "lists", "tuples", "text",
                  "sampled_from", "just", "one_of", "data", "composite"):
        setattr(_st, _name, _strategy_stub)
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given_stub
    _hyp.settings = _settings_stub
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    _hyp.assume = lambda *a, **k: True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

# ---- per-test timeout ------------------------------------------------------
# pytest-timeout is not available in hermetic containers, so the harness is
# hand-rolled: every test gets a SIGALRM-based wall-clock budget (default
# REPRO_TEST_TIMEOUT_S, override per test with @pytest.mark.timeout(N)) so a
# hung transport quiesce or deadlocked FIFO fails fast with a stack instead
# of wedging CI.  SIGALRM interrupts the main thread only — exactly where
# pytest runs test bodies; proxy worker threads are daemons and die with it.
_DEFAULT_TEST_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT_S", "300"))


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    limit = float(marker.args[0]) if marker and marker.args \
        else _DEFAULT_TEST_TIMEOUT_S
    if (limit <= 0 or not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        return (yield)

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {limit:.0f}s per-test timeout")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        return (yield)
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)


# NOTE: no XLA_FLAGS here — smoke tests must see 1 device (assignment rule).
# Multi-device tests run via run_distributed() subprocesses.


def run_distributed(script: str, n_devices: int = 8, timeout: int = 900):
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = "import repro.compat  # jax version shims\n" + script
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"distributed subprocess failed:\nSTDOUT:\n{proc.stdout[-4000:]}"
            f"\nSTDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def dist_runner():
    return run_distributed
