"""Checkpointer: atomic writes, retention, resume, corruption fallback."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced_config
from repro.training.train_loop import init_state


def _tiny_state():
    cfg = reduced_config(get_config("qwen3_1_7b"), n_layers=2, d_model=32,
                         vocab=128)
    return cfg, init_state(cfg, jax.random.PRNGKey(0))


def test_roundtrip(tmp_path):
    cfg, state = _tiny_state()
    ck = Checkpointer(tmp_path)
    ck.save(state, 7)
    got, step = ck.restore_latest(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention(tmp_path):
    cfg, state = _tiny_state()
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(state, s)
    assert ck.list_steps() == [3, 4]


def test_corruption_falls_back(tmp_path):
    cfg, state = _tiny_state()
    ck = Checkpointer(tmp_path, keep=5)
    ck.save(state, 1)
    p2 = ck.save(state, 2)
    # crash mid-write: truncate the newest npz
    with open(p2 / "state.npz", "r+b") as f:
        f.truncate(100)
    got, step = ck.restore_latest(state)
    assert step == 1


def test_missing_manifest_is_invisible(tmp_path):
    cfg, state = _tiny_state()
    ck = Checkpointer(tmp_path)
    p = ck.save(state, 3)
    os.remove(p / "MANIFEST.json")           # crashed before manifest
    assert ck.list_steps() == []
    assert ck.restore_latest(state) is None


def test_shape_mismatch_rejected(tmp_path):
    cfg, state = _tiny_state()
    ck = Checkpointer(tmp_path)
    ck.save(state, 1)
    bigger = jax.tree.map(lambda x: jnp.zeros((7,) + x.shape, x.dtype), state)
    try:
        ck.restore(bigger, 1)
        raised = False
    except ValueError:
        raised = True
    assert raised
