"""EP dispatch/combine logic tests (single device: mesh (1,1) degenerates
the collectives to identity, exercising all bucketing/dedup/combine math).
Multi-device equivalence runs in test_distributed.py subprocesses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import AxisType, PartitionSpec as P

from repro.core.ep import (EPSpec, dispatch_combine_ht, dispatch_combine_ll,
                           moe_ref)
from repro.kernels.ref import grouped_swiglu_ref


def _mesh11():
    return jax.make_mesh((1,), ("model",), axis_types=(AxisType.Auto,))


def _run(mode, spec, x, ti, tw, wg, wu, wd, mesh):
    fn = dispatch_combine_ll if mode == "ll" else dispatch_combine_ht

    def island(x, ti, tw, wg, wu, wd):
        r = fn(spec, x, ti, tw, lambda t: grouped_swiglu_ref(t, wg, wu, wd))
        return r.out, r.aux["dropped"]

    return jax.jit(jax.shard_map(
        island, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P()), check_vma=False))(x, ti, tw, wg, wu, wd)


@pytest.mark.parametrize("mode", ["ll", "ht"])
@pytest.mark.parametrize("e,k,t", [(8, 2, 32), (4, 3, 16), (16, 1, 64)])
def test_matches_oracle_single_shard(mode, e, k, t):
    d, f = 16, 24
    key = jax.random.PRNGKey(e * 100 + k)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d))
    ti = jax.random.randint(ks[1], (t, k), 0, e).astype(jnp.int32)
    tw = jax.nn.softmax(jax.random.normal(ks[2], (t, k)), -1)
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[4], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[5], (e, f, d)) * 0.2
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, chunks=2 if mode == "ht" else 1,
                  dtype=jnp.float32)
    out, dropped = _run(mode, spec, x, ti, tw, wg, wu, wd, _mesh11())
    ref = moe_ref(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert float(dropped) == 0.0


def test_capacity_drops_counted_under_skew():
    """All tokens to expert 0 with a tight capacity -> drops > 0, and kept
    tokens still combine correctly."""
    e, k, t, d, f = 8, 1, 64, 8, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, d))
    ti = jnp.zeros((t, k), jnp.int32)
    tw = jnp.ones((t, k))
    wg = jnp.ones((e, d, f)) * 0.1
    wu = jnp.ones((e, d, f)) * 0.1
    wd = jnp.ones((e, f, d)) * 0.1
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=1.0, dtype=jnp.float32)
    out, dropped = _run("ll", spec, x, ti, tw, wg, wu, wd, _mesh11())
    assert float(dropped) > 0.0
    # dropped tokens produce zero output, kept ones match the oracle
    ref = np.asarray(moe_ref(x, ti, tw, wg, wu, wd))
    got = np.asarray(out)
    kept = np.abs(got).sum(-1) > 0
    assert 0 < kept.sum() < t
    np.testing.assert_allclose(got[kept], ref[kept], rtol=2e-4, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 4),
       e=st.sampled_from([4, 8, 16]))
def test_property_ht_equals_oracle(seed, k, e):
    """Any routing table: HT dedup+hierarchical == dense oracle."""
    t, d, f = 24, 8, 12
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d))
    ti = jax.random.randint(ks[1], (t, k), 0, e).astype(jnp.int32)
    tw = jax.nn.softmax(jax.random.normal(ks[2], (t, k)), -1)
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[4], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[5], (e, f, d)) * 0.2
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, dtype=jnp.float32)
    out, dropped = _run("ht", spec, x, ti, tw, wg, wu, wd, _mesh11())
    ref = moe_ref(x, ti, tw, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)
    assert float(dropped) == 0.0


def test_gradients_flow_through_dispatch():
    """EP dispatch/combine is differentiable; grads match the oracle's."""
    e, k, t, d, f = 4, 2, 16, 8, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d))
    ti = jax.random.randint(ks[1], (t, k), 0, e).astype(jnp.int32)
    tw = jax.nn.softmax(jax.random.normal(ks[2], (t, k)), -1)
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[4], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[5], (e, f, d)) * 0.2
    spec = EPSpec(axes=("model",), sizes=(1,), n_experts=e, top_k=k,
                  capacity_factor=8.0, dtype=jnp.float32)
    mesh = _mesh11()

    def loss_ep(wg, wu, wd):
        def island(x, ti, tw, wg, wu, wd):
            r = dispatch_combine_ht(spec, x, ti, tw,
                                    lambda tk: grouped_swiglu_ref(tk, wg, wu, wd))
            return r.out
        out = jax.shard_map(island, mesh=mesh,
                            in_specs=(P(),) * 6, out_specs=P(),
                            check_vma=False)(x, ti, tw, wg, wu, wd)
        return (out ** 2).sum()

    def loss_ref(wg, wu, wd):
        return (moe_ref(x, ti, tw, wg, wu, wd) ** 2).sum()

    g_ep = jax.grad(loss_ep, argnums=(0, 1, 2))(wg, wu, wd)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(wg, wu, wd)
    for a, b in zip(g_ep, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=1e-4)
