"""Serving-engine invariants (ISSUE 10, DESIGN.md §18): paged KV pool
bookkeeping, continuous-batching scheduler rules (no token without its KV
block, chunked prefill, FIFO admission), seeded arrival determinism, and
the end-to-end :class:`ServingEngine` — session-vs-naive schedule identity,
clean per-step quiesce, verifier-clean session slots, fp8 wire shrink and
the replicated-expert LoadBalancer path.
"""
import numpy as np
import pytest

from repro.serving import (EngineConfig, ServingEngine, bursty_arrivals,
                           load_curve_arrivals, poisson_arrivals)
from repro.serving.kv_cache import KVBlockPool
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.workload import Request


# ---------------------------------------------------------------------------
# KV block pool
# ---------------------------------------------------------------------------
def test_kv_pool_grow_release_invariants():
    pool = KVBlockPool(n_blocks=8, block_size=4)
    got = pool.grow(0, 5)                 # 5 tokens -> 2 blocks
    assert len(got) == 2 and pool.n_used == 2
    assert pool.grow(0, 7) == []          # covered, nothing new
    assert pool.blocks_needed(0, 9) == 1
    pool.grow(1, 4)
    pool.assert_consistent()
    # no double allocation across tables
    held = pool.block_table(0) + pool.block_table(1)
    assert len(held) == len(set(held))
    n = pool.release(0)
    assert n == 2 and pool.n_used == 1
    pool.assert_consistent()
    assert pool.allocs == 3 and pool.frees == 2 and pool.high_water == 3


def test_kv_pool_lifo_reuse_is_deterministic():
    pool = KVBlockPool(n_blocks=4, block_size=2)
    a = pool.grow(0, 4)
    pool.release(0)
    b = pool.grow(1, 4)
    # release pushes in reverse, so reuse hands back the same block order
    assert b == a


def test_kv_pool_exhaustion_raises():
    pool = KVBlockPool(n_blocks=2, block_size=2)
    pool.grow(0, 4)
    assert not pool.can_grow(1, 1)
    with pytest.raises(MemoryError):
        pool.grow(1, 1)
    pool.assert_consistent()


def test_kv_pool_consistency_catches_double_alloc():
    pool = KVBlockPool(n_blocks=4, block_size=2)
    pool.grow(0, 2)
    pool.tables[1] = [pool.tables[0][0]]  # corrupt: block in two tables
    with pytest.raises(AssertionError, match="two tables"):
        pool.assert_consistent()


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------
def test_arrivals_deterministic_and_ordered():
    a = poisson_arrivals(1000.0, 32, seed=5)
    b = poisson_arrivals(1000.0, 32, seed=5)
    assert a == b                         # frozen dataclasses, bit-equal
    ts = [r.arrival_us for r in a]
    assert ts == sorted(ts) and ts[0] > 0
    assert poisson_arrivals(1000.0, 32, seed=6) != a


def test_bursty_arrivals_cluster_but_keep_mean():
    n = 64
    br = bursty_arrivals(2000.0, n, seed=1, burst_factor=4.0, burst_len=8)
    ts = np.asarray([r.arrival_us for r in br])
    gaps = np.diff(ts)
    # in-burst gaps are ~4x shorter than the mean gap; the inter-burst
    # gaps carry the balance, so the overall mean stays near 1/rate
    mean_gap = 1e6 / 2000.0
    in_burst = np.concatenate([gaps[i:i + 7] for i in range(0, len(gaps), 8)])
    assert np.median(in_burst) < 0.5 * mean_gap
    assert 0.5 * mean_gap < gaps.mean() < 2.0 * mean_gap


def test_load_curve_arrivals_respect_segments():
    reqs = load_curve_arrivals([(10_000.0, 2000.0), (10_000.0, 0.0),
                                (10_000.0, 2000.0)], seed=2)
    ts = [r.arrival_us for r in reqs]
    assert ts == sorted(ts)
    assert not [t for t in ts if 10_000.0 <= t < 20_000.0]  # idle segment
    assert [t for t in ts if t < 10_000.0] and [t for t in ts if t >= 20_000.0]


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def _sched(token_budget=16, prefill_chunk=8, n_blocks=64, block_size=4):
    pool = KVBlockPool(n_blocks, block_size)
    return Scheduler(SchedulerConfig(token_budget, prefill_chunk), pool), pool


def test_scheduler_chunked_prefill_then_decode():
    sched, pool = _sched(token_budget=16, prefill_chunk=8)
    sched.add(Request(0, 0.0, prompt_len=20, max_new_tokens=3))
    # chunked prefill: 8 + 8 + 4 tokens, never exceeding the chunk
    for want in (8, 8, 4):
        mb = sched.schedule(0.0)
        (s,) = mb.slices
        assert s.kind == "prefill" and s.n_tokens == want
        # no token scheduled without its block: table covers the new span
        assert len(pool.block_table(0)) * pool.block_size >= s.start + want
        sched.complete_step(mb, 1.0)
    st = sched.running[0]
    assert st.prefilled == 20 and st.generated == 1      # first tok w/ last chunk
    assert st.first_token_us == 1.0
    # then pure decode until max_new_tokens
    mb = sched.schedule(2.0)
    (s,) = mb.slices
    assert s.kind == "decode" and s.n_tokens == 1 and s.start == 20
    sched.complete_step(mb, 3.0)
    mb = sched.schedule(4.0)
    done = sched.complete_step(mb, 5.0)
    assert done == [0] and sched.counters["completed"] == 1
    assert pool.n_used == 0               # eviction returned every block
    pool.assert_consistent()


def test_scheduler_decode_before_prefill_and_budget():
    sched, _ = _sched(token_budget=8, prefill_chunk=8)
    sched.add(Request(0, 0.0, prompt_len=4, max_new_tokens=4))
    sched.complete_step(sched.schedule(0.0), 1.0)        # 0 fully prefilled
    sched.add(Request(1, 0.0, prompt_len=8, max_new_tokens=2))
    mb = sched.schedule(2.0)
    kinds = [(s.rid, s.kind, s.n_tokens) for s in mb.slices]
    # decode of rid 0 first, remaining budget to rid 1's prefill
    assert kinds == [(0, "decode", 1), (1, "prefill", 7)]
    assert mb.n_tokens == 8               # budget exactly respected


def test_scheduler_admission_blocks_on_cache_pressure():
    sched, pool = _sched(token_budget=16, prefill_chunk=8, n_blocks=2,
                         block_size=4)
    sched.add(Request(0, 0.0, prompt_len=8, max_new_tokens=2))
    sched.add(Request(1, 0.0, prompt_len=8, max_new_tokens=2))
    mb = sched.schedule(0.0)
    # rid 0 takes both blocks; rid 1 must NOT be admitted (head-of-line)
    assert [s.rid for s in mb.slices] == [0]
    assert sched.counters["admission_blocked"] == 1
    assert len(sched.waiting) == 1
    sched.complete_step(mb, 1.0)
    # decode of rid 0 needs a 3rd block -> stalls; rid 1 still blocked
    assert sched.schedule(2.0) is None
    assert sched.counters["decode_stalls"] >= 1
    pool.assert_consistent()


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------
def _cfg(**over) -> EngineConfig:
    kw = dict(n_layers=2, n_experts=8, top_k=2, d_model=16, d_ff=32,
              ep_degree=4, token_budget=16, prefill_chunk=8, block_size=8,
              n_blocks=64, step_mode="pipelined", nonmoe_us=10.0, seed=0)
    kw.update(over)
    return EngineConfig(**kw)


def _reqs(n=6, rate=100_000.0, seed=11):
    return poisson_arrivals(rate, n, seed=seed, prompt_len=(6, 20),
                            gen_len=(3, 8))


def _run(**over):
    reqs = over.pop("reqs", None) or _reqs()
    eng = ServingEngine(_cfg(**over))
    eng.submit_all(reqs)
    stats = eng.run()
    assert stats["sched_completed"] == len(reqs), stats
    return eng, stats


def test_engine_end_to_end_and_determinism():
    eng, s1 = _run()
    _, s2 = _run()
    assert s1 == s2                       # bit-identical stats, same config
    assert s1["generated_tokens"] == sum(r.max_new_tokens for r in _reqs())
    assert s1["tokens_per_s"] > 0 and s1["ttft_p50_us"] > 0
    assert s1["kv_allocs"] == s1["kv_frees"]     # all blocks evicted
    assert eng.pool.n_used == 0
    assert eng.output_digest > 0


def test_engine_session_vs_naive_identical_schedule():
    rs = {m: _run(step_mode=m) for m in ("pipelined", "serial", "per_layer")}
    sched_keys = [k for k in rs["pipelined"][1] if k.startswith("sched_")]
    for key in sched_keys + ["kv_allocs", "kv_frees", "kv_high_water"]:
        assert rs["pipelined"][1][key] == rs["per_layer"][1][key], key
        assert rs["serial"][1][key] == rs["per_layer"][1][key], key
    # same routing + weights -> same math on every path
    for m in ("serial", "per_layer"):
        np.testing.assert_allclose(rs[m][0].output_digest,
                                   rs["pipelined"][0].output_digest,
                                   rtol=1e-5)
    # drain accounting: 1/microbatch pipelined, L/microbatch otherwise
    L = rs["pipelined"][0].cfg.n_layers
    assert rs["pipelined"][1]["drains"] == rs["pipelined"][1]["steps"]
    assert rs["serial"][1]["drains"] == rs["serial"][1]["steps"] * L
    assert rs["per_layer"][1]["drains"] == rs["per_layer"][1]["steps"] * L
    # the persistent session is never slower than per-call worlds
    assert rs["pipelined"][1]["elapsed_us"] < rs["per_layer"][1]["elapsed_us"]


def test_engine_clean_quiesce_and_verified_session_slots():
    from repro.analysis.verify import verify_session_slots
    eng, _ = _run()
    (world,) = eng.backend._sessions.values()
    assert not world.net.pending          # clean quiesce after every step
    findings = verify_session_slots(world._slots,
                                    n_channels=world.n_channels,
                                    counter_stride=world._counter_stride)
    assert not findings, findings


def test_engine_fp8_wire_dispatch_shrinks_bytes():
    _, s32 = _run()
    _, s8 = _run(wire_dtype="fp8")
    assert s8["sched_generated_tokens"] == s32["sched_generated_tokens"]
    assert 0 < s8["dispatch_wire_bytes"] < s32["dispatch_wire_bytes"]
    assert s8["dispatch_msgs"] == s32["dispatch_msgs"]
    assert s8["elapsed_us"] < s32["elapsed_us"]   # less wire time, same work


def test_engine_replicated_experts_load_balancer_path():
    reqs = _reqs(n=10, seed=13)
    eng, s = _run(reqs=reqs, replicas_per_expert=2, route_alpha=1.2,
                  n_experts=8, ep_degree=4)
    assert eng.lb is not None
    assert eng.spec.n_experts == 16       # physical slots
    assert s["rebalances"] >= 1           # zipf skew trips the threshold
    assert np.isfinite(eng.output_digest) and eng.output_digest > 0
    # replicated run is deterministic too
    eng2, s2 = _run(reqs=reqs, replicas_per_expert=2, route_alpha=1.2,
                    n_experts=8, ep_degree=4)
    assert s == s2 and eng.output_digest == eng2.output_digest


def test_engine_idle_gap_jumps_clock_to_arrival():
    # one early request, one far-future request: the engine must idle-jump
    reqs = [Request(0, 0.0, 4, 2), Request(1, 500_000.0, 4, 2)]
    eng = ServingEngine(_cfg())
    eng.submit_all(reqs)
    s = eng.run()
    assert s["sched_completed"] == 2
    assert s["elapsed_us"] > 500_000.0
    st = eng.sched.finished[1]
    assert st.first_token_us >= 500_000.0


def test_engine_stall_detection():
    # pool too small for even one prompt chunk -> hard error, not a hang
    eng = ServingEngine(_cfg(n_blocks=1, block_size=2, prefill_chunk=8))
    eng.submit(Request(0, 0.0, prompt_len=8, max_new_tokens=2))
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()
