"""ISSUE 1 microbenchmark: old (seed) Python-loop EPWorld dispatch command
generation vs the vectorized plan-layer path, at fig15 scale (~50k cmds).

The seed EPWorld.run computed slot assignment with an O(R*T*K) dict loop and
built one TransferCmd object (+ one 128-bit pack) per command.  The plan
layer computes the same slots/counts with one vectorized pass
(repro.core.plan.make_world_plan) and packs the whole command stream as an
(N, 4) uint32 array (repro.core.transport.fifo.pack_cmds) pushed through the
bulk FIFO path.  Acceptance: >= 5x at fig15 scale.
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.core.transport import EPWorld, NetConfig
from repro.core.transport.ep_executor import build_command_streams
from repro.core.transport.fifo import FLAG_FENCE, Op, TransferCmd

# fig15 pushes 50k descriptors; same command volume here: R*Tl*K = 50_000
R, Tl, K, E, D = 4, 3125, 4, 32, 64


def _routing(seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)


# ------------------------- seed path (verbatim loop structure) -------------
def gen_seed(top_idx: np.ndarray, capacity: int, n_channels: int = 8):
    """The seed EPWorld.run dispatch path: dict-based slot assignment, then
    one TransferCmd object + pack per write and per fence."""
    eps = E // R
    tb = D * 4
    send0, recv0 = 0, Tl * tb
    slot_of = np.zeros((R, Tl, K), np.int32)
    counts: dict[tuple[int, int], int] = {}
    for r in range(R):
        for t in range(Tl):
            for k in range(K):
                e = int(top_idx[r, t, k])
                c = counts.get((r, e), 0)
                counts[(r, e)] = c + 1
                slot_of[r, t, k] = c
    out = []
    for r in range(R):
        for t in range(Tl):
            for k in range(K):
                e = int(top_idx[r, t, k])
                dst, el = e // eps, e % eps
                dst_off = recv0 + ((r * eps + el) * capacity
                                   + int(slot_of[r, t, k])) * tb
                # expert-keyed write channel (matches the shipped stream;
                # the coalescer needs one bucket's writes on one channel)
                ch = e % n_channels
                out.append(TransferCmd(
                    op=Op.WRITE, dst_rank=dst, channel=ch,
                    src_off=send0 + t * tb, dst_off=dst_off,
                    length=tb).pack())
        for e in range(E):
            c = counts.get((r, e), 0)
            if not c:
                continue
            dst, el = e // eps, e % eps
            # fence descriptor: src_off carries the full 32-bit write count;
            # dst_off the wide guard id (receivers key guards by registered
            # address ranges — no expert slot in `value`; see ISSUE 4)
            out.append(TransferCmd(
                op=Op.ATOMIC, dst_rank=dst, channel=e % n_channels,
                src_off=c, dst_off=r * eps + el, length=0,
                flags=FLAG_FENCE).pack())
    return np.stack(out)


# ------------------------- plan path (vectorized) --------------------------
def gen_plan(top_idx: np.ndarray, capacity: int, n_channels: int = 8):
    """The shipped path: exactly what EPWorld.run executes."""
    eps = E // R
    tb = D * 4
    send0, recv0 = 0, Tl * tb
    ret0 = recv0 + R * eps * capacity * tb
    cs = build_command_streams(top_idx, E, eps, capacity, tb, n_channels,
                               send0, recv0, ret0)
    return np.concatenate([cs.writes, cs.fences])


def _time(fn, *args, iters=5):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6        # median, us


def main():
    ti = _routing()
    cap = Tl * K
    # correctness first: both generators must produce the same command set
    a, b = gen_seed(ti, cap), gen_plan(ti, cap)
    assert a.shape == b.shape
    order_a = np.lexsort(a.T)
    order_b = np.lexsort(b.T)
    np.testing.assert_array_equal(a[order_a], b[order_b])

    n_cmds = len(a)
    t_seed = _time(gen_seed, ti, cap, iters=3)
    t_plan = _time(gen_plan, ti, cap)
    emit(f"bench_plan/seed_loop_gen/cmds={n_cmds}", t_seed,
         f"{n_cmds / t_seed:.2f}cmds_per_us")
    emit(f"bench_plan/vectorized_gen/cmds={n_cmds}", t_plan,
         f"{n_cmds / t_plan:.2f}cmds_per_us")
    emit("bench_plan/speedup", t_seed / t_plan,
         f"{t_seed / t_plan:.1f}x (acceptance: >=5x)")

    # context: full EPWorld.run at a smaller (protocol-complete) scale
    rng = np.random.default_rng(0)
    Rs, Ts, Ks, Ds, Fs, Es = 4, 256, 4, 64, 64, 8
    x = rng.standard_normal((Rs, Ts, Ds)).astype(np.float32)
    ti2 = rng.integers(0, Es, size=(Rs, Ts, Ks)).astype(np.int32)
    tw = rng.random((Rs, Ts, Ks)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((Es, Ds, Fs)) * 0.1).astype(np.float32)
    wu = (rng.standard_normal((Es, Ds, Fs)) * 0.1).astype(np.float32)
    wd = (rng.standard_normal((Es, Fs, Ds)) * 0.1).astype(np.float32)

    def full_run():
        w = EPWorld(n_ranks=Rs, n_experts=Es, top_k=Ks, d=Ds,
                    capacity=Ts * Ks, net_cfg=NetConfig(mode="srd", seed=1))
        return w.run(x, ti2, tw, wg, wu, wd)

    out = full_run()
    ref = EPWorld.oracle(x, ti2, tw, wg, wu, wd)
    assert np.allclose(out, ref, rtol=1e-4, atol=1e-5)
    emit(f"bench_plan/epworld_run_e2e/cmds={Rs * Ts * Ks * 2}",
         _time(full_run, iters=3), "dispatch+combine+experts, srd")


if __name__ == "__main__":
    main()
