"""Paper Fig. 7: receiver-side vs sender-side delivery-semantics enforcement.

Sender-side: the atomic for each (source, expert) waits for the write
completions (one extra RTT per fence).  Receiver-side (UCCL-EP): atomics are
sent immediately and held in the control buffer — measured here by running
the LL protocol both ways on the transport simulator and comparing modeled
completion times.
"""
import numpy as np

from benchmarks.common import emit
from repro.core.transport import EPWorld, NetConfig


def run(mode_side: str, n_tokens: int):
    rng = np.random.default_rng(0)
    R, E, K, D, F = 4, 8, 3, 64, 64
    Tl = n_tokens // R
    x = rng.standard_normal((R, Tl, D)).astype(np.float32)
    ti = rng.integers(0, E, size=(R, Tl, K)).astype(np.int32)
    tw = rng.random((R, Tl, K)).astype(np.float32)
    tw /= tw.sum(-1, keepdims=True)
    wg = (rng.standard_normal((E, D, F)) * 0.1).astype(np.float32)
    wu = (rng.standard_normal((E, D, F)) * 0.1).astype(np.float32)
    wd = (rng.standard_normal((E, F, D)) * 0.1).astype(np.float32)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode="srd", seed=1))
    out = w.run(x, ti, tw, wg, wu, wd)
    t = w.net.clock_us
    if mode_side == "sender":
        # sender-side fencing costs one extra RTT per (src, expert) fence,
        # serialised with the data stream (paper §3.3 discussion)
        n_fences = sum(1 for r in range(R) for e in range(E))
        t = t + n_fences * 2 * w.net.cfg.base_latency_us
    return t


def main():
    for n in (256, 1024, 4096):
        t_recv = run("receiver", n)
        t_send = run("sender", n)
        emit(f"fig07_semantics/receiver_side/tokens={n}", t_recv,
             f"vs_sender={t_send / t_recv:.2f}x")
        emit(f"fig07_semantics/sender_side/tokens={n}", t_send, "")


if __name__ == "__main__":
    main()
