"""Paper Fig. 7: receiver-side vs sender-side delivery-semantics enforcement.

Sender-side: the atomic for each (source, expert) waits for the write
completions (one extra RTT per fence).  Receiver-side (UCCL-EP): atomics are
sent immediately and held in the control buffer — measured here by running
the LL protocol both ways on the transport simulator and comparing modeled
completion times.
"""
from benchmarks.common import emit
from repro.core.transport import EPWorld, NetConfig


def run(mode_side: str, n_tokens: int, protocol: str = "ll"):
    from benchmarks.common import make_ep_problem

    R, E, K, D, F = 4, 8, 3, 64, 64
    Tl = n_tokens // R
    x, ti, tw, wg, wu, wd = make_ep_problem(0, R, E, K, D, F, Tl)
    w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F, capacity=Tl * K,
                net_cfg=NetConfig(mode="srd", seed=1))
    if protocol == "ht":
        w.run_ht(x, ti, tw, wg, wu, wd, n_chunks=4)
    else:
        w.run(x, ti, tw, wg, wu, wd)
    t = w.net.clock_us
    if mode_side == "sender":
        # sender-side fencing costs one extra RTT per (src, expert) fence,
        # serialised with the data stream (paper §3.3 discussion)
        n_fences = sum(1 for r in range(R) for e in range(E))
        t = t + n_fences * 2 * w.net.cfg.base_latency_us
    return t, w.timeline


def main():
    for n in (256, 1024, 4096):
        t_recv, tl = run("receiver", n)
        t_send, _ = run("sender", n)
        emit(f"fig07_semantics/receiver_side/tokens={n}", t_recv,
             f"vs_sender={t_send / t_recv:.2f}x;"
             f"overlap_us={tl['overlap_us']:.2f}")
        emit(f"fig07_semantics/sender_side/tokens={n}", t_send, "")
        t_ht, tl_ht = run("receiver", n, protocol="ht")
        emit(f"fig07_semantics/receiver_side_ht/tokens={n}", t_ht,
             f"vs_ll={t_recv / t_ht:.2f}x;"
             f"overlap_us={tl_ht['overlap_us']:.2f}")


if __name__ == "__main__":
    main()
