"""Paper Figs. 8/9/10/12 + Fig. 4: dispatch+combine latency vs #tokens for
LL / HT / nccl_bulk baselines on an 8-device CPU mesh (EP8), plus modeled
bytes-on-wire (derived column) showing dedup + hierarchical-reduce savings.

Run via ``python -m benchmarks.run`` (it spawns this with 8 devices).
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import repro.compat  # noqa: F401  jax version shims
from jax.sharding import AxisType, PartitionSpec as P

from benchmarks.common import emit, timeit
from benchmarks.ep_baselines import moe_nccl_bulk
from repro.core.ep import EPSpec, dispatch_combine_ht, dispatch_combine_ll
from repro.kernels.ref import grouped_swiglu_ref

E, K, D, F = 32, 6, 256, 128


def build(mesh, axes, mode, n_tokens_global, chunks=1, wire_dtype="fp32"):
    sizes = tuple(mesh.shape[a] for a in axes)
    spec = EPSpec(axes=axes, sizes=sizes, n_experts=E, top_k=K,
                  capacity_factor=2.0, chunks=chunks, dtype=jnp.bfloat16,
                  wire_dtype=wire_dtype)
    ep_p = axes if len(axes) > 1 else axes[0]

    def island(x, ti, tw, wg, wu, wd, with_aux):
        fn = {"ll": dispatch_combine_ll, "ht": dispatch_combine_ht}.get(mode)
        if fn is None:
            out = moe_nccl_bulk(spec, x, ti, tw, wg, wu, wd)
            return (out, jnp.float32(0.0), jnp.float32(1.0)) if with_aux \
                else out
        # occupancy-carrying expert_fn contract; the jnp ref needs no mask
        # (EP buffers pad with exact zeros), the kernel paths skip the rows
        r = fn(spec, x, ti, tw,
               lambda t, c=None: grouped_swiglu_ref(t, wg, wu, wd))
        if not with_aux:
            return r.out
        ax = axes if len(axes) > 1 else axes[0]
        return (r.out, jax.lax.pmean(r.aux["dropped"], ax),
                jax.lax.pmean(jnp.float32(r.aux["occupancy"]), ax))

    in_specs = (P(axes), P(axes), P(axes), P(ep_p, None, None),
                P(ep_p, None, None), P(ep_p, None, None))
    # the timed function returns only `out` (the aux pmean collectives are
    # dead-code-eliminated, keeping the timing comparable across PRs); the
    # aux scalars for the derived column come from one separate call
    f = jax.jit(jax.shard_map(
        partial(island, with_aux=False), mesh=mesh, in_specs=in_specs,
        out_specs=P(axes), check_vma=False))
    f_aux = jax.jit(jax.shard_map(
        partial(island, with_aux=True), mesh=mesh, in_specs=in_specs,
        out_specs=(P(axes), P(), P()), check_vma=False))
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (n_tokens_global, D), jnp.bfloat16)
    ti = jax.random.randint(ks[1], (n_tokens_global, K), 0, E).astype(jnp.int32)
    tw = jax.nn.softmax(jax.random.normal(ks[2], (n_tokens_global, K)), -1)
    tw = tw.astype(jnp.bfloat16)
    wg = (jax.random.normal(ks[3], (E, D, F)) * 0.1).astype(jnp.bfloat16)
    wu = (jax.random.normal(ks[4], (E, D, F)) * 0.1).astype(jnp.bfloat16)
    wd = (jax.random.normal(ks[5], (E, F, D)) * 0.1).astype(jnp.bfloat16)
    args = (x, ti, tw, wg, wu, wd)

    def run():
        jax.block_until_ready(f(*args))

    def aux():
        _, dropped, occ = f_aux(*args)
        return float(dropped), float(occ)
    run.aux = aux
    return run


def wire_bytes_model(n_tokens, mode, P_ep=8, pods=2, wire_dtype="fp32"):
    """Modeled inter-shard payload bytes (dispatch+combine), global.

    Compressed wire dtypes shrink the *dispatch* leg to the wire-row size
    (quantized bytes + inline fp32 scales); the combine leg stays full
    precision (the fp32-accumulation contract, DESIGN.md §14)."""
    from repro.core.plan import wire_layout
    tok = D * 2
    disp = tok if wire_dtype == "fp32" else wire_layout(D, wire_dtype).token_bytes
    if mode == "nccl":
        return n_tokens * tok * (P_ep - 1) * 2          # all-gather + psum
    if mode == "ll":
        return n_tokens * K * (disp + tok)              # per choice, both ways
    # ht: dedup per shard group + one combined return per (token, group)
    frac = 1.0 - (1.0 - 1.0 / P_ep) ** K
    groups_hit = P_ep * frac
    return int(n_tokens * groups_hit * (disp + tok))


def main():
    mesh = jax.make_mesh((8,), ("model",), axis_types=(AxisType.Auto,))
    for n in (128, 512, 2048, 8192):
        for mode in ("ll", "ht", "nccl"):
            try:
                fn = build(mesh, ("model",), mode, n,
                           chunks=2 if mode == "ht" and n >= 512 else 1)
                us = timeit(fn, warmup=2, iters=5)
                dropped, occ = fn.aux()
            except Exception as e:  # noqa: BLE001
                emit(f"fig08_dispatch_combine/{mode}/tokens={n}", float("nan"),
                     f"error:{type(e).__name__}")
                continue
            wb = wire_bytes_model(n, mode)
            emit(f"fig08_dispatch_combine/{mode}/tokens={n}", us,
                 f"wire_bytes={wb},occupancy={occ:.3f},dropped={dropped:.4f}")
    # compression columns: fp8/int8 wire dispatch on the LL path (the
    # decode-latency regime compression targets); derived shows the modeled
    # payload reduction vs the fp32 row alongside the measured time
    for n in (512, 2048):
        wb32 = wire_bytes_model(n, "ll")
        for wdt in ("fp8", "int8"):
            try:
                fn = build(mesh, ("model",), "ll", n, wire_dtype=wdt)
                us = timeit(fn, warmup=2, iters=5)
                dropped, occ = fn.aux()
            except Exception as e:  # noqa: BLE001
                emit(f"fig08_dispatch_combine/ll_{wdt}/tokens={n}",
                     float("nan"), f"error:{type(e).__name__}")
                continue
            wb = wire_bytes_model(n, "ll", wire_dtype=wdt)
            emit(f"fig08_dispatch_combine/ll_{wdt}/tokens={n}", us,
                 f"wire_bytes={wb},payload_reduction={wb32 / wb:.2f}x,"
                 f"occupancy={occ:.3f},dropped={dropped:.4f}")
    # two-level (pod x model) HT: the hierarchical/dedup path (Fig. 12 analog)
    mesh2 = jax.make_mesh((2, 4), ("pod", "model"),
                          axis_types=(AxisType.Auto,) * 2)
    for n in (512, 2048):
        fn = build(mesh2, ("pod", "model"), "ht", n, chunks=2)
        us = timeit(fn, warmup=2, iters=5)
        dropped, occ = fn.aux()
        emit(f"fig08_dispatch_combine/ht2level/tokens={n}", us,
             f"hierarchical+dedup,occupancy={occ:.3f},dropped={dropped:.4f}")


if __name__ == "__main__":
    main()
