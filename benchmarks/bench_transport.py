"""ISSUE 5 microbenchmark: the batched proxy→network→receiver fast path.

Three stages of the transport hot path at fig15 scale (50k descriptors),
scalar (PR 4) vs columnar/coalesced (this PR), all in one session so the
A/B is apples-to-apples on this machine:

- **proxy drain**: ``Proxy.drain_inline`` consuming pre-pushed FIFO rings —
  per-row ``TransferCmd.unpack`` + per-message ``Network.send`` vs the
  columnar ``_execute_batch`` (vectorized decode/seq/imm, write coalescing,
  one ``send_batch`` per ring batch).  Acceptance: columnar >= 5x.
- **wire delivery**: draining the scheduled event heap through
  ``deliver_ready`` into the receiving proxy (guard resolution + seq
  bookkeeping), scalar messages vs coalesced runs.
- **deterministic counters** on a fig08-shaped EP workload (E=32, K=6
  routing over the substrate): delivered wire messages with and without
  coalescing, exact-gated by ``benchmarks/run.py --compare`` — the
  coalescing win recorded machine-independently.
"""
import time

import numpy as np

from benchmarks.common import emit, make_ep_problem
from repro.core.transport import EPWorld, NetConfig, Network, Op, Proxy, \
    SymmetricMemory, pack_cmds
from repro.core.transport.fifo import FLAG_FENCE

N_CMDS = 50_000
N_BUCKETS = 64          # receive buckets (one fence guard each)
TB = 64                 # bytes per write
N_CHANNELS = 8


def _stream():
    """A bucket-ordered LL-shaped command stream: N_CMDS writes landing
    contiguously per bucket (the coalescer's food), one fence per bucket."""
    per = N_CMDS // N_BUCKETS
    i = np.arange(N_BUCKETS * per)
    bucket = i // per
    writes = pack_cmds(int(Op.WRITE), 1, bucket % N_CHANNELS,
                       (i % per) * TB, N_CMDS * TB + i * TB, TB, 0)
    fences = pack_cmds(int(Op.ATOMIC), 1,
                       np.arange(N_BUCKETS) % N_CHANNELS, per,
                       np.arange(N_BUCKETS), 0, 0, FLAG_FENCE)
    return np.concatenate([writes, fences]), per


def _world(columnar):
    net = Network(NetConfig(mode="srd", seed=0), 2, threadsafe=False)
    mem_bytes = 2 * N_CMDS * TB + 4096
    p0 = Proxy(0, net, SymmetricMemory.create(mem_bytes),
               n_channels=N_CHANNELS, k_max_inflight=8192,
               columnar=columnar)
    p1 = Proxy(1, net, SymmetricMemory.create(mem_bytes),
               n_channels=N_CHANNELS, columnar=columnar)
    per = N_CMDS // N_BUCKETS
    p1.register_table(N_CMDS * TB + np.arange(N_BUCKETS) * per * TB,
                      per * TB, np.arange(N_BUCKETS))
    return net, p0, p1


def bench_drain(columnar, iters=5):
    """Median drain+send / delivery time for the full stream."""
    words, _ = _stream()
    drains, delivers = [], []
    for _ in range(iters):
        net, p0, p1 = _world(columnar)
        for c in range(N_CHANNELS):             # pre-fill the rings
            rows = words[np.asarray(words[:, 0] >> 16 & 0xFF) == c]
            assert p0.channels[c].try_push_batch(rows) == len(rows)
        t0 = time.perf_counter()
        p0.drain_inline()
        t1 = time.perf_counter()
        while net.deliver_ready():
            pass
        t2 = time.perf_counter()
        drains.append(t1 - t0)
        delivers.append(t2 - t1)
        assert net.pending == 0
        for cb in p1.ctrl.values():
            assert cb.n_held == 0
    drains.sort(), delivers.sort()
    return (drains[len(drains) // 2] * 1e6,
            delivers[len(delivers) // 2] * 1e6, net)


def bench_counters():
    """fig08-shaped substrate workload (E=32, K=6): delivered wire-message
    count with and without write coalescing.  Event-clock counters of a
    seeded inline run — exactly reproducible, exact-gated in compare."""
    R, E, K, D, F, Tl = 4, 32, 6, 64, 64, 128
    x, ti, tw, wg, wu, wd = make_ep_problem(3, R, E, K, D, F, Tl)
    out = {}
    for tag, coal in (("scalar_msgs", False), ("coalesced_msgs", True)):
        w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F,
                    capacity=Tl * K, net_cfg=NetConfig(mode="srd", seed=2),
                    coalesce=coal)
        ref = w.run(x, ti, tw, wg, wu, wd)
        assert np.isfinite(ref).all()
        out[tag] = w.net
    return out


def bench_compression():
    """ISSUE 6: compressed LL dispatch at D=1024 (the regime where the
    per-128-feature scale overhead is amortized).  A/B over wire dtypes on
    the identical routing table; event-clock counters are deterministic and
    exact-gated.  Floor: fp8 payload reduction >= 3.5x (4096 fp32 bytes vs
    1024 + 32 scale bytes = 3.88x by construction — the assert catches
    layout regressions, e.g. scales going per-64 or payloads padding)."""
    R, E, K, D, F, Tl = 2, 8, 2, 1024, 16, 16
    x, ti, tw, wg, wu, wd = make_ep_problem(6, R, E, K, D, F, Tl)
    out = {}
    for wdt in ("fp32", "fp8", "int8"):
        w = EPWorld(n_ranks=R, n_experts=E, top_k=K, d=D, f=F,
                    capacity=Tl * K, net_cfg=NetConfig(mode="srd", seed=4),
                    wire_dtype=wdt)
        res = w.run(x, ti, tw, wg, wu, wd)
        assert np.isfinite(res).all()
        out[wdt] = w
    return out


def main():
    n_total = N_CMDS + N_BUCKETS
    t_scalar, d_scalar, _ = bench_drain(columnar=False, iters=3)
    t_col, d_col, net = bench_drain(columnar=True)
    emit(f"bench_transport/proxy_drain/scalar/cmds={n_total}", t_scalar,
         f"{n_total / t_scalar:.2f}cmds_per_us")
    # the speedup ratio rides the derived column (a standalone ratio row
    # would make the 1.25x gate flag *improvements* as regressions)
    emit(f"bench_transport/proxy_drain/columnar/cmds={n_total}", t_col,
         f"{n_total / t_col:.2f}cmds_per_us;"
         f"coalesced_msgs={net.coalesced_msgs};"
         f"speedup={t_scalar / t_col:.1f}x (acceptance: >=5x)")
    emit(f"bench_transport/wire_deliver/scalar/cmds={n_total}", d_scalar,
         "per-message on_write")
    emit(f"bench_transport/wire_deliver/columnar/cmds={n_total}", d_col,
         f"{d_scalar / d_col:.1f}x vs scalar (vectorized guard resolve)")
    # same-session regression gate: absolute wall clock flaps with host
    # load (the compare gate skips these rows), but the scalar/columnar
    # ratio is measured in one process and load cancels out — a drop
    # below 4x means the columnar drain itself regressed (acceptance 5x;
    # observed 7.6-9x).
    assert t_scalar / t_col >= 4.0, \
        f"columnar proxy drain regressed: {t_scalar / t_col:.1f}x < 4x"

    nets = bench_counters()
    scalar, coal = nets["scalar_msgs"], nets["coalesced_msgs"]
    assert scalar.bytes_moved == coal.bytes_moved
    emit("bench_transport/counters/fig08ll/delivered_scalar",
         scalar.delivered, "exact-gated")
    emit("bench_transport/counters/fig08ll/delivered_coalesced",
         coal.delivered,
         f"exact-gated;reduction={scalar.delivered / coal.delivered:.1f}x")
    emit("bench_transport/counters/fig08ll/coalesced_msgs",
         coal.coalesced_msgs,
         f"exact-gated;coalesced_writes={coal.coalesced_writes}")
    emit("bench_transport/counters/fig08ll/bytes_moved", coal.bytes_moved,
         "exact-gated;identical scalar vs coalesced")

    worlds = bench_compression()
    p32 = worlds["fp32"].timeline["dispatch_payload_bytes"]
    t32 = worlds["fp32"].net.clock_us
    for wdt in ("fp32", "fp8", "int8"):
        w = worlds[wdt]
        pq = w.timeline["dispatch_payload_bytes"]
        emit(f"bench_transport/counters/compression/{wdt}_payload_bytes",
             pq, f"exact-gated;wire_bytes={w.timeline['dispatch_wire_bytes']}"
             f";reduction={p32 / pq:.2f}x")
        emit(f"bench_transport/counters/compression/{wdt}_clock_us",
             w.net.clock_us,
             f"exact-gated event clock;vs_fp32={t32 / w.net.clock_us:.2f}x")
    # acceptance floor: fp8 at D=1024 moves >= 3.5x fewer payload bytes AND
    # the modeled end-to-end completion time improves (same-session A/B on
    # the deterministic event clock — host load cannot flap this)
    red = p32 / worlds["fp8"].timeline["dispatch_payload_bytes"]
    assert red >= 3.5, f"fp8 payload reduction {red:.2f}x < 3.5x floor"
    assert worlds["fp8"].net.clock_us < t32, \
        "fp8 dispatch did not improve event-clock completion time"


if __name__ == "__main__":
    main()
