"""Benchmark harness (assignment deliverable d): one entry per paper figure.
Prints ``name,us_per_call,derived`` CSV and writes the same results as
machine-readable JSON (``BENCH_results.json`` by default) so the perf
trajectory is trackable across PRs.  Host-only benchmarks run in-process
(1 device); device benchmarks run in subprocesses with 8 fake CPU devices.

  PYTHONPATH=src python -m benchmarks.run [--only figXX] [--json PATH]
"""
import argparse
import json
import math
import os
import sys

from benchmarks.common import run_subprocess_bench

HOST_BENCHES = [
    "benchmarks.fig04_token_vs_bulk",
    "benchmarks.fig07_semantics_side",
    "benchmarks.fig15_fifo",
    "benchmarks.fig17_proxy_threads",
    "benchmarks.bench_transport",
    # event-clock serving engine (deterministic, 1 process)
    "benchmarks.fig13_serving",
]
DEVICE_BENCHES = [
    "benchmarks.fig08_dispatch_combine",
    "benchmarks.bench_kernels",
    "benchmarks.fig16_ep_sweep",
    "benchmarks.fig14_training",
]

# --compare gate: flag a regression when the new timing exceeds the baseline
# by >25% plus a per-entry absolute slack.  The slack is proportional for
# micro-benchmarks (which jitter far more than 25% run-to-run on shared CI
# hosts) but capped so large benchmarks keep a tight gate: an 8us FIFO
# micro tolerates ~2x, a 300ms mesh benchmark only +100us on top of 1.25x.
REGRESSION_RATIO = 1.25
REGRESSION_SLACK_US = 100.0
# Deterministic counter rows (messages delivered, bytes moved, coalesced
# messages, pcie reads — all on the seeded event clock, independent of host
# speed) are gated at EXACT equality: any drift means the transport changed
# behaviour, not that the machine was busy.
EXACT_PREFIXES = ("fig17_counters/", "bench_transport/counters/",
                  "fig16_ep_sweep/skew_clock/", "fig14_training/counters/",
                  "fig13_serving/counters/")
# Wall-clock rows that flap 1.0-1.7x between back-to-back runs of
# IDENTICAL code (real-thread benches contending for the host's cores;
# the bench_transport scalar-vs-columnar A/B pair under CI load), so any
# cross-session wall-clock ratio either cries wolf or catches nothing.
# They are excluded from the gate entirely; their compare signals are the
# exact counter rows above and bench_transport's own SAME-SESSION
# speedup-floor assert (load cancels out of a ratio measured in one
# process).  Everything else keeps the tight 1.25x ratio.
SKIP_PREFIXES = ("fig17_proxy_threads/", "bench_transport/proxy_drain/",
                 "bench_transport/wire_deliver/")


def _slack_us(old: float) -> float:
    return min(REGRESSION_SLACK_US, max(5.0, old))


def compare_results(results: dict, baseline: dict) -> list[str]:
    """Names whose us_per_call regressed vs the recorded baseline (only
    names present in both; non-finite entries are skipped).  Counter rows
    (EXACT_PREFIXES) must match exactly; SKIP_PREFIXES are not compared.
    Raises when the name intersection is empty — a silently-green gate
    that compared nothing (e.g. after a benchmark rename) is worse than a
    failure."""
    bad = []
    n_compared = 0
    for name in sorted(set(results) & set(baseline)):
        if name.startswith(SKIP_PREFIXES):
            continue
        new = results[name].get("us_per_call")
        old = baseline[name].get("us_per_call")
        exact = name.startswith(EXACT_PREFIXES)
        if not all(isinstance(v, (int, float)) and math.isfinite(v)
                   and (v >= 0 if exact else v > 0) for v in (new, old)):
            continue
        n_compared += 1
        if exact:
            if new != old:
                bad.append(f"{name}: counter {old:.0f} -> {new:.0f} "
                           "(exact-equality gate)")
        elif new > old * REGRESSION_RATIO + _slack_us(old):
            bad.append(f"{name}: {old:.1f}us -> {new:.1f}us "
                       f"({new / old:.2f}x)")
    if not n_compared:
        raise ValueError("perf gate compared 0 entries: no finite baseline "
                         "names match the run (renamed benchmarks?)")
    return bad


def parse_csv_lines(text: str) -> dict:
    """``name,us_per_call,derived`` lines -> {name: {us_per_call, derived}}.
    Lines that don't parse (subprocess noise, headers) are skipped."""
    out = {}
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] in ("", "name"):
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        out[parts[0]] = {"us_per_call": us,
                         "derived": parts[2] if len(parts) > 2 else ""}
    return out


def validate_results(results: dict) -> None:
    """Schema check used by the CI smoke step: at least one entry, every
    entry keyed by a non-empty name with a finite, positive us_per_call
    (exact-gated counter rows may legitimately be zero)."""
    assert isinstance(results, dict) and results, "no benchmark results"
    for name, entry in results.items():
        assert isinstance(name, str) and name, name
        assert isinstance(entry, dict), (name, entry)
        us = entry.get("us_per_call")
        assert isinstance(us, (int, float)) and math.isfinite(us) and \
            (us >= 0 if name.startswith(EXACT_PREFIXES) else us > 0), \
            (name, us)
        assert isinstance(entry.get("derived", ""), str), (name, entry)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substrings of module names to run")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="write results as JSON here ('' disables)")
    ap.add_argument("--compare", default="",
                    help="baseline JSON; exit nonzero when any us_per_call "
                         "regresses >25%% vs the recorded baseline")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results: dict = {}
    only = [tok for tok in args.only.split(",") if tok]
    for mod in HOST_BENCHES + DEVICE_BENCHES:
        if only and not any(tok in mod for tok in only):
            continue
        # every bench runs in a subprocess so the parent never initialises
        # jax with the wrong device count
        n_dev = 8 if mod in DEVICE_BENCHES else 1
        out = run_subprocess_bench(mod, n_devices=n_dev)
        sys.stdout.write(out)
        sys.stdout.flush()
        results.update(parse_csv_lines(out))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {len(results)} results to {args.json}",
              file=sys.stderr)
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        bad = compare_results(results, baseline)
        if bad:
            print("# PERF REGRESSIONS vs " + args.compare, file=sys.stderr)
            for line in bad:
                print("#   " + line, file=sys.stderr)
            # the committed baseline is absolute wall clock from one
            # machine; REPRO_BENCH_GATE=warn keeps the report without
            # failing CI on hosts of a different speed class
            if os.environ.get("REPRO_BENCH_GATE") != "warn":
                sys.exit(1)
            print("# (REPRO_BENCH_GATE=warn: not failing)", file=sys.stderr)
        else:
            print(f"# perf gate OK (all compared entries within "
                  f"{REGRESSION_RATIO:.2f}x of {args.compare})",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
