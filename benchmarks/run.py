"""Benchmark harness (assignment deliverable d): one entry per paper figure.
Prints ``name,us_per_call,derived`` CSV.  Host-only benchmarks run in-process
(1 device); device benchmarks run in subprocesses with 8 fake CPU devices.

  PYTHONPATH=src python -m benchmarks.run [--only figXX]
"""
import argparse
import sys

from benchmarks.common import run_subprocess_bench

HOST_BENCHES = [
    "benchmarks.fig04_token_vs_bulk",
    "benchmarks.fig07_semantics_side",
    "benchmarks.fig15_fifo",
    "benchmarks.fig17_proxy_threads",
]
DEVICE_BENCHES = [
    "benchmarks.fig08_dispatch_combine",
    "benchmarks.fig16_ep_sweep",
    "benchmarks.fig13_serving",
    "benchmarks.fig14_training",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for mod in HOST_BENCHES + DEVICE_BENCHES:
        if args.only and args.only not in mod:
            continue
        # every bench runs in a subprocess so the parent never initialises
        # jax with the wrong device count
        n_dev = 8 if mod in DEVICE_BENCHES else 1
        sys.stdout.write(run_subprocess_bench(mod, n_devices=n_dev))
        sys.stdout.flush()


if __name__ == "__main__":
    main()
