"""Benchmark harness (assignment deliverable d): one entry per paper figure.
Prints ``name,us_per_call,derived`` CSV and writes the same results as
machine-readable JSON (``BENCH_results.json`` by default) so the perf
trajectory is trackable across PRs.  Host-only benchmarks run in-process
(1 device); device benchmarks run in subprocesses with 8 fake CPU devices.

  PYTHONPATH=src python -m benchmarks.run [--only figXX] [--json PATH]
"""
import argparse
import json
import math
import sys

from benchmarks.common import run_subprocess_bench

HOST_BENCHES = [
    "benchmarks.fig04_token_vs_bulk",
    "benchmarks.fig07_semantics_side",
    "benchmarks.fig15_fifo",
    "benchmarks.fig17_proxy_threads",
]
DEVICE_BENCHES = [
    "benchmarks.fig08_dispatch_combine",
    "benchmarks.fig16_ep_sweep",
    "benchmarks.fig13_serving",
    "benchmarks.fig14_training",
]


def parse_csv_lines(text: str) -> dict:
    """``name,us_per_call,derived`` lines -> {name: {us_per_call, derived}}.
    Lines that don't parse (subprocess noise, headers) are skipped."""
    out = {}
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] in ("", "name"):
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        out[parts[0]] = {"us_per_call": us,
                         "derived": parts[2] if len(parts) > 2 else ""}
    return out


def validate_results(results: dict) -> None:
    """Schema check used by the CI smoke step: at least one entry, every
    entry keyed by a non-empty name with a finite, positive us_per_call."""
    assert isinstance(results, dict) and results, "no benchmark results"
    for name, entry in results.items():
        assert isinstance(name, str) and name, name
        assert isinstance(entry, dict), (name, entry)
        us = entry.get("us_per_call")
        assert isinstance(us, (int, float)) and math.isfinite(us) and us > 0, \
            (name, us)
        assert isinstance(entry.get("derived", ""), str), (name, entry)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="write results as JSON here ('' disables)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results: dict = {}
    for mod in HOST_BENCHES + DEVICE_BENCHES:
        if args.only and args.only not in mod:
            continue
        # every bench runs in a subprocess so the parent never initialises
        # jax with the wrong device count
        n_dev = 8 if mod in DEVICE_BENCHES else 1
        out = run_subprocess_bench(mod, n_devices=n_dev)
        sys.stdout.write(out)
        sys.stdout.flush()
        results.update(parse_csv_lines(out))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {len(results)} results to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
