"""Paper Fig. 14: training throughput (tokens/s + achieved FLOP/s) for the
HT EP path vs the dense bulk baseline on a reduced MoE model, 8 devices."""
import time

import jax

from benchmarks.common import emit
from repro.configs import get_config, reduced_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.distributed.sharding import make_dist_ctx
from repro.launch.mesh import make_bench_mesh
from repro.training.train_loop import HParams, init_state, make_train_step


def run(moe_mode: str, steps: int = 4, B: int = 16, S: int = 128):
    cfg = reduced_config(get_config("moonshot_v1_16b_a3b"), n_layers=2,
                         d_model=128, n_experts=8, vocab=1024)
    mesh = make_bench_mesh(len(jax.devices()), model=4)
    dist = make_dist_ctx(cfg, mesh)
    hp = HParams(moe_mode=moe_mode, loss_chunk=S)
    state = init_state(cfg, jax.random.PRNGKey(0), dist=dist)
    step = make_train_step(cfg, hp, dist)
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=B, seq_len=S, seed=0)
    state, m = step(state, synth_batch(dc, 0))       # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(1, steps + 1):
        state, m = step(state, synth_batch(dc, i))
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    toks = B * S * steps
    flops = 6 * cfg.active_param_count() * toks
    return toks / dt, flops / dt


def main():
    tput_ht, fl_ht = run("ht")
    tput_ref, fl_ref = run("ref")
    emit("fig14_training/uccl_ep_ht", 1e6 / tput_ht,
         f"tok_per_s={tput_ht:.0f} tflops={fl_ht/1e12:.3f} "
         f"vs_dense={tput_ht / tput_ref:.2f}x")
    emit("fig14_training/dense_baseline", 1e6 / tput_ref,
         f"tok_per_s={tput_ref:.0f} tflops={fl_ref/1e12:.3f}")


if __name__ == "__main__":
    main()
